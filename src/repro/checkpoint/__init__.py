from .engine import (CheckpointEngine, latest_step, manifest_path,
                     restore_sharded, save_sharded)

__all__ = ["CheckpointEngine", "save_sharded", "restore_sharded",
           "latest_step", "manifest_path"]
