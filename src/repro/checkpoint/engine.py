"""Sharded, fault-tolerant checkpoint engine.

Every byte flows through the traced I/O facades (``core.apis.shardio`` ->
``core.apis.posix``), so a Recorder session sees the full call chain with
depths, and -- because shard ``r`` of every array lands at offset
``global_offset + r * shard_bytes`` -- the trace compresses to a constant
size across hosts (the paper's Listing-3 pattern, our §5 experiments).

Layout of one checkpoint::

    <dir>/step_<N>.tmp/arrays.bin     all arrays, rank-sharded on dim 0
    <dir>/step_<N>.tmp/manifest.json  shapes, dtypes, offsets, crc32 per shard
    -> fsync + rename to <dir>/step_<N>   (atomic commit)

Fault tolerance:
  * atomic tmp+rename commit; readers only ever see complete checkpoints,
  * crc32 per (array, rank-slice), verified on restore,
  * ``latest_step`` skips trailing .tmp debris from crashed writers,
  * elastic restore: offsets are *global*, so a checkpoint written by N
    hosts restores on M hosts (each reads its own byte range),
  * keep-k garbage collection,
  * async snapshot thread (thread id visible in traces, paper §2.2).
"""

from __future__ import annotations

import json
import os
import threading
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..core.apis import posix, shardio
from ..core.comm import Comm, SoloComm


def _flat_with_names(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]

    def name(path) -> str:
        parts = []
        for p in path:
            parts.append(str(getattr(p, "key", getattr(p, "idx", ""))))
        return "/".join(parts)

    return [(name(p), leaf) for p, leaf in flat]


def _shard_range(n_rows: int, rank: int, nranks: int) -> Tuple[int, int]:
    """Row range of ``rank``'s shard (dim-0 block partitioning; the last
    rank takes the remainder)."""
    per = n_rows // nranks
    lo = rank * per
    hi = n_rows if rank == nranks - 1 else lo + per
    return lo, hi


def manifest_path(d: str) -> str:
    return os.path.join(d, "manifest.json")


def save_sharded(tree, ckpt_dir: str, step: int, rank: int = 0,
                 nranks: int = 1, comm: Optional[Comm] = None,
                 meta: Optional[Dict] = None, commit: bool = True) -> str:
    """Write ``rank``'s shards of every array. Rank 0 writes the manifest
    and commits. Returns the final checkpoint directory.

    ``commit=False`` defers the atomic rename (used when simulated ranks
    run sequentially in one process: writers go first, rank 0 commits)."""
    comm = comm or SoloComm()
    tmp = os.path.join(ckpt_dir, f"step_{step:08d}.tmp")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if rank == 0 or not os.path.isdir(tmp):
        posix.makedirs(tmp, 0o755)  # idempotent + race-safe across writers
    comm.barrier()
    data_path = os.path.join(tmp, "arrays.bin")
    fh = shardio.shard_open(data_path, 1)

    entries = []
    crcs: Dict[str, int] = {}
    offset = 0
    for name, leaf in _flat_with_names(tree):
        arr = np.asarray(leaf)
        nbytes = arr.nbytes
        n_rows = arr.shape[0] if arr.ndim else 1
        if arr.ndim >= 1 and n_rows >= nranks:
            lo, hi = _shard_range(n_rows, rank, nranks)
            row_bytes = nbytes // max(n_rows, 1)
            buf = np.ascontiguousarray(arr[lo:hi]).tobytes()
            shardio.shard_write_at(fh, buf, offset + lo * row_bytes)
        elif rank == 0:  # small / scalar arrays: rank 0 writes whole
            buf = arr.tobytes()
            shardio.shard_write_at(fh, buf, offset)
        else:
            buf = b""
        crcs[name] = zlib.crc32(buf)
        entries.append({"name": name, "dtype": str(arr.dtype),
                        "shape": list(arr.shape), "offset": offset,
                        "nbytes": nbytes})
        offset += nbytes
    shardio.shard_sync(fh)
    shardio.shard_close(fh)

    gathered = comm.gather(crcs)
    if rank == 0:
        manifest = {"step": step, "nranks": nranks, "total_bytes": offset,
                    "arrays": entries,
                    "crcs": {str(r): g for r, g in enumerate(gathered)},
                    "meta": meta or {}}
        mfh = shardio.shard_open(manifest_path(tmp), 1)
        shardio.shard_write_at(mfh, json.dumps(manifest).encode(), 0)
        shardio.shard_sync(mfh)
        shardio.shard_close(mfh)
    comm.barrier()
    if rank == 0 and commit:
        shardio.shard_commit(tmp, final)   # atomic rename
    comm.barrier()
    return final if commit else tmp


def restore_sharded(tree_shapes, ckpt_path: str, rank: int = 0,
                    nranks: int = 1, verify: bool = True):
    """Read this rank's shards (elastic: any nranks works for any writer
    count -- offsets are global).  ``tree_shapes``: pytree of arrays or
    ShapeDtypeStructs defining what to read."""
    mfh = shardio.shard_open(manifest_path(ckpt_path), 0)
    msize = posix.stat(manifest_path(ckpt_path))
    manifest = json.loads(shardio.shard_read_at(mfh, msize, 0))
    shardio.shard_close(mfh)
    by_name = {e["name"]: e for e in manifest["arrays"]}

    fh = shardio.shard_open(os.path.join(ckpt_path, "arrays.bin"), 0)
    out_leaves = []
    names = []
    for name, sds in _flat_with_names(tree_shapes):
        e = by_name[name]
        shape, dtype = tuple(e["shape"]), np.dtype(
            e["dtype"].replace("bfloat16", "V2"))
        want = tuple(sds.shape)
        if want != shape:
            raise ValueError(f"{name}: checkpoint shape {shape} != {want}")
        raw = shardio.shard_read_at(fh, e["nbytes"], e["offset"])
        arr = np.frombuffer(raw, dtype=np.uint8).copy()
        if str(e["dtype"]) == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16).reshape(shape)
        else:
            arr = arr.view(np.dtype(e["dtype"])).reshape(shape)
        out_leaves.append(arr)
        names.append(name)
    shardio.shard_close(fh)

    if verify:
        # recompute this rank's slice crc against the writer's record
        w_ranks = manifest["nranks"]
        for name, arr in zip(names, out_leaves):
            n_rows = arr.shape[0] if arr.ndim else 1
            if arr.ndim >= 1 and n_rows >= w_ranks:
                for r in range(w_ranks):
                    lo, hi = _shard_range(n_rows, r, w_ranks)
                    crc = zlib.crc32(np.ascontiguousarray(arr[lo:hi]).tobytes())
                    want = manifest["crcs"][str(r)].get(name)
                    if want is not None and crc != want:
                        raise IOError(
                            f"crc mismatch for {name} shard {r}: corrupt "
                            f"checkpoint {ckpt_path}")
            else:
                crc = zlib.crc32(arr.tobytes())
                want = manifest["crcs"]["0"].get(name)
                if want is not None and crc != want:
                    raise IOError(f"crc mismatch for {name}")

    treedef = jax.tree_util.tree_structure(tree_shapes)
    return jax.tree_util.tree_unflatten(treedef, out_leaves), manifest


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Largest committed step (ignores .tmp debris from crashes)."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            try:
                steps.append(int(d.split("_")[1]))
            except ValueError:
                continue
    return max(steps) if steps else None


class CheckpointEngine:
    """Keep-k, optionally-async checkpoint manager for the train loop."""

    def __init__(self, ckpt_dir: str, keep: int = 2, rank: int = 0,
                 nranks: int = 1, comm: Optional[Comm] = None,
                 async_save: bool = False):
        self.dir = ckpt_dir
        self.keep = keep
        self.rank = rank
        self.nranks = nranks
        self.comm = comm or SoloComm()
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def save(self, tree, step: int, meta: Optional[Dict] = None) -> None:
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot off device
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._save_and_gc, args=(host_tree, step, meta),
                name=f"ckpt-async-{step}")
            self._thread.start()
        else:
            self._save_and_gc(host_tree, step, meta)

    def _save_and_gc(self, tree, step: int, meta) -> None:
        save_sharded(tree, self.dir, step, self.rank, self.nranks,
                     self.comm, meta)
        if self.rank == 0:
            self._gc()

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.dir)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[: max(0, len(steps) - self.keep)]:
            d = os.path.join(self.dir, f"step_{s:08d}")
            for f in ("arrays.bin", "manifest.json"):
                p = os.path.join(d, f)
                if os.path.exists(p):
                    posix.unlink(p)
            posix.rmdir(d)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, tree_shapes):
        """(tree, manifest) of the newest valid checkpoint, or None.
        Falls back to older checkpoints if the newest fails crc."""
        self.wait()
        step = latest_step(self.dir)
        while step is not None:
            path = os.path.join(self.dir, f"step_{step:08d}")
            try:
                return restore_sharded(tree_shapes, path, self.rank,
                                       self.nranks)
            except Exception:
                older = [s for s in (latest_step(self.dir),) if s is not None]
                prev = sorted(
                    int(d.split("_")[1]) for d in os.listdir(self.dir)
                    if d.startswith("step_") and not d.endswith(".tmp"))
                prev = [s for s in prev if s < step]
                step = prev[-1] if prev else None
        return None
