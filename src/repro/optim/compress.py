"""Error-feedback int8 gradient compression for cross-pod reduction.

At 1000+-node scale the inter-pod links are the scarce resource; the
standard trick is hierarchical reduction -- reduce-scatter within a pod at
full precision, all-reduce *across* pods on int8-quantized gradients with
an error-feedback accumulator so quantization noise is unbiased over steps
(Seide et al., 1-bit SGD lineage).

``ef_int8_compress(g + err)`` -> (q, scale, new_err); the caller psums
``q`` over the pod axis and dequantizes.  Pure functions; the train loop
wires them into a ``shard_map`` over the "pod" axis (train.py), and the
collective-bytes saving shows up in the dry-run roofline term.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def ef_int8_compress(g: jax.Array, err: jax.Array,
                     scale: jax.Array = None
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Quantize (g + err) to int8.

    ``scale`` may be supplied externally (the collective path shares ONE
    scale across ranks via pmax -- int8 payloads from different ranks are
    only summable on a common scale).  Returns (q_int8, scale, new_err)
    with new_err = input - dequant(q).
    """
    x = g.astype(jnp.float32) + err
    if scale is None:
        amax = jnp.max(jnp.abs(x))
        scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, x - deq


def ef_int8_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_tree(grads, err_tree, axis_name: str):
    """Quantize+psum each leaf over ``axis_name`` (call inside shard_map).

    The int8 payload crosses the wire; scales are psum'd separately (4 bytes
    per tensor).  Dequantization averages over the axis size.
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, err):
        # shared scale across the axis: int8 payloads are only summable on
        # a common scale (4-byte pmax per tensor crosses the wire)
        amax = jnp.max(jnp.abs(g.astype(jnp.float32) + err))
        scale = jnp.maximum(jax.lax.pmax(amax, axis_name), 1e-12) / 127.0
        q, _, new_err = ef_int8_compress(g, err, scale=scale)
        # int8 collectives: sum in int32 to avoid overflow across pods
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        avg = qsum.astype(jnp.float32) * scale / n
        return avg.astype(g.dtype), new_err

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_tree)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    g_out = jax.tree.unflatten(treedef, [o[0] for o in outs])
    e_out = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return g_out, e_out
