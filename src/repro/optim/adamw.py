"""AdamW with f32 master weights, built from scratch (no optax).

Mixed-precision / ZeRO-1 layout (DESIGN.md Section 4):

  * the train state holds f32 master weights + f32 first/second moments,
    all sharded over (data x model) -- the ZeRO-1 partitioning; compute
    params are ``master.astype(bf16)`` re-materialized each step (the cast
    is GSPMD's all-gather, i.e. the ZeRO-1 gather),
  * gradients arrive in the compute sharding; GSPMD reshards them onto the
    optimizer sharding (the ZeRO-1 reduce-scatter).

The update is fully functional: ``adamw_update`` returns a new state.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to ``min_lr_frac * lr``."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr * (cfg.min_lr_frac
                    + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params) -> Dict[str, Any]:
    """Build the optimizer state from (possibly low-precision) params."""
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    zeros = lambda t: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), t)
    return {"master": master, "mu": zeros(master), "nu": zeros(master),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(cfg: AdamWConfig, state: Dict[str, Any], grads
                 ) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]:
    step = state["step"] + 1
    lr = cosine_lr(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip else jnp.float32(1.0)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(m, mu, nu, g):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        mhat = mu / bc1
        nhat = nu / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        decay = cfg.weight_decay if m.ndim >= 2 else 0.0  # no decay on norms
        m2 = m - lr * (delta + decay * m)
        return m2, mu, nu

    flat_m, treedef = jax.tree.flatten(state["master"])
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    flat_g = jax.tree.leaves(grads)
    outs = [upd(m, mu, nu, g) for m, mu, nu, g
            in zip(flat_m, flat_mu, flat_nu, flat_g)]
    new = {
        "master": jax.tree.unflatten(treedef, [o[0] for o in outs]),
        "mu": jax.tree.unflatten(treedef, [o[1] for o in outs]),
        "nu": jax.tree.unflatten(treedef, [o[2] for o in outs]),
        "step": step,
    }
    return new, {"lr": lr, "grad_norm": gnorm}
