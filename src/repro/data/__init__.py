from .pipeline import (SyntheticConfig, TokenFileDataset, synthetic_batch,
                       write_corpus)

__all__ = ["SyntheticConfig", "TokenFileDataset", "synthetic_batch",
           "write_corpus"]
