"""Deterministic token data pipeline with traced, resumable file reads.

Two tiers:

  * ``synthetic_batch(cfg, step, rank)`` -- pure-function batches (no I/O),
    deterministic in (seed, step, rank); used by trainer unit tests and the
    quickstart example.
  * ``TokenFileDataset`` -- a binary token corpus on disk, read through the
    traced POSIX facade with per-host strided offsets:

        offset(step, rank) = (step * nranks + rank) * batch_bytes  (mod file)

    i.e. rank-linear *and* step-linear -- precisely the access pattern the
    paper's intra-/inter-process recognition compresses to O(1) (Section 3.2).

Resumability: the dataset is stateless given ``step``; the trainer persists
only the step counter in its checkpoint metadata.
"""

from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from ..core.apis import posix


@dataclass(frozen=True)
class SyntheticConfig:
    vocab_size: int = 1024
    seq_len: int = 128
    batch_size: int = 8          # per-host batch
    seed: int = 0


def synthetic_batch(cfg: SyntheticConfig, step: int, rank: int = 0
                    ) -> Dict[str, np.ndarray]:
    """Markov-ish deterministic tokens: next = (3*prev + pos + mix) % V.
    Learnable structure so short training runs show a falling loss."""
    rs = np.random.RandomState((cfg.seed * 9176 + step) * 131 + rank)
    B, S, V = cfg.batch_size, cfg.seq_len, cfg.vocab_size
    first = rs.randint(0, V, size=(B, 1))
    toks = np.empty((B, S + 1), np.int64)
    toks[:, :1] = first
    mix = rs.randint(0, 7, size=(B, 1))
    for t in range(1, S + 1):
        toks[:, t] = (3 * toks[:, t - 1] + t + mix[:, 0]) % V
    return {"tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32)}


def write_corpus(path: str, n_tokens: int, vocab: int, seed: int = 0) -> None:
    """Materialize a synthetic corpus file (uint32 tokens) via the traced
    facade, in 1 MiB strided writes."""
    rs = np.random.RandomState(seed)
    fd = posix.open(path, os.O_RDWR | os.O_CREAT, 0o644)
    chunk = 1 << 18  # tokens per write
    off = 0
    left = n_tokens
    while left > 0:
        n = min(chunk, left)
        buf = rs.randint(0, vocab, size=n).astype("<u4").tobytes()
        posix.pwrite(fd, buf, off)
        off += len(buf)
        left -= n
    posix.fsync(fd)
    posix.close(fd)


class TokenFileDataset:
    """Strided reader over a token corpus file (traced pread per batch)."""

    def __init__(self, path: str, seq_len: int, batch_size: int,
                 rank: int = 0, nranks: int = 1, vocab: Optional[int] = None):
        self.path = path
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.rank = rank
        self.nranks = nranks
        self.vocab = vocab
        self._fd = posix.open(path, os.O_RDONLY, 0o644)
        self._file_bytes = posix.stat(path)
        self.batch_bytes = 4 * batch_size * (seq_len + 1)
        if self._file_bytes < self.batch_bytes:
            raise ValueError("corpus smaller than one batch")

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic batch for (step, rank); wraps around the file."""
        idx = step * self.nranks + self.rank
        max_start = self._file_bytes - self.batch_bytes
        off = (idx * self.batch_bytes) % (max_start + 1)
        off -= off % 4
        raw = posix.pread(self._fd, self.batch_bytes, off)
        toks = np.frombuffer(raw, dtype="<u4").astype(np.int64)
        toks = toks.reshape(self.batch_size, self.seq_len + 1)
        if self.vocab:
            toks = toks % self.vocab
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def close(self) -> None:
        posix.close(self._fd)
