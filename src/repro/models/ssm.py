"""Mamba-2 (SSD, state-space duality) blocks -- arXiv:2405.21060.

Train/prefill uses the chunked SSD algorithm:

  * within a chunk of length Q the output is a masked, decay-weighted
    attention-like contraction (quadratic in Q only),
  * chunk boundary states (nh, hd, ns) are passed through a sequential
    ``lax.scan`` over chunks (linear in sequence length).

The chunk loop materializes at most (B, nh, Q, Q) decay tensors for ONE
chunk at a time, bounding memory for the 500k-token shapes.  The Pallas
kernel in ``repro.kernels.ssd_scan`` implements the per-chunk contraction
with VMEM tiling; this module is the XLA path and the numerical reference.

Decode is the O(1) recurrence ``h = exp(dt*A) h + dt * B outer x``.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..distributed.sharding import shard
from .config import ModelConfig
from .layers import dense_init, rms_norm_head

Params = Dict[str, Any]


def ssd_init(key, cfg: ModelConfig) -> Params:
    d, di, ns, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    conv_ch = di + 2 * ns
    # dt bias initialized so softplus(dt_bias) spans [1e-3, 1e-1]
    u = jax.random.uniform(ks[2], (nh,), jnp.float32)
    dt_init = jnp.log(jnp.expm1(jnp.exp(u * (math.log(0.1) - math.log(1e-3))
                                        + math.log(1e-3))))
    return {
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * ns + nh, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, conv_ch),
                                     jnp.float32) / math.sqrt(cfg.conv_width)
                   ).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": dt_init,
        "gate_norm": jnp.ones((cfg.ssm_head_dim,), jnp.float32),
        "out_proj": dense_init(ks[3], di, d, dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d.  x: (B, S, C); w: (W, C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    # sum of shifted slices: cheap, fusion-friendly, no conv op needed
    S = x.shape[1]
    out = jnp.zeros_like(x)
    for i in range(W):
        out = out + xp[:, i : i + S, :] * w[i].astype(x.dtype)
    return out + b.astype(x.dtype)


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di : di + di + 2 * ns]
    dt_raw = proj[..., di + di + 2 * ns :]
    return z, xbc, dt_raw


def ssd_apply(p: Params, cfg: ModelConfig, x_in: jax.Array,
              with_cache: bool = False):
    """Full-sequence SSD. x_in: (B, S, d_model) -> (B, S, d_model).

    ``with_cache=True`` additionally returns the decode cache (final state +
    conv tail) for prefill."""
    Bsz, S, _ = x_in.shape
    di, ns, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    Q = min(cfg.ssm_chunk, S)
    while S % Q:
        Q -= 1
    nc = S // Q

    proj = x_in @ p["in_proj"].astype(x_in.dtype)
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xs = xbc[..., :di].reshape(Bsz, S, nh, hd)
    Bm = xbc[..., di : di + ns]                     # (B, S, ns), group=1
    Cm = xbc[..., di + ns :]                        # (B, S, ns)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"])            # (B, S, nh)
    A = -jnp.exp(p["A_log"])                        # (nh,)
    dA = dt * A                                     # (B, S, nh)

    xs = shard(xs, "batch", None, None, None)

    # chunked views
    xs_c = xs.reshape(Bsz, nc, Q, nh, hd)
    B_c = Bm.reshape(Bsz, nc, Q, ns)
    C_c = Cm.reshape(Bsz, nc, Q, ns)
    dt_c = dt.reshape(Bsz, nc, Q, nh)
    dA_c = dA.reshape(Bsz, nc, Q, nh)

    def chunk_step(h, ci):
        xb = xs_c[:, ci]                            # (B, Q, nh, hd)
        bb = B_c[:, ci]                             # (B, Q, ns)
        cb = C_c[:, ci]                             # (B, Q, ns)
        dtb = dt_c[:, ci]                           # (B, Q, nh)
        dab = dA_c[:, ci]                           # (B, Q, nh)
        cs = jnp.cumsum(dab, axis=1)                # (B, Q, nh)
        tot = cs[:, -1]                             # (B, nh)
        # -- inter-chunk: y_inter[q] = exp(cs_q) * C_q . h ------------------
        decay_in = jnp.exp(cs)                      # (B, Q, nh)
        y_inter = jnp.einsum("bqs,bhsd->bqhd", cb.astype(jnp.float32),
                             h) * decay_in[..., None]
        # -- intra-chunk (quadratic in Q) -----------------------------------
        scores = jnp.einsum("bqs,bps->bqp", cb.astype(jnp.float32),
                            bb.astype(jnp.float32))          # (B, Q, Q)
        ldecay = jnp.exp(cs[:, :, None, :] - cs[:, None, :, :])  # (B, Q, P, nh)
        causal = jnp.tril(jnp.ones((Q, Q), bool))
        ldecay = jnp.where(causal[None, :, :, None], ldecay, 0.0)
        w = scores[..., None] * ldecay * dtb[:, None, :, :]      # (B,Q,P,nh)
        y_intra = jnp.einsum("bqph,bphd->bqhd", w,
                             xs_c[:, ci].astype(jnp.float32))
        # -- state update ----------------------------------------------------
        sdecay = jnp.exp(tot[:, None, :] - cs)      # (B, Q, nh)
        contrib = jnp.einsum("bqs,bqh,bqhd->bhsd",
                             bb.astype(jnp.float32),
                             (dtb * sdecay), xb.astype(jnp.float32))
        h_new = h * jnp.exp(tot)[:, :, None, None] + contrib
        return h_new, (y_inter + y_intra).astype(x_in.dtype)

    h0 = jnp.zeros((Bsz, nh, ns, hd), jnp.float32)
    # checkpoint: recompute per-chunk decay/score tensors in backward
    h_fin, ys = lax.scan(jax.checkpoint(chunk_step), h0, jnp.arange(nc),
                         unroll=cfg.unroll_scans)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, S, nh, hd)
    y = y + xs * p["D"].astype(x_in.dtype)[None, None, :, None]
    # gated head norm, then out-projection
    zs = z.reshape(Bsz, S, nh, hd)
    y = rms_norm_head(y * jax.nn.silu(zs), p["gate_norm"], cfg.norm_eps)
    y = y.reshape(Bsz, S, di)
    out = shard(y @ p["out_proj"].astype(x_in.dtype), "batch", None, None)
    if with_cache:
        # raw (pre-conv) xbc tail feeds the decode-side conv window
        raw_xbc = proj[..., di : di + di + 2 * ns]
        cache = {"h": h_fin,
                 "conv": raw_xbc[:, S - (cfg.conv_width - 1):, :]}
        return out, cache
    return out


# ---------------------------------------------------------------------------
# decode (O(1) recurrence)
# ---------------------------------------------------------------------------


def ssd_cache_init(cfg: ModelConfig, batch: int, dtype) -> Dict:
    di, ns, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    return {
        "h": jnp.zeros((batch, nh, ns, hd), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di + 2 * ns), dtype),
    }


def ssd_decode(p: Params, cfg: ModelConfig, x_in: jax.Array, cache: Dict
               ) -> Tuple[jax.Array, Dict]:
    """One-token SSD step. x_in: (B, 1, d_model)."""
    Bsz = x_in.shape[0]
    di, ns, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = x_in[:, 0] @ p["in_proj"].astype(x_in.dtype)
    z, xbc, dt_raw = _split_proj(cfg, proj)
    # conv over (cached W-1 inputs, current)
    hist = jnp.concatenate([cache["conv"], xbc[:, None, :].astype(cache["conv"].dtype)], axis=1)
    w = p["conv_w"].astype(x_in.dtype)
    conv_out = jnp.einsum("bwc,wc->bc", hist.astype(x_in.dtype), w) \
        + p["conv_b"].astype(x_in.dtype)
    xbc = jax.nn.silu(conv_out)
    xs = xbc[:, :di].reshape(Bsz, nh, hd)
    Bm = xbc[:, di : di + ns]
    Cm = xbc[:, di + ns :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B, nh)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A)                                          # (B, nh)
    h = cache["h"] * decay[:, :, None, None] + jnp.einsum(
        "bs,bh,bhd->bhsd", Bm.astype(jnp.float32), dt,
        xs.astype(jnp.float32))
    y = jnp.einsum("bs,bhsd->bhd", Cm.astype(jnp.float32), h)
    y = y.astype(x_in.dtype) + xs * p["D"].astype(x_in.dtype)[None, :, None]
    zs = z.reshape(Bsz, nh, hd)
    y = rms_norm_head(y * jax.nn.silu(zs), p["gate_norm"], cfg.norm_eps)
    out = y.reshape(Bsz, 1, di) @ p["out_proj"].astype(x_in.dtype)
    new_cache = {"h": h, "conv": hist[:, 1:]}
    return out, new_cache
