"""Model configuration.

One ``ModelConfig`` covers every assigned architecture family:

  dense   GQA transformer (chatglm3, stablelm, qwen3, qwen1.5)
  moe     fine-grained MoE with shared experts (deepseek-moe, deepseek-v2-lite)
  mla     multi-head latent attention (deepseek-v2-lite)
  ssm     Mamba-2 / SSD, attention-free (mamba2-370m)
  hybrid  parallel attention+SSM heads with sliding-window attn (hymba)
  encdec  encoder-decoder backbone (seamless-m4t; audio frontend stubbed)
  vlm     decoder backbone consuming precomputed patch embeddings (llava-next)

The config records the *published* numbers; derived fields (padded vocab,
head dims, expert dims) are computed here so configs/<arch>.py stay literal.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


def pad_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"          # dense | moe | ssm | hybrid | encdec | vlm

    # -- core transformer dims ------------------------------------------------
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1024
    head_dim: Optional[int] = None          # default d_model // n_heads
    max_seq_len: int = 532480               # rope table upper bound (>=512k+pad)

    # attention flavor
    qk_norm: bool = False                   # qwen3
    qkv_bias: bool = False                  # qwen1.5
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0              # chatglm 2d-RoPE: 0.5; stablelm: 0.25
    sliding_window: int = 0                 # 0 = full attention; >0 = SWA width
    causal: bool = True
    norm: str = "rms"                       # rms | layer (stablelm, seamless)

    # mlp flavor
    mlp_gated: bool = True                  # SwiGLU (all assigned LMs)

    # -- MoE ------------------------------------------------------------------
    n_shared_experts: int = 0
    n_routed_experts: int = 0
    moe_top_k: int = 0
    d_ff_expert: int = 0
    first_k_dense: int = 0                  # deepseek: first k layers are dense
    moe_capacity_factor: float = 1.25
    router_aux_coef: float = 0.001
    first_dense_ff: int = 0                 # dense FFN width of first-k layers

    # -- MLA (deepseek-v2) ----------------------------------------------------
    mla: bool = False
    kv_lora_rank: int = 0                   # 512 for v2-lite
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # -- SSM (mamba2 / SSD) ---------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4

    # -- hybrid (hymba): parallel attn + ssm heads in one block ---------------
    hybrid: bool = False

    # -- encoder-decoder (seamless) -------------------------------------------
    n_encoder_layers: int = 0               # 0 = decoder-only
    frontend: str = "none"                  # none | audio | vision (stubbed)
    n_patches: int = 0                      # vlm: patch embeddings per sample

    # -- numerics / runtime ---------------------------------------------------
    dtype: str = "bfloat16"                 # activation/param compute dtype
    param_dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    remat: str = "block"                    # none | block  (scan remat policy)
    loss_chunk: int = 1024                  # CE over seq chunks (0 = off)
    unroll_scans: bool = False              # unroll all lax.scans (roofline
                                            # cost-exact small-L compiles)
    attn_q_chunk: int = 512                 # flash attention block sizes
    attn_kv_chunk: int = 1024
    decode_kv_chunk: int = 2048
    attn_impl: str = "xla"                  # xla | pallas_interpret
    logical_batch_axes: Tuple[str, ...] = ("pod", "data")
    tp_axis: str = "model"

    # ------------------------------------------------------------------------

    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to 256 for clean TP sharding (production practice;
        padded logits are masked in the loss)."""
        return pad_to(self.vocab_size, 256)

    @property
    def is_moe(self) -> bool:
        return self.n_routed_experts > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def kv_groups(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def attn_kind(self) -> str:
        if self.family == "ssm":
            return "none"
        if self.mla:
            return "mla"
        return "gqa"

    @property
    def decode_cache_kind(self) -> str:
        if self.family == "ssm":
            return "ssm"
        if self.hybrid:
            return "hybrid"
        if self.mla:
            return "mla"
        return "kv"

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # param count (for MODEL_FLOPS = 6 N D roofline term) ---------------------

    def param_counts(self) -> dict:
        """Analytic parameter counts: total and active-per-token."""
        d, hd = self.d_model, self.hd
        nl = self.n_layers
        emb = self.padded_vocab * d
        if self.family == "ssm":
            di, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
            # in_proj: d -> 2*di + 2*ns + nh ; out_proj: di -> d
            per_layer = d * (2 * di + 2 * ns + nh) + di * d \
                + self.conv_width * (di + 2 * ns) + 2 * nh + di
            tot = emb * 2 + nl * per_layer
            return {"total": tot, "active": tot, "embedding": emb}

        def attn_params() -> int:
            if self.mla:
                q = d * (self.n_heads * (self.qk_nope_dim + self.qk_rope_dim))
                kv = d * (self.kv_lora_rank + self.qk_rope_dim)
                up = self.kv_lora_rank * self.n_heads * (
                    self.qk_nope_dim + self.v_head_dim)
                o = self.n_heads * self.v_head_dim * d
                return q + kv + up + o
            qkv = d * hd * (self.n_heads + 2 * self.n_kv_heads)
            return qkv + self.n_heads * hd * d

        def mlp_params(dff: int) -> int:
            return d * dff * (3 if self.mlp_gated else 2)

        a = attn_params()
        dense_mlp = mlp_params(self.d_ff)
        if self.is_moe:
            shared = mlp_params(self.d_ff_expert * self.n_shared_experts)
            routed_all = self.n_routed_experts * mlp_params(self.d_ff_expert)
            routed_act = self.moe_top_k * mlp_params(self.d_ff_expert)
            router = d * self.n_routed_experts
            n_moe = nl - self.first_k_dense
            tot = nl * a + self.first_k_dense * dense_mlp \
                + n_moe * (shared + routed_all + router)
            act = nl * a + self.first_k_dense * dense_mlp \
                + n_moe * (shared + routed_act + router)
        elif self.hybrid:
            di, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
            ssm = d * (2 * di + 2 * ns + nh) + di * d \
                + self.conv_width * (di + 2 * ns) + 2 * nh + di
            tot = act = nl * (a + dense_mlp + ssm)
        else:
            tot = act = nl * (a + dense_mlp)
        enc = 0
        if self.n_encoder_layers:
            # encoder self-attn + mlp; decoder adds cross-attn
            enc = self.n_encoder_layers * (a + dense_mlp)
            tot += enc + nl * a  # cross-attention blocks
            act += enc + nl * a
        tot += emb * 2  # tied-off embed + lm head (counted separately)
        act += emb * 2
        return {"total": tot, "active": act, "embedding": emb}
