"""Decoder-only language models: dense / MoE / MLA / SSM / hybrid.

Layer stacks are ``lax.scan`` over stacked per-layer parameters (compile
time and HLO size independent of depth), with full-block rematerialization
when ``cfg.remat == "block"``.

Three entry points (what the dry-run lowers):

  train_forward  -> logits + aux  (full sequence, causal)
  prefill        -> last-position logits + stacked decode caches
  decode_step    -> next-token logits + updated caches (one token)

Multimodal stubs: ``patches`` (VLM) and ``frames`` (audio encoder-decoder
lives in encdec.py) enter as precomputed ``d_model`` embeddings.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..distributed.sharding import shard
from .config import ModelConfig
from . import layers as L
from . import ssm as S

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def block_init(key, cfg: ModelConfig, kind: str) -> Params:
    """kind: dense | moe | ssm | hybrid (+ '_densemlp' override for the
    first-k-dense MoE layers)."""
    ks = jax.random.split(key, 4)
    p: Params = {}
    if kind != "ssm":
        p["ln1"] = L.norm_init(cfg.d_model, cfg)
        p["attn"] = (L.mla_init(ks[0], cfg) if cfg.mla
                     else L.attn_init(ks[0], cfg))
        p["ln2"] = L.norm_init(cfg.d_model, cfg)
        if kind == "moe":
            p["moe"] = L.moe_init(ks[1], cfg)
        elif kind == "dense_first":
            # deepseek first-k-dense layers use the big dense FFN
            p["mlp"] = L.mlp_init(ks[1], cfg, d_ff=cfg.first_dense_ff)
        else:
            p["mlp"] = L.mlp_init(ks[1], cfg)
        if kind == "hybrid":
            p["ssm"] = S.ssd_init(ks[2], cfg)
    else:
        p["ln1"] = L.norm_init(cfg.d_model, cfg)
        p["ssm"] = S.ssd_init(ks[2], cfg)
    return p


def _mix(p: Params, cfg: ModelConfig, x: jax.Array, positions, kind: str):
    """The token-mixing half of a block (attention / SSD / both)."""
    h = L.apply_norm(x, p["ln1"], cfg)
    if kind == "ssm":
        return S.ssd_apply(p["ssm"], cfg, h)
    if cfg.mla:
        out = L.mla_apply(p["attn"], cfg, h, positions)
    else:
        out = L.attn_apply(p["attn"], cfg, h, positions)
    if kind == "hybrid":
        out = 0.5 * (out + S.ssd_apply(p["ssm"], cfg, h))
    return out


def block_apply(p: Params, cfg: ModelConfig, x: jax.Array,
                positions: jax.Array, kind: str) -> Tuple[jax.Array, jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    x = x + _mix(p, cfg, x, positions, kind)
    if kind == "ssm":
        return x, aux
    h = L.apply_norm(x, p["ln2"], cfg)
    if kind == "moe":
        y, aux = L.moe_apply(p["moe"], cfg, h)
    else:
        y = L.mlp_apply(p["mlp"], cfg, h)
    return x + y, aux


def block_prefill(p: Params, cfg: ModelConfig, x: jax.Array,
                  positions: jax.Array, kind: str) -> Tuple[jax.Array, Dict]:
    """Forward + produce this layer's decode cache."""
    h = L.apply_norm(x, p["ln1"], cfg)
    cache: Dict = {}
    if kind == "ssm":
        out, cache["ssm"] = S.ssd_apply(p["ssm"], cfg, h, with_cache=True)
        x = x + out
        return x, cache
    B, Sq, _ = h.shape
    if cfg.mla:
        out = L.mla_apply(p["attn"], cfg, h, positions)
        q_nope, q_rope, c, kr = L._mla_qc(p["attn"], cfg, h, positions)
        cache["c"], cache["kr"] = c, kr
    else:
        out = L.attn_apply(p["attn"], cfg, h, positions)
        _, k, v = L.qkv_project(p["attn"], cfg, h, positions)
        W = min(Sq, cfg.sliding_window) if cfg.sliding_window else Sq
        if W < Sq:  # ring layout consistent with decode's slot = pos % W
            kl, vl = k[:, Sq - W:], v[:, Sq - W:]
            idx = (Sq - W + jnp.arange(W)) % W
            cache["k"] = jnp.zeros_like(kl).at[:, idx].set(kl)
            cache["v"] = jnp.zeros_like(vl).at[:, idx].set(vl)
        else:
            cache["k"], cache["v"] = k, v
    if kind == "hybrid":
        s_out, cache["ssm"] = S.ssd_apply(p["ssm"], cfg, h, with_cache=True)
        out = 0.5 * (out + s_out)
    x = x + out
    h = L.apply_norm(x, p["ln2"], cfg)
    if kind == "moe":
        y, _ = L.moe_apply(p["moe"], cfg, h)
    else:
        y = L.mlp_apply(p["mlp"], cfg, h)
    return x + y, cache


def block_decode(p: Params, cfg: ModelConfig, x: jax.Array, cache: Dict,
                 pos: jax.Array, kind: str) -> Tuple[jax.Array, Dict]:
    h = L.apply_norm(x, p["ln1"], cfg)
    new_cache: Dict = {}
    if kind == "ssm":
        out, new_cache["ssm"] = S.ssd_decode(p["ssm"], cfg, h, cache["ssm"])
        return x + out, new_cache
    if cfg.mla:
        out, mc = L.mla_decode(p["attn"], cfg, h, cache, pos)
        new_cache.update(mc)
    else:
        out, kc = L.attn_decode(p["attn"], cfg, h, cache, pos)
        new_cache.update(kc)
    if kind == "hybrid":
        s_out, new_cache["ssm"] = S.ssd_decode(p["ssm"], cfg, h, cache["ssm"])
        out = 0.5 * (out + s_out)
    x = x + out
    h = L.apply_norm(x, p["ln2"], cfg)
    if kind == "moe":
        y, _ = L.moe_apply(p["moe"], cfg, h)
    else:
        y = L.mlp_apply(p["mlp"], cfg, h)
    return x + y, new_cache


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------


def _layer_kinds(cfg: ModelConfig) -> Tuple[str, int, int]:
    """(scan_kind, n_first_dense, n_scan)."""
    if cfg.family == "ssm":
        return "ssm", 0, cfg.n_layers
    if cfg.hybrid:
        return "hybrid", 0, cfg.n_layers
    if cfg.is_moe:
        return "moe", cfg.first_k_dense, cfg.n_layers - cfg.first_k_dense
    return "dense", 0, cfg.n_layers


def init_params(cfg: ModelConfig, rng) -> Params:
    kind, n_first, n_scan = _layer_kinds(cfg)
    k_emb, k_first, k_layers, k_head = jax.random.split(rng, 4)
    dt = jnp.dtype(cfg.param_dtype)
    V, d = cfg.padded_vocab, cfg.d_model
    p: Params = {
        "embed": (jax.random.normal(k_emb, (V, d), jnp.float32) * 0.02
                  ).astype(dt),
        "final_norm": L.norm_init(d, cfg),
        "lm_head": (jax.random.normal(k_head, (V, d), jnp.float32)
                    * (1.0 / d ** 0.5)).astype(dt),
    }
    keys = jax.random.split(k_layers, n_scan)
    p["layers"] = jax.vmap(lambda k: block_init(k, cfg, kind))(keys)
    for i in range(n_first):
        p[f"first_{i}"] = block_init(jax.random.fold_in(k_first, i), cfg,
                                     "dense_first")
    return p


def _embed_inputs(cfg: ModelConfig, params: Params, batch: Dict
                  ) -> Tuple[jax.Array, jax.Array]:
    """Token (+ stub-modality) embeddings and positions."""
    emb = params["embed"]
    tok = batch["tokens"]
    x = emb.astype(jnp.dtype(cfg.dtype))[tok]
    if cfg.family == "vlm" and "patches" in batch:
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
    B, Stot = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(Stot)[None, :], (B, Stot))
    from ..distributed.sharding import axis_size
    seq = "seq" if Stot % max(axis_size("seq"), 1) == 0 else None
    x = shard(x, "batch", seq, None)
    return x, positions


def _run_stack(cfg: ModelConfig, params: Params, x, positions
               ) -> Tuple[jax.Array, jax.Array]:
    kind, n_first, _ = _layer_kinds(cfg)
    aux = jnp.zeros((), jnp.float32)
    for i in range(n_first):
        x, a = block_apply(params[f"first_{i}"], cfg, x, positions,
                           "dense_first")
        aux += a

    def body(carry, lp):
        xc, auxc = carry
        xo, a = block_apply(lp, cfg, xc, positions, kind)
        # layer-boundary activations are (batch x seq)-sharded so the
        # remat-saved carries divide over the whole mesh (Megatron-SP)
        xo = shard(xo, "batch", "seq", None)
        return (xo, auxc + a), None

    if cfg.remat == "block":
        body = jax.checkpoint(body)
    (x, aux), _ = lax.scan(body, (x, aux), params["layers"],
                           unroll=cfg.unroll_scans)
    return x, aux


def train_forward(cfg: ModelConfig, params: Params, batch: Dict
                  ) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence logits. Returns (logits_f32, aux_loss)."""
    x, positions = _embed_inputs(cfg, params, batch)
    x, aux = _run_stack(cfg, params, x, positions)
    x = L.apply_norm(x, params["final_norm"], cfg)
    if cfg.family == "vlm":  # only text positions produce logits
        x = x[:, -batch["tokens"].shape[1]:]
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return shard(logits, "batch", None, "tp"), aux


def chunked_ce(cfg: ModelConfig, x: jax.Array, lm_head: jax.Array,
               labels: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Cross-entropy without materializing full-sequence logits.

    Scans over sequence chunks; each chunk's (B, C, V) logits live only
    inside a remat block, bounding the memory term by one chunk.  Returns
    (nll_sum, token_count)."""
    B, S, d = x.shape
    mask = (labels >= 0)
    labels = jnp.maximum(labels, 0)
    C = cfg.loss_chunk
    head = lm_head.astype(x.dtype)

    def ce(xb, lb, mb):
        logits = jnp.einsum("btd,vd->btv", xb, head,
                            preferred_element_type=jnp.float32)
        logits = shard(logits, "batch", None, "tp")
        lse = jax.nn.logsumexp(logits, axis=-1)
        # gold logit via one-hot contraction: take_along_axis over the
        # vocab-SHARDED axis makes GSPMD all-gather the logits; the one-hot
        # einsum reduces locally + psums a (B, C) scalar field instead
        oh = jax.nn.one_hot(lb, logits.shape[-1], dtype=logits.dtype)
        gold = jnp.einsum("btv,btv->bt", logits, oh)
        return jnp.sum((lse - gold) * mb)

    if not C or S <= C or S % C:
        nll = ce(x, labels, mask.astype(jnp.float32))
        return nll, mask.sum().astype(jnp.float32)

    n = S // C
    xc = jnp.moveaxis(x.reshape(B, n, C, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, n, C), 1, 0)
    mc = jnp.moveaxis(mask.reshape(B, n, C), 1, 0).astype(jnp.float32)

    def body(acc, inp):
        xb, lb, mb = inp
        return acc + ce(xb, lb, mb), None

    nll, _ = lax.scan(jax.checkpoint(body), jnp.zeros((), jnp.float32),
                      (xc, lc, mc), unroll=cfg.unroll_scans)
    return nll, mask.sum().astype(jnp.float32)


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict
            ) -> Tuple[jax.Array, Dict]:
    x, positions = _embed_inputs(cfg, params, batch)
    x, aux = _run_stack(cfg, params, x, positions)
    x = L.apply_norm(x, params["final_norm"], cfg)
    if cfg.family == "vlm":  # only text positions produce logits
        x = x[:, -batch["tokens"].shape[1]:]
    nll_sum, ntok = chunked_ce(cfg, x, params["lm_head"], batch["labels"])
    denom = jnp.maximum(ntok, 1.0)
    loss = nll_sum / denom + aux
    return loss, {"nll": nll_sum / denom, "aux": aux, "ntok": ntok}


# -- serving ----------------------------------------------------------------


def prefill(cfg: ModelConfig, params: Params, batch: Dict
            ) -> Tuple[jax.Array, Any]:
    """Process the full prompt; return last-position logits + caches."""
    kind, n_first, _ = _layer_kinds(cfg)
    x, positions = _embed_inputs(cfg, params, batch)
    first_caches = []
    for i in range(n_first):
        x, c = block_prefill(params[f"first_{i}"], cfg, x, positions,
                             "dense_first")
        first_caches.append(c)

    def body(xc, lp):
        xo, c = block_prefill(lp, cfg, xc, positions, kind)
        return xo, c

    x, caches = lax.scan(body, x, params["layers"],
                         unroll=cfg.unroll_scans)
    x = L.apply_norm(x[:, -1:], params["final_norm"], cfg)
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return logits[:, 0], {"layers": caches, "first": first_caches,
                          "pos": jnp.full((x.shape[0],), positions.shape[1],
                                          jnp.int32)}


def init_cache(cfg: ModelConfig, batch: int, seq: int) -> Dict:
    """Zero decode caches for a max context of ``seq`` tokens."""
    kind, n_first, n_scan = _layer_kinds(cfg)
    dt = jnp.dtype(cfg.dtype)

    def one(k: str) -> Dict:
        c: Dict = {}
        if k == "ssm":
            return {"ssm": S.ssd_cache_init(cfg, batch, dt)}
        if cfg.mla:
            c = L.mla_cache_init(cfg, batch, seq, dt)
        else:
            c = L.kv_cache_init(cfg, batch, seq, dt)
        if k == "hybrid":
            c["ssm"] = S.ssd_cache_init(cfg, batch, dt)
        return c

    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_scan,) + x.shape).copy()
        if n_scan else x, one(kind))
    # scan requires a true stacked copy, broadcast_to gives one post-copy
    return {"layers": stacked,
            "first": [one("dense_first") for _ in range(n_first)],
            "pos": jnp.zeros((batch,), jnp.int32)}


def decode_step(cfg: ModelConfig, params: Params, cache: Dict,
                tokens: jax.Array) -> Tuple[jax.Array, Dict]:
    """One greedy decode step. tokens: (B, 1) -> (next (B, 1), new cache)."""
    kind, n_first, _ = _layer_kinds(cfg)
    pos = cache["pos"]
    x = params["embed"].astype(jnp.dtype(cfg.dtype))[tokens]
    x = shard(x, "batch", None, None)
    new_first = []
    for i in range(n_first):
        x, c = block_decode(params[f"first_{i}"], cfg, x, cache["first"][i],
                            pos, "dense_first")
        new_first.append(c)

    def body(xc, layer):
        lp, lc = layer
        xo, c = block_decode(lp, cfg, xc, lc, pos, kind)
        return xo, c

    x, new_caches = lax.scan(body, x, (params["layers"], cache["layers"]),
                             unroll=cfg.unroll_scans)
    x = L.apply_norm(x, params["final_norm"], cfg)
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    # mask vocab padding, then greedy
    V = cfg.vocab_size
    neg = jnp.full((cfg.padded_vocab - V,), -jnp.inf, logits.dtype)
    logits = logits.at[..., V:].set(neg) if cfg.padded_vocab > V else logits
    next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return next_tok, {"layers": new_caches, "first": new_first,
                      "pos": pos + 1}
