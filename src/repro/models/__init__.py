"""Unified model API over the decoder-only and encoder-decoder families."""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax

from .config import ModelConfig
from . import encdec, lm


class ModelAPI:
    """Family-dispatching facade: init / loss / prefill / decode."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self._m = encdec if cfg.n_encoder_layers else lm

    def init_params(self, rng) -> Dict:
        return self._m.init_params(self.cfg, rng)

    def loss_fn(self, params, batch) -> Tuple[jax.Array, Dict]:
        return self._m.loss_fn(self.cfg, params, batch)

    def train_forward(self, params, batch):
        return self._m.train_forward(self.cfg, params, batch)

    def prefill(self, params, batch):
        return self._m.prefill(self.cfg, params, batch)

    def init_cache(self, batch: int, seq: int):
        if self.cfg.n_encoder_layers:
            return encdec.init_cache(self.cfg, batch, seq, seq)
        return lm.init_cache(self.cfg, batch, seq)

    def decode_step(self, params, cache, tokens):
        return self._m.decode_step(self.cfg, params, cache, tokens)


def get_model(cfg: ModelConfig) -> ModelAPI:
    return ModelAPI(cfg)


__all__ = ["ModelConfig", "ModelAPI", "get_model"]
