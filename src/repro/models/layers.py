"""Transformer building blocks (pure JAX; GSPMD-shardable).

All attention paths are *chunked* (online-softmax / flash-style in lax) so
that no O(S^2) score tensor is ever materialized -- mandatory for the 32k
prefill and 4k x 256 train shapes to pass the dry-run memory analysis.  The
Pallas kernel in ``repro.kernels.flash_attention`` implements the same math
for TPU; ``attn_impl`` selects the path.

Sharding is expressed through logical constraints (``distributed.shard``):
  batch  -> ("pod","data")    activations' leading batch dim
  heads  -> "model"           when n_heads % tp == 0 (TP attention)
  seq    -> "model"           otherwise (sequence/context parallelism)
  ff/kv  -> "model"           MLP hidden, KV-cache heads
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..distributed.sharding import axis_size, current_mesh_axes, shard
from .config import ModelConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# initializers / norms
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def norm_init(d: int, cfg: ModelConfig, bias: bool = False) -> Params:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layer" or bias:
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(x: jax.Array, p: Params, cfg: ModelConfig) -> jax.Array:
    # statistics accumulate in f32 WITHOUT materializing convert(x): a full
    # f32 copy of x gets loop-hoisted by XLA across the layer scan, i.e. an
    # f32 replica of every saved carry (measured: +10 GiB/chip on qwen3).
    d = x.shape[-1]
    if cfg.norm == "layer":
        mu = (jnp.sum(x, axis=-1, keepdims=True, dtype=jnp.float32) / d)
        xc = x - mu.astype(x.dtype)
    else:
        xc = x
    var = jnp.sum(jnp.square(xc), axis=-1, keepdims=True,
                  dtype=jnp.float32) / d
    nf = lax.rsqrt(var + cfg.norm_eps)
    y = xc * nf.astype(x.dtype) * p["scale"].astype(x.dtype)
    if "bias" in p:
        y = y + p["bias"].astype(x.dtype)
    return y


def rms_norm_head(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    """Per-head RMS norm over the last dim (qwen3 qk_norm)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * lax.rsqrt(var + eps) * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings (full / partial fraction / 2d-half)
# ---------------------------------------------------------------------------


def rope_rotate(x: jax.Array, positions: jax.Array, theta: float,
                fraction: float = 1.0) -> jax.Array:
    """Apply RoPE to the first ``fraction`` of the head dim.

    x: (..., S, H, hd); positions: broadcastable to (..., S).
    """
    hd = x.shape[-1]
    rot = int(hd * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    # positions: (B, S) -> angles (B, S, 1, half), broadcast over heads
    ang = positions.astype(jnp.float32)[..., :, None, None] * freqs
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    out = jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)
    if x_pass.shape[-1]:
        out = jnp.concatenate([out, x_pass.astype(x.dtype)], axis=-1)
    return out


# ---------------------------------------------------------------------------
# chunked (flash-style) attention -- XLA path
# ---------------------------------------------------------------------------


def _pick_chunk(s: int, target: int) -> int:
    c = min(s, target)
    while s % c:
        c -= 1
    return c


def flash_attention_xla(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool, window: int = 0, q_offset=0,
                        q_chunk: int = 512, kv_chunk: int = 1024,
                        kv_valid: Optional[jax.Array] = None,
                        unroll: bool = False) -> jax.Array:
    """Online-softmax attention without materializing (S, S) scores.

    q: (B, Sq, H, hd); k, v: (B, Skv, KVH, hd_[v]).  GQA via head grouping.
    ``q_offset``: global position of q[0] (decode / sequence-sharding).
    ``window`` > 0: sliding-window attention (keys in [pos-window+1, pos]).
    ``kv_valid``: optional number of valid kv positions (decode caches).
    Returns (B, Sq, H, hd_v).
    """
    B, Sq, H, Dq = q.shape
    _, Skv, KVH, _ = k.shape
    Dv = v.shape[-1]
    G = H // KVH
    qc = _pick_chunk(Sq, q_chunk)
    kc = _pick_chunk(Skv, kv_chunk)
    nq, nk = Sq // qc, Skv // kc
    scale = 1.0 / math.sqrt(Dq)

    # (B, nq, qc, KVH, G, Dq)
    qr = q.reshape(B, nq, qc, KVH, G, Dq)
    kr = k.reshape(B, nk, kc, KVH, Dq)
    vr = v.reshape(B, nk, kc, KVH, Dv)

    def q_block(carry, qi):
        qb = qr[:, qi]  # (B, qc, KVH, G, Dq)
        q_pos = q_offset + qi * qc + jnp.arange(qc)

        def kv_block(state, ki_valid):
            ki, chunk_valid = ki_valid
            m, l, acc = state
            kb = kr[:, ki]      # (B, kc, KVH, Dq)
            vb = vr[:, ki]      # (B, kc, KVH, Dv)
            k_pos = ki * kc + jnp.arange(kc)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.broadcast_to(chunk_valid, (qc, kc))
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window:
                mask &= k_pos[None, :] > q_pos[:, None] - window
            if kv_valid is not None:
                mask &= (k_pos[None, :] < kv_valid)
            s = jnp.where(mask, s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # guard fully-masked rows
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(mask, p, 0.0)
            corr = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
            corr = jnp.where(jnp.isfinite(m), corr, 0.0)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KVH, G, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KVH, G, qc), jnp.float32)
        a0 = jnp.zeros((B, KVH, G, qc, Dv), jnp.float32)
        if window and nk > 1:
            # SWA: only kv chunks overlapping [q_start - window, q_end] are
            # visited -> cost O(S*W) not O(S^2). Out-of-range iterations are
            # clipped to a real chunk index but masked out via chunk_valid.
            lo = jnp.maximum((q_offset + qi * qc - window) // kc, 0)
            n_iter = min(nk, (window + qc + kc - 1) // kc + 1)
            js = lo + jnp.arange(n_iter)
            valid = js < nk
            js = jnp.clip(js, 0, nk - 1)
            (m, l, acc), _ = lax.scan(kv_block, (m0, l0, a0), (js, valid),
                                      unroll=unroll)
        else:
            (m, l, acc), _ = lax.scan(
                kv_block, (m0, l0, a0),
                (jnp.arange(nk), jnp.ones((nk,), bool)), unroll=unroll)
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return carry, out.astype(q.dtype)

    # rematerialize each q-block in backward: without this the kv scans'
    # per-step probability matrices (nq*nk blocks of f32[qc,kc] per head)
    # are all saved -- the flash-attention backward trick, in lax
    _, outs = lax.scan(jax.checkpoint(q_block), None, jnp.arange(nq),
                       unroll=unroll)
    # outs: (nq, B, KVH, G, qc, Dv) -> (B, Sq, H, Dv)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq, KVH, G, qc, Dv)
    out = jnp.einsum("bnhgqd->bnqhgd", out).reshape(B, Sq, H, Dv)
    return out


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cur_len: jax.Array, *, window: int = 0,
                     kv_chunk: int = 2048, unroll: bool = False) -> jax.Array:
    """Single-token attention over a (possibly ring) KV cache.

    q: (B, 1, H, Dq); caches: (B, S, KVH, D*); cur_len: scalar count of
    valid entries (ring caches pass W once full).  Chunked online-softmax
    over the sequence: never materializes (B, H, S) f32 scores (measured
    +16 GiB/chip on the 34B decode_32k cell unchunked).
    """
    B, _, H, Dq = q.shape
    _, S, KVH, Dv = v_cache.shape
    G = H // KVH
    scale = 1.0 / math.sqrt(Dq)
    qr = q.reshape(B, KVH, G, Dq)
    kc = _pick_chunk(S, kv_chunk)
    nk = S // kc
    kr = k_cache.reshape(B, nk, kc, KVH, Dq)
    vr = v_cache.reshape(B, nk, kc, KVH, Dv)

    def kv_block(state, ki):
        m, l, acc = state
        kb = kr[:, ki]
        vb = vr[:, ki]
        s = jnp.einsum("bhgd,bkhd->bhgk", qr, kb,
                       preferred_element_type=jnp.float32) * scale
        valid = (ki * kc + jnp.arange(kc)) < cur_len
        s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.where(valid[None, None, None, :],
                      jnp.exp(s - m_safe[..., None]), 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgk,bkhd->bhgd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KVH, G), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, KVH, G), jnp.float32)
    a0 = jnp.zeros((B, KVH, G, Dv), jnp.float32)
    if nk == 1:
        (m, l, acc), _ = kv_block((m0, l0, a0), jnp.int32(0))
    else:
        (m, l, acc), _ = lax.scan(kv_block, (m0, l0, a0), jnp.arange(nk),
                                  unroll=unroll)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, 1, H, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer (init / train / decode)
# ---------------------------------------------------------------------------


def attn_init(key, cfg: ModelConfig) -> Params:
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.param_dtype)
    p: Params = {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd, dt),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd, dt),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd, dt),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), jnp.float32)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.float32)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _tp_heads(cfg: ModelConfig) -> bool:
    """Shard attention by heads when divisible by the tp extent; otherwise
    fall back to sequence sharding (llava 56H, hymba 25H)."""
    tp = axis_size("tp")
    return tp > 1 and cfg.n_heads % tp == 0


def _shard_qkv(cfg: ModelConfig, q, k, v):
    """Pick an attention sharding that divides cleanly.

    * heads divisible by tp and kv-heads divisible -> classic TP attention;
    * heads divisible but kv-heads NOT (qwen3 kv=8, chatglm kv=2 on tp=16):
      broadcast KV to full heads first -- otherwise the (KVH, G) split inside
      flash attention has no shardable axis and GSPMD replicates the whole
      score computation (measured: 132 GiB/chip on qwen3 train_4k);
    * heads not divisible (llava 56H, hymba 25H) -> sequence sharding.
    """
    tp = axis_size("tp")
    kvh = k.shape[2]
    if _tp_heads(cfg):
        q = shard(q, "batch", None, "tp", None)
        if kvh % tp != 0 and q.shape[2] % kvh == 0:
            g = q.shape[2] // kvh
            k = jnp.repeat(k, g, axis=2)
            v = jnp.repeat(v, g, axis=2)
        k = shard(k, "batch", None, "tp", None)
        v = shard(v, "batch", None, "tp", None)
    else:     # sequence sharding over the model axis
        q = shard(q, "batch", "seq", None, None)
        k = shard(k, "batch", None, None, None)
        v = shard(v, "batch", None, None, None)
    return q, k, v


def qkv_project(p: Params, cfg: ModelConfig, x: jax.Array,
                positions: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, S, _ = x.shape
    hd = cfg.hd
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm_head(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm_head(k, p["k_norm"], cfg.norm_eps)
    q = rope_rotate(q, positions, cfg.rope_theta, cfg.rope_fraction)
    k = rope_rotate(k, positions, cfg.rope_theta, cfg.rope_fraction)
    return q, k, v


def attn_apply(p: Params, cfg: ModelConfig, x: jax.Array,
               positions: jax.Array) -> jax.Array:
    """Full-sequence (train / prefill) attention."""
    B, S, _ = x.shape
    q, k, v = qkv_project(p, cfg, x, positions)
    q, k, v = _shard_qkv(cfg, q, k, v)
    if cfg.attn_impl == "pallas_interpret":
        from ..kernels.flash_attention.ops import flash_attention as fa
        out = fa(q, k, v, causal=cfg.causal, window=cfg.sliding_window,
                 interpret=True)
    else:
        out = flash_attention_xla(q, k, v, causal=cfg.causal,
                                  window=cfg.sliding_window,
                                  q_chunk=cfg.attn_q_chunk,
                                  kv_chunk=cfg.attn_kv_chunk,
                                  unroll=cfg.unroll_scans)
    out = out.reshape(B, S, cfg.n_heads * cfg.hd)
    return shard(out @ p["wo"].astype(x.dtype), "batch", None, None)


def attn_decode(p: Params, cfg: ModelConfig, x: jax.Array, cache: Dict,
                pos: jax.Array) -> Tuple[jax.Array, Dict]:
    """One-token decode with KV cache (ring buffer when SWA)."""
    B = x.shape[0]
    q, k, v = qkv_project(p, cfg, x, pos[:, None])
    W = cache["k"].shape[1]
    slot = (pos[0] % W) if cfg.sliding_window else pos[0]
    k_cache = lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    v_cache = lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
    cur = jnp.minimum(pos[0] + 1, W)
    out = decode_attention(q, k_cache, v_cache, cur,
                           kv_chunk=cfg.decode_kv_chunk,
                           unroll=cfg.unroll_scans)
    out = out.reshape(B, 1, cfg.n_heads * cfg.hd)
    y = out @ p["wo"].astype(x.dtype)
    return y, {"k": k_cache, "v": v_cache}


def kv_cache_init(cfg: ModelConfig, batch: int, seq: int, dtype) -> Dict:
    W = min(seq, cfg.sliding_window) if cfg.sliding_window else seq
    shape = (batch, W, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------


def mla_init(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    H, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "w_q": dense_init(ks[0], d, H * (dn + dr), dt),
        "w_dkv": dense_init(ks[1], d, r + dr, dt),       # latent + shared rope key
        "w_uk": dense_init(ks[2], r, H * dn, dt),        # latent -> k_nope
        "w_uv": dense_init(ks[3], r, H * dv, dt),        # latent -> v
        "kv_norm": jnp.ones((r,), jnp.float32),
        "wo": dense_init(ks[4], H * dv, d, dt),
    }


def _mla_qc(p: Params, cfg: ModelConfig, x: jax.Array, positions: jax.Array):
    B, S, _ = x.shape
    H, dn, dr = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    r = cfg.kv_lora_rank
    q = (x @ p["w_q"].astype(x.dtype)).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope_rotate(q_rope, positions, cfg.rope_theta)
    ckv = x @ p["w_dkv"].astype(x.dtype)
    c, k_rope = ckv[..., :r], ckv[..., r:]
    c = rms_norm_head(c, p["kv_norm"], cfg.norm_eps)
    k_rope = rope_rotate(k_rope[:, :, None, :], positions, cfg.rope_theta)
    return q_nope, q_rope, c, k_rope[:, :, 0, :]


def mla_apply(p: Params, cfg: ModelConfig, x: jax.Array,
              positions: jax.Array) -> jax.Array:
    """Training/prefill MLA: expand latent to per-head K/V, flash attention."""
    B, S, _ = x.shape
    H, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_rope, c, k_rope = _mla_qc(p, cfg, x, positions)
    k_nope = (c @ p["w_uk"].astype(x.dtype)).reshape(B, S, H, dn)
    v = (c @ p["w_uv"].astype(x.dtype)).reshape(B, S, H, dv)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, dr))], axis=-1)
    q = shard(q, "batch", None, "tp", None)
    k = shard(k, "batch", None, "tp", None)
    v = shard(v, "batch", None, "tp", None)
    out = flash_attention_xla(q, k, v, causal=cfg.causal,
                              q_chunk=cfg.attn_q_chunk,
                              kv_chunk=cfg.attn_kv_chunk,
                              unroll=cfg.unroll_scans)
    out = out.reshape(B, S, H * dv)
    return shard(out @ p["wo"].astype(x.dtype), "batch", None, None)


def mla_decode(p: Params, cfg: ModelConfig, x: jax.Array, cache: Dict,
               pos: jax.Array) -> Tuple[jax.Array, Dict]:
    """Absorbed-matmul latent decode: the cache stores (c, k_rope) only --
    (kv_lora + rope_dim) floats/token instead of 2*H*hd (paper's MLA win)."""
    B = x.shape[0]
    H, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    q_nope, q_rope, c_new, kr_new = _mla_qc(p, cfg, x, pos[:, None])
    S = cache["c"].shape[1]
    c_cache = lax.dynamic_update_slice_in_dim(
        cache["c"], c_new.astype(cache["c"].dtype), pos[0], axis=1)
    kr_cache = lax.dynamic_update_slice_in_dim(
        cache["kr"], kr_new.astype(cache["kr"].dtype), pos[0], axis=1)
    w_uk = p["w_uk"].astype(x.dtype).reshape(r, H, dn)
    # absorb: q_lat[b,h,r] = q_nope[b,h,dn] . w_uk[r,h,dn]
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk)
    s = jnp.einsum("bhr,bsr->bhs", q_lat.astype(jnp.float32),
                   c_cache.astype(jnp.float32))
    s += jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32),
                    kr_cache.astype(jnp.float32))
    s *= 1.0 / math.sqrt(dn + dr)
    valid = jnp.arange(S)[None, None, :] <= pos[0]
    s = jnp.where(valid, s, -jnp.inf)
    pr = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", pr, c_cache.astype(jnp.float32))
    w_uv = p["w_uv"].astype(x.dtype).reshape(r, H, dv)
    out = jnp.einsum("bhr,rhd->bhd", o_lat.astype(x.dtype), w_uv)
    y = out.reshape(B, 1, H * dv) @ p["wo"].astype(x.dtype)
    return y, {"c": c_cache, "kr": kr_cache}


def mla_cache_init(cfg: ModelConfig, batch: int, seq: int, dtype) -> Dict:
    return {"c": jnp.zeros((batch, seq, cfg.kv_lora_rank), dtype),
            "kr": jnp.zeros((batch, seq, cfg.qk_rope_dim), dtype)}


# ---------------------------------------------------------------------------
# MLP (gated SwiGLU / plain GELU)
# ---------------------------------------------------------------------------


def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    p = {"w_up": dense_init(ks[1], d, ff, dt),
         "w_down": dense_init(ks[2], ff, d, dt)}
    if cfg.mlp_gated:
        p["w_gate"] = dense_init(ks[0], d, ff, dt)
    return p


def mlp_apply(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    up = shard(x @ p["w_up"].astype(x.dtype), "batch", None, "tp")
    if cfg.mlp_gated:
        gate = shard(x @ p["w_gate"].astype(x.dtype), "batch", None, "tp")
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    return shard(h @ p["w_down"].astype(x.dtype), "batch", None, None)


# ---------------------------------------------------------------------------
# Mixture of Experts (fine-grained, shared + routed, top-k)
# ---------------------------------------------------------------------------


def moe_init(key, cfg: ModelConfig) -> Params:
    d, fe = cfg.d_model, cfg.d_ff_expert
    E = cfg.n_routed_experts
    ks = jax.random.split(key, 5)
    dt = jnp.dtype(cfg.param_dtype)
    scale = 1.0 / math.sqrt(d)

    def experts(k, din, dout):
        return (jax.random.normal(k, (E, din, dout), jnp.float32)
                * (1.0 / math.sqrt(din))).astype(dt)

    p: Params = {
        "router": (jax.random.normal(ks[0], (d, E), jnp.float32) * scale
                   ).astype(jnp.float32),
        "w_gate_e": experts(ks[1], d, fe),
        "w_up_e": experts(ks[2], d, fe),
        "w_down_e": experts(ks[3], fe, d),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], cfg, d_ff=fe * cfg.n_shared_experts)
    return p


def _route(p: Params, cfg: ModelConfig, x_flat: jax.Array):
    """Top-k routing with normalized weights + aux load-balance loss."""
    logits = (x_flat.astype(jnp.float32) @ p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = lax.top_k(probs, cfg.moe_top_k)          # (T, k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    E = cfg.n_routed_experts
    # aux: E * sum_e f_e * P_e  (Switch-style)
    f = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(1), axis=0)
    pm = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f * pm) * cfg.router_aux_coef
    return w.astype(x_flat.dtype), idx, aux


def _expert_ffn(recv: jax.Array, wg, wu, wd, dtype) -> jax.Array:
    """(E_loc, C, d) -> (E_loc, C, d) batched expert matmuls (MXU-friendly)."""
    g = jnp.einsum("ecd,edf->ecf", recv, wg.astype(dtype))
    u = jnp.einsum("ecd,edf->ecf", recv, wu.astype(dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("ecf,efd->ecd", h, wd.astype(dtype))


def _dispatch_combine(p: Params, cfg: ModelConfig, x_flat: jax.Array,
                      ep: int, axis_name: Optional[str]) -> Tuple[jax.Array, jax.Array]:
    """Capacity-based dispatch -> (all_to_all) -> expert FFN -> combine.

    Runs inside shard_map when ``axis_name`` is set (EP over the model axis),
    or locally (ep=1) for smoke tests and the decode path.
    """
    T, d = x_flat.shape
    E, k = cfg.n_routed_experts, cfg.moe_top_k
    C = max(1, int(math.ceil(T * k / E * cfg.moe_capacity_factor)))
    w, idx, aux = _route(p, cfg, x_flat)

    flat_e = idx.reshape(-1)                                   # (T*k,)
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)            # (T*k, E)
    # position of each (token, k) within its expert's capacity buffer
    pos_in_e = jnp.take_along_axis(jnp.cumsum(oh, axis=0) - 1,
                                   flat_e[:, None], axis=1)[:, 0]
    keep = pos_in_e < C
    slot = jnp.where(keep, flat_e * C + pos_in_e, E * C)       # overflow row
    x_rep = jnp.repeat(x_flat, k, axis=0)                      # (T*k, d)
    send = jnp.zeros((E * C + 1, d), x_flat.dtype).at[slot].set(x_rep)
    send = send[:-1].reshape(E, C, d)

    if axis_name is not None and ep > 1:
        recv = lax.all_to_all(send, axis_name, split_axis=0, concat_axis=1,
                              tiled=True)                      # (E/ep, C*ep, d)
    else:
        recv = send
    out = _expert_ffn(recv, p["w_gate_e"], p["w_up_e"], p["w_down_e"],
                      x_flat.dtype)
    if axis_name is not None and ep > 1:
        out = lax.all_to_all(out, axis_name, split_axis=1, concat_axis=0,
                             tiled=True)                       # (E, C, d)
    got = jnp.concatenate([out.reshape(E * C, d),
                           jnp.zeros((1, d), x_flat.dtype)], axis=0)
    y = got[slot] * keep[:, None].astype(x_flat.dtype)         # (T*k, d)
    y = (y.reshape(T, k, d) * w[..., None]).sum(axis=1)
    return y, aux


def moe_apply(p: Params, cfg: ModelConfig, x: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """Routed + shared experts.  Train/prefill uses shard_map EP over the
    model axis; without a mesh it degrades to local dispatch (same math)."""
    B, S, d = x.shape
    axes = current_mesh_axes()
    ep = axis_size("tp")
    use_ep = ("model" in axes) and ep > 1 and S % ep == 0
    if use_ep:
        from ..distributed.sharding import current_mesh, get_shard_map
        from jax.sharding import PartitionSpec as P
        mesh = current_mesh()
        dp_axes = tuple(a for a in ("pod", "data") if a in axes)

        def blk(xb, router, wg, wu, wd):
            pb = {"router": router, "w_gate_e": wg, "w_up_e": wu, "w_down_e": wd}
            t = xb.reshape(-1, d)
            y, aux = _dispatch_combine(pb, cfg, t, ep, "model")
            aux = lax.pmean(aux, axis_name="model")
            if dp_axes:
                aux = lax.pmean(aux, axis_name=dp_axes)
            return y.reshape(xb.shape), aux

        y, aux = get_shard_map()(
            blk, mesh=mesh,
            in_specs=(P(dp_axes or None, "model", None), P(None, None),
                      P("model", None, None), P("model", None, None),
                      P("model", None, None)),
            out_specs=(P(dp_axes or None, "model", None), P()),
        )(x, p["router"], p["w_gate_e"], p["w_up_e"], p["w_down_e"])
    else:
        y, aux = _dispatch_combine(p, cfg, x.reshape(-1, d), 1, None)
        y = y.reshape(B, S, d)
    if cfg.n_shared_experts:
        y = y + mlp_apply(p["shared"], cfg, x)
    return shard(y, "batch", None, None), aux
