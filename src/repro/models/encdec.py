"""Encoder-decoder backbone (seamless-m4t-large-v2).

The speech frontend (fbank + conv subsampling) is a stub per the assignment:
``frames`` enter as precomputed (B, S_enc, d_model) embeddings.  The
encoder is a bidirectional transformer; the decoder adds causal
self-attention plus cross-attention over the encoder output.

Decode caches: per-layer self-attention KV (ring-free) plus the
cross-attention K/V computed once from the encoder output at prefill.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..distributed.sharding import shard
from .config import ModelConfig
from . import layers as L

Params = Dict[str, Any]


# -- cross attention ---------------------------------------------------------


def cross_attn_init(key, cfg: ModelConfig) -> Params:
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    return {"wq": L.dense_init(ks[0], d, cfg.n_heads * hd, dt),
            "wk": L.dense_init(ks[1], d, cfg.n_heads * hd, dt),
            "wv": L.dense_init(ks[2], d, cfg.n_heads * hd, dt),
            "wo": L.dense_init(ks[3], cfg.n_heads * hd, d, dt)}


def cross_kv(p: Params, cfg: ModelConfig, enc_out: jax.Array):
    B, Se, _ = enc_out.shape
    k = (enc_out @ p["wk"].astype(enc_out.dtype)).reshape(B, Se, cfg.n_heads, cfg.hd)
    v = (enc_out @ p["wv"].astype(enc_out.dtype)).reshape(B, Se, cfg.n_heads, cfg.hd)
    return k, v


def cross_attn_apply(p: Params, cfg: ModelConfig, x: jax.Array,
                     k: jax.Array, v: jax.Array) -> jax.Array:
    B, Sq, _ = x.shape
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, Sq, cfg.n_heads, cfg.hd)
    q, k, v = L._shard_qkv(cfg, q, k, v)
    out = L.flash_attention_xla(q, k, v, causal=False,
                                q_chunk=cfg.attn_q_chunk,
                                kv_chunk=cfg.attn_kv_chunk,
                                unroll=cfg.unroll_scans)
    out = out.reshape(B, Sq, cfg.n_heads * cfg.hd)
    return shard(out @ p["wo"].astype(x.dtype), "batch", None, None)


# -- blocks -------------------------------------------------------------------


def enc_block_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    return {"ln1": L.norm_init(cfg.d_model, cfg),
            "attn": L.attn_init(ks[0], cfg),
            "ln2": L.norm_init(cfg.d_model, cfg),
            "mlp": L.mlp_init(ks[1], cfg)}


def dec_block_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 3)
    return {"ln1": L.norm_init(cfg.d_model, cfg),
            "attn": L.attn_init(ks[0], cfg),
            "lnx": L.norm_init(cfg.d_model, cfg),
            "xattn": cross_attn_init(ks[1], cfg),
            "ln2": L.norm_init(cfg.d_model, cfg),
            "mlp": L.mlp_init(ks[2], cfg)}


def enc_block_apply(p: Params, cfg: ModelConfig, x, positions):
    bicfg = cfg.replace(causal=False)
    x = x + L.attn_apply(p["attn"], bicfg,
                         L.apply_norm(x, p["ln1"], cfg), positions)
    x = x + L.mlp_apply(p["mlp"], cfg, L.apply_norm(x, p["ln2"], cfg))
    return x


def dec_block_apply(p: Params, cfg: ModelConfig, x, positions, enc_out):
    x = x + L.attn_apply(p["attn"], cfg,
                         L.apply_norm(x, p["ln1"], cfg), positions)
    k, v = cross_kv(p["xattn"], cfg, enc_out)
    x = x + cross_attn_apply(p["xattn"], cfg,
                             L.apply_norm(x, p["lnx"], cfg), k, v)
    x = x + L.mlp_apply(p["mlp"], cfg, L.apply_norm(x, p["ln2"], cfg))
    return x


def dec_block_decode(p: Params, cfg: ModelConfig, x, cache, pos):
    h = L.apply_norm(x, p["ln1"], cfg)
    out, kv = L.attn_decode(p["attn"], cfg, h, cache, pos)
    x = x + out
    h = L.apply_norm(x, p["lnx"], cfg)
    B = x.shape[0]
    q = (h @ p["xattn"]["wq"].astype(x.dtype)).reshape(B, 1, cfg.n_heads, cfg.hd)
    xo = L.decode_attention(q, cache["xk"], cache["xv"],
                            cache["xk"].shape[1],
                            kv_chunk=cfg.decode_kv_chunk,
                            unroll=cfg.unroll_scans)
    x = x + xo.reshape(B, 1, -1) @ p["xattn"]["wo"].astype(x.dtype)
    x = x + L.mlp_apply(p["mlp"], cfg, L.apply_norm(x, p["ln2"], cfg))
    new_cache = dict(kv, xk=cache["xk"], xv=cache["xv"])
    return x, new_cache


# -- model --------------------------------------------------------------------


def init_params(cfg: ModelConfig, rng) -> Params:
    k_emb, k_enc, k_dec, k_head = jax.random.split(rng, 4)
    V, d = cfg.padded_vocab, cfg.d_model
    dt = jnp.dtype(cfg.param_dtype)
    enc_keys = jax.random.split(k_enc, cfg.n_encoder_layers)
    dec_keys = jax.random.split(k_dec, cfg.n_layers)
    return {
        "dec_embed": (jax.random.normal(k_emb, (V, d), jnp.float32) * 0.02
                      ).astype(dt),
        "enc_layers": jax.vmap(lambda k: enc_block_init(k, cfg))(enc_keys),
        "enc_norm": L.norm_init(d, cfg),
        "dec_layers": jax.vmap(lambda k: dec_block_init(k, cfg))(dec_keys),
        "final_norm": L.norm_init(d, cfg),
        "lm_head": (jax.random.normal(k_head, (V, d), jnp.float32)
                    * (1.0 / d ** 0.5)).astype(dt),
    }


def encode(cfg: ModelConfig, params: Params, frames: jax.Array) -> jax.Array:
    B, Se, _ = frames.shape
    x = shard(frames.astype(jnp.dtype(cfg.dtype)), "batch", None, None)
    positions = jnp.broadcast_to(jnp.arange(Se)[None, :], (B, Se))

    def body(xc, lp):
        return enc_block_apply(lp, cfg, xc, positions), None

    if cfg.remat == "block":
        body = jax.checkpoint(body)
    x, _ = lax.scan(body, x, params["enc_layers"], unroll=cfg.unroll_scans)
    return L.apply_norm(x, params["enc_norm"], cfg)


def _decode_stack(cfg, params, x, positions, enc_out):
    def body(xc, lp):
        return dec_block_apply(lp, cfg, xc, positions, enc_out), None

    if cfg.remat == "block":
        body = jax.checkpoint(body)
    x, _ = lax.scan(body, x, params["dec_layers"], unroll=cfg.unroll_scans)
    return x


def train_forward(cfg: ModelConfig, params: Params, batch: Dict
                  ) -> Tuple[jax.Array, jax.Array]:
    enc_out = encode(cfg, params, batch["frames"])
    tok = batch["tokens"]
    B, Sd = tok.shape
    x = params["dec_embed"].astype(jnp.dtype(cfg.dtype))[tok]
    x = shard(x, "batch", None, None)
    positions = jnp.broadcast_to(jnp.arange(Sd)[None, :], (B, Sd))
    x = _decode_stack(cfg, params, x, positions, enc_out)
    x = L.apply_norm(x, params["final_norm"], cfg)
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return shard(logits, "batch", None, "tp"), jnp.zeros((), jnp.float32)


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict):
    from .lm import chunked_ce
    enc_out = encode(cfg, params, batch["frames"])
    tok = batch["tokens"]
    B, Sd = tok.shape
    x = params["dec_embed"].astype(jnp.dtype(cfg.dtype))[tok]
    x = shard(x, "batch", None, None)
    positions = jnp.broadcast_to(jnp.arange(Sd)[None, :], (B, Sd))
    x = _decode_stack(cfg, params, x, positions, enc_out)
    x = L.apply_norm(x, params["final_norm"], cfg)
    nll_sum, ntok = chunked_ce(cfg, x, params["lm_head"], batch["labels"])
    denom = jnp.maximum(ntok, 1.0)
    loss = nll_sum / denom
    return loss, {"nll": loss, "aux": jnp.zeros((), jnp.float32),
                  "ntok": ntok}


def prefill(cfg: ModelConfig, params: Params, batch: Dict):
    """Encode frames + run the decoder over the target prefix."""
    enc_out = encode(cfg, params, batch["frames"])
    tok = batch["tokens"]
    B, Sd = tok.shape
    x = params["dec_embed"].astype(jnp.dtype(cfg.dtype))[tok]
    positions = jnp.broadcast_to(jnp.arange(Sd)[None, :], (B, Sd))

    def body(xc, lp):
        h = L.apply_norm(xc, lp["ln1"], cfg)
        _, k, v = L.qkv_project(lp["attn"], cfg, h, positions)
        xo = dec_block_apply(lp, cfg, xc, positions, enc_out)
        xk, xv = cross_kv(lp["xattn"], cfg, enc_out)
        return xo, {"k": k, "v": v, "xk": xk, "xv": xv}

    x, caches = lax.scan(body, x, params["dec_layers"],
                         unroll=cfg.unroll_scans)
    x = L.apply_norm(x[:, -1:], params["final_norm"], cfg)
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return logits[:, 0], {"layers": caches,
                          "pos": jnp.full((B,), Sd, jnp.int32)}


def init_cache(cfg: ModelConfig, batch: int, seq: int, enc_seq: int) -> Dict:
    dt = jnp.dtype(cfg.dtype)
    nl, H, hd = cfg.n_layers, cfg.n_heads, cfg.hd
    return {"layers": {
        "k": jnp.zeros((nl, batch, seq, cfg.n_kv_heads, hd), dt),
        "v": jnp.zeros((nl, batch, seq, cfg.n_kv_heads, hd), dt),
        "xk": jnp.zeros((nl, batch, enc_seq, H, hd), dt),
        "xv": jnp.zeros((nl, batch, enc_seq, H, hd), dt)},
        "pos": jnp.zeros((batch,), jnp.int32)}


def decode_step(cfg: ModelConfig, params: Params, cache: Dict,
                tokens: jax.Array) -> Tuple[jax.Array, Dict]:
    pos = cache["pos"]
    x = params["dec_embed"].astype(jnp.dtype(cfg.dtype))[tokens]

    def body(xc, layer):
        lp, lc = layer
        xo, c = dec_block_decode(lp, cfg, xc, lc, pos)
        return xo, c

    x, new_caches = lax.scan(body, x,
                             (params["dec_layers"], cache["layers"]),
                             unroll=cfg.unroll_scans)
    x = L.apply_norm(x, params["final_norm"], cfg)
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    V = cfg.vocab_size
    if cfg.padded_vocab > V:
        neg = jnp.full((cfg.padded_vocab - V,), -jnp.inf, logits.dtype)
        logits = logits.at[..., V:].set(neg)
    next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return next_tok, {"layers": new_caches, "pos": pos + 1}
