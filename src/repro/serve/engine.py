"""Batched serving engine: prefill + greedy decode with a static batch.

Each dispatched decode step emits a ``frame.serve_step`` event; the
step-index OFFSET pattern means an arbitrarily long generation loop
compresses to a constant-size grammar in the trace (paper's technique
applied to the serving loop).
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.apis import framework as frame
from ..models import get_model
from ..models.config import ModelConfig


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, max_seq: int = 4096):
        self.cfg = cfg
        self.model = get_model(cfg)
        self.params = params
        self.max_seq = max_seq
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(1,))

    def generate(self, batch: Dict, n_new: int) -> np.ndarray:
        """Greedy-decode ``n_new`` tokens after the prompt batch.

        The prefill cache is re-seated into a fresh max_seq cache so long
        generations never reallocate (static-shape serving).
        """
        B = batch["tokens"].shape[0]
        logits, pf_cache = self._prefill(self.params, batch)
        prompt_len = int(pf_cache["pos"][0])
        cache = self.model.init_cache(B, self.max_seq)
        cache = _seat(self.cfg, cache, pf_cache, prompt_len)
        V = self.cfg.vocab_size
        tok = jnp.argmax(logits[:, :V], axis=-1).astype(jnp.int32)[:, None]
        out = [np.asarray(tok)]
        for i in range(n_new - 1):
            frame.serve_step(i)
            tok, cache = self._decode(self.params, cache, tok)
            out.append(np.asarray(tok))
        return np.concatenate(out, axis=1)


def _seat(cfg: ModelConfig, cache, pf_cache, prompt_len: int):
    """Copy prefill KV/state into the preallocated max_seq decode cache."""
    def leaf(path, dst):
        names = [str(getattr(p, "key", getattr(p, "idx", ""))) for p in path]
        src = pf_cache
        for n in names:
            src = src[int(n)] if n.isdigit() else src[n]
        if names[-1] == "pos":
            return jnp.asarray(src)
        src = jnp.asarray(src)
        if src.shape == dst.shape:
            return src.astype(dst.dtype)
        # sequence-dim mismatch: place the prompt at the cache head
        # (k/v/xk/xv: seq axis = ndim-3 ; c/kr: ndim-2)
        ax = dst.ndim - 3 if names[-1] in ("k", "v", "xk", "xv") else dst.ndim - 2
        idx = [slice(None)] * dst.ndim
        idx[ax] = slice(0, src.shape[ax])
        return dst.at[tuple(idx)].set(src.astype(dst.dtype))

    return jax.tree_util.tree_map_with_path(leaf, cache)
