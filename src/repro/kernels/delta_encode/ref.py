"""Oracle: the numpy delta+zigzag used by the live tracing pipeline."""

from __future__ import annotations

import numpy as np

from ...core.timestamps import delta_zigzag_encode


def delta_zigzag_ref(ticks: np.ndarray) -> np.ndarray:
    """ticks: flat u32 -> zigzag u32 (delegates to core.timestamps)."""
    flat = np.asarray(ticks, np.uint32).reshape(-1, 2) \
        if ticks.ndim == 1 and ticks.size % 2 == 0 \
        else np.asarray(ticks, np.uint32).reshape(-1, 1)
    out = delta_zigzag_encode(flat.reshape(-1, flat.shape[-1]))
    return out
