"""Oracle: the numpy delta+zigzag used by the live tracing pipeline."""

from __future__ import annotations

import numpy as np

from ...core.timestamps import delta_zigzag_encode


def delta_zigzag_ref(ticks: np.ndarray) -> np.ndarray:
    """ticks: flat u32 -> zigzag u32 (delegates to core.timestamps)."""
    flat = np.asarray(ticks, np.uint32).reshape(-1, 2) \
        if ticks.ndim == 1 and ticks.size % 2 == 0 \
        else np.asarray(ticks, np.uint32).reshape(-1, 1)
    out = delta_zigzag_encode(flat.reshape(-1, flat.shape[-1]))
    return out


def uvarint_planes_ref(values: np.ndarray):
    """u64 values -> (byte counts, (10, n) byte planes); the numpy mirror
    of the varint kernels (delegates to core.encode_backend)."""
    from ...core.encode_backend import _uvarint_planes_np
    return _uvarint_planes_np(np.asarray(values, np.uint64))


def fit_columns_ref(V: np.ndarray):
    """(C, R) int columns -> (flags, first deltas) per the kernel's
    encoding: 1 = constant, 2 = rank-linear, 0 = no fit."""
    V = np.asarray(V, np.int64)
    d = V[:, 1:] - V[:, :-1]
    const = (d == 0).all(axis=1)
    linear = (d == d[:, :1]).all(axis=1) & (d[:, 0] != 0)
    return np.where(const, 1, np.where(linear, 2, 0)), d[:, 0]
