"""Timestamp delta+zigzag Pallas kernel (Recorder Section 2.2.1 hot loop).

The tracing pipeline's only arithmetic-dense stage: millions of u32 ticks
-> first-order delta -> zigzag, before zlib.  On a real pod the staging
buffers can be encoded on-device before DMA to host.  Grid = (n_blocks,)
sequential; VMEM scratch carries the previous block's last element so the
cross-block delta is exact.

Arithmetic is 32-bit two's-complement: deltas wrap mod 2^32, which matches
the reference encoder bit-for-bit whenever |delta| < 2^31 (tick deltas are
microseconds between adjacent events) and still roundtrips losslessly
through the mod-2^32 decoder otherwise.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _delta_kernel(x_ref, o_ref, prev_ref):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.int32)               # bit-pattern reinterpret

    @pl.when(i == 0)
    def _first():
        prev_ref[0] = jnp.array(0, jnp.int32)

    prev = prev_ref[0]
    shifted = jnp.concatenate([prev[None], x[:-1]])
    first_mask = (i == 0) & (jax.lax.iota(jnp.int32, x.shape[0]) == 0)
    delta = jnp.where(first_mask, x, x - shifted)  # wraps mod 2^32
    zz = (delta << 1) ^ (delta >> 31)
    o_ref[...] = zz.astype(jnp.uint32)
    prev_ref[0] = x[-1]


def delta_zigzag_pallas(ticks: jax.Array, *, block: int = 4096,
                        interpret: bool = False) -> jax.Array:
    """ticks: flat u32 array -> zigzag'd u32 deltas (first element kept)."""
    n = ticks.shape[0]
    blk = min(block, n)
    while n % blk:
        blk -= 1
    return pl.pallas_call(
        _delta_kernel,
        grid=(n // blk,),
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,))],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint32),
        scratch_shapes=[pltpu.VMEM((1,), jnp.int32)],
        interpret=interpret,
    )(ticks)


# ---------------------------------------------------------------------------
# fused delta -> zigzag -> varint (lengths + byte planes)
# ---------------------------------------------------------------------------
#
# The variable-length total output size is data-dependent, so the kernel
# cannot emit the packed stream directly (Pallas output shapes are static).
# Instead it runs the per-element pass of the classic two-pass scheme:
# per-element byte counts plus five "byte planes" (plane j = byte j of
# every element, continuation bit already set).  The host half
# (encode_backend._emit_varint_bytes) does the exclusive-scan offsets and
# masked scatter -- pure vectorized numpy, no per-element Python.


def _varint_planes(zz, len_ref, plane_ref, n_planes):
    ln = jnp.ones(zz.shape, jnp.int32)
    for k in range(1, n_planes):
        ln = ln + (zz >= jnp.uint32(1 << (7 * k))).astype(jnp.int32)
    len_ref[...] = ln
    for j in range(n_planes):
        b = (zz >> jnp.uint32(7 * j)).astype(jnp.uint32) & jnp.uint32(0x7F)
        b = jnp.where(j < ln - 1, b | jnp.uint32(0x80), b)
        plane_ref[j, :] = b.astype(jnp.int32)


def _delta_varint_kernel(x_ref, zz_ref, len_ref, plane_ref, prev_ref):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.int32)

    @pl.when(i == 0)
    def _first():
        prev_ref[0] = jnp.array(0, jnp.int32)

    prev = prev_ref[0]
    shifted = jnp.concatenate([prev[None], x[:-1]])
    first_mask = (i == 0) & (jax.lax.iota(jnp.int32, x.shape[0]) == 0)
    delta = jnp.where(first_mask, x, x - shifted)
    zz = ((delta << 1) ^ (delta >> 31)).astype(jnp.uint32)
    zz_ref[...] = zz
    prev_ref[0] = x[-1]
    _varint_planes(zz, len_ref, plane_ref, 5)


def delta_zigzag_varint_pallas(ticks: jax.Array, *, block: int = 4096,
                               interpret: bool = False):
    """Fused encode: flat u32 ticks -> (zigzag u32, varint byte counts,
    (5, n) byte planes).  A u32 varint is at most 5 bytes."""
    n = ticks.shape[0]
    blk = min(block, n)
    while n % blk:
        blk -= 1
    return pl.pallas_call(
        _delta_varint_kernel,
        grid=(n // blk,),
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((5, blk), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.uint32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((5, n), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((1,), jnp.int32)],
        interpret=interpret,
    )(ticks)


def _uvarint64_kernel(lo_ref, hi_ref, len_ref, plane_ref):
    # u64 values arrive as (lo, hi) u32 planes -- Pallas TPU has no i64
    # lanes.  Byte j covers bits 7j..7j+6: for 7j < 32 that straddles the
    # lo/hi boundary, above it reads hi alone.  A u64 varint is <= 10 bytes.
    lo = lo_ref[...].astype(jnp.uint32)
    hi = hi_ref[...].astype(jnp.uint32)
    ln = jnp.ones(lo.shape, jnp.int32)
    for k in range(1, 10):
        s = 7 * k
        if s < 32:
            ge = (hi > 0) | (lo >= jnp.uint32(1 << s))
        else:
            ge = hi >= jnp.uint32(1 << (s - 32))
        ln = ln + ge.astype(jnp.int32)
    len_ref[...] = ln
    for j in range(10):
        s = 7 * j
        if s == 0:
            b = lo & jnp.uint32(0x7F)
        elif s < 32:
            b = ((lo >> jnp.uint32(s)) | (hi << jnp.uint32(32 - s))) \
                & jnp.uint32(0x7F)
        else:
            b = (hi >> jnp.uint32(s - 32)) & jnp.uint32(0x7F)
        b = jnp.where(j < ln - 1, b | jnp.uint32(0x80), b)
        plane_ref[j, :] = b.astype(jnp.int32)


def uvarint_encode64_pallas(lo: jax.Array, hi: jax.Array, *,
                            block: int = 4096, interpret: bool = False):
    """u64 values as (lo, hi) u32 arrays -> (byte counts, (10, n) byte
    planes) for the host scatter.  Elementwise; blocks are independent."""
    n = lo.shape[0]
    blk = min(block, n)
    while n % blk:
        blk -= 1
    return pl.pallas_call(
        _uvarint64_kernel,
        grid=(n // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((10, blk), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((10, n), jnp.int32),
        ],
        interpret=interpret,
    )(lo, hi)


# ---------------------------------------------------------------------------
# rank-linear column classification (interprocess.batch_fit_columns)
# ---------------------------------------------------------------------------
#
# A column fits the rank-linear model iff its first-order deltas are
# constant -- the same delta core as the timestamp kernel, batched over
# column tiles.  flag: 0 = no fit, 1 = constant, 2 = linear (nonzero
# slope); d0 = the first delta (the slope when linear).


def _fit_columns_kernel(v_ref, flag_ref, d0_ref):
    v = v_ref[...]                                  # (blk, R) int32
    d = v[:, 1:] - v[:, :-1]
    const = (d == 0).all(axis=1)
    linear = (d == d[:, :1]).all(axis=1) & (d[:, 0] != 0)
    flag_ref[...] = jnp.where(const, 1,
                              jnp.where(linear, 2, 0)).astype(jnp.int32)
    d0_ref[...] = d[:, 0]


def fit_columns_pallas(V: jax.Array, *, block: int = 256,
                       interpret: bool = False):
    """(C, R) int32 column matrix (R >= 2) -> per-column (flags, first
    deltas) in one pallas_call over padded column tiles.  Rows are padded
    to a block multiple with zeros (classified constant; callers slice)."""
    c, r = V.shape
    blk = min(block, c)
    pad = (-c) % blk
    if pad:
        V = jnp.concatenate([V, jnp.zeros((pad, r), V.dtype)], axis=0)
    cp = c + pad
    return pl.pallas_call(
        _fit_columns_kernel,
        grid=(cp // blk,),
        in_specs=[pl.BlockSpec((blk, r), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((cp,), jnp.int32),
            jax.ShapeDtypeStruct((cp,), jnp.int32),
        ],
        interpret=interpret,
    )(V)
