"""Timestamp delta+zigzag Pallas kernel (Recorder Section 2.2.1 hot loop).

The tracing pipeline's only arithmetic-dense stage: millions of u32 ticks
-> first-order delta -> zigzag, before zlib.  On a real pod the staging
buffers can be encoded on-device before DMA to host.  Grid = (n_blocks,)
sequential; VMEM scratch carries the previous block's last element so the
cross-block delta is exact.

Arithmetic is 32-bit two's-complement: deltas wrap mod 2^32, which matches
the reference encoder bit-for-bit whenever |delta| < 2^31 (tick deltas are
microseconds between adjacent events) and still roundtrips losslessly
through the mod-2^32 decoder otherwise.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _delta_kernel(x_ref, o_ref, prev_ref):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.int32)               # bit-pattern reinterpret

    @pl.when(i == 0)
    def _first():
        prev_ref[0] = jnp.array(0, jnp.int32)

    prev = prev_ref[0]
    shifted = jnp.concatenate([prev[None], x[:-1]])
    first_mask = (i == 0) & (jax.lax.iota(jnp.int32, x.shape[0]) == 0)
    delta = jnp.where(first_mask, x, x - shifted)  # wraps mod 2^32
    zz = (delta << 1) ^ (delta >> 31)
    o_ref[...] = zz.astype(jnp.uint32)
    prev_ref[0] = x[-1]


def delta_zigzag_pallas(ticks: jax.Array, *, block: int = 4096,
                        interpret: bool = False) -> jax.Array:
    """ticks: flat u32 array -> zigzag'd u32 deltas (first element kept)."""
    n = ticks.shape[0]
    blk = min(block, n)
    while n % blk:
        blk -= 1
    return pl.pallas_call(
        _delta_kernel,
        grid=(n // blk,),
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,))],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint32),
        scratch_shapes=[pltpu.VMEM((1,), jnp.int32)],
        interpret=interpret,
    )(ticks)
