from __future__ import annotations

from functools import partial

import jax

from .delta_encode import (
    delta_zigzag_pallas,
    delta_zigzag_varint_pallas,
    fit_columns_pallas,
    uvarint_encode64_pallas,
)


@partial(jax.jit, static_argnames=("block", "interpret"))
def delta_zigzag(ticks, *, block: int = 4096, interpret: bool = False):
    """Flat u32 ticks -> zigzag u32 deltas (matches core.timestamps)."""
    return delta_zigzag_pallas(ticks, block=block, interpret=interpret)


@partial(jax.jit, static_argnames=("block", "interpret"))
def delta_zigzag_varint(ticks, *, block: int = 4096,
                        interpret: bool = False):
    """Fused encode: flat u32 ticks -> (zigzag u32, varint byte counts,
    (5, n) byte planes with continuation bits)."""
    return delta_zigzag_varint_pallas(ticks, block=block,
                                      interpret=interpret)


@partial(jax.jit, static_argnames=("block", "interpret"))
def uvarint_encode64(lo, hi, *, block: int = 4096,
                     interpret: bool = False):
    """u64 values as (lo, hi) u32 planes -> (byte counts, (10, n) byte
    planes) for the host varint scatter."""
    return uvarint_encode64_pallas(lo, hi, block=block, interpret=interpret)


@partial(jax.jit, static_argnames=("block", "interpret"))
def fit_columns(V, *, block: int = 256, interpret: bool = False):
    """(C, R) int32 columns -> (flags, first deltas); flag 1 = constant,
    2 = rank-linear, 0 = no fit.  Outputs padded to a block multiple."""
    return fit_columns_pallas(V, block=block, interpret=interpret)
