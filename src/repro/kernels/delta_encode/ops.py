from __future__ import annotations

from functools import partial

import jax

from .delta_encode import delta_zigzag_pallas


@partial(jax.jit, static_argnames=("block", "interpret"))
def delta_zigzag(ticks, *, block: int = 4096, interpret: bool = False):
    """Flat u32 ticks -> zigzag u32 deltas (matches core.timestamps)."""
    return delta_zigzag_pallas(ticks, block=block, interpret=interpret)
