from .ops import (
    delta_zigzag,
    delta_zigzag_varint,
    fit_columns,
    uvarint_encode64,
)

__all__ = ["delta_zigzag", "delta_zigzag_varint", "fit_columns",
           "uvarint_encode64"]
