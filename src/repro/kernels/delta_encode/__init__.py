from .ops import delta_zigzag

__all__ = ["delta_zigzag"]
