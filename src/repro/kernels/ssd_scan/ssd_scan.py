"""Mamba-2 SSD chunk-scan Pallas kernel (TPU target).

Computes, per (batch, head), the chunked SSD recurrence:

  y[c] = (C_c B_c^T * L_c) dt_c x_c  +  exp(cs_c) C_c h_c      (intra+inter)
  h_{c+1} = exp(tot_c) h_c + B_c^T (dt_c * exp(tot_c - cs_c) x_c)

Grid = (B, nh, nc); the chunk axis nc is the minor/sequential grid dim and
the head state h (ns, hd) lives in VMEM scratch across chunks.  Inputs are
pre-chunked: x (B, nc, Q, nh, hd), b/c (B, nc, Q, ns), dA/dt (B, nc, Q, nh).
Block working set: Q*hd + 2*Q*ns + Q*Q + ns*hd floats; with Q=128/256,
ns=128, hd=64 this is well under VMEM.  All accumulation in f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, b_ref, c_ref, dt_ref, da_ref, y_ref, h_ref, *,
                Q: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0, :, 0, :].astype(jnp.float32)     # (Q, hd)
    b = b_ref[0, 0].astype(jnp.float32)              # (Q, ns)
    c = c_ref[0, 0].astype(jnp.float32)              # (Q, ns)
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)      # (Q,)
    da = da_ref[0, 0, :, 0].astype(jnp.float32)      # (Q,)

    cs = jnp.cumsum(da)                              # (Q,)
    tot = cs[-1]
    h = h_ref[...]                                   # (ns, hd)

    # inter-chunk: y_inter[q] = exp(cs_q) * (c_q . h)
    y_inter = jnp.exp(cs)[:, None] * jnp.dot(
        c, h, preferred_element_type=jnp.float32)    # (Q, hd)

    # intra-chunk: masked decay-weighted attention within the chunk
    scores = jnp.dot(c, b.T, preferred_element_type=jnp.float32)  # (Q, Q)
    ldecay = jnp.exp(cs[:, None] - cs[None, :])
    qi = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    w = jnp.where(qi >= ki, scores * ldecay * dt[None, :], 0.0)
    y_intra = jnp.dot(w, x, preferred_element_type=jnp.float32)

    y_ref[0, 0, :, 0, :] = (y_inter + y_intra).astype(y_ref.dtype)

    # state update: h' = exp(tot) h + B^T (dt * exp(tot - cs) * x)
    sdecay = (dt * jnp.exp(tot - cs))[:, None] * x   # (Q, hd)
    h_ref[...] = jnp.exp(tot) * h + jnp.dot(
        b.T, sdecay, preferred_element_type=jnp.float32)


def ssd_scan_pallas(x: jax.Array, b: jax.Array, c: jax.Array,
                    dt: jax.Array, da: jax.Array, *,
                    interpret: bool = False) -> jax.Array:
    """x (B, nc, Q, nh, hd); b, c (B, nc, Q, ns); dt, da (B, nc, Q, nh).
    Returns y with x's shape.  Chunk axis is scanned sequentially per
    (batch, head) with the SSD state carried in VMEM."""
    B, nc, Q, nh, hd = x.shape
    ns = b.shape[-1]
    kernel = functools.partial(_ssd_kernel, Q=Q)
    return pl.pallas_call(
        kernel,
        grid=(B, nh, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, 1, hd),
                         lambda bi, hi, ci: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, Q, ns), lambda bi, hi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, Q, ns), lambda bi, hi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda bi, hi, ci: (bi, ci, 0, hi)),
            pl.BlockSpec((1, 1, Q, 1), lambda bi, hi, ci: (bi, ci, 0, hi)),
        ],
        out_specs=pl.BlockSpec((1, 1, Q, 1, hd),
                               lambda bi, hi, ci: (bi, ci, 0, hi, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((ns, hd), jnp.float32)],
        interpret=interpret,
    )(x, b, c, dt, da)
