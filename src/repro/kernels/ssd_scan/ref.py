"""Pure-jnp oracle for the SSD chunk scan (sequential per-token recurrence)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def ssd_scan_ref(x: jax.Array, b: jax.Array, c: jax.Array,
                 dt: jax.Array, da: jax.Array) -> jax.Array:
    """Token-by-token SSD recurrence (the definitional form):

       h_t = exp(da_t) h_{t-1} + dt_t * b_t (outer) x_t
       y_t = c_t . h_t

    x (B, nc, Q, nh, hd); b, c (B, nc, Q, ns); dt, da (B, nc, Q, nh).
    """
    B, nc, Q, nh, hd = x.shape
    ns = b.shape[-1]
    xf = x.reshape(B, nc * Q, nh, hd).astype(jnp.float32)
    bf = b.reshape(B, nc * Q, ns).astype(jnp.float32)
    cf = c.reshape(B, nc * Q, ns).astype(jnp.float32)
    dtf = dt.reshape(B, nc * Q, nh).astype(jnp.float32)
    daf = da.reshape(B, nc * Q, nh).astype(jnp.float32)

    def step(h, inp):
        xt, bt, ct, dtt, dat = inp
        h = jnp.exp(dat)[..., None, None] * h + jnp.einsum(
            "bs,bh,bhd->bhsd", bt, dtt, xt)
        y = jnp.einsum("bs,bhsd->bhd", ct, h)
        return h, y

    h0 = jnp.zeros((B, nh, ns, hd), jnp.float32)
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(bf, 1, 0),
          jnp.moveaxis(cf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(daf, 1, 0))
    _, ys = lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).reshape(x.shape).astype(x.dtype)
