"""Public wrapper for the SSD chunk-scan kernel."""

from __future__ import annotations

from functools import partial

import jax

from .ssd_scan import ssd_scan_pallas


@partial(jax.jit, static_argnames=("interpret",))
def ssd_scan(x, b, c, dt, da, *, interpret: bool = False):
    """x (B, nc, Q, nh, hd); b, c (B, nc, Q, ns); dt, da (B, nc, Q, nh)."""
    return ssd_scan_pallas(x, b, c, dt, da, interpret=interpret)
