from __future__ import annotations

from functools import partial

import jax

from .rmsnorm import rmsnorm_pallas


@partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, w, *, eps: float = 1e-5, block_rows: int = 256,
            interpret: bool = False):
    return rmsnorm_pallas(x, w, eps=eps, block_rows=block_rows,
                          interpret=interpret)
