"""Fused RMSNorm Pallas kernel.

One pass over HBM: each grid step loads a (rows, d) tile into VMEM,
computes the f32 row statistics on-chip and writes the normalized tile --
vs. the unfused XLA path that runs reduce + broadcast-multiply as separate
HBM round trips.  Grid = (n_row_blocks,); d stays whole (a model dim up to
8k in bf16 is ~16 KiB/row -- trivially VMEM-resident)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)            # (rows, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_pallas(x: jax.Array, w: jax.Array, *, eps: float = 1e-5,
                   block_rows: int = 256, interpret: bool = False
                   ) -> jax.Array:
    """x (..., d); w (d,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    xr = x.reshape(-1, d)
    n = xr.shape[0]
    rb = min(block_rows, n)
    while n % rb:
        rb -= 1
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    out = pl.pallas_call(
        kernel,
        grid=(n // rb,),
        in_specs=[pl.BlockSpec((rb, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((rb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(xr, w)
    return out.reshape(orig_shape)
