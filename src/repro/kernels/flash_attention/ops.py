"""Public wrapper: (B, S, H, D) layout in, kernel layout inside."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_pallas


@partial(jax.jit, static_argnames=("causal", "window", "q_block",
                                   "kv_block", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    q_block: int = 128, kv_block: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q (B, Sq, H, D); k, v (B, Skv, KVH, D) -> (B, Sq, H, D)."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention_pallas(qt, kt, vt, causal=causal, window=window,
                                 q_block=q_block, kv_block=kv_block,
                                 interpret=interpret)
    return jnp.swapaxes(out, 1, 2)
