"""Flash attention Pallas kernel (TPU target).

Layout: q (B, H, Sq, D), k/v (B, KVH, Skv, D) -> out (B, H, Sq, D).

Grid = (B*H, nq, nk); the kv axis is the minor (sequential) grid dim, so
VMEM scratch (acc, m, l) carries the online-softmax state across kv blocks
of one q block.  Block shapes are MXU-aligned: q/out tiles (qc, D), k/v
tiles (kc, D) with qc/kc multiples of 128 in production (any divisor works
in interpret mode).  GQA is expressed in the k/v index_map (h -> h //
group); causal and sliding-window masks use block-local iota offset by the
block coordinates.  VMEM working set per step:
qc*D + 2*kc*D + qc*D (acc) + O(qc)  floats -- e.g. qc=kc=128, D=128 bf16
inputs + f32 acc = ~200 KiB, comfortably inside the ~16 MiB VMEM budget,
leaving room for double-buffered DMA of the next k/v tiles.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale: float, causal: bool, window: int, qc: int, kc: int,
                 nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (qc, D)
    k = k_ref[0, 0].astype(jnp.float32)            # (kc, D)
    v = v_ref[0, 0].astype(jnp.float32)            # (kc, D)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    q_pos = qi * qc + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 0)
    k_pos = ki * kc + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 1)
    mask = jnp.ones((qc, kc), jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           q_block: int = 128, kv_block: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q (B, H, Sq, D); k, v (B, KVH, Skv, D) with H % KVH == 0."""
    B, H, Sq, D = q.shape
    _, KVH, Skv, _ = k.shape
    if H % KVH:
        raise ValueError("H must be a multiple of KVH")
    group = H // KVH
    qc = min(q_block, Sq)
    while Sq % qc:
        qc -= 1
    kc = min(kv_block, Skv)
    while Skv % kc:
        kc -= 1
    nq, nk = Sq // qc, Skv // kc
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_attn_kernel, scale=scale, causal=causal,
                               window=window, qc=qc, kc=kc, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, qc, D),
                         lambda bh, qi, ki: (bh // H, bh % H, qi, 0)),
            pl.BlockSpec((1, 1, kc, D),
                         lambda bh, qi, ki: (bh // H, (bh % H) // group,
                                             ki, 0)),
            pl.BlockSpec((1, 1, kc, D),
                         lambda bh, qi, ki: (bh // H, (bh % H) // group,
                                             ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qc, D),
                               lambda bh, qi, ki: (bh // H, bh % H, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qc, D), jnp.float32),   # acc
            pltpu.VMEM((qc,), jnp.float32),     # running max
            pltpu.VMEM((qc,), jnp.float32),     # running denom
        ],
        interpret=interpret,
    )(q, k, v)
