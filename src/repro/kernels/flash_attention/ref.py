"""Pure-jnp oracle for flash attention (naive O(S^2) softmax attention)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0) -> jax.Array:
    """q (B, H, Sq, D); k, v (B, KVH, Skv, D)."""
    B, H, Sq, D = q.shape
    _, KVH, Skv, _ = k.shape
    g = H // KVH
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
