"""Numpy oracles for the grammar_stats kernels."""

from __future__ import annotations

import numpy as np


def row_boundaries_ref(V: np.ndarray) -> np.ndarray:
    V = np.asarray(V)
    if V.ndim == 1:
        V = V[:, None]
    mask = np.empty(V.shape[0], np.int32)
    if V.shape[0]:
        mask[0] = 1
        mask[1:] = (V[1:] != V[:-1]).any(axis=1)
    return mask


def histogram_ref(stream: np.ndarray, n_bins: int) -> np.ndarray:
    s = np.asarray(stream, np.int64).reshape(-1)
    s = s[(s >= 0) & (s < n_bins)]
    return np.bincount(s, minlength=n_bins)[:n_bins].astype(np.int32)


def digram_codes_ref(stream: np.ndarray, n_terminals: int) -> np.ndarray:
    s = np.asarray(stream, np.int64).reshape(-1)
    out = np.empty(s.shape[0], np.int32)
    if s.shape[0]:
        out[0] = -1
        out[1:] = s[:-1] * n_terminals + s[1:]
    return out
