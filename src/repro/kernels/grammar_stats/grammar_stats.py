"""Symbol-stream statistics kernels (Sequitur / TraceView, ROADMAP dir. 2+4).

Three blocked passes over expanded symbol streams:

- ``row_boundaries``: row-change mask of an (n, k) matrix, the shared core
  of ``interprocess.arith_segments`` (over row diffs: a new arithmetic run
  starts where the diff row changes) and ``Sequitur.push_stream`` RLE
  pre-tokenization (over the raw terminal column: run starts).  VMEM
  scratch carries the previous block's last row so cross-block comparisons
  are exact.
- ``histogram``: terminal occurrence counts via a blocked one-hot
  accumulate into a single output tile (grid is sequential on TPU, so
  ``o_ref[...] +=`` across blocks is well-defined).
- ``digram_codes``: directly-follows pair codes ``prev * T + cur`` with a
  cross-block carry of the previous element; the host bincounts the codes
  into the digram histogram that seeds the DFG analyses.

All int32: symbol ids and diffs fit comfortably (callers guard and fall
back to numpy otherwise).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _boundary_kernel(x_ref, o_ref, prev_ref):
    i = pl.program_id(0)
    x = x_ref[...]                                   # (blk, k) int32

    @pl.when(i == 0)
    def _first():
        prev_ref[...] = x[0]                         # row 0 forced below

    prev = prev_ref[...]
    shifted = jnp.concatenate([prev[None, :], x[:-1]], axis=0)
    diff = (x != shifted).any(axis=1)
    first_mask = (i == 0) & (jax.lax.iota(jnp.int32, x.shape[0]) == 0)
    o_ref[...] = (diff | first_mask).astype(jnp.int32)
    prev_ref[...] = x[-1]


def row_boundaries_pallas(V: jax.Array, *, block: int = 4096,
                          interpret: bool = False) -> jax.Array:
    """(n, k) int32 matrix -> int32 mask, 1 where row i != row i-1
    (position 0 always 1)."""
    n, k = V.shape
    blk = min(block, n)
    while n % blk:
        blk -= 1
    return pl.pallas_call(
        _boundary_kernel,
        grid=(n // blk,),
        in_specs=[pl.BlockSpec((blk, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        scratch_shapes=[pltpu.VMEM((k,), jnp.int32)],
        interpret=interpret,
    )(V)


def _hist_kernel(x_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]                                   # (blk,) int32
    bins = jax.lax.iota(jnp.int32, o_ref.shape[0])
    o_ref[...] += (x[None, :] == bins[:, None]).astype(jnp.int32).sum(axis=1)


def histogram_pallas(stream: jax.Array, n_bins: int, *, block: int = 4096,
                     interpret: bool = False) -> jax.Array:
    """Flat int32 stream -> (n_bins,) occurrence counts (values outside
    [0, n_bins) are ignored)."""
    n = stream.shape[0]
    blk = min(block, n)
    while n % blk:
        blk -= 1
    return pl.pallas_call(
        _hist_kernel,
        grid=(n // blk,),
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,))],
        out_specs=pl.BlockSpec((n_bins,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n_bins,), jnp.int32),
        interpret=interpret,
    )(stream)


def _digram_kernel(x_ref, o_ref, prev_ref, *, n_terminals: int):
    i = pl.program_id(0)
    x = x_ref[...]                                   # (blk,) int32

    @pl.when(i == 0)
    def _first():
        prev_ref[0] = x[0]

    prev = prev_ref[0]
    shifted = jnp.concatenate([prev[None], x[:-1]])
    codes = shifted * jnp.int32(n_terminals) + x
    first_mask = (i == 0) & (jax.lax.iota(jnp.int32, x.shape[0]) == 0)
    o_ref[...] = jnp.where(first_mask, jnp.int32(-1), codes)
    prev_ref[0] = x[-1]


def digram_codes_pallas(stream: jax.Array, n_terminals: int, *,
                        block: int = 4096,
                        interpret: bool = False) -> jax.Array:
    """Flat int32 terminal stream -> pair codes ``prev * T + cur``
    (position 0, which has no predecessor, yields -1)."""
    n = stream.shape[0]
    blk = min(block, n)
    while n % blk:
        blk -= 1
    return pl.pallas_call(
        partial(_digram_kernel, n_terminals=n_terminals),
        grid=(n // blk,),
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,))],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1,), jnp.int32)],
        interpret=interpret,
    )(stream)
