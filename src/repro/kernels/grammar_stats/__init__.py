from .ops import digram_codes, histogram, row_boundaries

__all__ = ["digram_codes", "histogram", "row_boundaries"]
