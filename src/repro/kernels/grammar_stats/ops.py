from __future__ import annotations

from functools import partial

import jax

from .grammar_stats import (
    digram_codes_pallas,
    histogram_pallas,
    row_boundaries_pallas,
)


@partial(jax.jit, static_argnames=("block", "interpret"))
def row_boundaries(V, *, block: int = 4096, interpret: bool = False):
    """(n, k) int32 matrix -> int32 row-change mask (position 0 = 1)."""
    return row_boundaries_pallas(V, block=block, interpret=interpret)


@partial(jax.jit, static_argnames=("n_bins", "block", "interpret"))
def histogram(stream, n_bins: int, *, block: int = 4096,
              interpret: bool = False):
    """Flat int32 stream -> (n_bins,) occurrence counts."""
    return histogram_pallas(stream, n_bins, block=block, interpret=interpret)


@partial(jax.jit, static_argnames=("n_terminals", "block", "interpret"))
def digram_codes(stream, n_terminals: int, *, block: int = 4096,
                 interpret: bool = False):
    """Flat int32 stream -> directly-follows pair codes (first = -1)."""
    return digram_codes_pallas(stream, n_terminals, block=block,
                               interpret=interpret)
