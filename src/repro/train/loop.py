"""Fault-tolerant training loop.

Production behaviors, all exercised by tests:

  * auto-resume from the newest valid checkpoint (crc-verified, falls back
    to older ones on corruption),
  * periodic checkpointing (sync or async thread) with keep-k GC, through
    the traced I/O facades -- a Recorder session sees the whole step loop
    (``frame.step`` events) plus the checkpoint call chains,
  * step retry with restore-on-repeated-failure,
  * straggler detection: per-step wall-time z-score against a running
    mean/variance; slow steps are reported (on a real pod this feeds the
    controller's slow-host list),
  * gradient-accumulation microbatching (``accum_steps``) for memory,
  * deterministic, resumable data (state == step counter).
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from ..checkpoint import CheckpointEngine
from ..core.apis import framework as frame
from ..models import get_model
from ..models.config import ModelConfig
from ..optim import AdamWConfig, adamw_init
from ..launch.steps import make_train_step


@dataclass
class TrainerConfig:
    num_steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 2
    log_every: int = 10
    retry_max: int = 2
    straggler_z: float = 3.0
    async_ckpt: bool = False
    accum_steps: int = 1
    seed: int = 0


class StragglerDetector:
    """Welford running mean/var over step times; flags z-score outliers."""

    def __init__(self, z: float = 3.0, warmup: int = 8):
        self.z = z
        self.warmup = warmup
        self.n = 0
        self.mean = 0.0
        self.m2 = 0.0
        self.flagged: List[int] = []

    def update(self, step: int, dt: float) -> bool:
        slow = False
        if self.n >= self.warmup:
            std = math.sqrt(self.m2 / max(self.n - 1, 1))
            if std > 0 and (dt - self.mean) / std > self.z:
                slow = True
                self.flagged.append(step)
        self.n += 1
        d = dt - self.mean
        self.mean += d / self.n
        self.m2 += d * (dt - self.mean)
        return slow


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig,
                 ocfg: Optional[AdamWConfig] = None,
                 data: Optional[Callable[[int], Dict]] = None,
                 fault_hook: Optional[Callable[[int], None]] = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.ocfg = ocfg or AdamWConfig()
        self.model = get_model(cfg)
        self.data = data
        self.fault_hook = fault_hook
        self.engine = CheckpointEngine(tcfg.ckpt_dir, keep=tcfg.keep,
                                       async_save=tcfg.async_ckpt)
        self.straggler = StragglerDetector(z=tcfg.straggler_z)
        self._step_fn = jax.jit(
            make_train_step(cfg, self.ocfg, accum_steps=tcfg.accum_steps),
            donate_argnums=(0,))
        self.state = None
        self.start_step = 0
        self.metrics_log: List[Dict[str, float]] = []

    # -- state ----------------------------------------------------------------

    def init_state(self) -> None:
        """Fresh init or auto-resume from the newest valid checkpoint."""
        params = self.model.init_params(jax.random.PRNGKey(self.tcfg.seed))
        state = adamw_init(params)
        restored = self.engine.restore_latest(jax.tree.map(np.asarray, state))
        if restored is not None:
            tree, manifest = restored
            self.state = jax.tree.map(jax.numpy.asarray, tree)
            self.start_step = int(manifest["meta"].get("next_step",
                                                       manifest["step"]))
        else:
            self.state = state
            self.start_step = 0

    # -- loop -------------------------------------------------------------------

    def _run_step(self, step: int) -> Dict[str, float]:
        batch = self.data(step)
        frame.fetch_batch(step, sum(v.nbytes for v in batch.values()))
        if self.fault_hook is not None:
            self.fault_hook(step)
        self.state, metrics = self._step_fn(self.state, batch)
        return {k: float(v) for k, v in metrics.items()}

    def run(self) -> Dict[str, Any]:
        if self.state is None:
            self.init_state()
        step = self.start_step
        retries = 0
        while step < self.tcfg.num_steps:
            frame.step(step)
            t0 = time.perf_counter()
            try:
                metrics = self._run_step(step)
            except Exception:
                retries += 1
                if retries <= self.tcfg.retry_max:
                    continue  # transient failure: retry the same step
                # repeated failure: restore from last good checkpoint
                restored = self.engine.restore_latest(
                    jax.tree.map(np.asarray, self.state))
                if restored is None:
                    raise
                tree, manifest = restored
                self.state = jax.tree.map(jax.numpy.asarray, tree)
                step = int(manifest["meta"].get("next_step",
                                                manifest["step"]))
                retries = 0
                continue
            retries = 0
            dt = time.perf_counter() - t0
            self.straggler.update(step, dt)
            metrics["step_time_s"] = dt
            metrics["step"] = step
            self.metrics_log.append(metrics)
            step += 1
            if self.tcfg.ckpt_every and step % self.tcfg.ckpt_every == 0:
                frame.ckpt_begin(step)
                self.engine.save(self.state, step, meta={"next_step": step})
                nbytes = sum(v.nbytes if hasattr(v, "nbytes") else 0
                             for v in jax.tree.leaves(self.state))
                frame.ckpt_end(step, nbytes)
        self.engine.wait()
        return {"final_step": step,
                "stragglers": list(self.straggler.flagged),
                "last_loss": self.metrics_log[-1]["loss"]
                if self.metrics_log else float("nan")}
