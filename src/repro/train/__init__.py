from .loop import StragglerDetector, Trainer, TrainerConfig

__all__ = ["Trainer", "TrainerConfig", "StragglerDetector"]
