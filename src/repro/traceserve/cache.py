"""Incremental TraceView cache: hot views, per-segment invalidation.

The service keeps one :class:`~repro.core.reader.TraceReader` per hot job
and answers queries from its memoized :class:`TraceView`.  When the job's
writer commits a new ``epoch_NNNNN/`` segment, the cache calls
``reader.refresh()`` -- the O(delta) fold that reads ONLY the new
segment, splices it onto the stitched grammars, and rolls the view's
per-unique-CFG memos forward.  Already-loaded segments are never
re-read, re-decoded, or re-walked: one new epoch costs exactly one
segment fold (``stats["segment_folds"]`` counts them, so tests can
assert the invariant directly).

Reads are *generation-stamped snapshots*.  A refresh builds a complete
replacement :class:`ViewSnapshot` under the entry lock and publishes it
with one reference swap; queries run on whatever snapshot they grabbed,
outside any lock, so a query never observes a half-folded view -- it
sees generation N in full or generation N+1 in full, nothing in between.
(Snapshot views memoize internally on first query; concurrent queries on
one snapshot may duplicate an idempotent memo fill, never corrupt one.)

Eviction is LRU by *resident compressed size* -- the bytes a cached job
actually pins (stitched CST + serialized CFGs + compressed timestamps),
which is the compressed-domain footprint, tiny next to the expanded
trace.  Evicting drops the entry without waiting on in-flight queries
(their snapshot keeps its references); a per-path generation floor keeps
generations monotonic across evict/rebuild cycles.
"""

from __future__ import annotations

import threading
import time
import warnings
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from ..core.reader import TraceReader


def resident_bytes(reader: TraceReader) -> int:
    """Compressed-domain footprint a cached reader pins: CST signature
    bytes, serialized stitched CFGs, and the compressed timestamp blobs
    (per-segment stores expose their raw blob sizes via ``nbytes`` when
    available)."""
    total = sum(len(s) for s in reader.merged_cst)
    total += sum(len(b) for b in reader._unique_bytes)
    store = reader.ts_store
    for sub in getattr(store, "_stores", [store]):
        total += int(getattr(sub, "nbytes", 0) or 0)
    return total


@dataclass(frozen=True)
class ViewSnapshot:
    """One immutable published state of a cached job.

    ``generation`` increases by exactly one per refresh that folded at
    least one segment (and per rebuild), monotonic per path even across
    evictions.  ``refreshed_at`` is the cache-clock instant the directory
    was last checked -- ``age(now)`` is therefore an upper bound on how
    far this snapshot can lag the directory (the observed staleness)."""

    path: str
    view: Any                      # TraceView
    generation: int
    n_segments: int
    coverage: Dict[str, Any]
    refreshed_at: float

    def age(self, now: float) -> float:
        return max(0.0, now - self.refreshed_at)


@dataclass
class _Entry:
    lock: threading.Lock = field(default_factory=threading.Lock)
    reader: Optional[TraceReader] = None
    snapshot: Optional[ViewSnapshot] = None
    resident: int = 0


class IncrementalViewCache:
    """LRU cache of live trace views with incremental refresh.

    ``get(path, max_staleness_s)`` returns a snapshot no older than the
    bound: a miss builds the reader + view once (``view_builds`` /
    ``segments_loaded``); a stale hit runs one ``refresh()`` and counts
    the folded segments (``segment_folds``); a fresh hit is pure
    dictionary lookup.  ``max_staleness_s=None`` always refreshes,
    ``float("inf")`` never does (pin the current snapshot).
    """

    def __init__(self, mode: str = "auto",
                 max_resident_bytes: Optional[int] = None,
                 clock=time.monotonic) -> None:
        self.mode = mode
        self.max_resident_bytes = max_resident_bytes
        self.clock = clock
        self._lock = threading.Lock()
        self._entries: "OrderedDict[str, _Entry]" = OrderedDict()
        self._gen_floor: Dict[str, int] = {}
        self.stats: Dict[str, int] = {
            "hits": 0, "misses": 0, "view_builds": 0, "segments_loaded": 0,
            "refreshes": 0, "segment_folds": 0, "evictions": 0,
        }

    # -- public API -----------------------------------------------------------

    def get(self, path: str,
            max_staleness_s: Optional[float] = None) -> ViewSnapshot:
        with self._lock:
            entry = self._entries.get(path)
            if entry is None:
                entry = _Entry()
                self._entries[path] = entry
                self.stats["misses"] += 1
            else:
                self.stats["hits"] += 1
            self._entries.move_to_end(path)
        with entry.lock:
            if entry.reader is None:
                snap = self._build(entry, path)
            else:
                snap = entry.snapshot
                if (max_staleness_s is None
                        or snap.age(self.clock()) > max_staleness_s):
                    snap = self._refresh(entry, path)
        self._maybe_evict(keep=path)
        return snap

    def peek(self, path: str) -> Optional[ViewSnapshot]:
        """Current snapshot without refreshing or touching LRU order."""
        with self._lock:
            entry = self._entries.get(path)
        return entry.snapshot if entry is not None else None

    def invalidate(self, path: str) -> bool:
        """Drop a cached job (e.g. its directory was deleted).  In-flight
        queries on its snapshots are unaffected."""
        with self._lock:
            entry = self._entries.pop(path, None)
            if entry is not None and entry.snapshot is not None:
                self._gen_floor[path] = entry.snapshot.generation
        return entry is not None

    def resident_paths(self) -> List[str]:
        with self._lock:
            return list(self._entries)

    def total_resident_bytes(self) -> int:
        with self._lock:
            return sum(e.resident for e in self._entries.values())

    # -- internals (entry.lock held) ------------------------------------------

    def _build(self, entry: _Entry, path: str) -> ViewSnapshot:
        with warnings.catch_warnings():
            # coverage is reported structurally in every snapshot; the
            # PARTIAL-coverage RuntimeWarning is for ad-hoc readers
            warnings.simplefilter("ignore", RuntimeWarning)
            reader = TraceReader(path, mode=self.mode)
            view = reader.view()
        entry.reader = reader
        self.stats["view_builds"] += 1
        self.stats["segments_loaded"] += reader.n_segments
        return self._publish(entry, path, view,
                             self._gen_floor.get(path, 0) + 1)

    def _refresh(self, entry: _Entry, path: str) -> ViewSnapshot:
        reader = entry.reader
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            folded = reader.refresh()
            view = reader.view()
        self.stats["refreshes"] += 1
        self.stats["segment_folds"] += folded
        old = entry.snapshot
        if folded == 0 and view is old.view:
            # nothing changed: keep the snapshot, reset its staleness clock
            snap = ViewSnapshot(path=path, view=old.view,
                                generation=old.generation,
                                n_segments=old.n_segments,
                                coverage=old.coverage,
                                refreshed_at=self.clock())
            entry.snapshot = snap
            return snap
        return self._publish(entry, path, view, old.generation + 1)

    def _publish(self, entry: _Entry, path: str, view,
                 generation: int) -> ViewSnapshot:
        reader = entry.reader
        snap = ViewSnapshot(path=path, view=view, generation=generation,
                            n_segments=reader.n_segments,
                            coverage=reader.coverage(),
                            refreshed_at=self.clock())
        entry.snapshot = snap
        entry.resident = resident_bytes(reader)
        return snap

    # -- eviction -------------------------------------------------------------

    def _maybe_evict(self, keep: str) -> None:
        if self.max_resident_bytes is None:
            return
        with self._lock:
            total = sum(e.resident for e in self._entries.values())
            while total > self.max_resident_bytes and len(self._entries) > 1:
                victim = next(iter(self._entries))
                if victim == keep:
                    self._entries.move_to_end(victim)
                    victim = next(iter(self._entries))
                entry = self._entries.pop(victim)
                if entry.snapshot is not None:
                    self._gen_floor[victim] = entry.snapshot.generation
                total -= entry.resident
                self.stats["evictions"] += 1
