"""Job discovery: manifest scans over a root of trace directories.

A *job* is any direct subdirectory of the watched root that is a trace:
either a streaming directory (top-level ``manifest.json``, the layout
``Recorder.flush`` commits epoch segments into) or a plain single-segment
trace (``metadata.json``).  Scanning is metadata-only -- the manifest and,
when validation is on, each segment's files are checked against their
recorded sizes/CRC32s, but no CST/CFG blob is ever decoded here.

Committed segments are immutable (atomic rename + manifest append), so
validation results are cached per ``(job, segment)``: a scan of a root
with hundreds of jobs re-reads only each job's manifest, not the payload
of every epoch ever committed.
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from ..core import trace_format


@dataclass
class JobInfo:
    """One watched trace directory, as discovered by a manifest scan."""

    name: str
    path: str
    is_stream: bool
    n_segments: int = 0
    newest_epoch: int = -1
    n_records: int = 0                 # summed from manifest entries
    has_merged: bool = False           # cleanly finalized
    degraded: List[str] = field(default_factory=list)
    quarantined: List[Dict[str, str]] = field(default_factory=list)
    error: Optional[str] = None        # unreadable manifest etc.

    @property
    def complete(self) -> bool:
        return not (self.degraded or self.quarantined or self.error)


class JobWatcher:
    """Discover jobs under ``root`` and classify their segments.

    ``validate=True`` (default) runs :func:`trace_format.validate_segment`
    on every newly seen segment -- size and CRC32 checks -- and reports
    failures as ``quarantined`` (the reader-side stitch will skip exactly
    these).  Because committed segments never change, each is validated
    once per watcher lifetime.
    """

    def __init__(self, root: str, validate: bool = True) -> None:
        self.root = root
        self.validate = validate
        self._val_cache: Dict[tuple, Optional[str]] = {}

    def scan(self) -> Dict[str, JobInfo]:
        """All jobs under the root, keyed by directory name.  Directories
        that are not traces (no manifest, no metadata) are ignored; a job
        whose manifest is unreadable is reported with ``error`` set."""
        jobs: Dict[str, JobInfo] = {}
        try:
            names = sorted(os.listdir(self.root))
        except OSError:
            return jobs
        for name in names:
            path = os.path.join(self.root, name)
            if not os.path.isdir(path):
                continue
            info = self.probe(name, path)
            if info is not None:
                jobs[name] = info
        return jobs

    def probe(self, name: str, path: str) -> Optional[JobInfo]:
        """Classify one directory; None when it is not a trace at all."""
        if trace_format.is_stream_dir(path):
            info = JobInfo(name=name, path=path, is_stream=True)
            try:
                manifest = trace_format.read_manifest(path)
            except trace_format.TraceFormatError as e:
                info.error = str(e)
                return info
            entries = manifest.get("segments", [])
            info.n_segments = len(entries)
            info.has_merged = manifest.get("merged") is not None
            for entry in entries:
                info.newest_epoch = max(info.newest_epoch,
                                        int(entry.get("epoch", -1)))
                info.n_records += int(entry.get("n_records", 0))
                if "ranks_present" in entry:
                    info.degraded.append(entry["name"])
                if self.validate:
                    reason = self._validate(path, entry)
                    if reason is not None:
                        info.quarantined.append(
                            {"segment": entry["name"], "reason": reason})
            return info
        if os.path.exists(os.path.join(path, "metadata.json")):
            return JobInfo(name=name, path=path, is_stream=False,
                           n_segments=1)
        return None

    def _validate(self, path: str, entry: Dict) -> Optional[str]:
        key = (path, entry["name"])
        if key not in self._val_cache:
            self._val_cache[key] = trace_format.validate_segment(path, entry)
        return self._val_cache[key]
