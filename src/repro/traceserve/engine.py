"""Compressed-domain query dispatch + cross-job comparisons.

One function, :func:`run_query`, maps a ``(family, params)`` request onto
the :class:`TraceView` snapshot the cache handed out -- the five
``analysis.py`` query families (``io_summary``, ``size_histogram``,
``call_chains``, ``overlap_ratio``, ``consistency_pairs``) plus
``digram_counts``, windowed ``bandwidth_bounds``, ``n_records``, the
structural ``coverage`` report, and the compressed-domain DFG
observability families (``dfg``, ``phases``, ``anomalies`` -- all
O(|grammar|), see ``core/dfg.py``).  All results are JSON-serializable.

:class:`QueryEngine` adds a per-``(job, family, params)`` memo keyed by
the snapshot's *generation*: while no new epoch has been folded, a
repeated query is a dictionary hit; the moment the cache publishes
generation N+1 the memo entry misses and the query recomputes against
the refreshed view.  Cross-job comparisons -- the bandwidth league table
and per-rank straggler detection -- compose single-job answers, so they
ride the same memo.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .cache import IncrementalViewCache, ViewSnapshot

QUERY_FAMILIES = (
    "io_summary", "size_histogram", "call_chains", "overlap_ratio",
    "consistency_pairs", "digram_counts", "bandwidth_bounds", "n_records",
    "coverage", "dfg", "phases", "anomalies",
)


def run_query(snap: ViewSnapshot, family: str,
              params: Optional[Dict[str, Any]] = None) -> Any:
    """Answer one query family against one snapshot (no caching here).

    ``params`` per family: ``size_histogram`` takes ``edges``;
    ``call_chains``/``overlap_ratio``/``digram_counts`` take ``rank``;
    ``overlap_ratio`` and ``bandwidth_bounds`` take ``t0``/``t1``;
    ``digram_counts`` takes ``top`` (default 20); ``n_records`` takes an
    optional ``rank`` (omitted: per-rank list plus total).
    """
    p = params or {}
    view = snap.view
    if family == "io_summary":
        return view.io_summary()
    if family == "size_histogram":
        if "edges" in p:
            return view.size_histogram(edges=tuple(p["edges"]))
        return view.size_histogram()
    if family == "call_chains":
        return view.call_chains(rank=int(p.get("rank", 0)))
    if family == "overlap_ratio":
        return view.overlap_ratio(
            rank=int(p.get("rank", 0)),
            t0=None if p.get("t0") is None else int(p["t0"]),
            t1=None if p.get("t1") is None else int(p["t1"]))
    if family == "consistency_pairs":
        return view.consistency_pairs()
    if family == "digram_counts":
        counts = view.digram_counts(rank=int(p.get("rank", 0)))
        top = int(p.get("top", 20))
        ranked = sorted(counts.items(), key=lambda kv: (-kv[1], kv[0]))
        return {"n_digrams": len(counts),
                "top": [[int(a), int(b), int(c)]
                        for (a, b), c in ranked[:top]]}
    if family == "bandwidth_bounds":
        if "t0" not in p or "t1" not in p:
            raise ValueError("bandwidth_bounds needs params t0 and t1")
        return view.bandwidth_bounds(int(p["t0"]), int(p["t1"]))
    if family == "n_records":
        if "rank" in p and p["rank"] is not None:
            return {"rank": int(p["rank"]),
                    "n_records": view.n_records(int(p["rank"]))}
        per_rank = [view.n_records(r) for r in range(view.nranks)]
        return {"per_rank": per_rank, "total": sum(per_rank)}
    if family == "coverage":
        return dict(snap.coverage)
    if family == "dfg":
        rank = p.get("rank")
        g = view.dfg(rank=None if rank is None else int(rank))
        top = int(p.get("top", 30))
        return {"n_nodes": len(g["nodes"]), "n_edges": len(g["edges"]),
                "n_records": g["n_records"], "nodes": g["nodes"],
                "edges": g["edges"][:top]}
    if family == "phases":
        rank = int(p.get("rank", 0))
        return {"rank": rank, "phases": view.phases(rank=rank)}
    if family == "anomalies":
        return view.rank_divergence(
            threshold=float(p.get("threshold", 0.25)))
    raise ValueError(
        f"unknown query family {family!r}; known: {QUERY_FAMILIES}")


@dataclass
class QueryResult:
    """One answered query, stamped with the snapshot it was served from."""

    path: str
    family: str
    params: Dict[str, Any]
    value: Any
    generation: int
    coverage: Dict[str, Any]
    staleness_s: float      # snapshot age when the query was answered
    latency_s: float
    cached: bool            # True: answered from the per-generation memo

    def to_dict(self) -> Dict[str, Any]:
        return {
            "path": self.path, "family": self.family, "params": self.params,
            "value": self.value, "generation": self.generation,
            "coverage": self.coverage, "staleness_s": self.staleness_s,
            "latency_s": self.latency_s, "cached": self.cached,
        }


def _freeze(params: Optional[Dict[str, Any]]) -> tuple:
    if not params:
        return ()
    return tuple(sorted(
        (k, tuple(v) if isinstance(v, (list, tuple)) else v)
        for k, v in params.items()))


class QueryEngine:
    """Memoizing query front end over an :class:`IncrementalViewCache`."""

    def __init__(self, cache: IncrementalViewCache,
                 memo_size: int = 1024) -> None:
        self.cache = cache
        self.memo_size = memo_size
        self._memo: Dict[tuple, Tuple[int, Any]] = {}
        self._memo_lock = threading.Lock()
        self.stats: Dict[str, int] = {"queries": 0, "memo_hits": 0}

    def query(self, path: str, family: str,
              params: Optional[Dict[str, Any]] = None,
              max_staleness_s: Optional[float] = None) -> QueryResult:
        t_start = time.perf_counter()
        snap = self.cache.get(path, max_staleness_s=max_staleness_s)
        key = (path, family, _freeze(params))
        cached = False
        with self._memo_lock:
            hit = self._memo.get(key)
        if hit is not None and hit[0] == snap.generation:
            value, cached = hit[1], True
        else:
            value = run_query(snap, family, params)
            with self._memo_lock:
                if len(self._memo) >= self.memo_size:
                    self._memo.clear()  # bounded; regenerates on demand
                self._memo[key] = (snap.generation, value)
        with self._memo_lock:
            self.stats["queries"] += 1
            self.stats["memo_hits"] += int(cached)
        return QueryResult(
            path=path, family=family, params=dict(params or {}), value=value,
            generation=snap.generation, coverage=dict(snap.coverage),
            staleness_s=snap.age(self.cache.clock()),
            latency_s=time.perf_counter() - t_start, cached=cached)

    # -- cross-job comparisons ------------------------------------------------

    def league_table(self, paths: Sequence[str],
                     metric: str = "aggregate_MBps",
                     max_staleness_s: Optional[float] = None
                     ) -> List[Dict[str, Any]]:
        """Jobs ranked by an ``io_summary`` metric (default: aggregate
        bandwidth), highest first.  Unreadable jobs sort last with their
        error recorded instead of a value."""
        rows: List[Dict[str, Any]] = []
        for path in paths:
            try:
                res = self.query(path, "io_summary",
                                 max_staleness_s=max_staleness_s)
            except Exception as e:  # noqa: BLE001 -- per-job isolation
                rows.append({"path": path, "error": f"{type(e).__name__}: {e}",
                             metric: None})
                continue
            rows.append({
                "path": path,
                metric: res.value.get(metric),
                "total_bytes": res.value.get("total_bytes"),
                "n_data_calls": res.value.get("n_data_calls"),
                "generation": res.generation,
                "complete": res.coverage.get("complete", True),
            })
        rows.sort(key=lambda r: (r[metric] is None, -(r[metric] or 0)))
        for i, row in enumerate(rows):
            row["rank"] = i
        return rows

    def stragglers(self, path: str, threshold: float = 0.5,
                   divergence: float = 0.25,
                   max_staleness_s: Optional[float] = None
                   ) -> Dict[str, Any]:
        """Per-rank straggler report with REASONS attached.

        A rank is flagged ``lagging`` when its record count falls below
        ``threshold`` x the median, ``partial_coverage`` when a degraded
        epoch is missing its stream (``coverage.ranks_partial``), and
        ``dfg_divergent`` when its grammar's label-projected DFG sits
        more than ``divergence`` away from the SPMD majority (the
        ``anomalies`` family).  ``reasons`` maps each flagged rank to
        its reason list; ``stragglers`` stays the flat union for
        compatibility.  Both sub-queries ride the per-generation memo.
        """
        res = self.query(path, "n_records", max_staleness_s=max_staleness_s)
        anom = self.query(path, "anomalies", {"threshold": divergence},
                          max_staleness_s=max_staleness_s)
        per_rank: List[int] = res.value["per_rank"]
        srt = sorted(per_rank)
        median = (srt[len(srt) // 2] if len(srt) % 2
                  else (srt[len(srt) // 2 - 1] + srt[len(srt) // 2]) / 2
                  ) if srt else 0
        lagging = [r for r, n in enumerate(per_rank)
                   if n < threshold * median]
        partial = list(res.coverage.get("ranks_partial", []))
        divergent = list(anom.value["divergent"])
        reasons: Dict[int, List[str]] = {}
        for rs, tag in ((lagging, "lagging"),
                        (partial, "partial_coverage"),
                        (divergent, "dfg_divergent")):
            for r in rs:
                reasons.setdefault(int(r), []).append(tag)
        return {
            "path": path,
            "median_records": median,
            "threshold": threshold,
            "divergence_threshold": divergence,
            "per_rank": per_rank,
            "lagging": lagging,
            "ranks_partial": partial,
            "dfg_divergent": divergent,
            "divergence_per_rank": anom.value["per_rank"],
            "reasons": {r: reasons[r] for r in sorted(reasons)},
            "stragglers": sorted(reasons),
            "generation": res.generation,
        }
