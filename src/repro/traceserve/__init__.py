"""Always-on trace query service (ROADMAP direction 1).

Watches many trace directories -- hundreds of concurrently-running jobs,
each committing ``epoch_NNNNN/`` segments through ``Recorder.flush`` --
and serves live compressed-domain queries over them:

:class:`~repro.traceserve.watcher.JobWatcher`
    manifest-scan discovery of jobs and their new / degraded /
    quarantined segments (reusing ``trace_format.validate_segment`` and
    the reader's ``coverage()`` semantics; committed segments are
    immutable, so each is validated once).

:class:`~repro.traceserve.cache.IncrementalViewCache`
    keeps hot :class:`~repro.core.traceview.TraceView`\\ s cached and
    folds newly committed segments in via ``TraceReader.refresh()`` --
    per-segment invalidation, one fold per new epoch, never a rescan of
    already-loaded segments -- with generation-stamped snapshot reads (a
    query can never observe a half-folded view) and LRU eviction bounded
    by resident compressed size.

:class:`~repro.traceserve.engine.QueryEngine`
    the five ``analysis.py`` query families plus ``digram_counts``,
    windowed ``bandwidth_bounds``/``overlap_ratio``, ``n_records``,
    ``coverage``, and the compressed-domain observability families
    ``dfg`` / ``phases`` / ``anomalies`` (Directly-Follows Graph, phase
    segmentation, cross-rank divergence -- all O(|grammar|), from
    ``core/dfg.py``), each answered from the cached view and memoized
    per (job, query, generation); cross-job comparisons (bandwidth
    league table, reasons-attached straggler detection) compose
    single-job answers.

:class:`~repro.traceserve.service.TraceService`
    the thread-pool front end tying the three together: per-job staleness
    bounds (a query may be answered from a view at most ``staleness_s``
    behind the directory), a background watch thread, and service-level
    stats.  ``repro.launch.traceserve`` is the CLI.
"""

from .cache import IncrementalViewCache, ViewSnapshot
from .engine import QUERY_FAMILIES, QueryEngine, QueryResult, run_query
from .service import TraceService
from .watcher import JobInfo, JobWatcher

__all__ = [
    "IncrementalViewCache", "ViewSnapshot", "QUERY_FAMILIES", "QueryEngine",
    "QueryResult", "run_query", "TraceService", "JobInfo", "JobWatcher",
]
