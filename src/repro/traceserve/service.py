"""TraceService: the always-on thread-pool front end.

Composes the watcher, the incremental view cache, and the query engine
into one object a monitoring dashboard (or the ``repro.launch.traceserve``
CLI) talks to:

* ``jobs()`` -- manifest-scan of every trace directory under the root.
* ``query(job, family, params)`` -- synchronous answer from a snapshot at
  most ``max_staleness_s`` behind the job's directory; ``submit`` is the
  same through the worker pool (concurrent clients).
* ``league_table()`` / ``stragglers(job)`` -- cross-job comparisons;
  ``stragglers`` attaches per-rank reasons (lagging / partial coverage /
  DFG-divergent).
* ``phases(job, rank)`` / ``anomalies(job)`` -- structural observability
  straight from the grammar (``core/dfg.py``).
* an optional background *watch thread* that refreshes cache-resident
  jobs every ``watch_interval_s``, so interactive queries mostly hit a
  fresh snapshot and pay dictionary-lookup latency.

Staleness contract: a query's answer reflects every segment committed up
to at most ``max_staleness_s`` before the query started (default from the
service; per-call override).  Refreshes are per-segment incremental --
serving N + 1 epochs after serving N costs one segment fold, regardless
of N -- which is what keeps an always-on service O(delta) per tick
instead of O(history).
"""

from __future__ import annotations

import os
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Sequence

from .cache import IncrementalViewCache
from .engine import QueryEngine, QueryResult
from .watcher import JobInfo, JobWatcher


class TraceService:
    def __init__(self, root: str, *, mode: str = "auto", workers: int = 4,
                 max_staleness_s: float = 1.0,
                 max_resident_bytes: Optional[int] = None,
                 validate: bool = True,
                 watch_interval_s: Optional[float] = None) -> None:
        self.root = root
        self.max_staleness_s = max_staleness_s
        self.watcher = JobWatcher(root, validate=validate)
        self.cache = IncrementalViewCache(
            mode=mode, max_resident_bytes=max_resident_bytes)
        self.engine = QueryEngine(self.cache)
        self._pool = ThreadPoolExecutor(
            max_workers=workers, thread_name_prefix="traceserve")
        self._stats_lock = threading.Lock()
        self._staleness_sum = 0.0
        self._staleness_max = 0.0
        self._n_results = 0
        self._watch_stop = threading.Event()
        self._watch_thread: Optional[threading.Thread] = None
        if watch_interval_s is not None:
            self.start_watching(watch_interval_s)

    # -- discovery ------------------------------------------------------------

    def jobs(self) -> Dict[str, JobInfo]:
        return self.watcher.scan()

    def resolve(self, job: str) -> str:
        """Job name (directory under the root) or explicit path -> path."""
        cand = os.path.join(self.root, job)
        if os.path.isdir(cand):
            return cand
        if os.path.isdir(job):
            return job
        raise KeyError(f"no job {job!r} under {self.root!r}")

    # -- queries --------------------------------------------------------------

    def query(self, job: str, family: str,
              params: Optional[Dict[str, Any]] = None,
              max_staleness_s: Optional[float] = None) -> QueryResult:
        bound = (self.max_staleness_s if max_staleness_s is None
                 else max_staleness_s)
        res = self.engine.query(self.resolve(job), family, params,
                                max_staleness_s=bound)
        with self._stats_lock:
            self._staleness_sum += res.staleness_s
            self._staleness_max = max(self._staleness_max, res.staleness_s)
            self._n_results += 1
        return res

    def submit(self, job: str, family: str,
               params: Optional[Dict[str, Any]] = None,
               max_staleness_s: Optional[float] = None) -> "Future[QueryResult]":
        """Async :meth:`query` through the worker pool."""
        return self._pool.submit(self.query, job, family, params,
                                 max_staleness_s)

    def league_table(self, jobs: Optional[Sequence[str]] = None,
                     metric: str = "aggregate_MBps") -> List[Dict[str, Any]]:
        """Bandwidth league table across jobs (default: every stream job
        under the root with at least one committed segment)."""
        if jobs is None:
            infos = self.jobs()
            paths = [i.path for i in infos.values()
                     if i.error is None and (i.n_segments or not i.is_stream)]
        else:
            paths = [self.resolve(j) for j in jobs]
        return self.engine.league_table(
            paths, metric=metric, max_staleness_s=self.max_staleness_s)

    def stragglers(self, job: str, threshold: float = 0.5,
                   divergence: float = 0.25) -> Dict[str, Any]:
        """Reasons-attached straggler report: per-rank ``lagging`` /
        ``partial_coverage`` / ``dfg_divergent`` flags plus the flat
        union (see :meth:`QueryEngine.stragglers`)."""
        return self.engine.stragglers(
            self.resolve(job), threshold=threshold, divergence=divergence,
            max_staleness_s=self.max_staleness_s)

    def phases(self, job: str, rank: int = 0) -> QueryResult:
        """Phase segmentation of one rank's stream (``phases`` family):
        labeled ``[start_record, end_record)`` ranges straight from the
        job's grammar, folded incrementally as epochs commit."""
        return self.query(job, "phases", {"rank": rank})

    def anomalies(self, job: str, threshold: float = 0.25) -> QueryResult:
        """Cross-rank DFG divergence (``anomalies`` family): per-rank
        distance from the SPMD-majority graph and the flagged ranks."""
        return self.query(job, "anomalies", {"threshold": threshold})

    # -- background watch ------------------------------------------------------

    def start_watching(self, interval_s: float) -> None:
        """Refresh every cache-resident job each ``interval_s`` so queries
        land on fresh snapshots.  Only jobs somebody queried (hence
        cached) are watched -- discovery of brand-new jobs stays on the
        query path, keeping the watch tick O(hot jobs)."""
        if self._watch_thread is not None:
            return
        self._watch_stop.clear()

        def loop() -> None:
            while not self._watch_stop.wait(interval_s):
                for path in self.cache.resident_paths():
                    if self._watch_stop.is_set():
                        return
                    try:
                        self.cache.get(path, max_staleness_s=None)
                    except Exception:  # noqa: BLE001 -- job may be deleted
                        self.cache.invalidate(path)

        self._watch_thread = threading.Thread(
            target=loop, name="traceserve-watch", daemon=True)
        self._watch_thread.start()

    def stop_watching(self) -> None:
        self._watch_stop.set()
        if self._watch_thread is not None:
            self._watch_thread.join(timeout=5.0)
            self._watch_thread = None

    # -- lifecycle / stats -----------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        with self._stats_lock:
            n = self._n_results
            mean = self._staleness_sum / n if n else 0.0
            smax = self._staleness_max
        return {
            "queries": dict(self.engine.stats),
            "cache": dict(self.cache.stats),
            "resident_jobs": len(self.cache.resident_paths()),
            "resident_bytes": self.cache.total_resident_bytes(),
            "staleness_mean_s": mean,
            "staleness_max_s": smax,
        }

    def close(self) -> None:
        self.stop_watching()
        self._pool.shutdown(wait=True)

    def __enter__(self) -> "TraceService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
