"""Sharding utilities: logical-axis constraints that degrade gracefully.

Models are written against *logical* axes ("batch", "seq", "tp", "exp", …).
``mesh_context`` records which physical mesh axes exist; ``shard`` applies a
``with_sharding_constraint`` only when every referenced physical axis is
present, so the same model code runs

  * unsharded on one CPU device (smoke tests),
  * GSPMD-sharded under the production meshes (dry-run / real pods).

Physical mapping (DESIGN.md Section 4):

  batch  -> ("pod", "data")     DP over pods x data axis
  tp     -> "model"             tensor parallel / expert parallel / seq shard
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def get_shard_map():
    """Version-tolerant ``shard_map`` accessor.

    ``jax.shard_map`` is the public name on new jax releases;  older ones
    (<= 0.4.x) only ship ``jax.experimental.shard_map.shard_map``.  All
    repo code (and test subprocess snippets) goes through this accessor.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm
    from jax.experimental.shard_map import shard_map as sm_exp

    return sm_exp


#: resolved once at import; usable as ``shard_map(f, mesh=..., ...)``
shard_map = get_shard_map()

LOGICAL_TO_PHYSICAL: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "tp": ("model",),
    "seq": ("model",),   # sequence sharding rides the model axis
    "exp": ("model",),   # expert parallelism rides the model axis
    None: (),
}


def current_mesh_axes() -> Tuple[str, ...]:
    return getattr(_state, "axes", ())


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextmanager
def mesh_context(mesh: Optional[Mesh]):
    """Enter a mesh: activates both jax's mesh context and logical sharding."""
    if mesh is None:
        yield
        return
    prev_axes = getattr(_state, "axes", ())
    prev_mesh = getattr(_state, "mesh", None)
    _state.axes = tuple(mesh.axis_names)
    _state.mesh = mesh
    try:
        with mesh:
            yield
    finally:
        _state.axes = prev_axes
        _state.mesh = prev_mesh


def _resolve(logical: Optional[str]) -> Optional[Tuple[str, ...]]:
    """Logical name -> tuple of available physical axes (None if none)."""
    axes = current_mesh_axes()
    if logical is None:
        return None
    phys = tuple(a for a in LOGICAL_TO_PHYSICAL.get(logical, (logical,))
                 if a in axes)
    return phys if phys else None


def spec(*logical: Optional[str]) -> P:
    parts = []
    for l in logical:
        r = _resolve(l)
        parts.append(r if r else None)
    return P(*parts)


def shard(x, *logical: Optional[str]):
    """Constrain ``x`` to the logical spec; no-op outside a mesh."""
    if not current_mesh_axes():
        return x
    return jax.lax.with_sharding_constraint(x, spec(*logical))


def logical_shard(x, spec_: P):
    if not current_mesh_axes():
        return x
    return jax.lax.with_sharding_constraint(x, spec_)


def axis_size(logical: str) -> int:
    """Product of the physical axis sizes behind a logical axis (1 if absent)."""
    mesh = current_mesh()
    if mesh is None:
        return 1
    n = 1
    for a in _resolve(logical) or ():
        n *= mesh.shape[a]
    return n


def named(mesh: Mesh, *parts) -> NamedSharding:
    return NamedSharding(mesh, P(*parts))


# ---------------------------------------------------------------------------
# host-to-host byte transport (Recorder's point-to-point reduce carrier)
# ---------------------------------------------------------------------------
#
# jax has no independent pairwise send: its point-to-point primitive is
# ``lax.ppermute``, a COLLECTIVE permutation every process participates in.
# ``PpermuteByteTransport.exchange`` therefore moves one log-round's worth
# of pair payloads together -- ``comm.reduce_tree_via_exchange`` calls it
# once per round with the round's (src, dst) list -- and only the round's
# senders contribute non-empty arrays.  Payloads are opaque bytes
# (serialized RankStates), packed into fixed-size length-prefixed uint8
# device arrays so every process contributes an identically-shaped operand
# (the SPMD requirement).

#: presence byte + 4-byte little-endian payload length
_LEN_HEADER = 5

#: mesh axis the host transport permutes over (one device per process)
HOST_AXIS = "hosts"


def pack_bytes_array(payload: Optional[bytes], pad_to: int) -> np.ndarray:
    """A byte payload as a fixed-size uint8 array: 1 presence byte, 4-byte
    little-endian length, payload, zero padding.  ``None`` (rank sends
    nothing this round) is distinct from ``b""`` -- the presence byte
    round-trips it."""
    n = 0 if payload is None else len(payload)
    if pad_to < n + _LEN_HEADER:
        raise ValueError(
            f"pad_to={pad_to} cannot hold a {n}-byte payload plus the "
            f"{_LEN_HEADER}-byte header")
    arr = np.zeros(pad_to, dtype=np.uint8)
    if payload is not None:
        arr[0] = 1
        arr[1:5] = np.frombuffer(n.to_bytes(4, "little"), dtype=np.uint8)
        if n:
            arr[_LEN_HEADER : _LEN_HEADER + n] = np.frombuffer(
                payload, dtype=np.uint8)
    return arr


def unpack_bytes_array(arr) -> Optional[bytes]:
    """Inverse of :func:`pack_bytes_array` (padding ignored)."""
    a = np.asarray(arr, dtype=np.uint8).reshape(-1)
    if a.size < _LEN_HEADER or a[0] == 0:
        return None
    n = int.from_bytes(a[1:5].tobytes(), "little")
    return a[_LEN_HEADER : _LEN_HEADER + n].tobytes()


class PpermuteByteTransport:
    """Collective point-to-point byte mover between jax host processes.

    ``exchange(payload, perm)`` must be called by EVERY process with the
    same ``perm`` (a list of ``(src, dst)`` process pairs); it returns the
    payload addressed to this process, or None.  Wire path: allgather the
    payload lengths to agree on a common array size, pack to uint8, lay
    the per-host arrays out over a 1-D ``hosts`` mesh (one device per
    process) and move them with a single shard_map'd ``lax.ppermute``.

    Requires a multi-process jax runtime; with one process every schedule
    is empty, so ``exchange`` is never reached (``comm.JaxComm`` guards).
    """

    def __init__(self, mesh: Optional[Mesh] = None):
        self._mesh = mesh

    def _host_mesh(self) -> Mesh:
        if self._mesh is None:
            devs = [jax.local_devices(process_index=p)[0]
                    for p in range(jax.process_count())]
            self._mesh = Mesh(np.asarray(devs), (HOST_AXIS,))
        return self._mesh

    def exchange(self, payload: Optional[bytes],
                 perm: List[Tuple[int, int]]) -> Optional[bytes]:
        if not perm:
            return None
        from jax.experimental import multihost_utils

        n = 0 if payload is None else len(payload)
        cap = int(multihost_utils.process_allgather(
            np.asarray([n], np.int64)).max()) + _LEN_HEADER
        local = pack_bytes_array(payload, cap)[None, :]
        mesh = self._host_mesh()
        spec_ = P(HOST_AXIS, None)
        global_arr = multihost_utils.host_local_array_to_global_array(
            local, mesh, spec_)
        shifted = get_shard_map()(
            lambda x: jax.lax.ppermute(x, HOST_AXIS, perm),
            mesh=mesh, in_specs=spec_, out_specs=spec_)(global_arr)
        back = multihost_utils.global_array_to_host_local_array(
            shifted, mesh, spec_)
        return unpack_bytes_array(np.asarray(back)[0])


def global_any(flag: bool) -> bool:
    """Cross-process boolean OR (the flush-cadence vote): allgather one
    uint8 per process and reduce locally.  Identity with one process."""
    if jax.process_count() == 1:
        return bool(flag)
    from jax.experimental import multihost_utils

    votes = multihost_utils.process_allgather(
        np.asarray([1 if flag else 0], np.uint8))
    return bool(np.asarray(votes).any())


# ---------------------------------------------------------------------------
# parameter sharding rules
# ---------------------------------------------------------------------------

#: leaf-name suffix -> PartitionSpec factory. Parameters are named
#: hierarchically ("layers/attn/wq", …); the *last* matching rule wins.
#: Conventions: weight matrices (in, out); stacked-layer params have a
#: leading layer dim handled by ``stacked=True``.

def param_sharding_rules(name: str, shape: Tuple[int, ...],
                         tp: str = "model") -> P:
    """Sharding spec for one parameter by naming convention.

    Layout rules (MaxText-style):
      embeddings       (vocab, d)        -> (tp, None)   vocab-sharded
      attn in-proj     (d, heads*hd)     -> (None, tp)   head-sharded
      attn out-proj    (heads*hd, d)     -> (tp, None)
      mlp in/gate      (d, ff)           -> (None, tp)
      mlp out          (ff, d)           -> (tp, None)
      experts          (E, d, ff)        -> (tp, None, None)  expert-sharded
      biases/norms/small vectors         -> replicated
    Stacked-layer params carry a leading layer axis (never sharded).
    """
    parts: list = []
    lead = 0
    if name.startswith("layers/") or name.startswith("enc_layers/") or \
            name.startswith("dec_layers/"):
        lead = 1  # scan-stacked leading layer dim
    base = [None] * (len(shape) - lead)
    ndim = len(base)

    def out(spec_parts):
        return P(*([None] * lead + list(spec_parts)))

    leaf = name.rsplit("/", 1)[-1]
    if ndim <= 1:
        return out(base)  # norms, biases, scalars: replicated
    # expert-stacked weights: (E, d_in, d_out) -> shard experts over tp
    if leaf in ("w_gate_e", "w_up_e", "w_down_e") and ndim == 3:
        return out([tp, None, None])
    if leaf in ("embed", "lm_head", "dec_embed"):
        return out([tp, None])
    if leaf in ("wq", "wk", "wv", "wkv", "w_gate", "w_up", "in_proj",
                "w_dkv", "w_kr", "w_uk", "w_uv", "w_q"):
        return out([None] * (ndim - 1) + [tp])
    if leaf in ("wo", "w_down", "out_proj"):
        return out([tp] + [None] * (ndim - 1))
    if leaf == "router":
        return out([None] * ndim)
    return out(base)


def tree_param_specs(params, tp: str = "model"):
    """Map a {name: leaf} flat dict (or pytree with '/'-joined key paths)
    to PartitionSpecs using ``param_sharding_rules``."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]

    def path_name(path) -> str:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
        return "/".join(parts)

    specs = {path_name(path): param_sharding_rules(path_name(path),
                                                   leaf.shape, tp)
             for path, leaf in flat}
    treedef = jax.tree_util.tree_structure(params)
    return jax.tree_util.tree_unflatten(
        treedef, [specs[path_name(p)] for p, _ in flat])
