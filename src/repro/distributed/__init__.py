from .sharding import (axis_size, current_mesh_axes, logical_shard,
                       mesh_context, param_sharding_rules, shard)

__all__ = ["shard", "logical_shard", "mesh_context", "current_mesh_axes",
           "axis_size", "param_sharding_rules"]
