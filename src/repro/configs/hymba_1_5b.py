"""hymba-1.5b  [arXiv:2411.13676; hf:nvidia/Hymba-1.5B-Base]

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Hybrid heads: every block runs attention and Mamba(-2 style) SSM heads in
parallel on the same input and averages the branch outputs.  Sliding-window
attention (W=1024) keeps the attention branch sub-quadratic, which is what
qualifies this arch for the ``long_500k`` shape.  Deviations from the HF
release (meta tokens, per-layer full-attn exceptions, learned branch
scales) are documented in DESIGN.md SectionArch-applicability.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    hybrid=True,
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    sliding_window=1024,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    rope_theta=10000.0,
)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=5, n_kv_heads=5, head_dim=8,
    d_ff=160, vocab_size=503, sliding_window=16, ssm_state=8,
    ssm_head_dim=16, dtype="float32", param_dtype="float32",
)
