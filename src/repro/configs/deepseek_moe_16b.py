"""deepseek-moe-16b  [arXiv:2401.06066; hf:deepseek-ai/deepseek-moe-16b-base]

28L d_model=2048 16H (GQA kv=16) vocab=102400.  Fine-grained MoE: 2 shared +
64 routed experts, top-6, expert d_ff=1408.  Layer 0 is a dense-FFN layer
(first_k_dense_replace=1, dense d_ff=10944 per the HF config); the
assignment line's d_ff=1408 is the per-expert (moe_intermediate) width.

I/O-pattern note (paper technique): expert-sharded checkpoints write shard
offsets linear in (rank, expert_id) -- the nested IterPattern/RankPattern
case of paper Fig 3(c).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,                   # per-expert width (assignment)
    vocab_size=102400,
    head_dim=128,
    n_shared_experts=2,
    n_routed_experts=64,
    moe_top_k=6,
    d_ff_expert=1408,
    first_k_dense=1,
    first_dense_ff=10944,
    rope_theta=10000.0,
)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=32, d_ff_expert=32, n_routed_experts=8, moe_top_k=2,
    n_shared_experts=1, first_k_dense=1, first_dense_ff=128,
    vocab_size=503, dtype="float32", param_dtype="float32",
)
