"""qwen1.5-0.5b  [hf:Qwen/Qwen1.5-0.5B; hf]

24L d_model=1024 16H (kv=16) d_ff=2816 vocab=151936.  QKV bias enabled
(the Qwen1.5 signature).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1000000.0,
)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=8, n_kv_heads=8, d_ff=160,
    vocab_size=503, dtype="float32", param_dtype="float32",
)
