"""deepseek-v2-lite-16b  [arXiv:2405.04434; hf:deepseek-ai/DeepSeek-V2-Lite]

27L d_model=2048 16H vocab=102400.  MLA attention with kv_lora_rank=512
(qk_nope=128, qk_rope=64, v=128; no q-LoRA in the Lite variant).  MoE:
2 shared + 64 routed top-6 experts (d_ff_expert=1408); layer 0 dense
(d_ff=10944).  The assignment note mentions "160 routed" (the full V2
number); V2-*Lite* ships 64 routed experts, matching the assignment header
"MoE 64e top-6" -- we implement 64 and expose ``n_routed_experts`` as a
plain config field (160 divides the 16-way expert axis too).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    mla=True,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    n_shared_experts=2,
    n_routed_experts=64,
    moe_top_k=6,
    d_ff_expert=1408,
    first_k_dense=1,
    first_dense_ff=10944,
    rope_theta=10000.0,
)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, kv_lora_rank=32, qk_nope_dim=16,
    qk_rope_dim=8, v_head_dim=16, d_ff=32, d_ff_expert=32,
    n_routed_experts=8, moe_top_k=2, n_shared_experts=1,
    first_k_dense=1, first_dense_ff=128, vocab_size=503,
    dtype="float32", param_dtype="float32",
)
