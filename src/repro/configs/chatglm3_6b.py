"""chatglm3-6b  [arXiv:2406.12793; hf:THUDM/chatglm3-6b]

28L d_model=4096 32H (multi-query GQA kv=2) d_ff=13696 vocab=65024.
2D RoPE: rotation applied to half of each head dim (rope_fraction=0.5);
QKV bias enabled (add_qkv_bias=true in the HF config).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    head_dim=128,
    rope_fraction=0.5,
    qkv_bias=True,
    rope_theta=10000.0,
)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=160, vocab_size=503, dtype="float32", param_dtype="float32",
)
