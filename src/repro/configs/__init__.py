"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the full published configuration;
``get_smoke_config(name)`` returns the reduced same-family configuration
used by the CPU smoke tests (few layers, narrow widths, tiny vocab).
"""

from __future__ import annotations

from importlib import import_module
from typing import Dict, List

from ..models.config import ModelConfig

ARCHS: List[str] = [
    "deepseek_moe_16b",
    "deepseek_v2_lite_16b",
    "chatglm3_6b",
    "stablelm_1_6b",
    "qwen3_32b",
    "qwen1_5_0_5b",
    "hymba_1_5b",
    "llava_next_34b",
    "mamba2_370m",
    "seamless_m4t_large_v2",
]

# assignment ids use dashes / dots
ALIASES = {
    "deepseek-moe-16b": "deepseek_moe_16b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "chatglm3-6b": "chatglm3_6b",
    "stablelm-1.6b": "stablelm_1_6b",
    "qwen3-32b": "qwen3_32b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "hymba-1.5b": "hymba_1_5b",
    "llava-next-34b": "llava_next_34b",
    "mamba2-370m": "mamba2_370m",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
}


def _module(name: str):
    key = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    if key not in ARCHS:
        raise KeyError(f"unknown architecture {name!r}; known: {sorted(ALIASES)}")
    return import_module(f".{key}", __package__)


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).SMOKE


def all_arch_names() -> List[str]:
    return list(ALIASES.keys())
