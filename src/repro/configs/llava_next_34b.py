"""llava-next-34b  [hf:llava-hf/llava-v1.6-34b-hf (Yi-34B backbone); unverified]

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.  The vision tower
and anyres tiling are a STUB per the assignment: ``input_specs`` provides
precomputed patch embeddings (B, n_patches=2880, d_model) -- 5 anyres tiles
x 576 CLIP patches -- that are prepended to the text embeddings.  Text
positions follow the patch positions; logits/loss cover text only.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    head_dim=128,
    n_patches=2880,
    rope_theta=5000000.0,
)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=7, n_kv_heads=7, head_dim=8,
    d_ff=160, vocab_size=503, n_patches=8,
    dtype="float32", param_dtype="float32",
)
