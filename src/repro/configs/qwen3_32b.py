"""qwen3-32b  [hf:Qwen/Qwen3-32B (per Qwen3-8B family card); hf]

64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936.  QK-norm per head
(RMS over head dim), explicit head_dim=128, no QKV bias (Qwen3 dropped it).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_ff=25600,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1000000.0,
)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=160, vocab_size=503, dtype="float32", param_dtype="float32",
)
