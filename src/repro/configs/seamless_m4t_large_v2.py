"""seamless-m4t-large-v2  [arXiv:2308.11596; hf:facebook/seamless-m4t-v2-large]

Encoder-decoder backbone: 24 encoder + 24 decoder layers, d_model=1024,
16H (kv=16), d_ff=8192, vocab=256206 (padded to 256256 for TP sharding).
The speech frontend (fbank + conformer conv subsampling) is a STUB:
``input_specs`` provides precomputed frame embeddings (B, S, d_model).
Decoder decode steps cache self-attention KV plus the cross-attention K/V
computed once from the encoder output.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    norm="layer",
    frontend="audio",
    rope_theta=10000.0,
)

SMOKE = CONFIG.replace(
    n_layers=2, n_encoder_layers=2, d_model=64, n_heads=8, n_kv_heads=8,
    d_ff=160, vocab_size=503, dtype="float32", param_dtype="float32",
)
