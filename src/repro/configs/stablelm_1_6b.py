"""stablelm-1.6b  [hf:stabilityai/stablelm-2-1_6b; unverified]

24L d_model=2048 32H (kv=32) d_ff=5632 vocab=100352.  LayerNorm (not RMS),
partial rotary (25% of head dim), QKV bias per the HF config.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    norm="layer",
    rope_fraction=0.25,
    qkv_bias=True,
    rope_theta=10000.0,
)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=8, n_kv_heads=8, d_ff=160,
    vocab_size=503, dtype="float32", param_dtype="float32",
)
