"""mamba2-370m  [arXiv:2405.21060; hf:state-spaces/mamba2-370m; unverified]

48L d_model=1024, attention-free SSD (state-space duality), ssm_state=128,
vocab=50280.  d_inner = 2*d_model = 2048, head_dim 64 -> 32 SSD heads,
depthwise conv width 4, chunked scan with Q=256.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
    vocab_size=503, dtype="float32", param_dtype="float32",
)
