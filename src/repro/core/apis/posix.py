"""POSIX layer (traced facade over ``os``) -- paper Fig 1 bottom layer.

The framework's checkpoint/data subsystems perform all file I/O through this
module so every call is interceptable (the LD_PRELOAD analogue; see
DESIGN.md).  When no recorder is attached, each function is a direct
passthrough to ``os``.
"""

from __future__ import annotations

import os

from ..specs import REGISTRY, Arg, FnSpec, Role
from ..wrappers import generate_wrappers

_L = "posix"

SPECS = [
    FnSpec("open", _L, [Arg("path", Role.PATH), Arg("flags", Role.VAL),
                        Arg("mode", Role.VAL)],
           impl=os.open, ret_role=Role.HANDLE),
    FnSpec("close", _L, [Arg("fd", Role.HANDLE)], impl=os.close),
    FnSpec("pwrite", _L, [Arg("fd", Role.HANDLE), Arg("buf", Role.BUF),
                          Arg("offset", Role.OFFSET)],
           impl=os.pwrite, ret_role=Role.SIZE),
    FnSpec("pread", _L, [Arg("fd", Role.HANDLE), Arg("count", Role.SIZE),
                         Arg("offset", Role.OFFSET)],
           impl=os.pread, ret_role=Role.BUF),
    FnSpec("write", _L, [Arg("fd", Role.HANDLE), Arg("buf", Role.BUF)],
           impl=os.write, ret_role=Role.SIZE),
    FnSpec("read", _L, [Arg("fd", Role.HANDLE), Arg("count", Role.SIZE)],
           impl=os.read, ret_role=Role.BUF),
    FnSpec("lseek", _L, [Arg("fd", Role.HANDLE), Arg("offset", Role.OFFSET),
                         Arg("whence", Role.VAL)],
           impl=os.lseek, ret_role=Role.OFFSET),
    FnSpec("fsync", _L, [Arg("fd", Role.HANDLE)], impl=os.fsync),
    FnSpec("ftruncate", _L, [Arg("fd", Role.HANDLE), Arg("length", Role.SIZE)],
           impl=os.ftruncate),
    FnSpec("rename", _L, [Arg("src", Role.PATH), Arg("dst", Role.PATH)],
           impl=os.rename),
    FnSpec("unlink", _L, [Arg("path", Role.PATH)], impl=os.unlink),
    # real POSIX mkdir: creating an existing directory fails with EEXIST
    # (recorded as an err return); use makedirs for idempotent recursive
    # creation (the checkpoint engine's commit-dir preparation)
    FnSpec("mkdir", _L, [Arg("path", Role.PATH), Arg("mode", Role.VAL)],
           impl=os.mkdir),
    FnSpec("makedirs", _L, [Arg("path", Role.PATH), Arg("mode", Role.VAL)],
           impl=lambda path, mode=0o777: os.makedirs(path, mode, exist_ok=True)),
    FnSpec("rmdir", _L, [Arg("path", Role.PATH)], impl=os.rmdir),
    FnSpec("stat", _L, [Arg("path", Role.PATH)],
           impl=lambda path: os.stat(path).st_size),
    FnSpec("access", _L, [Arg("path", Role.PATH), Arg("mode", Role.VAL)],
           impl=os.access),
    FnSpec("chmod", _L, [Arg("path", Role.PATH), Arg("mode", Role.VAL)],
           impl=os.chmod),
    FnSpec("opendir", _L, [Arg("path", Role.PATH)],
           impl=lambda path: len(os.listdir(path))),
    FnSpec("readlink", _L, [Arg("path", Role.PATH)], impl=os.readlink),
    FnSpec("symlink", _L, [Arg("src", Role.PATH), Arg("dst", Role.PATH)],
           impl=os.symlink),
]

_api = generate_wrappers(SPECS, REGISTRY)

open = _api.open
close = _api.close
pwrite = _api.pwrite
pread = _api.pread
write = _api.write
read = _api.read
lseek = _api.lseek
fsync = _api.fsync
ftruncate = _api.ftruncate
rename = _api.rename
unlink = _api.unlink
mkdir = _api.mkdir
makedirs = _api.makedirs
rmdir = _api.rmdir
stat = _api.stat
access = _api.access
chmod = _api.chmod
opendir = _api.opendir
readlink = _api.readlink
symlink = _api.symlink
