"""Framework event layer (paper §2.2 CUDA-kernel analogue).

The original Recorder optionally traces CUDA kernel launches via CUPTI,
"treating kernel invocations as ordinary I/O calls".  The TPU-framework
analogue is the dispatch of compiled steps and pipeline events:

    step(step_idx)         one optimizer step dispatch
    serve_step(step_idx)   one decode step dispatch
    fetch_batch(step_idx)  one data-pipeline batch
    ckpt_begin/ckpt_end    checkpoint bracket (async thread shows its own tid)

``step_idx`` is OFFSET-role: the intra-process pattern pass recognizes the
``i*1 + 0`` progression, so an arbitrarily long step loop compresses to a
constant-size grammar -- the paper's technique applied to the training loop
itself.
"""

from __future__ import annotations

from ..specs import REGISTRY, Arg, FnSpec, Role
from ..wrappers import generate_wrappers

_L = "frame"


def _noop(*a, **k):
    return 0


SPECS = [
    FnSpec("step", _L, [Arg("step_idx", Role.OFFSET)], impl=_noop),
    FnSpec("serve_step", _L, [Arg("step_idx", Role.OFFSET)], impl=_noop),
    FnSpec("fetch_batch", _L, [Arg("step_idx", Role.OFFSET),
                               Arg("nbytes", Role.SIZE)], impl=_noop),
    FnSpec("ckpt_begin", _L, [Arg("step_idx", Role.OFFSET)], impl=_noop),
    FnSpec("ckpt_end", _L, [Arg("step_idx", Role.OFFSET),
                            Arg("nbytes", Role.SIZE)], impl=_noop),
]

_api = generate_wrappers(SPECS, REGISTRY)

step = _api.step
serve_step = _api.serve_step
fetch_batch = _api.fetch_batch
ckpt_begin = _api.ckpt_begin
ckpt_end = _api.ckpt_end
