"""Traced API layers.  Import order fixes function ids; keep it stable."""

from . import posix  # noqa: F401  (layer: posix)
from . import shardio  # noqa: F401  (layer: shardio -- the MPI-IO analogue)
from . import framework  # noqa: F401  (layer: frame -- step/fetch/ckpt events)
