"""Shard-I/O layer (the framework's MPI-IO analogue) -- paper Fig 1 middle.

Collective shard read/write used by the checkpoint engine: every host writes
its shard of each global array into a shared file at
``offset = rank * shard_bytes`` -- exactly the strided pattern of paper
Listing 3, which the compression pipeline recognizes across ranks.

Implementations call down through the traced POSIX facade, so traces show
the full call chain with increasing call depth (paper §2.2.1 "Call Depth").
"""

from __future__ import annotations

import os as _os

from ..specs import REGISTRY, Arg, FnSpec, Role
from ..wrappers import generate_wrappers
from . import posix

_L = "shardio"


def _shard_open_impl(path, mode):
    flags = _os.O_RDWR | _os.O_CREAT if mode == 1 else _os.O_RDONLY
    return posix.open(path, flags, 0o644)


def _shard_write_at_impl(fh, buf, offset):
    return posix.pwrite(fh, buf, offset)


def _shard_read_at_impl(fh, count, offset):
    return posix.pread(fh, count, offset)


def _shard_sync_impl(fh):
    return posix.fsync(fh)


def _shard_close_impl(fh):
    return posix.close(fh)


def _shard_commit_impl(tmp_path, final_path):
    return posix.rename(tmp_path, final_path)


SPECS = [
    FnSpec("shard_open", _L, [Arg("path", Role.PATH), Arg("mode", Role.VAL)],
           impl=_shard_open_impl, ret_role=Role.HANDLE, collective=True),
    FnSpec("shard_write_at", _L, [Arg("fh", Role.HANDLE), Arg("buf", Role.BUF),
                                  Arg("offset", Role.OFFSET)],
           impl=_shard_write_at_impl, ret_role=Role.SIZE),
    FnSpec("shard_read_at", _L, [Arg("fh", Role.HANDLE), Arg("count", Role.SIZE),
                                 Arg("offset", Role.OFFSET)],
           impl=_shard_read_at_impl, ret_role=Role.BUF),
    FnSpec("shard_sync", _L, [Arg("fh", Role.HANDLE)], impl=_shard_sync_impl),
    FnSpec("shard_close", _L, [Arg("fh", Role.HANDLE)], impl=_shard_close_impl),
    FnSpec("shard_commit", _L, [Arg("tmp_path", Role.PATH),
                                Arg("final_path", Role.PATH)],
           impl=_shard_commit_impl),
]

_api = generate_wrappers(SPECS, REGISTRY)

shard_open = _api.shard_open
shard_write_at = _api.shard_write_at
shard_read_at = _api.shard_read_at
shard_sync = _api.shard_sync
shard_close = _api.shard_close
shard_commit = _api.shard_commit
