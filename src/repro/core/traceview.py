"""Compressed-domain trace queries (paper Section 4 without expansion).

``TraceView`` is the read-side counterpart of the tree finalize (PR 1): it
answers the Section-4 analyses from the compressed representation directly
instead of expanding every record through a per-record Python iterator.
Three pillars:

grammar-weighted aggregation
    Per-terminal occurrence counts come from Sequitur rule expansion
    weights (``sequitur.rule_weights`` / ``terminal_counts``) in
    O(|grammar|), so record counts, call mixes, size histograms and byte
    totals are sums over <= |CST| distinct signatures x weights -- never
    over expanded records.

columnar materialization
    The merged CST is batch-decoded ONCE into NumPy header columns plus
    role-indexed size / handle / offset-encoding columns
    (``encoding.decode_signatures_batch``).  Per-rank timestamp arrays are
    decompressed lazily and memoized, only when a query touches them.

rank-symbolic resolution
    ``RankPattern`` / ``IterPattern`` offsets stay symbolic in the columns.
    Queries that need concrete per-record extents (consistency analysis)
    walk the terminal stream ONCE per unique CFG -- every rank sharing a
    CFG has the same stream -- keeping each offset as a linear function of
    the rank, then resolve all ranks in a closed-form vectorized pass
    (the read-side use of the linear-summary idea from ``interprocess``).

Exactness: every query is value-identical to the record-iterator path
(``TraceReader.iter_records``), property-tested in
``tests/test_traceview.py``.  Where a compressed-domain shortcut could
diverge on pathological streams (per-file attribution under ambiguous
handle reuse, rank-dependent pattern-run continuation), the view detects
the case from the compressed form and falls back to an exact per-CFG or
per-rank walk.
"""

from __future__ import annotations

import heapq
import warnings
from collections import defaultdict
from itertools import repeat
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from . import dfg as _dfg
from .encoding import (Handle, IterPattern, RankPattern,
                       concat_signature_columns, decode_signatures_batch)
from .patterns import IntraPatternDecoder
from .reader import Record, _resolve_rank
from .sequitur import (_topo_order, expand_grammar, expand_grammar_reversed,
                       parse_grammar, terminal_counts, terminal_positions)
from .specs import DATA_FUNCS
from .timestamps import effective_exit

# record path and read side share one definition of "data-moving call"
# (specs.DATA_FUNCS); the old name stays importable for existing callers
_DATA_FUNCS = DATA_FUNCS
_OPEN_FUNCS = ("open", "shard_open")
_IO_LAYERS = ("posix", "shardio")
_WRITE_FUNCS = ("pwrite", "shard_write_at")
_I64_SAFE = 1 << 62
_NO_HANDLE = object()


class _SpanBail(Exception):
    """Span walk cannot resolve rank-symbolically (same conditions under
    which the linear replay returns None)."""


class _SpanOverflow(Exception):
    """Span walk left the int64-exact range; redo with Python ints."""


def _contains_rankpattern(v: Any) -> bool:
    if isinstance(v, RankPattern):
        return True
    if isinstance(v, IterPattern):
        return _contains_rankpattern(v.a) or _contains_rankpattern(v.b)
    if isinstance(v, tuple):
        return any(_contains_rankpattern(x) for x in v)
    return False


def _lin0(v: Any) -> Tuple[int, int]:
    """(rank coefficient, constant) of a rank-symbolic scalar."""
    if isinstance(v, RankPattern):
        return v.a, v.b
    return 0, int(v)


def _derive_key(func_id: int, tidx: int, args: tuple, ret: Any,
                roles: Sequence[str], ret_is_offset: bool) -> tuple:
    """The pattern-run decode key of one call: non-offset args split into
    handle ids and key parts (single definition site -- the decoder state
    only matches the runtime tracker if every path builds keys this way)."""
    handle_ids: List[int] = []
    keyparts: List[Any] = []
    for j, a in enumerate(args):
        role = roles[j] if j < len(roles) else "val"
        if role == "offset":
            continue
        if isinstance(a, Handle):
            handle_ids.append(a.id)
        else:
            keyparts.append(a)
    key_ret = None if ret_is_offset else (
        ("h", ret.id) if isinstance(ret, Handle) else ret)
    return (func_id, tidx, tuple(handle_ids), tuple(keyparts), key_ret)


def sweep_conflicts(writes: Dict[Any, List[Tuple[int, int, int]]]
                    ) -> List[Dict[str, Any]]:
    """Cross-rank conflicting extents via an active-interval sweep.

    ``writes`` maps a handle id to ``(rank, start, end)`` half-open spans.
    Every pair of overlapping spans from DIFFERENT ranks is reported (the
    seed scanned only start-adjacent pairs, dropping e.g. a long extent
    overlapping a later non-adjacent span); identical conflicts are
    deduplicated.  ``ranks`` orders the earlier-starting span first and the
    reported extent is ``(later start, min(ends))``.
    """
    conflicts: List[Dict[str, Any]] = []
    seen = set()
    for hid, spans in writes.items():
        # identical (rank, start, end) spans can only rediscover already-
        # deduplicated conflicts; dropping them up front keeps the sweep
        # near-linear when ranks repeatedly rewrite one extent
        spans = list(dict.fromkeys(spans))
        active: List[Tuple[int, int]] = []  # heap of (end, rank)
        for r2, a2, b2 in sorted(spans, key=lambda s: s[1]):
            while active and active[0][0] <= a2:
                heapq.heappop(active)
            for b1, r1 in active:
                if r1 != r2:
                    ext = (a2, min(b1, b2))
                    k = (hid, r1, r2, ext)
                    if k not in seen:
                        seen.add(k)
                        conflicts.append({"handle": hid, "ranks": (r1, r2),
                                          "extent": ext})
            heapq.heappush(active, (b2, r2))
    return conflicts


class _SigInfo:
    """Per-CST-entry derived metadata (role-indexed columns)."""

    __slots__ = ("name", "layer", "is_data", "is_io_layer", "size",
                 "size_symbolic", "handle", "enc")

    def __init__(self) -> None:
        self.enc: Optional[tuple] = None


def make_sig_info(cols, functions: Dict[int, Dict[str, Any]],
                  t: int) -> _SigInfo:
    """Derived metadata of CST entry ``t`` from decoded columns -- the one
    definition site shared by full view construction and the incremental
    refresh path (which derives it only for NEW entries)."""
    finfo = functions[int(cols.func_id[t])]
    args, ret = cols.args[t], cols.ret[t]
    roles = finfo["arg_roles"]
    s = _SigInfo()
    s.name = finfo["name"]
    s.layer = finfo["layer"]
    s.is_data = s.name in _DATA_FUNCS
    s.is_io_layer = s.layer in _IO_LAYERS
    # _size_of: first BUF/SIZE int arg, else int return, else 0
    size = None
    for v, role in zip(args, roles):
        if role in ("buf", "size") and isinstance(v, int):
            size = v
            break
    ret_is_offset = (finfo["ret_role"] == "offset"
                     and isinstance(ret, (int, IterPattern, RankPattern)))
    s.size = size if size is not None else (
        ret if isinstance(ret, int) else 0)
    # a size that would come from a pattern-coded return cannot be read
    # off the signature alone (it depends on the run index / rank)
    s.size_symbolic = size is None and ret_is_offset \
        and not isinstance(ret, int)
    s.handle = next((v.id for v, role in zip(args, roles)
                     if role == "handle" and hasattr(v, "id")), _NO_HANDLE)
    off_slots = [j for j, r in enumerate(roles)
                 if r == "offset" and j < len(args)]
    if off_slots or ret_is_offset:
        key = _derive_key(int(cols.func_id[t]), int(cols.thread[t]),
                          args, ret, roles, ret_is_offset)
        enc = [args[j] for j in off_slots]
        if ret_is_offset:
            enc.append(ret)
        patsig = tuple((v.a, v.b) if isinstance(v, IterPattern) else v
                       for v in enc)
        has_iter = any(isinstance(v, IterPattern) for v in enc)
        # run-key components are never offset-fitted, so a RankPattern
        # in them would make run identity rank-dependent (guarded)
        key_rankdep = (_contains_rankpattern(key[3])
                       or _contains_rankpattern(key[4]))
        s.enc = (key, tuple(enc), patsig, has_iter, off_slots,
                 ret_is_offset, key_rankdep)
    return s


def per_file_fold(rules: List[List[Tuple[int, int]]], sigs, cols,
                  live0: Dict[int, str], toff: int = 0
                  ) -> Tuple[Dict[Any, Tuple[int, int]], Dict[int, str]]:
    """Per-file attribution of ONE grammar's stream as a resumable fold.

    Evaluates ``rules`` (terminal ids local to the grammar, offset by
    ``toff`` into ``sigs``/``cols``) under ENTRY handle->path bindings
    ``live0`` and returns ``(contrib, exit_live)`` where ``contrib`` maps
    file key -> ``(bytes, calls)`` and ``exit_live`` is the binding state
    after the whole stream.  This makes per-file attribution composable
    across epoch segments: fold segment k+1 with segment k's exit state
    and add the contributions -- the incremental-refresh path never
    replays already-folded segments.

    Same rule/read-set memo walk as the PR 8 sublinear path (a rule's
    contribution depends only on the live bindings of the handles its
    subtree reads; idempotent state updates collapse exponents in closed
    form).  Raises RecursionError on pathologically deep grammars --
    callers fall back to :func:`per_file_fold_linear`.
    """
    n = len(rules)
    # static per-rule summaries, children before parents: the handles a
    # rule's subtree attributes data calls to (its read set) and its net
    # handle->path state update (constant strings -> idempotent)
    reads: List[set] = [set() for _ in range(n)]
    upd: List[Dict[int, str]] = [{} for _ in range(n)]
    for i in reversed(_topo_order(rules)):
        rd: set = set()
        up: Dict[int, str] = {}
        for code, _exp in rules[i]:
            x = code >> 1
            if code & 1:
                rd |= reads[x]
                up.update(upd[x])
            else:
                s = sigs[x + toff]
                if s.is_data and s.handle is not _NO_HANDLE:
                    rd.add(s.handle)
                if s.name in _OPEN_FUNCS and hasattr(cols.ret[x + toff],
                                                     "id"):
                    up[cols.ret[x + toff].id] = str(cols.args[x + toff][0])
        reads[i] = rd
        upd[i] = up

    live: Dict[int, str] = dict(live0)
    memo: Dict[tuple, Dict[Any, Tuple[int, int]]] = {}

    def add(dst: Dict[Any, Tuple[int, int]],
            src: Dict[Any, Tuple[int, int]], mult: int) -> None:
        for k, (b, c) in src.items():
            ob, oc = dst.get(k, (0, 0))
            dst[k] = (ob + mult * b, oc + mult * c)

    def walk(rid: int) -> Dict[Any, Tuple[int, int]]:
        rkey = (rid,) + tuple((h, live.get(h))
                              for h in sorted(reads[rid]))
        hit = memo.get(rkey)
        if hit is not None:
            live.update(upd[rid])
            return hit
        contrib: Dict[Any, Tuple[int, int]] = {}
        for code, exp in rules[rid]:
            x = code >> 1
            if code & 1:
                add(contrib, walk(x), 1)
                if exp > 1:
                    # state after app 1 is a fixed point: apps 2..exp
                    # all see the same entry state and contribute alike
                    add(contrib, walk(x), exp - 1)
            else:
                s = sigs[x + toff]
                if s.name in _OPEN_FUNCS and hasattr(cols.ret[x + toff],
                                                     "id"):
                    live[cols.ret[x + toff].id] = str(cols.args[x + toff][0])
                if s.is_data:
                    k = "?" if s.handle is _NO_HANDLE \
                        else live.get(s.handle)
                    ob, oc = contrib.get(k, (0, 0))
                    contrib[k] = (ob + exp * s.size, oc + exp)
        memo[rkey] = contrib
        return contrib

    res = walk(0) if rules else {}
    return res, live


def per_file_fold_linear(rules: List[List[Tuple[int, int]]], sigs, cols,
                         live0: Dict[int, str], toff: int = 0
                         ) -> Tuple[Dict[Any, Tuple[int, int]],
                                    Dict[int, str]]:
    """Linear-stream reference (and deep-grammar fallback) for
    :func:`per_file_fold`: one walk of the expanded stream."""
    handles: Dict[int, str] = dict(live0)
    per: Dict[Any, Tuple[int, int]] = {}
    for t in expand_grammar(rules):
        s = sigs[t + toff]
        if s.name in _OPEN_FUNCS and hasattr(cols.ret[t + toff], "id"):
            handles[cols.ret[t + toff].id] = str(cols.args[t + toff][0])
        if s.is_data:
            key = "?" if s.handle is _NO_HANDLE else handles.get(s.handle)
            b, c = per.get(key, (0, 0))
            per[key] = (b + s.size, c + 1)
    return per, handles


def _contrib_dicts(contrib: Dict[Any, Tuple[int, int]]
                   ) -> Dict[Any, Dict[str, int]]:
    return {k: {"bytes": b, "calls": c} for k, (b, c) in contrib.items()}


class TraceView:
    """Columnar, compressed-domain query API over one trace directory.

    Build it with :meth:`TraceReader.view`.  Aggregate queries
    (:meth:`io_summary`, :meth:`size_histogram`, :meth:`n_records`) run in
    O(|grammar| + |CST|); sequential queries (:meth:`call_chains`,
    :meth:`consistency_pairs`) cost one stream walk per *unique CFG*, not
    per rank; :meth:`iter_records` is the lossless row-wise reference path
    that the ``TraceReader`` shims delegate to.
    """

    def __init__(self, reader,
                 _reuse: Optional[Dict[str, Any]] = None) -> None:
        if getattr(reader, "degraded", False):
            cov = reader.coverage()
            warnings.warn(
                f"trace has PARTIAL coverage: "
                f"{len(cov['degraded_epochs'])} degraded epoch(s) "
                f"(ranks with gapped streams: {cov['ranks_partial']}), "
                f"{len(cov['skipped'])} skipped segment(s) -- analyses "
                f"are exact over the records present but do not cover "
                f"the full job history", RuntimeWarning, stacklevel=3)
        self.reader = reader
        self.nranks: int = reader.nranks
        self.functions: Dict[int, Dict[str, Any]] = reader.functions
        self.grammars = reader.unique_cfgs
        self.cfg_index: List[int] = reader.cfg_index
        # the timestamp store is CAPTURED at build time: a later
        # `reader.refresh()` swaps the reader's store, but this view keeps
        # serving the snapshot it was built from (generation safety)
        self._ts_store = reader.ts_store
        if _reuse is None:
            self.columns = decode_signatures_batch(reader.merged_cst)
            self._sigs = [self._sig_info(t)
                          for t in range(len(self.columns))]
            self._counts: Dict[int, Dict[int, int]] = {}
            self._positions: Dict[int, Tuple[Dict[int, int],
                                             Dict[int, int]]] = {}
            self._pfstate: Dict[int, Tuple[Dict[Any, Tuple[int, int]],
                                           Dict[int, str]]] = {}
            self._ts: Dict[int, Optional[np.ndarray]] = {}
            self._digrams: Dict[int, Tuple[Dict[Tuple[int, int], int],
                                           Optional[int],
                                           Optional[int]]] = {}
            self._phases: Dict[int, List[Dict[str, Any]]] = {}
        else:
            # seeded construction (refreshed_view): the already-decoded
            # column prefix plus per-unique-CFG memos folded forward --
            # nothing about the previously-loaded segments is re-derived
            self.columns = _reuse["columns"]
            self._sigs = _reuse["sigs"]
            self._counts = dict(_reuse["counts"])
            self._positions = dict(_reuse["positions"])
            self._pfstate = dict(_reuse["pfstate"])
            self._ts = dict(_reuse["ts"])
            self._digrams = dict(_reuse["digrams"])
            self._phases = dict(_reuse["phases"])
        self._cfg_mult: Dict[int, int] = {}
        for u in self.cfg_index:
            self._cfg_mult[u] = self._cfg_mult.get(u, 0) + 1
        # per-unique-CFG memos
        self._perfile: Dict[int, Dict[Any, Dict[str, int]]] = {
            u: _contrib_dicts(contrib)
            for u, (contrib, _exit) in self._pfstate.items()}
        self._spancols: Dict[Tuple[int, tuple], Any] = {}
        self._totals: Optional[Dict[int, int]] = None

    # -- column construction --------------------------------------------------

    def _sig_info(self, t: int) -> _SigInfo:
        return make_sig_info(self.columns, self.functions, t)

    # -- grammar-weighted counts ----------------------------------------------

    def cfg_terminal_counts(self, u: int) -> Dict[int, int]:
        """Occurrence count of every terminal of unique CFG ``u`` --
        O(|grammar|) via rule expansion weights, memoized."""
        counts = self._counts.get(u)
        if counts is None:
            counts = terminal_counts(self.grammars[u])
            self._counts[u] = counts
        return counts

    def rank_terminal_counts(self, rank: int) -> Dict[int, int]:
        return self.cfg_terminal_counts(self.cfg_index[rank])

    def total_terminal_counts(self) -> Dict[int, int]:
        """Terminal counts summed over ALL ranks: one weighted pass per
        unique CFG, resolved across ranks by CFG multiplicity (never a
        per-rank loop over records)."""
        if self._totals is None:
            totals: Dict[int, int] = {}
            for u, mult in self._cfg_mult.items():
                for t, c in self.cfg_terminal_counts(u).items():
                    totals[t] = totals.get(t, 0) + mult * c
            self._totals = totals
        return self._totals

    def n_records(self, rank: int) -> int:
        """Record count of one rank in O(|grammar|) (no expansion)."""
        return sum(self.cfg_terminal_counts(self.cfg_index[rank]).values())

    def total_records(self) -> int:
        return sum(self.total_terminal_counts().values())

    def digram_counts(self, rank: Optional[int] = 0,
                      backend: Optional[str] = None
                      ) -> Dict[Tuple[int, int], int]:
        """Adjacent-pair (digram) counts of the expanded call-signature
        stream -- the repeated-structure profile Sequitur compresses.

        Default path (``backend=None``): derived straight from the
        grammar in O(|grammar|) via :func:`dfg.grammar_digrams` -- no
        record expansion -- memoized per unique CFG.  ``rank=None``
        aggregates over ALL ranks with one walk per unique CFG, scaled
        by CFG multiplicity (the same trick as
        :meth:`total_terminal_counts`).

        An explicit ``backend`` keeps the expansion reference: the
        stream is materialized as an int64 vector and the histogram
        dispatched through :mod:`encode_backend` (NumPy bincount or the
        ``grammar_stats`` digram kernel) -- O(records), kept as the
        kernel-comparison and property-test path.
        """
        if backend is not None:
            if rank is None:
                total: Dict[Tuple[int, int], int] = {}
                for u, mult in self._cfg_mult.items():
                    for k, c in self._digrams_expand(u, backend).items():
                        total[k] = total.get(k, 0) + mult * c
                return total
            return self._digrams_expand(self.cfg_index[rank], backend)
        if rank is None:
            total = {}
            for u, mult in self._cfg_mult.items():
                for k, c in self._cfg_digrams(u)[0].items():
                    total[k] = total.get(k, 0) + mult * c
            return total
        return dict(self._cfg_digrams(self.cfg_index[rank])[0])

    def _digrams_expand(self, u: int, backend: Optional[str]
                        ) -> Dict[Tuple[int, int], int]:
        stream = np.fromiter(expand_grammar(self.grammars[u]),
                             dtype=np.int64)
        from . import encode_backend as _eb
        return _eb.digram_histogram(stream, len(self._sigs), backend)

    # -- DFG / phase / divergence observability (O(|grammar|)) ----------------

    def _cfg_digrams(self, u: int) -> Tuple[Dict[Tuple[int, int], int],
                                            Optional[int], Optional[int]]:
        """``(edges, first, last)`` of unique CFG ``u``'s expansion --
        O(|grammar|), memoized, and seeded forward by the incremental
        refresh (one delta-sized walk per new epoch segment)."""
        d = self._digrams.get(u)
        if d is None:
            d = _dfg.grammar_digrams(self.grammars[u])
            self._digrams[u] = d
        return d

    def _cfg_phases(self, u: int) -> List[Dict[str, Any]]:
        """Raw phase rows of unique CFG ``u`` (shared by every rank using
        it): episode profile + dominant-set merge, O(|grammar|),
        memoized and refresh-folded like :meth:`_cfg_digrams`."""
        p = self._phases.get(u)
        if p is None:
            sigs = self._sigs
            eps = _dfg.grammar_episodes(self.grammars[u],
                                        lambda t: sigs[t].name)
            p = _dfg.phase_segments(eps)
            self._phases[u] = p
        return p

    def _label_of(self, t: int) -> Tuple[str, str]:
        return _dfg.node_label(self._sigs[t])

    def dfg(self, rank: Optional[int] = None) -> Dict[str, Any]:
        """Directly-Follows Graph of one rank (or, default, all ranks
        aggregated) at ``(func, pattern-class)`` node granularity.

        Nodes carry occurrence counts (grammar-weighted), edges the
        exact directly-follows counts of the expanded stream(s) --
        derived entirely in the compressed domain: one
        :func:`dfg.grammar_digrams` walk per unique CFG, scaled by CFG
        multiplicity for the aggregate.  Label granularity makes the
        graph identical across merged/stitched reads (whose terminal id
        spaces differ) and across SPMD ranks whose offsets differ only
        by rank.
        """
        if rank is None:
            term_counts = self.total_terminal_counts()
            edges = self.digram_counts(rank=None)
        else:
            term_counts = self.cfg_terminal_counts(self.cfg_index[rank])
            edges = self._cfg_digrams(self.cfg_index[rank])[0]
        node_ids: Dict[Tuple[str, str], int] = {}
        nodes: List[Dict[str, Any]] = []

        def nid(t: int) -> int:
            lab = self._label_of(t)
            i = node_ids.get(lab)
            if i is None:
                i = node_ids[lab] = len(nodes)
                nodes.append({"func": lab[0], "pattern": lab[1],
                              "count": 0})
            return i

        for t in sorted(term_counts):
            nodes[nid(t)]["count"] += term_counts[t]
        agg: Dict[Tuple[int, int], int] = {}
        for (a, b), w in edges.items():
            k = (nid(a), nid(b))
            agg[k] = agg.get(k, 0) + w
        rows = [{"src": a, "dst": b, "weight": w}
                for (a, b), w in agg.items()]
        rows.sort(key=lambda e: (-e["weight"], e["src"], e["dst"]))
        return {"nodes": nodes, "edges": rows,
                "n_records": sum(term_counts.values())}

    def phases(self, rank: int = 0) -> List[Dict[str, Any]]:
        """Phase segmentation of one rank's stream: contiguous record
        ranges ``[start_record, end_record)`` where the dominant
        function set is stable, labeled (``write-loop``, ``read``,
        ``metadata``, ...).  Derived from the grammar's episode
        structure -- O(|grammar|), no expansion; record positions come
        from the closed-form per-rule expansion lengths, so they are
        exact stream indices without materializing the stream."""
        return _dfg.phase_report(self._cfg_phases(self.cfg_index[rank]))

    def rank_divergence(self, threshold: float = 0.25) -> Dict[str, Any]:
        """Per-rank structural divergence from the SPMD majority.

        Every unique CFG's label-projected DFG is fingerprinted; the
        fingerprint group covering the most ranks is the majority
        behavior, and each rank is scored by :func:`dfg.dfg_distance`
        against it (total variation on edge-weight distributions, in
        [0, 1]).  Ranks above ``threshold`` are flagged divergent --
        the structural signal behind the ``anomalies`` query family and
        the ``dfg_divergent`` straggler reason.  Cost: one grammar walk
        per unique CFG, never per rank.
        """
        if not self._cfg_mult:
            return {"per_rank": [], "divergent": [], "majority_size": 0,
                    "nranks": self.nranks, "threshold": threshold}
        label_edges = {
            u: _dfg.project_edges(self._cfg_digrams(u)[0], self._label_of)
            for u in self._cfg_mult}
        groups: Dict[tuple, List[int]] = {}
        for u, le in label_edges.items():
            fp = tuple(sorted(le.items()))
            groups.setdefault(fp, []).append(u)

        def group_ranks(us: List[int]) -> int:
            return sum(self._cfg_mult[u] for u in us)

        maj_fp = max(groups, key=lambda fp: (group_ranks(groups[fp]), fp))
        maj_edges = dict(maj_fp)
        per_rank = [round(_dfg.dfg_distance(
            label_edges[self.cfg_index[r]], maj_edges), 9)
            for r in range(self.nranks)]
        return {
            "per_rank": per_rank,
            "divergent": [r for r, d in enumerate(per_rank)
                          if d > threshold],
            "majority_size": group_ranks(groups[maj_fp]),
            "nranks": self.nranks,
            "threshold": threshold,
        }

    # -- lazy, memoized per-rank timestamps -----------------------------------

    @property
    def ts_store(self):
        """The per-rank timestamp store THIS VIEW was built over
        (single-blob, block-indexed or stitched multi-segment; shared
        ``blocks_touched`` counter).  Captured at construction: the view
        stays consistent with its snapshot even after the reader folds in
        newly committed segments."""
        return self._ts_store

    def _decompress_ts(self, rank: int) -> Optional[np.ndarray]:
        return self._ts_store.load(rank)

    def timestamps(self, rank: int) -> Optional[np.ndarray]:
        """(n, 2) entry/exit tick array of one rank, or None when the trace
        has no timestamps for it.  Decompressed on first touch, memoized."""
        if rank not in self._ts:
            self._ts[rank] = self._decompress_ts(rank)
        return self._ts[rank]

    def timestamps_unwrapped(self, rank: int) -> Optional[np.ndarray]:
        """(n, 2) int64 entry/exit ticks with the uint32 wrap (~71.6 min)
        unwrapped into a monotonic clock: the store seeds the wrap base
        from each segment's per-epoch ``tick_wraps`` metadata and detects
        further in-epoch wraps from the tick sequence itself.  Not
        memoized (days-long traces; callers keep what they need)."""
        return self.ts_store.load_unwrapped(rank)

    # -- aggregate queries (grammar-weighted) ---------------------------------

    def io_summary(self) -> Dict[str, Any]:
        """Aggregate transfer sizes, call mix, per-file totals, bandwidth.

        Counts and byte totals are weighted sums over distinct signatures;
        per-file attribution is weighted too when the grammar proves every
        data call follows a unique open of its handle (first/last terminal
        positions), else it falls back to one exact walk per unique CFG.
        Timestamp bounds are the only part that touches expanded data, and
        only lazily (per-rank decompressed arrays, vectorized min/max).
        """
        totals = self.total_terminal_counts()
        sigs = self._sigs
        n_data = n_meta = total_bytes = 0
        for t, c in totals.items():
            s = sigs[t]
            if s.is_data:
                n_data += c
                total_bytes += c * s.size
            elif s.is_io_layer:
                n_meta += c
        per_file: Dict[Any, Dict[str, int]] = defaultdict(
            lambda: {"bytes": 0, "calls": 0})
        for u, mult in self._cfg_mult.items():
            for key, d in self._per_file_cfg(u).items():
                agg = per_file[key]
                agg["bytes"] += mult * d["bytes"]
                agg["calls"] += mult * d["calls"]
        t_lo: Any = float("inf")
        t_hi: Any = 0
        for r in range(self.nranks):
            # transient decompress: reducing all ranks to a min/max must not
            # pin every rank's array in the memo (reuse it when present)
            ts = self._ts[r] if r in self._ts else self._decompress_ts(r)
            if ts is None or not len(ts):
                continue
            ent = ts[:, 0].astype(np.int64)
            ext = ts[:, 1].astype(np.int64)
            t_lo = min(t_lo, int(ent.min()))
            # a zero exit tick falls back to the entry tick (seed `or`)
            t_hi = max(t_hi, int(np.where(ext != 0, ext, ent).max()))
        wall_us = max(t_hi - t_lo, 1)
        return {
            "files": dict(per_file),
            "n_data_calls": n_data,
            "n_metadata_calls": n_meta,
            "metadata_ratio": n_meta / max(n_data + n_meta, 1),
            "total_bytes": total_bytes,
            "aggregate_MBps": total_bytes / wall_us,  # bytes/us == MB/s
        }

    def size_histogram(self, edges: Sequence[int] = (512, 4096, 65536, 1 << 20)
                       ) -> Dict[str, int]:
        """Request-size distribution of data calls: pure weighted sum over
        distinct signatures (O(|grammar| + |CST|))."""
        buckets = {f"<{e}": 0 for e in edges}
        top = f">={edges[-1]}"
        buckets[top] = 0
        sigs = self._sigs
        for t, c in self.total_terminal_counts().items():
            s = sigs[t]
            if not s.is_data:
                continue
            for e in edges:
                if s.size < e:
                    buckets[f"<{e}"] += c
                    break
            else:
                buckets[top] += c
        return buckets

    def _cfg_positions(self, u: int):
        pos = self._positions.get(u)
        if pos is None:
            pos = terminal_positions(self.grammars[u])
            self._positions[u] = pos
        return pos

    def _per_file_cfg(self, u: int) -> Dict[Any, Dict[str, int]]:
        """Per-file {bytes, calls} of ONE rank using CFG ``u`` (identical
        for every rank sharing the CFG; callers scale by multiplicity).

        Fast path: grammar-weighted, using first/last terminal positions to
        prove each data call sees exactly one open path for its handle.
        Ambiguous handle/path reuse falls back to one exact stream walk.
        """
        cached = self._perfile.get(u)
        if cached is not None:
            return cached
        counts = self.cfg_terminal_counts(u)
        sigs = self._sigs
        cols = self.columns
        opens: Dict[int, set] = {}
        open_first: Dict[int, int] = {}
        data_terms = []
        need_pos = False
        for t in counts:
            s = sigs[t]
            if s.name in _OPEN_FUNCS and hasattr(cols.ret[t], "id"):
                opens.setdefault(cols.ret[t].id, set()).add(
                    str(cols.args[t][0]))
                need_pos = True
            if s.is_data:
                data_terms.append(t)
        per: Dict[Any, Dict[str, int]] = {}
        first = last = None
        if need_pos:
            first, last = self._cfg_positions(u)
            for t in counts:
                s = sigs[t]
                if s.name in _OPEN_FUNCS and hasattr(cols.ret[t], "id"):
                    h = cols.ret[t].id
                    p = first[t]
                    if h not in open_first or p < open_first[h]:
                        open_first[h] = p
        ok = True
        for t in data_terms:
            s = sigs[t]
            if s.handle is _NO_HANDLE:
                key: Any = "?"
            elif s.handle not in opens:
                key = None  # never opened in this stream
            elif len(opens[s.handle]) == 1:
                if open_first[s.handle] < first[t]:
                    key = next(iter(opens[s.handle]))
                elif open_first[s.handle] > last[t]:
                    key = None  # every occurrence precedes the open
                else:
                    ok = False  # occurrences straddle the open
                    break
            else:
                ok = False  # handle re-opened under different paths
                break
            agg = per.setdefault(key, {"bytes": 0, "calls": 0})
            agg["bytes"] += counts[t] * s.size
            agg["calls"] += counts[t]
        if not ok:
            per = self._per_file_walk(u)
        self._perfile[u] = per
        return per

    def _per_file_walk(self, u: int) -> Dict[Any, Dict[str, int]]:
        """Exact per-file attribution without expanding the stream.

        Recursive rule evaluation with a per-rule memo (the carried-over
        ROADMAP item): a rule's contribution depends only on the live
        handle->path bindings of the handles its subtree READS, so the memo
        key is ``(rule, entry values of its read set)``.  Exponents
        collapse in closed form -- a rule's state effect is a constant
        overwrite map, hence idempotent, so application 2 is a fixed point
        and apps ``2..e`` contribute ``(e-1) x`` its result.  SPMD loop
        grammars evaluate in O(|grammar|) instead of O(stream).
        Property-tested equal to :meth:`_per_file_walk_linear`, which also
        serves as the fallback for pathologically deep grammars."""
        contrib, _exit = self._pf_state(u)
        return _contrib_dicts(contrib)

    def _pf_state(self, u: int) -> Tuple[Dict[Any, Tuple[int, int]],
                                         Dict[int, str]]:
        """``(contrib, exit_live)`` of CFG ``u``'s whole stream under empty
        entry bindings, memoized -- the resumable form the incremental
        refresh folds new segments onto (:func:`per_file_fold`)."""
        st = self._pfstate.get(u)
        if st is None:
            try:
                st = per_file_fold(self.grammars[u], self._sigs,
                                   self.columns, {})
            except RecursionError:
                st = per_file_fold_linear(self.grammars[u], self._sigs,
                                          self.columns, {})
            self._pfstate[u] = st
        return st

    def _per_file_walk_memo(self, u: int) -> Dict[Any, Dict[str, int]]:
        contrib, _exit = per_file_fold(self.grammars[u], self._sigs,
                                       self.columns, {})
        return _contrib_dicts(contrib)

    def _per_file_walk_linear(self, u: int) -> Dict[Any, Dict[str, int]]:
        """Exact per-file attribution: one linear walk of CFG ``u``'s
        stream (the reference for :meth:`_per_file_walk`)."""
        contrib, _exit = per_file_fold_linear(self.grammars[u], self._sigs,
                                              self.columns, {})
        return _contrib_dicts(contrib)

    # -- sequential queries (one walk per unique CFG) -------------------------

    def call_chains(self, targets=_DATA_FUNCS, rank: int = 0) -> Dict[str, int]:
        """Cross-layer ancestry chains ending in a target call.

        The post-order stream is walked in REVERSE, streamed lazily from
        the grammar (``expand_grammar_reversed``) -- parents appear before
        children, so the depth-indexed stack rebuilds each chain without
        materializing the forward record list.
        """
        sigs = self._sigs
        depth = self.columns.depth.tolist()
        chains: Dict[str, int] = defaultdict(int)
        stack: List[str] = []
        for t in expand_grammar_reversed(self.grammars[self.cfg_index[rank]]):
            name = sigs[t].name
            del stack[depth[t]:]
            stack.append(name)
            if name in targets:
                chains["->".join(stack)] += 1
        return dict(chains)

    @staticmethod
    def _overlap_sweep(ent: np.ndarray, ext: np.ndarray) -> float:
        t = np.concatenate([ent, ext]).astype(np.int64)
        n = len(ent)
        d = np.concatenate([np.ones(n, np.int64), -np.ones(n, np.int64)])
        # tuple-sort order of the seed: by time, exits (-1) before entries
        order = np.lexsort((d, t))
        t, d = t[order], d[order]
        c = np.cumsum(d)[:-1]  # depth between consecutive events
        dt = np.diff(t)
        busy = int(dt[c >= 1].sum())
        overlap = int(dt[c >= 2].sum())
        return overlap / busy if busy else 0.0

    def overlap_ratio(self, rank: int = 0, t0: Optional[int] = None,
                      t1: Optional[int] = None) -> float:
        """Fraction of busy I/O time with >= 2 threads inside calls:
        vectorized event sweep over the rank's timestamps.

        With a ``[t0, t1)`` window, only the timestamp blocks whose
        ``[t_min, t_max]`` span intersects the window are decompressed
        (block-indexed streaming traces; observable through
        ``ts_store.blocks_touched``) and call intervals are clipped to the
        window, effective exits (zero exit -> entry) applied.

        Windows are in raw uint32 microsecond ticks, which wrap at ~71.6
        minutes (the trace format's documented tick policy): windowed
        queries are exact within one wrap period; for multi-hour absolute
        windows rebase against :meth:`timestamps_unwrapped`, which serves
        monotonic int64 ticks from the per-epoch wrap metadata."""
        if t0 is None and t1 is None:
            ts = self.timestamps(rank)
            if ts is None or not len(ts):
                return 0.0
            return self._overlap_sweep(ts[:, 0], ts[:, 1])
        lo = 0 if t0 is None else int(t0)
        hi = (1 << 62) if t1 is None else int(t1)
        ts = self.ts_store.window(rank, lo, hi)
        if ts is None or not len(ts):
            return 0.0
        ent = np.clip(ts[:, 0].astype(np.int64), lo, hi)
        return self._overlap_sweep(ent, np.clip(effective_exit(ts), lo, hi))

    def bandwidth_bounds(self, t0: int, t1: int) -> Dict[str, Any]:
        """Compressed-domain aggregate bandwidth over ``[t0, t1)``.

        Call counts AND data bytes come from the timestamp stores' windowed
        stats (only blocks straddling the window edges are decompressed;
        fully covered blocks are answered from the index).  Traces written
        with per-block byte counters (the sized timestamp layout) get an
        EXACT byte total -- ``lo_MBps == hi_MBps`` and ``exact: True`` --
        matching a per-record walk.  Older traces without the counters fall
        back to the CST-derived bounds: every windowed call transfers at
        most the trace's largest data-call size, and at least 0 when the
        trace mixes in metadata calls (else the smallest data size).
        """
        if not t1 > t0:
            raise ValueError("window must satisfy t1 > t0")
        n_calls = 0
        n_bytes = 0
        exact = True
        for r in range(self.nranks):
            stats = self.ts_store.window_stats(r, t0, t1)
            if stats is None:
                continue
            n_calls += stats[0]
            if stats[1] is None:
                if stats[0]:
                    exact = False
            else:
                n_bytes += stats[1]
        window_us = t1 - t0
        if exact:
            lo_bytes = hi_bytes = n_bytes
        else:
            data_sizes = [s.size for s in self._sigs if s.is_data]
            any_non_data = any(not s.is_data for s in self._sigs)
            hi_bytes = n_calls * (max(data_sizes) if data_sizes else 0)
            lo_bytes = 0 if (any_non_data or not data_sizes) \
                else n_calls * min(data_sizes)
        return {
            "n_calls": n_calls,
            "window_us": window_us,
            "exact": exact,
            "bytes": n_bytes if exact else None,
            "lo_MBps": lo_bytes / window_us,   # bytes/us == MB/s
            "hi_MBps": hi_bytes / window_us,
        }

    def _span_cols(self, u: int, targets: tuple):
        """Rank-symbolic write extents of CFG ``u``, grouped by handle id in
        stream order (offsets stay linear functions of the rank).

        Returns ``[(hid, coefs, consts, sizes, np_cols)]`` or None when
        the run evolution could be rank-dependent (distinct pattern
        signatures carrying RankPattern compared under one key) -- callers
        then fall back to the exact per-rank record path.

        The default implementation (:meth:`_span_cols_walk`) replays the
        grammar recursively with closed-form loop extrapolation: a symbol
        repeated ``e`` times is applied twice, and if the pattern-run state
        is stationary between the applications the remaining ``e - 2`` are
        emitted as vectorized columns (each emission advances linearly in
        its run index) -- sublinear walk work for SPMD loops (ROADMAP
        carried-over item).  :meth:`_span_cols_linear` is the
        property-tested reference and the fallback for int64-overflowing
        offsets or pathologically deep grammars.
        """
        ck = (u, targets)
        if ck in self._spancols:
            return self._spancols[ck]
        try:
            result = self._span_cols_walk(u, targets)
        except _SpanBail:
            result = None
        except (_SpanOverflow, RecursionError):
            result = self._span_cols_linear(u, targets)
        self._spancols[ck] = result
        return result

    def _span_cols_walk(self, u: int, targets: tuple):
        rules = self.grammars[u]
        sigs = self._sigs
        nranks = self.nranks
        runs: Dict[Any, Tuple[int, Optional[tuple]]] = {}
        key_ids: Dict[Any, int] = {}      # run key -> dense id (kid)
        # columnar emission log: 7 parallel columns
        #   hid, coef, const, size, ca, va, kid
        # (ca, va) is the per-run-index advance of (coef, const) -- the
        # rank-linear components of the IterPattern stride -- and kid the
        # emission's run key (-1: value does not advance with any run).
        buf: List[List[int]] = [[] for _ in range(7)]
        chunks: List[List[np.ndarray]] = []

        def seal() -> None:
            if buf[0]:
                try:
                    chunks.append([np.asarray(c, np.int64) for c in buf])
                except OverflowError:
                    raise _SpanOverflow from None
                for c in buf:
                    c.clear()

        def do_terminal(x: int) -> None:
            s = sigs[x]
            vals0 = None  # (coef, const, ca, va, kid) of offset slot 0
            if s.enc is not None:
                (key, enc, patsig, has_iter, off_slots, _ret_is_offset,
                 key_rankdep) = s.enc
                if key_rankdep:
                    raise _SpanBail
                if not has_iter:
                    runs[key] = (1, None)
                    c0, k0 = _lin0(enc[0])
                    vals0 = (c0, k0, 0, 0, -1)
                else:
                    idx, prev = runs.get(key, (1, None))
                    if prev is not None and prev == patsig:
                        idx += 1
                    elif prev is not None and (
                            _contains_rankpattern(prev)
                            or _contains_rankpattern(patsig)):
                        raise _SpanBail
                    v = enc[0]
                    if isinstance(v, IterPattern):
                        ca, va = _lin0(v.a)
                        cb, vb = _lin0(v.b)
                        kid = key_ids.setdefault(key, len(key_ids))
                        vals0 = (cb + idx * ca, vb + idx * va, ca, va, kid)
                    else:
                        c0, k0 = _lin0(v)
                        vals0 = (c0, k0, 0, 0, -1)
                    runs[key] = (idx, patsig)
            if (s.name in targets and vals0 is not None
                    and s.enc is not None and s.enc[4]):
                if s.size_symbolic:
                    raise _SpanBail
                hid = -1 if s.handle is _NO_HANDLE else s.handle
                row = (hid, vals0[0], vals0[1], s.size, vals0[2], vals0[3],
                       vals0[4])
                for c, v in zip(buf, row):
                    c.append(v)

        def rep(fn, exp: int) -> None:
            if exp <= 2:
                for _ in range(exp):
                    fn()
                return
            fn()                          # application 1
            s1 = dict(runs)
            seal()
            mark = len(chunks)
            fn()                          # application 2
            s2 = dict(runs)
            # stationarity: same run keys with the same pattern signatures
            # -> apps 3..exp replay app 2 with run indices shifted by the
            # constant per-application advance (the guard bails are static
            # or patsig-driven, so app 2 passing implies the rest pass)
            if set(s1) != set(s2) or any(s1[k][1] != s2[k][1] for k in s1):
                for _ in range(exp - 2):
                    fn()
                return
            reps = exp - 2
            seal()
            app2 = chunks[mark:]
            if app2:
                cols2 = [np.concatenate([c[j] for c in app2])
                         for j in range(7)]
                hid2, coef2, const2, size2, ca2, va2, kid2 = cols2
                di_by_kid = np.zeros(len(key_ids) + 1, np.int64)
                for k, (i2, _sig) in s2.items():
                    kid = key_ids.get(k)
                    if kid is not None:
                        di_by_kid[kid] = i2 - s1[k][0]
                d = di_by_kid[np.where(kid2 >= 0, kid2, len(key_ids))]
                dc = d * ca2
                dk = d * va2
                # keep the extrapolated columns int64-exact (float bound is
                # conservative at these magnitudes: slack << headroom)
                base = max(float(np.abs(coef2).max(initial=0)),
                           float(np.abs(const2).max(initial=0)))
                step = max(float(np.abs(dc).max(initial=0)),
                           float(np.abs(dk).max(initial=0)))
                if base + reps * step >= float(_I64_SAFE):
                    raise _SpanOverflow
                j = np.arange(1, reps + 1, dtype=np.int64)
                chunks.append([
                    np.tile(hid2, reps),
                    (coef2[None, :] + j[:, None] * dc[None, :]).ravel(),
                    (const2[None, :] + j[:, None] * dk[None, :]).ravel(),
                    np.tile(size2, reps),
                    np.tile(ca2, reps),
                    np.tile(va2, reps),
                    np.tile(kid2, reps),
                ])
            for k, (i2, sig) in s2.items():
                di = i2 - s1[k][0]
                if di:
                    runs[k] = (i2 + reps * di, sig)

        def walk_rule(rid: int) -> None:
            for code, exp in rules[rid]:
                x = code >> 1
                if code & 1:
                    rep(lambda x=x: walk_rule(x), exp)
                else:
                    rep(lambda x=x: do_terminal(x), exp)

        if rules:
            walk_rule(0)
        seal()
        if not chunks:
            return []
        hids = np.concatenate([c[0] for c in chunks])
        coefs = np.concatenate([c[1] for c in chunks])
        consts = np.concatenate([c[2] for c in chunks])
        sizes = np.concatenate([c[3] for c in chunks])
        result = []
        _, first_idx = np.unique(hids, return_index=True)
        for i in np.sort(first_idx):      # first-appearance order
            h = int(hids[i])
            sel = hids == h
            cf, ct, sz = coefs[sel], consts[sel], sizes[sel]
            bound = (int(np.abs(ct).max(initial=0))
                     + nranks * int(np.abs(cf).max(initial=0))
                     + int(np.abs(sz).max(initial=0)))
            np_cols = (cf, ct, sz) if bound < _I64_SAFE else None
            result.append((h, cf.tolist(), ct.tolist(), sz.tolist(),
                           np_cols))
        return result

    def _span_cols_linear(self, u: int, targets: tuple):
        """Linear symbolic replay of CFG ``u``'s full stream -- the
        reference (and big-int / deep-grammar fallback) for
        :meth:`_span_cols_walk`."""
        sigs = self._sigs
        runs: Dict[Any, Tuple[int, Optional[tuple]]] = {}
        order: List[int] = []
        groups: Dict[Any, Tuple[List[int], List[int], List[int]]] = {}
        result: Any = []
        for t in expand_grammar(self.grammars[u]):
            s = sigs[t]
            vals: Optional[List[Tuple[int, int]]] = None
            if s.enc is not None:
                (key, enc, patsig, has_iter, off_slots, ret_is_offset,
                 key_rankdep) = s.enc
                if key_rankdep:
                    result = None
                    break
                if not has_iter:
                    runs[key] = (1, None)
                    vals = [_lin0(v) for v in enc]
                else:
                    idx, prev = runs.get(key, (1, None))
                    if prev is not None and prev == patsig:
                        idx += 1
                    elif prev is not None and (
                            _contains_rankpattern(prev)
                            or _contains_rankpattern(patsig)):
                        # symbolically distinct signatures could still
                        # coincide for individual ranks: not resolvable
                        # rank-symbolically
                        result = None
                        break
                    vals = []
                    for v in enc:
                        if isinstance(v, IterPattern):
                            ca, va = _lin0(v.a)
                            cb, vb = _lin0(v.b)
                            vals.append((cb + idx * ca, vb + idx * va))
                        else:
                            vals.append(_lin0(v))
                    runs[key] = (idx, patsig)
            if (s.name in targets and vals is not None and s.enc is not None
                    and s.enc[4]):  # has at least one offset ARG slot
                if s.size_symbolic:
                    result = None
                    break
                hid = -1 if s.handle is _NO_HANDLE else s.handle
                if hid not in groups:
                    groups[hid] = ([], [], [])
                    order.append(hid)
                coef, const = vals[0]
                g = groups[hid]
                g[0].append(coef)
                g[1].append(const)
                g[2].append(s.size)
        if result is not None:
            for hid in order:
                coefs, consts, sizes = groups[hid]
                bound = (max(map(abs, consts), default=0)
                         + self.nranks * max(map(abs, coefs), default=0)
                         + max(map(abs, sizes), default=0))
                np_cols = None
                if bound < _I64_SAFE:
                    np_cols = (np.asarray(coefs, dtype=np.int64),
                               np.asarray(consts, dtype=np.int64),
                               np.asarray(sizes, dtype=np.int64))
                result.append((hid, coefs, consts, sizes, np_cols))
        return result

    def consistency_pairs(self, targets=_WRITE_FUNCS) -> List[Dict[str, Any]]:
        """Cross-rank overlapping write extents per handle id.

        Extents are produced rank-symbolically once per unique CFG and
        resolved for every rank in one vectorized pass; conflicts come from
        :func:`sweep_conflicts` (ALL overlapping cross-rank pairs, not just
        start-adjacent ones).
        """
        targets = tuple(targets)
        writes: Dict[int, List[Tuple[int, int, int]]] = {}
        for r in range(self.nranks):
            cols = self._span_cols(self.cfg_index[r], targets)
            if cols is None:
                self._collect_spans_records(r, targets, writes)
                continue
            for hid, coefs, consts, sizes, np_cols in cols:
                lst = writes.setdefault(hid, [])
                if np_cols is not None:
                    c1, c0, sz = np_cols
                    starts = c0 + r * c1
                    lst.extend(zip(repeat(r), starts.tolist(),
                                   (starts + sz).tolist()))
                else:
                    lst.extend((r, c0 + r * c1, c0 + r * c1 + sz)
                               for c1, c0, sz in zip(coefs, consts, sizes))
        return sweep_conflicts(writes)

    def _collect_spans_records(self, rank: int, targets: tuple,
                               writes: Dict[int, List[Tuple[int, int, int]]]
                               ) -> None:
        """Exact per-rank fallback: expand this rank's records."""
        for rec in self.iter_records(rank, timestamps=False):
            if rec.func not in targets:
                continue
            off = next((v for v, role in zip(rec.args, rec.roles)
                        if role == "offset" and isinstance(v, int)), None)
            if off is None:
                continue
            sz = next((v for v, role in zip(rec.args, rec.roles)
                       if role in ("buf", "size") and isinstance(v, int)),
                      rec.ret if isinstance(rec.ret, int) else 0)
            hid = next((v.id for v, role in zip(rec.args, rec.roles)
                        if role == "handle" and hasattr(v, "id")), -1)
            writes.setdefault(hid, []).append((rank, off, off + sz))

    # -- the lossless row-wise reference path ---------------------------------

    def iter_records(self, rank: int, timestamps: bool = True
                     ) -> Iterator[Record]:
        """Expand one rank's full record stream (lossless reconstruction).

        This is the seed read path, now fed from the batch-decoded columns;
        ``TraceReader.iter_records`` delegates here.  Prefer the aggregate
        queries above -- they answer without expansion.
        """
        grammar = self.grammars[self.cfg_index[rank]]
        decoder = IntraPatternDecoder()
        cols = self.columns
        sigs = self._sigs
        # transient unless already memoized: a full-trace iteration (e.g.
        # the converters) must not pin every rank's array, like the seed
        ts = None
        if timestamps:
            ts = self._ts[rank] if rank in self._ts else \
                self._decompress_ts(rank)
        for i, terminal in enumerate(expand_grammar(grammar)):
            s = sigs[terminal]
            func_id = int(cols.func_id[terminal])
            tidx = int(cols.thread[terminal])
            finfo = self.functions[func_id]
            roles = finfo["arg_roles"]
            # resolve rank patterns everywhere
            args = tuple(_resolve_rank(a, rank)
                         for a in cols.args[terminal])
            ret = _resolve_rank(cols.ret[terminal], rank)
            # resolve iteration patterns on OFFSET-role slots (and returns),
            # reusing the per-terminal derivation from the columns; only a
            # rank-dependent key (RankPattern in its parts) is re-derived
            if s.enc is not None:
                key, _, _, _, off_slots, ret_is_offset, key_rankdep = s.enc
                if key_rankdep:
                    key = _derive_key(func_id, tidx, args, ret, roles,
                                      ret_is_offset)
                enc = [args[j] for j in off_slots]
                if ret_is_offset:
                    enc.append(ret)
                dec = decoder.decode(key, enc)
                args = list(args)
                for j, v in zip(off_slots, dec):
                    args[j] = v
                args = tuple(args)
                if ret_is_offset:
                    ret = dec[-1]
            t0 = int(ts[i, 0]) if ts is not None else None
            t1 = int(ts[i, 1]) if ts is not None else None
            yield Record(func=s.name, layer=s.layer, args=args,
                         arg_names=tuple(finfo["arg_names"]), ret=ret,
                         thread=tidx, depth=int(cols.depth[terminal]),
                         t_entry=t0, t_exit=t1, roles=tuple(roles))

    def all_records(self, timestamps: bool = True
                    ) -> Iterator[Tuple[int, Record]]:
        for r in range(self.nranks):
            for rec in self.iter_records(r, timestamps=timestamps):
                yield r, rec


# ---------------------------------------------------------------------------
# incremental view refresh (TraceReader.refresh support)
# ---------------------------------------------------------------------------


def refreshed_view(old_view: TraceView, reader,
                   folds: Sequence[Tuple[Dict[str, Any], int,
                                         Sequence[Tuple[int, int]], Any]]
                   ) -> TraceView:
    """The view of a just-refreshed reader, built by folding ONLY the newly
    committed segments onto ``old_view``'s memoized state.

    ``folds`` holds one ``(data, toff, pairs, seg_store)`` per folded
    segment in epoch order: ``data`` is the segment's decoded payload,
    ``toff`` the CST offset its terminals were spliced at, ``pairs`` the
    fold's unique-CFG provenance (``pairs[new_u] = (old_u, seg_u)``), and
    ``seg_store`` the segment's timestamp store.  Only the new segments'
    CST entries are decoded and only their (delta-sized) grammars are
    walked; every per-unique-CFG memo of ``old_view`` -- terminal counts,
    first/last positions, per-file fold state, DFG digram edges, phase
    segmentation, decompressed timestamps -- is carried forward through
    the provenance map, never re-derived from already-loaded segments.
    """
    cols = old_view.columns
    sigs = list(old_view._sigs)
    counts: Dict[int, Dict[int, int]] = {}
    positions = dict(old_view._positions)
    pfstate: Dict[int, Tuple[Dict[Any, Tuple[int, int]],
                             Dict[int, str]]] = {}
    digrams: Dict[int, Tuple[Dict[Tuple[int, int], int],
                             Optional[int], Optional[int]]] = \
        dict(old_view._digrams)
    phases: Dict[int, List[Dict[str, Any]]] = dict(old_view._phases)
    ts = dict(old_view._ts)
    functions = reader.functions
    first_fold = True
    for data, toff, pairs, seg_store in folds:
        seg_cols = decode_signatures_batch(data["merged_cst"])
        cols = concat_signature_columns(cols, seg_cols)
        sigs.extend(make_sig_info(cols, functions, toff + j)
                    for j in range(len(seg_cols)))
        seg_rules: Dict[int, Any] = {}

        def rules_of(su: int, data=data, seg_rules=seg_rules):
            r = seg_rules.get(su)
            if r is None:
                r = parse_grammar(data["unique_cfgs"][su])
                seg_rules[su] = r
            return r

        new_counts: Dict[int, Dict[int, int]] = {}
        new_positions: Dict[int, Tuple[Dict[int, int],
                                       Dict[int, int]]] = {}
        new_pfstate: Dict[int, Tuple[Dict[Any, Tuple[int, int]],
                                     Dict[int, str]]] = {}
        new_digrams: Dict[int, Tuple[Dict[Tuple[int, int], int],
                                     Optional[int], Optional[int]]] = {}
        new_phases: Dict[int, List[Dict[str, Any]]] = {}
        seg_dfg: Dict[int, Any] = {}
        seg_ph: Dict[int, Any] = {}
        for new_u, (old_u, seg_u) in enumerate(pairs):
            sr = rules_of(seg_u)
            # counts: always seeded (every query family needs them); the
            # old half comes from the old view's memo (computed at most
            # once per old unique CFG, O(|old grammar|), no segment reads)
            oc = old_view.cfg_terminal_counts(old_u) if first_fold \
                else counts[old_u]
            merged = dict(oc)
            for t, c in terminal_counts(sr).items():
                merged[toff + t] = merged.get(toff + t, 0) + c
            new_counts[new_u] = merged
            # positions: seeded only where the old view had them (lazy
            # memo) -- the old terminals' first/last stream positions are
            # unchanged by appending, the segment's shift by the old length
            op = positions.get(old_u)
            if op is not None:
                old_len = sum(oc.values())
                first = dict(op[0])
                last = dict(op[1])
                seg_first, seg_last = terminal_positions(sr)
                for t, p in seg_first.items():
                    first[toff + t] = old_len + p
                for t, p in seg_last.items():
                    last[toff + t] = old_len + p
                new_positions[new_u] = (first, last)
            # per-file attribution: resumable fold -- the segment's stream
            # is evaluated under the old stream's EXIT handle bindings and
            # its contributions added on
            if first_fold:
                pf = old_view._pf_state(old_u) \
                    if (old_u in old_view._pfstate
                        or old_u in old_view._perfile) else None
            else:
                pf = pfstate.get(old_u)
            if pf is not None:
                old_contrib, old_exit = pf
                try:
                    seg_contrib, exit_live = per_file_fold(
                        sr, sigs, cols, old_exit, toff)
                except RecursionError:
                    seg_contrib, exit_live = per_file_fold_linear(
                        sr, sigs, cols, old_exit, toff)
                merged_pf = dict(old_contrib)
                for k, (b, c) in seg_contrib.items():
                    ob, occ = merged_pf.get(k, (0, 0))
                    merged_pf[k] = (ob + b, occ + c)
                new_pfstate[new_u] = (merged_pf, exit_live)
            # DFG / phases: seeded only where the old view had them
            # (lazy memos) -- one DELTA-sized grammar walk per segment,
            # shifted to the splice offset and stitched at the junction
            od = digrams.get(old_u)
            if od is not None:
                sd = seg_dfg.get(seg_u)
                if sd is None:
                    sd = seg_dfg[seg_u] = _dfg.grammar_digrams(rules_of(seg_u))
                new_digrams[new_u] = _dfg.fold_digrams(od, sd, toff)
            op = phases.get(old_u)
            if op is not None:
                sp = seg_ph.get(seg_u)
                if sp is None:
                    sp = seg_ph[seg_u] = _dfg.phase_segments(
                        _dfg.grammar_episodes(
                            rules_of(seg_u),
                            lambda t: sigs[t + toff].name))
                new_phases[new_u] = _dfg.fold_phases(
                    op, sp, sum(oc.values()))
        counts, positions, pfstate = new_counts, new_positions, new_pfstate
        digrams, phases = new_digrams, new_phases
        # timestamps: append the segment's rows to already-decompressed
        # rank memos (untouched ranks stay lazy)
        for r, old_ts in list(ts.items()):
            seg_ts = seg_store.load(r)
            parts = [p for p in (old_ts, seg_ts) if p is not None]
            ts[r] = (parts[0] if len(parts) == 1
                     else np.concatenate(parts, axis=0)) if parts else None
        first_fold = False
    return TraceView(reader, _reuse={
        "columns": cols, "sigs": sigs, "counts": counts,
        "positions": positions, "pfstate": pfstate, "ts": ts,
        "digrams": digrams, "phases": phases})
