"""Comparison baselines for Section 5.3: Recorder-old and a Darshan-like profiler.

``ToolAdapter`` exposes the Recorder runtime interface (now/enter/exit/
record/...) so either baseline can be ``attach``ed behind the SAME
generated tracing wrappers -- the overhead and trace-size comparisons then
measure the tools, not different instrumentation paths.

``RecorderOld`` -- the predecessor's design (paper references [9]):
  * one trace file PER RANK (no inter-process stage at all),
  * every record stored individually: (func_id, tid, depth, args, ret,
    t_entry, t_exit) in the same varint encoding the new tool uses (so the
    comparison isolates the *compression algorithm*, not the serializer),
  * peephole compression only: a record identical to its predecessor except
    for an offset advanced by the same delta (and timestamps) is stored as a
    2-byte "repeat" token -- the strongest reasonable reading of the
    peephole scheme,
  * trace size therefore grows linearly in ranks x calls.

``DarshanLike`` -- counter-based profiling with optional DXT:
  * per (file, layer) counters: call counts per function, byte/offset
    aggregates, time histogram -- fixed size per file regardless of calls,
  * DXT mode: per data-call segment record (rank, offset, length, start,
    end) at 24 bytes, POSIX/MPC-IO data ops only -- linear in calls but
    lean; metadata calls and most parameters are NOT captured (that is the
    fidelity gap the paper's Table 3 discusses).
"""

from __future__ import annotations

import json
import os
import struct
import zlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from .encoding import encode_signature
from .specs import REGISTRY, FunctionRegistry, Role


# ---------------------------------------------------------------------------
# Recorder-old
# ---------------------------------------------------------------------------


class RecorderOld:
    """Per-rank, record-at-a-time tracer with peephole compression."""

    REPEAT = b"\xff\xfe"

    def __init__(self, rank: int, registry: FunctionRegistry = REGISTRY):
        self.rank = rank
        self.registry = registry
        self._buf = bytearray()
        self._prev: Optional[Tuple] = None   # (func, tid, depth, args, ret)
        self._prev_delta: Optional[Tuple] = None
        self.n_records = 0

    def record(self, func_id: int, tid: int, depth: int, args: tuple,
               ret: Any, t0: int, t1: int) -> None:
        self.n_records += 1
        spec = self.registry.spec(func_id)
        off_pos = spec.offset_positions
        key = (func_id, tid, depth,
               tuple(v for i, v in enumerate(args) if i not in off_pos), ret)
        offs = tuple(int(args[i]) for i in off_pos if i < len(args))
        if self._prev is not None:
            pkey, poffs = self._prev
            if key == pkey and len(offs) == len(poffs):
                delta = tuple(o - p for o, p in zip(offs, poffs))
                if self._prev_delta is None or delta == self._prev_delta:
                    # peephole hit: 2-byte repeat + 2x4-byte timestamps
                    self._buf += self.REPEAT
                    self._buf += struct.pack("<II", t0 & 0xFFFFFFFF,
                                             t1 & 0xFFFFFFFF)
                    self._prev = (key, offs)
                    self._prev_delta = delta
                    return
        sig = encode_signature(func_id, tid, depth, args, ret)
        self._buf += struct.pack("<H", len(sig))
        self._buf += sig
        self._buf += struct.pack("<II", t0 & 0xFFFFFFFF, t1 & 0xFFFFFFFF)
        self._prev = (key, offs)
        self._prev_delta = None

    @property
    def nbytes(self) -> int:
        return len(self._buf)

    def write(self, trace_dir: str) -> int:
        os.makedirs(trace_dir, exist_ok=True)
        p = os.path.join(trace_dir, f"rank_{self.rank}.rec2")
        with open(p, "wb") as f:
            f.write(bytes(self._buf))
        return os.path.getsize(p)


# ---------------------------------------------------------------------------
# Darshan-like
# ---------------------------------------------------------------------------


_DATA_OPS = {"pwrite", "pread", "write", "read", "shard_write_at",
             "shard_read_at"}


@dataclass
class _FileCounters:
    counts: Dict[str, int] = field(default_factory=dict)
    bytes_rw: int = 0
    max_offset: int = 0
    t_first: float = float("inf")
    t_last: float = 0.0


class ToolAdapter:
    """Drives a baseline tool through the generated wrapper interface."""

    def __init__(self, tool, rank: int = 0,
                 registry: FunctionRegistry = REGISTRY):
        import time
        self._tool = tool
        self._t0 = time.perf_counter()
        self._depth = 0
        self.rank = rank
        self.registry = registry

    def now(self) -> int:
        import time
        return int((time.perf_counter() - self._t0) * 1e6)

    def enter(self) -> int:
        d = self._depth
        self._depth += 1
        return d

    def exit(self) -> None:
        self._depth -= 1

    def layer_enabled(self, layer: str) -> bool:
        return True

    def record(self, func_id: int, raw_args: tuple, ret, depth: int,
               t0: int, t1: int) -> None:
        norm = tuple(len(a) if isinstance(a, (bytes, bytearray)) else a
                     for a in raw_args)
        self._tool.record(func_id, 0, depth, norm, _scrub(ret), t0, t1)

    def forget_handle(self, raw) -> None:
        pass


def _scrub(ret):
    return len(ret) if isinstance(ret, (bytes, bytearray)) else (
        ret if isinstance(ret, (int, float, str, bool, type(None), tuple))
        else repr(ret))


class DarshanLike:
    """Per-rank counter profiler + optional DXT segment capture."""

    DXT_RECORD = struct.Struct("<iqqII")  # rank, offset, length, t0, t1

    def __init__(self, rank: int, dxt: bool = True,
                 registry: FunctionRegistry = REGISTRY):
        self.rank = rank
        self.dxt = dxt
        self.registry = registry
        self.files: Dict[Any, _FileCounters] = {}
        self._dxt_buf = bytearray()
        self.n_records = 0

    def record(self, func_id: int, tid: int, depth: int, args: tuple,
               ret: Any, t0: int, t1: int) -> None:
        self.n_records += 1
        spec = self.registry.spec(func_id)
        # resolve a file key: first PATH or HANDLE arg
        fkey = "<none>"
        for i, a in enumerate(spec.args):
            if a.role in (Role.PATH, Role.HANDLE) and i < len(args):
                fkey = args[i]
                break
        fc = self.files.setdefault(fkey, _FileCounters())
        fc.counts[spec.name] = fc.counts.get(spec.name, 0) + 1
        size = 0
        offset = None
        for i, a in enumerate(spec.args):
            if i >= len(args):
                continue
            if a.role == Role.BUF:
                size = len(args[i]) if hasattr(args[i], "__len__") else \
                    int(args[i] or 0)
            elif a.role == Role.SIZE and isinstance(args[i], int):
                size = args[i]
            elif a.role == Role.OFFSET:
                offset = int(args[i])
        fc.bytes_rw += size
        if offset is not None:
            fc.max_offset = max(fc.max_offset, offset + size)
        fc.t_first = min(fc.t_first, t0)
        fc.t_last = max(fc.t_last, t1)
        if self.dxt and spec.name in _DATA_OPS and spec.layer in (
                "posix", "shardio"):
            self._dxt_buf += self.DXT_RECORD.pack(
                self.rank, offset or 0, size, t0 & 0xFFFFFFFF,
                t1 & 0xFFFFFFFF)

    def serialize(self) -> bytes:
        """Darshan-style compact log: zlib'd JSON counters + raw DXT."""
        counters = {str(k): {"counts": fc.counts, "bytes": fc.bytes_rw,
                             "max_offset": fc.max_offset,
                             "t": [fc.t_first, fc.t_last]}
                    for k, fc in self.files.items()}
        blob = zlib.compress(json.dumps(counters).encode(), 6)
        dxt = zlib.compress(bytes(self._dxt_buf), 6)  # darshan logs are zlib'd
        head = struct.pack("<II", len(blob), len(dxt))
        return head + blob + dxt

    def write(self, trace_dir: str) -> int:
        os.makedirs(trace_dir, exist_ok=True)
        p = os.path.join(trace_dir, f"rank_{self.rank}.darshan")
        with open(p, "wb") as f:
            f.write(self.serialize())
        return os.path.getsize(p)
