"""The Recorder runtime (paper Sections 2 and 3).

One ``Recorder`` instance per process (rank).  The generated tracing
wrappers (``wrappers.py``) call :meth:`Recorder.record` from their epilogue;
the record path performs, in order:

  * argument normalization by role (paths, unified handle ids, buffer
    lengths -- paper §2.2.1/§3.2.2),
  * runtime filtering by path prefix and layer (paper §2.1.1),
  * intra-process I/O pattern encoding of OFFSET-role args (paper §3.2.1),
  * CST interning of the call signature (paper §3.1),
  * Sequitur grammar append (paper §3.1),
  * timestamp buffering (paper §2.2.1).

``finalize`` runs the inter-process stage (paper §3.2.2/§3.3) through a
``Comm`` and writes the five trace files (unique CFGs, CFG index, merged
CST, timestamps, metadata).
"""

from __future__ import annotations

import concurrent.futures
import getpass
import os
import socket
import sys
import threading
import time
import warnings
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from .comm import Comm, SoloComm
from .cst import CST
from .encoding import Handle
from .interprocess import (deserialize_rank_state, finalize_ranks,
                           make_rank_state, materialize_state,
                           merge_serialized_states, serialize_rank_state)
from .patterns import IntraPatternTracker
from .sequitur import Sequitur, concat_grammars
from .specs import DATA_FUNCS, REGISTRY, FunctionRegistry, Role
from .timestamps import TimestampBuffer, compress_timestamps
from . import streaming, trace_format


def _env_int(name: str, minimum: int = 1) -> Optional[int]:
    raw = os.environ.get(name)
    if raw is None or raw == "":
        return None
    try:
        v = int(raw)
    except ValueError:
        raise ValueError(
            f"{name} must be an integer, got {raw!r}") from None
    if v < minimum:
        raise ValueError(f"{name} must be >= {minimum}, got {v}")
    return v


def _env_float(name: str, minimum: float = 0.0) -> Optional[float]:
    raw = os.environ.get(name)
    if raw is None or raw == "":
        return None
    try:
        v = float(raw)
    except ValueError:
        raise ValueError(f"{name} must be a number, got {raw!r}") from None
    if not v > minimum:
        raise ValueError(f"{name} must be > {minimum}, got {v}")
    return v


@dataclass
class RecorderConfig:
    trace_dir: Optional[str] = None
    layers: Optional[Set[str]] = None        # None = all layers enabled
    path_prefixes: Optional[List[str]] = None  # None = record everything
    intra_patterns: bool = True              # paper §3.2.1 toggle (Fig 4)
    inter_patterns: bool = True              # paper §3.2.2 toggle (Fig 5)
    timestamps: bool = True
    store_buffers: bool = False              # record buffer lengths only
    # "tree": hierarchical O(log N)-round reduction of serialized rank
    # states (interprocess.merge_rank_states) through Comm.reduce_tree.
    # "flat": the original gather-at-root pass, kept for bit-compat checks
    # (both produce byte-identical traces; see tests/test_tree_finalize.py).
    finalize_topology: str = "tree"
    # -- streaming (epoch flush) knobs; see core/streaming.py ----------------
    # auto-flush after this many locally recorded calls since the last flush
    flush_every_n_records: Optional[int] = None
    # auto-flush when this much wall time passed since the last flush
    flush_interval_s: Optional[float] = None
    # keep only the newest K committed epoch segments (live-monitoring ring)
    max_epochs_retained: Optional[int] = None
    # records per zlib block in the segment timestamp index
    ts_block_records: int = 4096
    # run epoch commits (reduce + segment write) in a background thread:
    # flush() snapshots the delta synchronously and returns immediately.
    # At most one epoch is in flight; a flush arriving while one is in
    # flight coalesces (its records ride the next epoch).  Errors from the
    # background commit surface on the next flush()/finalize()/drain().
    async_flush: bool = False
    # crash-resume: when flushing into an existing streaming trace
    # directory, rank 0 rebuilds the cumulative state from the committed
    # segments' state.bin deltas, so a preempted-and-restarted run keeps
    # appending epochs AND still writes a merged/ covering the full history
    resume: bool = True
    # degraded fault-tolerant flushes: when set (and the comm has true
    # point-to-point transport), every flush collective runs barrier-free
    # with this per-hop receive timeout -- an unresponsive rank is voted
    # around and the survivors commit a partial epoch carrying a
    # ranks_present mask; a rank whose delta missed the commit keeps it
    # in memory for the next attempt (see streaming.run_flush_degraded)
    flush_timeout_s: Optional[float] = None
    # backend for the batched encode/fit hot paths (timestamp delta+zigzag,
    # varint packing, rank-linear fitting): "python" (scalar reference),
    # "numpy" (vectorized host), "pallas" (device kernels; interpret-mode
    # on CPU-only hosts), or "auto" (crossover by batch size -- numpy on
    # CPU, kernels for large batches when an accelerator is attached).
    # Every backend writes byte-identical traces
    # (tests/test_encode_kernels.py); see core/encode_backend.py.
    encode_backend: str = "auto"

    def __post_init__(self) -> None:
        # the same bounds from_env enforces, so directly-constructed
        # configs (the README path) cannot silently degenerate -- e.g.
        # flush_every_n_records=0 would otherwise flush on EVERY record
        if (self.flush_every_n_records is not None
                and self.flush_every_n_records < 1):
            raise ValueError("flush_every_n_records must be >= 1, got "
                             f"{self.flush_every_n_records}")
        if self.flush_interval_s is not None and not self.flush_interval_s > 0:
            raise ValueError("flush_interval_s must be > 0, got "
                             f"{self.flush_interval_s}")
        if (self.max_epochs_retained is not None
                and self.max_epochs_retained < 1):
            raise ValueError("max_epochs_retained must be >= 1, got "
                             f"{self.max_epochs_retained}")
        if self.ts_block_records < 1:
            raise ValueError(
                f"ts_block_records must be >= 1, got {self.ts_block_records}")
        if self.flush_timeout_s is not None and not self.flush_timeout_s > 0:
            raise ValueError("flush_timeout_s must be > 0, got "
                             f"{self.flush_timeout_s}")
        from .encode_backend import BACKENDS
        if self.encode_backend not in BACKENDS:
            raise ValueError(f"encode_backend must be one of {BACKENDS}, "
                             f"got {self.encode_backend!r}")

    @classmethod
    def from_env(cls, **overrides) -> "RecorderConfig":
        """Environment-variable control, as in the original tool.

        Malformed streaming knobs raise ``ValueError`` naming the variable
        -- a long job silently falling back to "never flush" would defeat
        the crash-durability the knobs exist for.
        """
        cfg = cls(**overrides)
        layers = os.environ.get("RECORDER_LAYERS")
        if layers:
            cfg.layers = set(layers.split(","))
        prefixes = os.environ.get("RECORDER_PATH_PREFIXES")
        if prefixes:
            cfg.path_prefixes = prefixes.split(",")
        if os.environ.get("RECORDER_NO_INTRA_PATTERNS"):
            cfg.intra_patterns = False
        if os.environ.get("RECORDER_NO_INTER_PATTERNS"):
            cfg.inter_patterns = False
        topo = os.environ.get("RECORDER_FINALIZE_TOPOLOGY")
        if topo:
            cfg.finalize_topology = topo
        n = _env_int("RECORDER_FLUSH_EVERY_N_RECORDS")
        if n is not None:
            cfg.flush_every_n_records = n
        s = _env_float("RECORDER_FLUSH_INTERVAL_S")
        if s is not None:
            cfg.flush_interval_s = s
        k = _env_int("RECORDER_MAX_EPOCHS_RETAINED")
        if k is not None:
            cfg.max_epochs_retained = k
        b = _env_int("RECORDER_TS_BLOCK_RECORDS")
        if b is not None:
            cfg.ts_block_records = b
        if os.environ.get("RECORDER_ASYNC_FLUSH"):
            cfg.async_flush = True
        if os.environ.get("RECORDER_NO_RESUME"):
            cfg.resume = False
        t = _env_float("RECORDER_FLUSH_TIMEOUT_S")
        if t is not None:
            cfg.flush_timeout_s = t
        eb = os.environ.get("RECORDER_ENCODE_BACKEND")
        if eb:
            from .encode_backend import BACKENDS
            if eb not in BACKENDS:
                raise ValueError(
                    f"RECORDER_ENCODE_BACKEND must be one of {BACKENDS}, "
                    f"got {eb!r}")
            cfg.encode_backend = eb
        return cfg


@dataclass
class RecorderStats:
    n_records: int = 0
    n_skipped: int = 0
    cst_entries: int = 0
    cfg_bytes: int = 0
    cst_bytes: int = 0
    ts_bytes: int = 0
    epochs: int = 0   # committed streaming flushes (0 for one-shot traces)


class _ThreadState(threading.local):
    def __init__(self) -> None:
        self.depth = 0
        # this thread's dense index into the trace's thread column.  Kept in
        # thread-local storage, NOT in a dict keyed by threading.get_ident():
        # the OS recycles identifiers, so sequential short-lived threads would
        # collapse into one trace thread under an ident-keyed map.
        self.tidx: Optional[int] = None


class Recorder:
    def __init__(self, rank: int = 0, config: Optional[RecorderConfig] = None,
                 registry: FunctionRegistry = REGISTRY,
                 comm: Optional[Comm] = None) -> None:
        self.rank = rank
        self.config = config or RecorderConfig()
        self.registry = registry
        self.cst = CST()
        self.grammar = Sequitur()
        self.intra = IntraPatternTracker(enabled=self.config.intra_patterns)
        self.timestamps = TimestampBuffer()
        self._lock = threading.Lock()
        self._tls = _ThreadState()
        self._next_thread_index = 0
        self._handles: Dict[Any, Handle] = {}
        self._untracked: Set[Any] = set()
        self._next_handle = 0
        self._free_handles: Set[int] = set()  # reuse closed ids (fd-like)
        self._t0 = time.perf_counter()
        self.n_records = 0
        self.n_skipped = 0
        self._finalized = False
        # -- streaming state (core/streaming.py) --------------------------------
        self._comm = comm                 # default comm for flush/finalize
        self.epoch = 0                    # committed flushes so far
        self._records_at_flush = 0
        self._last_flush_t = time.perf_counter()
        self._flush_lock = threading.Lock()
        self._autoflush_broken = False
        # rank 0 only: the O(delta)-per-flush cross-epoch accumulator, and
        # summed per-flush byte sizes for the final RecorderStats
        self._cum = streaming.CumulativeState()
        self._stream_totals = RecorderStats()
        # a snapshotted epoch whose commit failed (or committed without
        # this rank): prepended to the next take_epoch so the next
        # successful flush covers those records exactly once
        self._pending: Optional[Tuple[List[bytes], bytes, Any, int]] = None
        self._records_at_flush_prev = 0
        self._resume_checked = False
        self.epochs_resumed = 0    # epochs recovered by crash-resume
        self.epochs_degraded = 0   # commits that went through partial
        self.epochs_restored = 0   # failed commits whose delta was kept
        self.last_flush_outcome: Optional[streaming.FlushOutcome] = None
        # first (unmasked) tick of the current epoch -> per-epoch wrap base
        self._epoch_first_tick: Optional[int] = None
        # -- async flush state (config.async_flush) -----------------------------
        self._flush_pool: Optional[concurrent.futures.ThreadPoolExecutor] = None
        self._inflight: Optional[concurrent.futures.Future] = None
        self._async_error: Optional[BaseException] = None
        self._bg_comm: Optional[Comm] = None
        self.epochs_coalesced = 0  # flush requests absorbed by an in-flight one

    # -- wrapper support ------------------------------------------------------

    def now(self) -> int:
        """Microsecond ticks since recorder start (4-byte timestamps)."""
        return int((time.perf_counter() - self._t0) * 1e6)

    def enter(self) -> int:
        d = self._tls.depth
        self._tls.depth = d + 1
        return d

    def exit(self) -> None:
        self._tls.depth -= 1

    def layer_enabled(self, layer: str) -> bool:
        return self.config.layers is None or layer in self.config.layers

    # -- the record path ------------------------------------------------------

    def _alloc_handle(self) -> Handle:
        """Smallest-free-id allocation: re-opening after close yields the
        SAME unified id (as POSIX fds do), so periodic re-writes of the same
        file (rolling checkpoints) produce identical call signatures."""
        if self._free_handles:
            hid = min(self._free_handles)
            self._free_handles.discard(hid)
            return Handle(hid)
        h = Handle(self._next_handle)
        self._next_handle += 1
        return h

    def _thread_index(self) -> int:
        """Dense per-thread index, assigned on a thread's first record
        (callers hold ``self._lock``, serializing the counter)."""
        idx = self._tls.tidx
        if idx is None:
            idx = self._next_thread_index
            self._next_thread_index += 1
            self._tls.tidx = idx
        return idx

    def record(self, func_id: int, raw_args: tuple, ret: Any, depth: int,
               t0: int, t1: int) -> None:
        spec = self.registry.spec(func_id)
        with self._lock:
            self._record_locked(spec, func_id, raw_args, ret, depth, t0, t1)
        if self._tls.depth == 0:
            # auto-flush only from top-level calls (a flush inside a layered
            # call would split parent and child records across epochs)
            self._maybe_autoflush()

    def _record_locked(self, spec, func_id: int, raw_args: tuple, ret: Any,
                       depth: int, t0: int, t1: int) -> None:
        tidx = self._thread_index()
        norm: List[Any] = []
        offsets: List[int] = []
        offset_slots: List[int] = []
        handle_ids: List[int] = []
        keyparts: List[Any] = []
        prefixes = self.config.path_prefixes
        for i, arg in enumerate(raw_args):
            role = spec.args[i].role if i < len(spec.args) else Role.VAL
            if role == Role.PATH:
                p = str(arg)
                if prefixes is not None and not any(
                        p.startswith(x) for x in prefixes):
                    # filtered out: skip the record entirely; if this call
                    # creates a handle, remember it as untracked
                    if spec.ret_role == Role.HANDLE and ret is not None:
                        self._untracked.add(ret)
                    self.n_skipped += 1
                    return
                norm.append(p)
                keyparts.append(p)
            elif role == Role.HANDLE:
                if arg in self._untracked:
                    self.n_skipped += 1
                    return
                h = self._handles.get(arg)
                if h is None:
                    # handle from before tracing started: late-register
                    h = self._alloc_handle()
                    self._handles[arg] = h
                norm.append(h)
                handle_ids.append(h.id)
            elif role == Role.OFFSET:
                offsets.append(int(arg))
                offset_slots.append(len(norm))
                norm.append(None)  # placeholder, filled below
            elif role == Role.BUF:
                v = len(arg) if hasattr(arg, "__len__") else (
                    int(arg) if isinstance(arg, int) else None)
                norm.append(v)
                keyparts.append(v)
            else:  # SIZE / VAL
                norm.append(arg)
                keyparts.append(arg)

        # normalize the return value
        is_err = isinstance(ret, tuple) and len(ret) == 2 and ret[0] == "err"
        if spec.ret_role == Role.HANDLE and ret is not None and not is_err:
            # layered opens (shard_open -> posix.open) return the same
            # raw handle: they share one unified id (paper Section 3.2.2)
            h = self._handles.get(ret)
            if h is None:
                h = self._alloc_handle()
                self._handles[ret] = h
            nret: Any = h
        elif spec.ret_role == Role.BUF and hasattr(ret, "__len__"):
            nret = len(ret)
        else:
            nret = ret
        if isinstance(nret, Handle):
            key_ret: Any = ("h", nret.id)
        else:
            key_ret = nret

        # OFFSET-role returns (e.g. lseek's resulting offset) join the
        # pattern run; they cannot be part of the pattern key then.
        ret_is_offset = (spec.ret_role == Role.OFFSET
                         and isinstance(nret, int) and not is_err)

        # intra-process I/O pattern encoding (paper §3.2.1)
        if offsets or ret_is_offset:
            key = (func_id, tidx, tuple(handle_ids), tuple(keyparts),
                   None if ret_is_offset else key_ret)
            vals = offsets + ([nret] if ret_is_offset else [])
            encoded = self.intra.encode(key, vals)
            for slot, val in zip(offset_slots, encoded):
                norm[slot] = val
            if ret_is_offset:
                nret = encoded[-1]

        sig = trace_format.make_signature(func_id, tidx, depth, tuple(norm), nret)
        terminal = self.cst.intern(sig)
        self.grammar.push(terminal)
        if self.config.timestamps:
            if self._epoch_first_tick is None:
                self._epoch_first_tick = t0
            self.timestamps.append(t0, t1,
                                   self._data_bytes(spec, norm, nret))
        self.n_records += 1

    @staticmethod
    def _data_bytes(spec, norm: List[Any], nret: Any) -> int:
        """Data bytes moved by this call, for the per-timestamp-block byte
        counters (exact windowed bandwidth).  Mirrors the signature-side
        rule in ``traceview._SigInfo``: first BUF/SIZE int arg, else int
        return, else 0 -- and only for the data-moving functions."""
        if spec.name not in DATA_FUNCS:
            return 0
        for a, v in zip(spec.args, norm):
            if a.role in (Role.BUF, Role.SIZE) and isinstance(v, int):
                return v
        return nret if isinstance(nret, int) else 0

    def forget_handle(self, raw: Any) -> None:
        """Called by close-style wrappers after recording."""
        with self._lock:
            h = self._handles.pop(raw, None)
            if h is not None:
                self._free_handles.add(h.id)
            self._untracked.discard(raw)

    # -- streaming epoch flushes (core/streaming.py) --------------------------

    def _is_streaming(self) -> bool:
        # an in-flight (or failed-but-unreaped) background commit counts:
        # finalize must take the streaming path and drain it even when a
        # failure's _restore_epoch already rolled the epoch counter back
        return (self.epoch > 0
                or self._inflight is not None
                or self._async_error is not None
                or self.config.flush_every_n_records is not None
                or self.config.flush_interval_s is not None)

    def take_epoch(self) -> Tuple[List[bytes], bytes, Any, int]:
        """Snapshot and reset the live per-rank state: returns the epoch's
        (CST entries, serialized CFG, raw tick array, tick wrap counter)
        and restarts the CST, grammar and intra-pattern tracker for the
        next epoch.  Handle ids and the tick clock persist across epochs,
        so cross-epoch streams stitch back into the exact one-shot record
        sequence.  The wrap counter is how many times the uint32
        microsecond clock had wrapped at the epoch's first record --
        readers seed timestamp unwrapping with it, so days-long streamed
        runs keep monotonic int64 timestamps.

        A pending snapshot from a failed earlier commit is spliced in
        FRONT of the live delta (CST concat + ``concat_grammars`` -- the
        same layout segment stitching produces), so retried records land
        in the next committed epoch exactly once."""
        with self._lock:
            entries = self.cst.entries
            cfg = self.grammar.serialize()
            ticks = self.timestamps.take()
            wraps = (self._epoch_first_tick or 0) >> 32
            self._epoch_first_tick = None
            self.cst = CST()
            self.grammar = Sequitur()
            self.intra = IntraPatternTracker(
                enabled=self.config.intra_patterns)
            self._records_at_flush_prev = self._records_at_flush
            self._records_at_flush = self.n_records
            if self._pending is not None:
                p_entries, p_cfg, p_ticks, p_wraps = self._pending
                self._pending = None
                cfg = concat_grammars([(p_cfg, 0), (cfg, len(p_entries))])
                entries = list(p_entries) + list(entries)
                if len(p_ticks):
                    ticks = np.concatenate([p_ticks, ticks], axis=0) \
                        if len(ticks) else p_ticks
                    wraps = p_wraps
        return entries, cfg, ticks, wraps

    def flush(self, comm: Optional[Comm] = None,
              trace_dir: Optional[str] = None) -> Optional[Dict[str, Any]]:
        """Commit one epoch segment without stopping tracing (collective:
        every rank of ``comm`` must call it in the same order).

        The epoch delta is reduced across ranks through
        ``comm.reduce_tree`` (O(delta), O(log N) rounds); timestamps ride
        the same tree as block-indexed zlib blocks via
        ``comm.gather_tree``.  Rank 0 folds the delta into the cumulative
        state, writes ``epoch_NNNNN/`` (atomic rename + manifest rewrite)
        and returns the manifest entry; other ranks return None.

        With ``config.async_flush`` the call only snapshots the delta
        (cheap, no compression or I/O) and hands reduce+commit to a
        background thread, returning None immediately.  At most one epoch
        is in flight: a flush arriving while one is still committing
        coalesces -- its records simply ride the next epoch (counted in
        ``epochs_coalesced``).  On a multi-rank comm the coalesce decision
        is taken in lockstep (``comm.vote_any`` of the local busy flags),
        so ranks never disagree on how many epochs exist; the background
        collectives run on ``comm.dup('recorder-flush')``, a separate
        communication context that cannot interleave with foreground
        collectives on ``comm``.  A failed background commit surfaces as a
        RuntimeError (with the original failure chained) on the NEXT
        flush()/drain()/finalize() -- it never vanishes.
        """
        if self._finalized:
            raise RuntimeError("recorder already finalized")
        comm = comm or self._comm or SoloComm()
        trace_dir = trace_dir or self.config.trace_dir
        if not trace_dir:
            raise ValueError("flush requires a trace_dir")
        with self._flush_lock:
            if self._finalized:  # re-check: finalize may have won the lock
                raise RuntimeError("recorder already finalized")
            return self._flush_impl(comm, trace_dir)

    def _maybe_resume(self, comm: Comm, trace_dir: str) -> None:
        """Crash-resume: before the first commit into an EXISTING stream
        directory, rank 0 rebuilds the cross-epoch cumulative state by
        folding the committed segments' ``state.bin`` deltas
        (:func:`streaming.resume_cumulative_state`), so a preempted-and-
        restarted run keeps appending epochs AND a clean finalize still
        writes ``merged/`` covering the FULL history.  Checked once per
        recorder; disabled by ``config.resume=False`` and meaningless
        under ring retention (no merged trace there).  An unresumable
        directory (corrupt/truncated segment) degrades to the old
        append-without-merged behavior with a warning."""
        if self._resume_checked:
            return
        self._resume_checked = True
        if (not self.config.resume or comm.rank != 0
                or self.config.max_epochs_retained is not None
                or self._cum.n_epochs != 0
                or not trace_dir or not trace_format.is_stream_dir(trace_dir)):
            return
        try:
            cum = streaming.resume_cumulative_state(trace_dir)
        except trace_format.TraceFormatError as e:
            warnings.warn(
                f"cannot resume cumulative state from existing trace dir "
                f"{trace_dir!r} ({e}); new epochs will append but no "
                f"full-history merged trace can be written on finalize",
                RuntimeWarning)
            return
        if cum.n_epochs:
            self._cum = cum
            self.epochs_resumed = cum.n_epochs

    def _flush_impl(self, comm: Comm, trace_dir: str
                    ) -> Optional[Dict[str, Any]]:
        self._maybe_resume(comm, trace_dir)
        if self.config.async_flush:
            return self._flush_async_locked(comm, trace_dir)
        return self._flush_locked(comm, trace_dir)

    def _flush_locked(self, comm: Comm, trace_dir: str
                      ) -> Optional[Dict[str, Any]]:
        entries, cfg, ticks, wraps = self.take_epoch()
        epoch = self.epoch
        self.epoch += 1
        self._last_flush_t = time.perf_counter()
        return self._commit_epoch(comm, trace_dir, entries, cfg, ticks,
                                  wraps, epoch)

    def _flush_async_locked(self, comm: Comm, trace_dir: str) -> None:
        self._reap()
        self._raise_async_error()
        busy = self._inflight is not None
        if comm.size > 1:
            # lockstep coalesce: if ANY rank is still committing, every
            # rank coalesces -- local decisions could desync epoch counts
            busy = self._vote(comm, busy)
        if busy:
            self.epochs_coalesced += 1
            return None
        entries, cfg, ticks, wraps = self.take_epoch()
        epoch = self.epoch
        self.epoch += 1
        self._last_flush_t = time.perf_counter()
        if self._bg_comm is None:
            self._bg_comm = comm.dup("recorder-flush")
        self._inflight = self._pool().submit(
            self._commit_epoch, self._bg_comm, trace_dir, entries, cfg,
            ticks, wraps, epoch)
        return None

    def _degraded(self, comm: Comm) -> bool:
        """True when flushes run the timed, failure-tolerant protocol:
        a flush timeout is configured and the comm has a p2p transport
        (the degraded collectives are barrier-free p2p trees)."""
        return (self.config.flush_timeout_s is not None
                and comm.size > 1
                and getattr(comm, "has_p2p", False))

    def _vote(self, comm: Comm, flag: bool) -> bool:
        """Lockstep OR-vote; under the degraded protocol uses the timed
        survivor vote so a dead rank cannot hang cadence decisions."""
        if self._degraded(comm):
            return comm.agree(flag, self.config.flush_timeout_s)[0]
        return comm.vote_any(flag)

    def _restore_epoch(self, entries: List[bytes], cfg: bytes, ticks: Any,
                       wraps: int) -> None:
        """Put a snapshotted-but-uncommitted epoch delta back: the next
        ``take_epoch`` splices it in front of the live delta, so a failed
        flush loses nothing and the retry covers its records exactly
        once.  A second failure before the retry keeps the OLDEST
        snapshot's splice position (it already contains this one)."""
        with self._lock:
            self._pending = (entries, cfg, ticks, wraps)
            self._records_at_flush = self._records_at_flush_prev
            self.epoch -= 1
            self.epochs_restored += 1

    def _commit_epoch(self, comm: Comm, trace_dir: str, entries: List[bytes],
                      cfg: bytes, ticks: Any, wraps: int, epoch: int
                      ) -> Optional[Dict[str, Any]]:
        """Reduce + write one already-snapshotted epoch (the part a
        background flush moves off the application's critical path).

        Any failure path restores the snapshot into ``_pending`` before
        propagating, so epoch records are never silently dropped: a
        crashed write, a lost survivor vote, or this rank being absent
        from a degraded commit all leave the delta intact for the next
        flush attempt."""
        try:
            if self._degraded(comm):
                outcome = streaming.run_flush_degraded(
                    comm, entries=entries, cfg=cfg, ticks=ticks,
                    registry=self.registry, trace_dir=trace_dir, epoch=epoch,
                    cum=self._cum, inter_patterns=self.config.inter_patterns,
                    ts_block_records=self.config.ts_block_records,
                    max_epochs_retained=self.config.max_epochs_retained,
                    meta_extra={**self._metadata(comm.size),
                                "tick_wraps": wraps},
                    timeout_s=self.config.flush_timeout_s,
                    encode_backend=self.config.encode_backend)
                self.last_flush_outcome = outcome
                if outcome.exc is not None:
                    raise outcome.exc
                if outcome.lost_local or not outcome.ok:
                    self._restore_epoch(entries, cfg, ticks, wraps)
                    warnings.warn(
                        f"epoch {epoch} flush did not include this rank "
                        f"({outcome.error or 'commit outcome unknown'}); "
                        f"its records were retained and ride the next "
                        f"flush", RuntimeWarning)
                    return None
                if (comm.rank == 0 and outcome.ranks_present
                        and len(outcome.ranks_present) < comm.size):
                    self.epochs_degraded += 1
                entry = outcome.entry
            else:
                entry = streaming.run_flush(
                    comm, entries=entries, cfg=cfg, ticks=ticks,
                    registry=self.registry, trace_dir=trace_dir, epoch=epoch,
                    cum=self._cum, inter_patterns=self.config.inter_patterns,
                    ts_block_records=self.config.ts_block_records,
                    max_epochs_retained=self.config.max_epochs_retained,
                    meta_extra={**self._metadata(comm.size),
                                "tick_wraps": wraps},
                    encode_backend=self.config.encode_backend)
        except BaseException:
            self._restore_epoch(entries, cfg, ticks, wraps)
            raise
        if entry is not None:
            t = self._stream_totals
            t.epochs += 1
            t.cst_entries += entry["cst_entries"]
            t.cfg_bytes += entry["files"]["unique_cfgs.bin"]
            t.cst_bytes += entry["files"]["merged_cst.bin"]
            t.ts_bytes += entry["files"]["timestamps.bin"]
        return entry

    # -- async flush plumbing -------------------------------------------------

    def _pool(self) -> concurrent.futures.ThreadPoolExecutor:
        if self._flush_pool is None:
            self._flush_pool = concurrent.futures.ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="recorder-flush")
        return self._flush_pool

    def _reap(self) -> None:
        """Collect a finished in-flight future; stash its failure (if any)
        for :meth:`_raise_async_error`.  Callers hold ``_flush_lock``."""
        fut = self._inflight
        if fut is not None and fut.done():
            self._inflight = None
            exc = fut.exception()
            if exc is not None:
                self._async_error = exc

    def _raise_async_error(self) -> None:
        exc, self._async_error = self._async_error, None
        if exc is not None:
            raise RuntimeError(
                "background epoch commit failed; its epoch's records were "
                "retained (restored as a pending delta that rides the next "
                "flush) and the trace directory and cumulative state remain "
                "consistent") from exc

    def _drain_locked(self) -> None:
        fut = self._inflight
        if fut is not None:
            concurrent.futures.wait([fut])
            self._reap()
        self._raise_async_error()

    def drain(self) -> None:
        """Block until any in-flight background epoch commit finished;
        re-raise its error if it failed.  Safe to call with async flushes
        disabled (no-op)."""
        with self._flush_lock:
            self._drain_locked()

    def maybe_flush(self, comm: Optional[Comm] = None,
                    trace_dir: Optional[str] = None
                    ) -> Optional[Dict[str, Any]]:
        """Collective cadence check -- call at a natural synchronization
        point (e.g. once per training step) on EVERY rank.  Each rank
        votes whether its own flush cadence (records / wall time) is due;
        the OR of the votes decides for all, so ranks with skewed record
        counts (non-SPMD workloads) still flush in lockstep.  Flushes via
        :meth:`flush` when the vote passes, else returns None after the
        one cheap vote collective (a barrier-sized piggyback)."""
        if self._finalized:
            return None
        comm = comm or self._comm or SoloComm()
        due = self._flush_due()
        if comm.size > 1:
            due = self._vote(comm, due)
        if not due:
            return None
        return self.flush(comm, trace_dir)

    def _flush_due(self) -> bool:
        cfg = self.config
        if (cfg.flush_every_n_records is not None
                and self.n_records - self._records_at_flush
                >= cfg.flush_every_n_records):
            return True
        return (cfg.flush_interval_s is not None
                and time.perf_counter() - self._last_flush_t
                >= cfg.flush_interval_s)

    def _maybe_autoflush(self) -> None:
        """Auto-flush on the configured record-count / wall-time cadence.

        Cadence is evaluated per rank against the recorder's own comm
        (default Solo).  A multi-rank comm never auto-flushes: flush is
        collective, and a rank-local record count crossing its threshold
        is not a synchronization point -- multi-rank jobs flush through
        the :meth:`maybe_flush` vote (or explicit :meth:`flush`) at
        application sync points.

        Concurrent recording threads race the dueness check, so it is
        re-evaluated under the flush lock and a thread that finds a flush
        already in progress simply moves on -- one cadence crossing
        produces exactly one epoch, never a spurious empty second one.

        Auto-flush runs inside the application's traced call, so a trace-
        volume failure (ENOSPC, removed trace_dir) must not surface -- or
        worse, REPLACE an in-flight exception -- in an unrelated I/O call:
        the failure is warned once and auto-flush disables itself; explicit
        ``flush()`` / ``finalize()`` still raise.
        """
        cfg = self.config
        if (cfg.trace_dir is None or self._finalized
                or self._autoflush_broken
                or (cfg.flush_every_n_records is None
                    and cfg.flush_interval_s is None)):
            return
        if self._comm is not None and self._comm.size > 1:
            # a rank-local cadence crossing is not a synchronization point
            # in a multi-rank job, and flush is collective there; cadence
            # goes through the maybe_flush vote at app sync points instead
            return
        if not self._flush_due():
            return
        if not self._flush_lock.acquire(blocking=False):
            return  # another thread is flushing this very crossing
        try:
            # re-check under the lock: the flush we raced may have
            # satisfied the cadence, or finalize may have completed
            if not self._finalized and self._flush_due():
                self._flush_impl(self._comm or SoloComm(), cfg.trace_dir)
        except Exception as e:
            self._autoflush_broken = True
            warnings.warn(
                f"recorder auto-flush failed ({type(e).__name__}: {e}); "
                f"auto-flush disabled, tracing continues -- call flush() "
                f"or finalize() explicitly to surface the error",
                RuntimeWarning)
        finally:
            self._flush_lock.release()

    # -- finalization (paper §3.3) --------------------------------------------

    def local_state(self) -> Tuple[List[bytes], bytes, bytes]:
        """(CST entries, serialized CFG, compressed timestamps)."""
        ts = compress_timestamps(self.timestamps.as_array(),
                                 backend=self.config.encode_backend)
        return self.cst.entries, self.grammar.serialize(), ts

    def finalize(self, comm: Optional[Comm] = None,
                 trace_dir: Optional[str] = None) -> Optional[RecorderStats]:
        """Run the inter-process stage and write the trace (root returns
        stats; other ranks return None).

        ``config.finalize_topology`` selects how rank states reach rank 0:
        ``"tree"`` reduces serialized states pairwise through
        ``comm.reduce_tree`` in O(log N) rounds (each hop merges two
        contiguous rank blocks, so rank 0 only materializes the already
        merged state); ``"flat"`` gathers every raw CST/CFG to rank 0 and
        merges there.  Both write byte-identical traces; tree timestamps
        travel as one concatenated payload per hop (``comm.gather_tree``),
        bounding rank-0 fan-in, while flat keeps the reference gather.

        **Streaming runs** (any flush happened, or flush cadence knobs are
        set) finalize differently: the remaining tail is flushed as the
        last epoch segment and rank 0 materializes the cumulative
        cross-epoch state into ``<trace_dir>/merged`` -- the incremental
        finalize: no re-reduction of earlier epochs ever happens.
        """
        if self._finalized:
            raise RuntimeError("recorder already finalized")
        comm = comm or self._comm or SoloComm()
        trace_dir = trace_dir or self.config.trace_dir
        if self._is_streaming():
            if not trace_dir:
                raise ValueError("streaming finalize requires a trace_dir")
            # drain any in-flight background commit FIRST (its failure must
            # surface here, not vanish), then flush the tail synchronously;
            # the tail flush is skippable only when provably empty AND the
            # decision needs no agreement (solo comm) -- multi-rank flushes
            # are collective, so every rank must make the same call.  The
            # _finalized flip happens under the flush lock so a racing
            # auto-flush can never commit an epoch after the tail (it
            # re-checks the flag under the same lock).  Safe to wait on the
            # future while holding the lock: the background commit never
            # takes it.
            with self._flush_lock:
                self._drain_locked()
                self._maybe_resume(comm, trace_dir)
                if (comm.size > 1 or self.epoch == 0
                        or self.n_records > self._records_at_flush):
                    self._flush_locked(comm, trace_dir)
                self._finalized = True
            if self._flush_pool is not None:
                self._flush_pool.shutdown(wait=True)
                self._flush_pool = None
            if comm.rank != 0:
                self._finalize_sync(comm)
                return None
            if self.config.max_epochs_retained is None:
                streaming.write_merged_trace(
                    trace_dir, self._cum, registry=self.registry,
                    inter_patterns=self.config.inter_patterns,
                    meta_extra=self._metadata(comm.size))
            stats = self._stream_totals
            stats.n_records = self.n_records
            stats.n_skipped = self.n_skipped
            self._finalize_sync(comm)
            return stats
        self._finalized = True
        if self.config.finalize_topology not in ("tree", "flat"):
            raise ValueError(
                f"finalize_topology must be 'tree' or 'flat', got "
                f"{self.config.finalize_topology!r}")
        entries, cfg, ts = self.local_state()
        if self.config.finalize_topology == "tree":
            leaf = make_rank_state(comm.rank, entries, cfg, self.registry)
            blob = comm.reduce_tree(serialize_rank_state(leaf),
                                    merge_serialized_states)
            ts_gathered = comm.gather_tree(ts)
            if comm.rank != 0:
                comm.barrier()
                return None
            rank_ts = ts_gathered
            merge, cfgs = materialize_state(
                deserialize_rank_state(blob),
                inter_patterns=self.config.inter_patterns)
        else:
            gathered = comm.gather((entries, cfg, ts))
            if comm.rank != 0:
                comm.barrier()
                return None
            rank_csts = [g[0] for g in gathered]
            rank_cfgs = [g[1] for g in gathered]
            rank_ts = [g[2] for g in gathered]
            merge, cfgs = finalize_ranks(
                rank_csts, rank_cfgs, self.registry,
                inter_patterns=self.config.inter_patterns,
                fit_mode=("pallas" if self.config.encode_backend == "pallas"
                          else "vectorized"))
        stats = RecorderStats(
            n_records=self.n_records,
            n_skipped=self.n_skipped,
            cst_entries=len(merge.merged_entries),
            cfg_bytes=sum(len(c) for c in cfgs.unique_cfgs),
            cst_bytes=sum(len(e) + 2 for e in merge.merged_entries),
            ts_bytes=sum(len(t) for t in rank_ts),
        )
        if trace_dir:
            trace_format.write_trace(
                trace_dir,
                registry=self.registry,
                merged_cst=merge.merged_entries,
                unique_cfgs=cfgs.unique_cfgs,
                cfg_index=cfgs.cfg_index,
                rank_timestamps=rank_ts,
                meta_extra=self._metadata(comm.size),
            )
        comm.barrier()
        return stats

    def _finalize_sync(self, comm: Comm) -> None:
        """Finalize-time synchronization point.  A plain barrier would
        wedge survivors forever if a rank died mid-run, so under the
        degraded protocol it is the timed survivor vote instead (same
        exit discipline, bounded wait)."""
        if self._degraded(comm):
            comm.agree(True, self.config.flush_timeout_s)
        else:
            comm.barrier()

    def _metadata(self, nranks: int) -> Dict[str, Any]:
        try:
            user = getpass.getuser()
        except Exception:  # pragma: no cover
            user = "unknown"
        return {
            "nranks": nranks,
            "app": os.path.basename(sys.argv[0]) if sys.argv else "unknown",
            "user": user,
            "host": socket.gethostname(),
            "pid": os.getpid(),
            "layers": sorted(self.config.layers) if self.config.layers else "all",
            "intra_patterns": self.config.intra_patterns,
            "inter_patterns": self.config.inter_patterns,
            "tick_unit": "us",
            "tick_wrap": 2 ** 32,
        }


# ---------------------------------------------------------------------------
# the active-recorder slot used by generated wrappers (LD_PRELOAD analogue)
# ---------------------------------------------------------------------------

_active: List[Optional[Recorder]] = [None]


def attach(rec: Recorder) -> None:
    _active[0] = rec


def detach() -> None:
    _active[0] = None


def active() -> Optional[Recorder]:
    return _active[0]


class session:
    """Context manager: trace a region and finalize on exit.

    >>> with session(RecorderConfig(trace_dir="/tmp/t")) as rec:
    ...     posix.open(...)  # traced
    """

    def __init__(self, config: Optional[RecorderConfig] = None,
                 comm: Optional[Comm] = None, rank: int = 0):
        self.config = config
        self.comm = comm
        self.rank = rank
        self.recorder: Optional[Recorder] = None
        self.stats: Optional[RecorderStats] = None

    def __enter__(self) -> Recorder:
        self.recorder = Recorder(rank=self.rank, config=self.config,
                                 comm=self.comm)
        attach(self.recorder)
        return self.recorder

    def __exit__(self, *exc) -> None:
        detach()
        if self.recorder is not None and exc[0] is None:
            self.stats = self.recorder.finalize(self.comm)
