"""The Recorder runtime (paper Sections 2 and 3).

One ``Recorder`` instance per process (rank).  The generated tracing
wrappers (``wrappers.py``) call :meth:`Recorder.record` from their epilogue;
the record path performs, in order:

  * argument normalization by role (paths, unified handle ids, buffer
    lengths -- paper §2.2.1/§3.2.2),
  * runtime filtering by path prefix and layer (paper §2.1.1),
  * intra-process I/O pattern encoding of OFFSET-role args (paper §3.2.1),
  * CST interning of the call signature (paper §3.1),
  * Sequitur grammar append (paper §3.1),
  * timestamp buffering (paper §2.2.1).

``finalize`` runs the inter-process stage (paper §3.2.2/§3.3) through a
``Comm`` and writes the five trace files (unique CFGs, CFG index, merged
CST, timestamps, metadata).
"""

from __future__ import annotations

import getpass
import os
import socket
import sys
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

from .comm import Comm, SoloComm
from .cst import CST
from .encoding import Handle
from .interprocess import (deserialize_rank_state, finalize_ranks,
                           make_rank_state, materialize_state,
                           merge_serialized_states, serialize_rank_state)
from .patterns import IntraPatternTracker
from .sequitur import Sequitur
from .specs import REGISTRY, FunctionRegistry, Role
from .timestamps import TimestampBuffer, compress_timestamps
from . import trace_format


@dataclass
class RecorderConfig:
    trace_dir: Optional[str] = None
    layers: Optional[Set[str]] = None        # None = all layers enabled
    path_prefixes: Optional[List[str]] = None  # None = record everything
    intra_patterns: bool = True              # paper §3.2.1 toggle (Fig 4)
    inter_patterns: bool = True              # paper §3.2.2 toggle (Fig 5)
    timestamps: bool = True
    store_buffers: bool = False              # record buffer lengths only
    # "tree": hierarchical O(log N)-round reduction of serialized rank
    # states (interprocess.merge_rank_states) through Comm.reduce_tree.
    # "flat": the original gather-at-root pass, kept for bit-compat checks
    # (both produce byte-identical traces; see tests/test_tree_finalize.py).
    finalize_topology: str = "tree"

    @classmethod
    def from_env(cls, **overrides) -> "RecorderConfig":
        """Environment-variable control, as in the original tool."""
        cfg = cls(**overrides)
        layers = os.environ.get("RECORDER_LAYERS")
        if layers:
            cfg.layers = set(layers.split(","))
        prefixes = os.environ.get("RECORDER_PATH_PREFIXES")
        if prefixes:
            cfg.path_prefixes = prefixes.split(",")
        if os.environ.get("RECORDER_NO_INTRA_PATTERNS"):
            cfg.intra_patterns = False
        if os.environ.get("RECORDER_NO_INTER_PATTERNS"):
            cfg.inter_patterns = False
        topo = os.environ.get("RECORDER_FINALIZE_TOPOLOGY")
        if topo:
            cfg.finalize_topology = topo
        return cfg


@dataclass
class RecorderStats:
    n_records: int = 0
    n_skipped: int = 0
    cst_entries: int = 0
    cfg_bytes: int = 0
    cst_bytes: int = 0
    ts_bytes: int = 0


class _ThreadState(threading.local):
    def __init__(self) -> None:
        self.depth = 0


class Recorder:
    def __init__(self, rank: int = 0, config: Optional[RecorderConfig] = None,
                 registry: FunctionRegistry = REGISTRY) -> None:
        self.rank = rank
        self.config = config or RecorderConfig()
        self.registry = registry
        self.cst = CST()
        self.grammar = Sequitur()
        self.intra = IntraPatternTracker(enabled=self.config.intra_patterns)
        self.timestamps = TimestampBuffer()
        self._lock = threading.Lock()
        self._tls = _ThreadState()
        self._thread_ids: Dict[int, int] = {}
        self._handles: Dict[Any, Handle] = {}
        self._untracked: Set[Any] = set()
        self._next_handle = 0
        self._free_handles: Set[int] = set()  # reuse closed ids (fd-like)
        self._t0 = time.perf_counter()
        self.n_records = 0
        self.n_skipped = 0
        self._finalized = False

    # -- wrapper support ------------------------------------------------------

    def now(self) -> int:
        """Microsecond ticks since recorder start (4-byte timestamps)."""
        return int((time.perf_counter() - self._t0) * 1e6)

    def enter(self) -> int:
        d = self._tls.depth
        self._tls.depth = d + 1
        return d

    def exit(self) -> None:
        self._tls.depth -= 1

    def layer_enabled(self, layer: str) -> bool:
        return self.config.layers is None or layer in self.config.layers

    # -- the record path ------------------------------------------------------

    def _alloc_handle(self) -> Handle:
        """Smallest-free-id allocation: re-opening after close yields the
        SAME unified id (as POSIX fds do), so periodic re-writes of the same
        file (rolling checkpoints) produce identical call signatures."""
        if self._free_handles:
            hid = min(self._free_handles)
            self._free_handles.discard(hid)
            return Handle(hid)
        h = Handle(self._next_handle)
        self._next_handle += 1
        return h

    def _thread_index(self, tid: int) -> int:
        idx = self._thread_ids.get(tid)
        if idx is None:
            idx = len(self._thread_ids)
            self._thread_ids[tid] = idx
        return idx

    def record(self, func_id: int, raw_args: tuple, ret: Any, depth: int,
               t0: int, t1: int) -> None:
        spec = self.registry.spec(func_id)
        with self._lock:
            tidx = self._thread_index(threading.get_ident())
            norm: List[Any] = []
            offsets: List[int] = []
            offset_slots: List[int] = []
            handle_ids: List[int] = []
            keyparts: List[Any] = []
            prefixes = self.config.path_prefixes
            for i, arg in enumerate(raw_args):
                role = spec.args[i].role if i < len(spec.args) else Role.VAL
                if role == Role.PATH:
                    p = str(arg)
                    if prefixes is not None and not any(
                            p.startswith(x) for x in prefixes):
                        # filtered out: skip the record entirely; if this call
                        # creates a handle, remember it as untracked
                        if spec.ret_role == Role.HANDLE and ret is not None:
                            self._untracked.add(ret)
                        self.n_skipped += 1
                        return
                    norm.append(p)
                    keyparts.append(p)
                elif role == Role.HANDLE:
                    if arg in self._untracked:
                        self.n_skipped += 1
                        return
                    h = self._handles.get(arg)
                    if h is None:
                        # handle from before tracing started: late-register
                        h = self._alloc_handle()
                        self._handles[arg] = h
                    norm.append(h)
                    handle_ids.append(h.id)
                elif role == Role.OFFSET:
                    offsets.append(int(arg))
                    offset_slots.append(len(norm))
                    norm.append(None)  # placeholder, filled below
                elif role == Role.BUF:
                    v = len(arg) if hasattr(arg, "__len__") else (
                        int(arg) if isinstance(arg, int) else None)
                    norm.append(v)
                    keyparts.append(v)
                else:  # SIZE / VAL
                    norm.append(arg)
                    keyparts.append(arg)

            # normalize the return value
            is_err = isinstance(ret, tuple) and len(ret) == 2 and ret[0] == "err"
            if spec.ret_role == Role.HANDLE and ret is not None and not is_err:
                # layered opens (shard_open -> posix.open) return the same
                # raw handle: they share one unified id (paper Section 3.2.2)
                h = self._handles.get(ret)
                if h is None:
                    h = self._alloc_handle()
                    self._handles[ret] = h
                nret: Any = h
            elif spec.ret_role == Role.BUF and hasattr(ret, "__len__"):
                nret = len(ret)
            else:
                nret = ret
            if isinstance(nret, Handle):
                key_ret: Any = ("h", nret.id)
            else:
                key_ret = nret

            # OFFSET-role returns (e.g. lseek's resulting offset) join the
            # pattern run; they cannot be part of the pattern key then.
            ret_is_offset = (spec.ret_role == Role.OFFSET
                             and isinstance(nret, int) and not is_err)

            # intra-process I/O pattern encoding (paper §3.2.1)
            if offsets or ret_is_offset:
                key = (func_id, tidx, tuple(handle_ids), tuple(keyparts),
                       None if ret_is_offset else key_ret)
                vals = offsets + ([nret] if ret_is_offset else [])
                encoded = self.intra.encode(key, vals)
                for slot, val in zip(offset_slots, encoded):
                    norm[slot] = val
                if ret_is_offset:
                    nret = encoded[-1]

            sig = trace_format.make_signature(func_id, tidx, depth, tuple(norm), nret)
            terminal = self.cst.intern(sig)
            self.grammar.push(terminal)
            if self.config.timestamps:
                self.timestamps.append(t0, t1)
            self.n_records += 1

    def forget_handle(self, raw: Any) -> None:
        """Called by close-style wrappers after recording."""
        with self._lock:
            h = self._handles.pop(raw, None)
            if h is not None:
                self._free_handles.add(h.id)
            self._untracked.discard(raw)

    # -- finalization (paper §3.3) --------------------------------------------

    def local_state(self) -> Tuple[List[bytes], bytes, bytes]:
        """(CST entries, serialized CFG, compressed timestamps)."""
        ts = compress_timestamps(self.timestamps.as_array())
        return self.cst.entries, self.grammar.serialize(), ts

    def finalize(self, comm: Optional[Comm] = None,
                 trace_dir: Optional[str] = None) -> Optional[RecorderStats]:
        """Run the inter-process stage and write the trace (root returns
        stats; other ranks return None).

        ``config.finalize_topology`` selects how rank states reach rank 0:
        ``"tree"`` reduces serialized states pairwise through
        ``comm.reduce_tree`` in O(log N) rounds (each hop merges two
        contiguous rank blocks, so rank 0 only materializes the already
        merged state); ``"flat"`` gathers every raw CST/CFG to rank 0 and
        merges there.  Both write byte-identical traces; timestamps are
        per-rank payload either way and always travel by gather.
        """
        if self._finalized:
            raise RuntimeError("recorder already finalized")
        self._finalized = True
        comm = comm or SoloComm()
        trace_dir = trace_dir or self.config.trace_dir
        if self.config.finalize_topology not in ("tree", "flat"):
            raise ValueError(
                f"finalize_topology must be 'tree' or 'flat', got "
                f"{self.config.finalize_topology!r}")
        entries, cfg, ts = self.local_state()
        if self.config.finalize_topology == "tree":
            leaf = make_rank_state(comm.rank, entries, cfg, self.registry)
            blob = comm.reduce_tree(serialize_rank_state(leaf),
                                    merge_serialized_states)
            ts_gathered = comm.gather(ts)
            if comm.rank != 0:
                comm.barrier()
                return None
            rank_ts = ts_gathered
            merge, cfgs = materialize_state(
                deserialize_rank_state(blob),
                inter_patterns=self.config.inter_patterns)
        else:
            gathered = comm.gather((entries, cfg, ts))
            if comm.rank != 0:
                comm.barrier()
                return None
            rank_csts = [g[0] for g in gathered]
            rank_cfgs = [g[1] for g in gathered]
            rank_ts = [g[2] for g in gathered]
            merge, cfgs = finalize_ranks(
                rank_csts, rank_cfgs, self.registry,
                inter_patterns=self.config.inter_patterns)
        stats = RecorderStats(
            n_records=self.n_records,
            n_skipped=self.n_skipped,
            cst_entries=len(merge.merged_entries),
            cfg_bytes=sum(len(c) for c in cfgs.unique_cfgs),
            cst_bytes=sum(len(e) + 2 for e in merge.merged_entries),
            ts_bytes=sum(len(t) for t in rank_ts),
        )
        if trace_dir:
            trace_format.write_trace(
                trace_dir,
                registry=self.registry,
                merged_cst=merge.merged_entries,
                unique_cfgs=cfgs.unique_cfgs,
                cfg_index=cfgs.cfg_index,
                rank_timestamps=rank_ts,
                meta_extra=self._metadata(comm.size),
            )
        comm.barrier()
        return stats

    def _metadata(self, nranks: int) -> Dict[str, Any]:
        try:
            user = getpass.getuser()
        except Exception:  # pragma: no cover
            user = "unknown"
        return {
            "nranks": nranks,
            "app": os.path.basename(sys.argv[0]) if sys.argv else "unknown",
            "user": user,
            "host": socket.gethostname(),
            "pid": os.getpid(),
            "layers": sorted(self.config.layers) if self.config.layers else "all",
            "intra_patterns": self.config.intra_patterns,
            "inter_patterns": self.config.inter_patterns,
            "tick_unit": "us",
            "tick_wrap": 2 ** 32,
        }


# ---------------------------------------------------------------------------
# the active-recorder slot used by generated wrappers (LD_PRELOAD analogue)
# ---------------------------------------------------------------------------

_active: List[Optional[Recorder]] = [None]


def attach(rec: Recorder) -> None:
    _active[0] = rec


def detach() -> None:
    _active[0] = None


def active() -> Optional[Recorder]:
    return _active[0]


class session:
    """Context manager: trace a region and finalize on exit.

    >>> with session(RecorderConfig(trace_dir="/tmp/t")) as rec:
    ...     posix.open(...)  # traced
    """

    def __init__(self, config: Optional[RecorderConfig] = None,
                 comm: Optional[Comm] = None, rank: int = 0):
        self.config = config
        self.comm = comm
        self.rank = rank
        self.recorder: Optional[Recorder] = None
        self.stats: Optional[RecorderStats] = None

    def __enter__(self) -> Recorder:
        self.recorder = Recorder(rank=self.rank, config=self.config)
        attach(self.recorder)
        return self.recorder

    def __exit__(self, *exc) -> None:
        detach()
        if self.recorder is not None and exc[0] is None:
            self.stats = self.recorder.finalize(self.comm)
