"""Function-signature specifications (the paper's Section 2.1 signature files).

The original Recorder auto-generates a C tracing wrapper per function from a
signature file.  Here the signature files are declarative ``FnSpec`` tables;
``wrappers.generate_wrappers`` turns each into a generated three-phase
wrapper (prologue / real call / epilogue).

Argument *roles* drive the pattern-recognition pipeline:

  PATH    file path (subject to runtime prefix filtering, Section 2.1.1)
  HANDLE  file handle (canonicalized to a group-unique id, Section 3.2.2)
  OFFSET  pattern-eligible integer (``i*a+b`` intra / ``rank*a+b`` inter)
  SIZE    byte count (stored verbatim; usually constant, dedupes in the CST)
  BUF     data buffer (length recorded, contents never stored)
  VAL     any other argument, stored verbatim
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple


class Role(enum.Enum):
    PATH = "path"
    HANDLE = "handle"
    OFFSET = "offset"
    SIZE = "size"
    BUF = "buf"
    VAL = "val"


#: the data-moving functions -- the calls whose BUF/SIZE argument (or int
#: return) counts as transferred bytes in bandwidth analyses.  One
#: definition site shared by the record path (per-timestamp-block byte
#: counters) and the read side (``traceview``): the two MUST agree or
#: windowed bandwidth stops being exact.
DATA_FUNCS = frozenset({"pwrite", "write", "pread", "read",
                        "shard_write_at", "shard_read_at"})


@dataclass
class Arg:
    name: str
    role: Role = Role.VAL


@dataclass
class FnSpec:
    name: str
    layer: str
    args: List[Arg]
    impl: Optional[Callable] = None   # the "real" function the wrapper calls
    ret_role: Role = Role.VAL         # HANDLE => register returned handle
    collective: bool = False          # opens that assign group-unique ids

    @property
    def offset_positions(self) -> Tuple[int, ...]:
        return tuple(i for i, a in enumerate(self.args) if a.role == Role.OFFSET)

    @property
    def handle_positions(self) -> Tuple[int, ...]:
        return tuple(i for i, a in enumerate(self.args) if a.role == Role.HANDLE)

    @property
    def path_positions(self) -> Tuple[int, ...]:
        return tuple(i for i, a in enumerate(self.args) if a.role == Role.PATH)


class FunctionRegistry:
    """Global id <-> spec mapping, identical on every rank (static code)."""

    def __init__(self) -> None:
        self._specs: List[FnSpec] = []
        self._by_name: Dict[str, int] = {}

    def register(self, spec: FnSpec) -> int:
        if spec.name in self._by_name:
            raise ValueError(f"duplicate function spec {spec.name!r}")
        fid = len(self._specs)
        self._specs.append(spec)
        self._by_name[spec.name] = fid
        return fid

    def register_all(self, specs: List[FnSpec]) -> List[int]:
        return [self.register(s) for s in specs]

    def spec(self, func_id: int) -> FnSpec:
        return self._specs[func_id]

    def id_of(self, name: str) -> int:
        return self._by_name[name]

    def __len__(self) -> int:
        return len(self._specs)

    def name_table(self) -> Dict[int, str]:
        return {i: s.name for i, s in enumerate(self._specs)}

    def layers(self) -> List[str]:
        return sorted({s.layer for s in self._specs})


# The process-wide registry.  API modules (core/apis/*.py) register into it at
# import time; ids are stable because import order is deterministic
# (apis/__init__ imports them in a fixed order).
REGISTRY = FunctionRegistry()
