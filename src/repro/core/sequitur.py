"""Sequitur grammar induction with run-length exponents.

The paper (Section 3.1) compresses the per-process stream of call-signature
terminals into a context-free grammar using Sequitur [Nevill-Manning &
Witten].  Plain Sequitur represents ``a^n`` as an O(log n) tower of binary
rules; the paper (following Pilgrim [19, 20]) shows rules of the form
``S -> A^m``, i.e. symbols carry repetition exponents.  We implement
exponent-carrying Sequitur:

  * every symbol node is ``(sym, exp)``; appending a terminal equal to the
    tail symbol increments the tail's exponent (streaming RLE),
  * digrams are keyed on both symbols *and* exponents, so a repeated loop
    body ``(a,n)(b,1)`` forms one rule regardless of ``n``,
  * adjacent equal symbols are always merged, which also removes the classic
    overlapping-digram corner case of textbook Sequitur.

The two Sequitur invariants are maintained:
  digram uniqueness -- no digram appears more than once in the grammar,
  rule utility      -- every rule is referenced more than once (a rule whose
                       reference count drops to one occurrence with exponent
                       one is inlined).

Complexity is amortized O(1) per appended terminal.
"""

from __future__ import annotations

from typing import Dict, Iterator, List, Optional, Tuple

from .encoding import pack_uvarints, read_uvarint, write_uvarint

Key = Tuple[int, int, int]  # (is_rule, sym_or_rule_id, exp)


class Symbol:
    __slots__ = ("term", "rule", "exp", "prev", "next")

    def __init__(self, term: Optional[int], rule: Optional["Rule"], exp: int):
        self.term = term          # terminal id (>= 0) or None
        self.rule = rule          # Rule reference or None
        self.exp = exp
        self.prev: Optional[Symbol] = None
        self.next: Optional[Symbol] = None

    @property
    def is_guard(self) -> bool:
        return self.exp == 0

    def key(self) -> Key:
        if self.rule is not None:
            return (1, self.rule.id, self.exp)
        return (0, self.term, self.exp)  # type: ignore[return-value]

    def same_sym(self, other: "Symbol") -> bool:
        if self.rule is not None:
            return other.rule is self.rule
        return other.rule is None and other.term == self.term

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        if self.is_guard:
            return f"<guard R{self.rule.id}>"
        base = f"R{self.rule.id}" if self.rule is not None else f"t{self.term}"
        return f"{base}^{self.exp}"


class Rule:
    __slots__ = ("id", "guard", "users")

    def __init__(self, rid: int):
        self.id = rid
        g = Symbol(None, self, 0)  # guard: exp 0, rule back-reference
        g.prev = g
        g.next = g
        self.guard = g
        # symbol nodes elsewhere in the grammar that reference this rule
        self.users: set = set()

    def body(self) -> Iterator[Symbol]:
        n = self.guard.next
        while n is not self.guard:
            yield n
            n = n.next

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"R{self.id} -> " + " ".join(repr(s) for s in self.body())


class Sequitur:
    """Online exponent-Sequitur over integer terminals."""

    def __init__(self) -> None:
        self._next_rule_id = 0
        self.start = self._new_rule()
        self.index: Dict[Tuple[Key, Key], Symbol] = {}
        self.n_pushed = 0  # total terminals (with multiplicity)

    # -- public API ---------------------------------------------------------

    def push(self, terminal: int, count: int = 1) -> None:
        """Append ``terminal`` repeated ``count`` times to the sequence."""
        if count <= 0:
            raise ValueError("count must be positive")
        self.n_pushed += count
        g = self.start.guard
        tail = g.prev
        if not tail.is_guard and tail.rule is None and tail.term == terminal:
            # streaming RLE: bump the tail's exponent in place
            self._unindex_digram(tail.prev)
            tail.exp += count
            self._scan_digram(tail.prev)
        else:
            node = Symbol(terminal, None, count)
            self._splice_after(tail, node)
            self._scan_digram(node.prev)

    def push_stream(self, terminals, backend: Optional[str] = None) -> None:
        """Append a whole terminal array with RLE pre-tokenization.

        Run boundaries are found in one batched pass
        (``encode_backend.run_boundaries``: NumPy or the grammar_stats
        kernel) and each maximal run enters the grammar as a single
        ``push(term, run_len)`` -- the batch semantics of the existing
        exponent API, so the expansion is always identical to per-terminal
        pushes and the grammar is identical to calling
        ``push(t, k)`` per run.  The ``python`` backend is the per-run
        scalar reference."""
        import numpy as np
        arr = np.asarray(terminals, dtype=np.int64).reshape(-1)
        n = int(arr.size)
        if n == 0:
            return
        from . import encode_backend as _eb
        eff = _eb.resolve(backend, n)
        if eff == "python":
            run_start = 0
            vals = arr.tolist()
            for i in range(1, n):
                if vals[i] != vals[run_start]:
                    self.push(vals[run_start], i - run_start)
                    run_start = i
            self.push(vals[run_start], n - run_start)
            return
        mask = _eb.run_boundaries(arr[:, None], eff)
        starts = np.flatnonzero(mask)
        ends = np.append(starts[1:], n)
        for s, e in zip(starts.tolist(), ends.tolist()):
            self.push(int(arr[s]), e - s)

    def rules(self) -> List[Rule]:
        seen: Dict[int, Rule] = {}
        stack = [self.start]
        while stack:
            r = stack.pop()
            if r.id in seen:
                continue
            seen[r.id] = r
            for s in r.body():
                if s.rule is not None:
                    stack.append(s.rule)
        return [seen[k] for k in sorted(seen)]

    def expand(self) -> List[int]:
        """Reconstruct the original terminal stream (lossless check)."""
        out: List[int] = []

        def walk(rule: Rule) -> None:
            for s in rule.body():
                for _ in range(s.exp):
                    if s.rule is not None:
                        walk(s.rule)
                    else:
                        out.append(s.term)  # type: ignore[arg-type]

        walk(self.start)
        return out

    # -- serialized grammar ---------------------------------------------------

    def serialize(self) -> bytes:
        """Compact byte form.  Rules are renumbered densely; rule references
        are encoded as ``2*local_index + 1``, terminals as ``2*terminal``.

        Layout: n_rules, then per rule: n_items, (code, exp)*  (all uvarints).
        Rule 0 is the start rule.
        """
        rules = self.rules()
        local = {r.id: i for i, r in enumerate(rules)}
        vals: List[int] = [len(rules)]
        for r in rules:
            items = list(r.body())
            vals.append(len(items))
            for s in items:
                if s.rule is not None:
                    vals.append(2 * local[s.rule.id] + 1)
                else:
                    vals.append(2 * s.term)  # type: ignore[operator]
                vals.append(s.exp)
        return pack_uvarints(vals)

    # -- internals ----------------------------------------------------------

    def _new_rule(self) -> Rule:
        r = Rule(self._next_rule_id)
        self._next_rule_id += 1
        return r

    @staticmethod
    def _splice_after(left: Symbol, node: Symbol) -> None:
        right = left.next
        node.prev = left
        node.next = right
        left.next = node
        right.prev = node
        if node.rule is not None:
            node.rule.users.add(node)

    def _unlink(self, node: Symbol) -> None:
        node.prev.next = node.next
        node.next.prev = node.prev
        if node.rule is not None:
            node.rule.users.discard(node)

    # digram index maintenance -------------------------------------------------

    def _digram_key(self, left: Symbol) -> Optional[Tuple[Key, Key]]:
        right = left.next
        if left.is_guard or right.is_guard:
            return None
        return (left.key(), right.key())

    def _unindex_digram(self, left: Symbol) -> None:
        key = self._digram_key(left)
        if key is not None and self.index.get(key) is left:
            del self.index[key]

    def _scan_digram(self, left: Symbol) -> None:
        """Register the digram starting at ``left``; on a duplicate, rewrite
        per the digram-uniqueness invariant."""
        key = self._digram_key(left)
        if key is None:
            return
        match = self.index.get(key)
        if match is None:
            self.index[key] = left
            return
        if match is left or match.next is left or left.next is match:
            # same occurrence, or occurrences sharing a node (cannot rewrite)
            return
        self._handle_match(left, match)

    def _handle_match(self, new: Symbol, match: Symbol) -> None:
        # If the matched occurrence is the full body of some rule, reuse it.
        if match.prev.is_guard and match.next.next is match.prev:
            rule = match.prev.rule
            self._substitute(new, rule)
        else:
            rule = self._new_rule()
            g = rule.guard
            a = Symbol(match.term, match.rule, match.exp)
            b = Symbol(match.next.term, match.next.rule, match.next.exp)
            self._splice_after(g, a)
            self._splice_after(a, b)
            self.index[(a.key(), b.key())] = a
            # rewrite both occurrences (match first so its digrams stay valid)
            self._substitute(match, rule)
            self._substitute(new, rule)
        # rule utility: inline a rule down to a single exp-1 reference
        self._check_utility(rule)

    def _substitute(self, left: Symbol, rule: Rule) -> None:
        """Replace digram (left, left.next) by one reference to ``rule``."""
        right = left.next
        prev = left.prev
        nxt = right.next
        self._unindex_digram(prev)
        self._unindex_digram(left)
        self._unindex_digram(right)
        used = [s.rule for s in (left, right) if s.rule is not None]
        self._unlink(left)
        self._unlink(right)
        node = Symbol(None, rule, 1)
        self._splice_after(prev, node)
        node = self._merge_adjacent(node)
        self._scan_digram(node.prev)
        self._scan_digram(node)
        for r in used:
            self._check_utility(r)

    def _merge_adjacent(self, node: Symbol) -> Symbol:
        """Merge ``node`` with equal-symbol neighbours (RLE invariant)."""
        prev = node.prev
        if not prev.is_guard and prev.same_sym(node):
            self._unindex_digram(prev.prev)
            self._unindex_digram(prev)
            self._unindex_digram(node)
            prev.exp += node.exp
            self._unlink(node)
            node = prev
        nxt = node.next
        if not nxt.is_guard and nxt.same_sym(node):
            self._unindex_digram(node.prev)
            self._unindex_digram(node)
            self._unindex_digram(nxt)
            node.exp += nxt.exp
            self._unlink(nxt)
        return node

    def _check_utility(self, rule: Rule) -> None:
        if rule is self.start:
            return
        if len(rule.users) != 1:
            return
        (user,) = tuple(rule.users)
        if user.exp != 1:
            return  # still useful: one reference but repeated
        # inline: replace `user` with the rule body
        prev = user.prev
        nxt = user.next
        self._unindex_digram(prev)
        self._unindex_digram(user)
        self._unlink(user)
        body = list(rule.body())
        # detach body symbols from the dying rule and splice them in
        at = prev
        for s in body:
            # unindex body digrams keyed at the old location
            self._unindex_digram(s)
            if s.rule is not None:
                s.rule.users.discard(s)
        for s in body:
            node = Symbol(s.term, s.rule, s.exp)
            self._splice_after(at, node)
            at = node
        # re-merge at the seams and rescan digrams across the spliced range
        first = prev.next
        node = self._merge_adjacent(first)
        # walk to the end of the spliced region, merging/rescanning
        cur = node
        while cur is not nxt and not cur.is_guard:
            cur = self._merge_adjacent(cur)
            self._scan_digram(cur.prev)
            cur = cur.next
        if not nxt.is_guard or True:
            self._scan_digram(nxt.prev)


# ---------------------------------------------------------------------------
# serialized-grammar helpers (shared by inter-process merge and the reader)
# ---------------------------------------------------------------------------


def parse_grammar(buf: bytes) -> List[List[Tuple[int, int]]]:
    """Parse ``Sequitur.serialize`` output into rule lists of (code, exp)."""
    pos = 0
    n_rules, pos = read_uvarint(buf, pos)
    rules: List[List[Tuple[int, int]]] = []
    for _ in range(n_rules):
        n_items, pos = read_uvarint(buf, pos)
        items: List[Tuple[int, int]] = []
        for _ in range(n_items):
            code, pos = read_uvarint(buf, pos)
            exp, pos = read_uvarint(buf, pos)
            items.append((code, exp))
        rules.append(items)
    return rules


def serialize_grammar(rules: List[List[Tuple[int, int]]]) -> bytes:
    vals: List[int] = [len(rules)]
    for items in rules:
        vals.append(len(items))
        for code, exp in items:
            vals.append(code)
            vals.append(exp)
    return pack_uvarints(vals)


def remap_grammar(buf: bytes, terminal_map: Dict[int, int]) -> bytes:
    """Rewrite terminal ids in a serialized grammar (inter-process CST merge,
    paper Section 3.3.1)."""
    rules = parse_grammar(buf)
    out = [
        [(code if code & 1 else 2 * terminal_map[code >> 1], exp)
         for code, exp in items]
        for items in rules
    ]
    return serialize_grammar(out)


def concat_grammars(parts: List[Tuple[bytes, int]]) -> bytes:
    """Concatenate serialized grammars into one whose expansion is the
    concatenation of the parts' expansions (streaming epoch append).

    Each part is ``(serialized grammar, terminal offset)``: the part's
    terminal ids are shifted by the offset (per-epoch CSTs restart at 0, so
    epoch k's terminals live after epoch k-1's rows in the combined
    stream).  The parts' start-rule items are spliced into the combined
    start rule; their non-start rules are appended with references
    renumbered.  The result is NOT what one-shot Sequitur would induce over
    the concatenated stream -- only its expansion is guaranteed equal --
    which is exactly the value-identity the stitched readers need.
    """
    out_rules: List[List[Tuple[int, int]]] = [[]]
    for cfg, toff in parts:
        rules = parse_grammar(cfg)
        if not rules:
            continue
        base = len(out_rules)  # where this part's rules 1.. land

        def remap(code: int, base: int = base, toff: int = toff) -> int:
            if code & 1:
                return 2 * (base + (code >> 1) - 1) + 1
            return 2 * ((code >> 1) + toff)

        out_rules[0].extend((remap(c), e) for c, e in rules[0])
        for items in rules[1:]:
            out_rules.append([(remap(c), e) for c, e in items])
    return serialize_grammar(out_rules)


def expand_grammar(rules: List[List[Tuple[int, int]]]) -> Iterator[int]:
    """Yield the terminal stream of a parsed grammar (rule 0 is start).

    Iterative expansion (no recursion limit); the stream is yielded lazily so
    readers can stop early.  Stack frames are [items, item_idx, reps_left].
    """
    stack: List[List] = [[rules[0], 0, 0]]
    while stack:
        frame = stack[-1]
        items = frame[0]
        if frame[2] == 0:
            if frame[1] >= len(items):
                stack.pop()
                continue
            frame[2] = items[frame[1]][1]
            frame[1] += 1
            continue
        code = items[frame[1] - 1][0]
        frame[2] -= 1
        if code & 1:
            stack.append([rules[code >> 1], 0, 0])
        else:
            yield code >> 1


def expand_grammar_reversed(rules: List[List[Tuple[int, int]]]
                            ) -> Iterator[int]:
    """Yield the terminal stream of a parsed grammar in REVERSE order.

    Same lazy stack machine as :func:`expand_grammar`, walking rule items
    from the tail: consumers that reconstruct ancestry from a post-order
    stream (``analysis.call_chains``) can stream it without materializing
    the forward expansion first.
    """
    start = rules[0]
    stack: List[List] = [[start, len(start) - 1, 0]]
    while stack:
        frame = stack[-1]
        items = frame[0]
        if frame[2] == 0:
            if frame[1] < 0:
                stack.pop()
                continue
            frame[2] = items[frame[1]][1]
            frame[1] -= 1
            continue
        code = items[frame[1] + 1][0]
        frame[2] -= 1
        if code & 1:
            body = rules[code >> 1]
            stack.append([body, len(body) - 1, 0])
        else:
            yield code >> 1


# ---------------------------------------------------------------------------
# grammar-weighted aggregation (compressed-domain analysis support)
# ---------------------------------------------------------------------------
#
# The expansion multiplicity of every rule -- and from it the occurrence
# count of every terminal -- is a pure function of the grammar, computable in
# O(|grammar|) without expanding a single record.  TraceView builds all its
# weighted aggregates (call mixes, size histograms, byte totals, record
# counts) on these.


def _topo_order(rules: List[List[Tuple[int, int]]]) -> List[int]:
    """Rule indices ordered so every rule precedes the rules it references
    (Kahn's algorithm over the rule-reference DAG)."""
    n = len(rules)
    refs = [[code >> 1 for code, _ in items if code & 1] for items in rules]
    indeg = [0] * n
    for rs in refs:
        for c in rs:
            indeg[c] += 1
    queue = [i for i in range(n) if indeg[i] == 0]
    order: List[int] = []
    while queue:
        i = queue.pop()
        order.append(i)
        for c in refs[i]:
            indeg[c] -= 1
            if indeg[c] == 0:
                queue.append(c)
    if len(order) != n:
        raise ValueError("cyclic grammar")
    return order


def rule_weights(rules: List[List[Tuple[int, int]]]) -> List[int]:
    """How many times each rule's body is expanded in the full expansion of
    rule 0 (the start rule has weight 1; unreachable rules weight 0).

    O(|grammar|): one pass in topological order, parents before children.
    """
    w = [0] * len(rules)
    if not rules:
        return w
    w[0] = 1
    for i in _topo_order(rules):
        wi = w[i]
        if not wi:
            continue
        for code, exp in rules[i]:
            if code & 1:
                w[code >> 1] += wi * exp
    return w


def terminal_counts(rules: List[List[Tuple[int, int]]]) -> Dict[int, int]:
    """Occurrence count of every terminal in the full expansion, in
    O(|grammar|) via :func:`rule_weights` -- never by expanding."""
    w = rule_weights(rules)
    counts: Dict[int, int] = {}
    for i, items in enumerate(rules):
        wi = w[i]
        if not wi:
            continue
        for code, exp in items:
            if not code & 1:
                t = code >> 1
                counts[t] = counts.get(t, 0) + wi * exp
    return counts


def expansion_length(rules: List[List[Tuple[int, int]]]) -> int:
    """Total number of terminals in the expansion, in O(|grammar|)."""
    w = rule_weights(rules)
    return sum(w[i] * exp
               for i, items in enumerate(rules) if w[i]
               for code, exp in items if not code & 1)


def terminal_positions(rules: List[List[Tuple[int, int]]]
                       ) -> Tuple[Dict[int, int], Dict[int, int]]:
    """(first, last) 0-based expansion position of every reachable terminal.

    A bottom-up DP over rules (children before parents): each rule carries
    its expansion length plus the first/last offset of every distinct
    terminal in its subtree.  Cost is O(|grammar| x distinct terminals per
    subtree) -- bounded by |grammar| x |CST|, tiny in practice -- and never
    expands the stream.  TraceView uses the positions to decide whether a
    handle's opens all precede its data calls (exactness guard for the
    grammar-weighted per-file attribution).
    """
    n = len(rules)
    lengths = [0] * n
    firsts: List[Optional[Dict[int, int]]] = [None] * n
    lasts: List[Optional[Dict[int, int]]] = [None] * n
    for i in reversed(_topo_order(rules)):
        f: Dict[int, int] = {}
        last: Dict[int, int] = {}
        pos = 0
        for code, exp in rules[i]:
            x = code >> 1
            if code & 1:
                sz = lengths[x]
                for t, off in firsts[x].items():  # type: ignore[union-attr]
                    if t not in f:
                        f[t] = pos + off
                for t, off in lasts[x].items():  # type: ignore[union-attr]
                    last[t] = pos + (exp - 1) * sz + off
            else:
                sz = 1
                if x not in f:
                    f[x] = pos
                last[x] = pos + (exp - 1)
            pos += exp * sz
        lengths[i] = pos
        firsts[i] = f
        lasts[i] = last
    return firsts[0] or {}, lasts[0] or {}


def grammar_stats(rules: List[List[Tuple[int, int]]]) -> Dict[str, int]:
    return {
        "n_rules": len(rules),
        "n_symbols": sum(len(r) for r in rules),
        "n_terminals_expanded": None,  # expensive; computed on demand
    }
