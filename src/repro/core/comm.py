"""Communicator abstraction for the finalization collectives (paper §3.3).

Recorder's inter-process compression needs gather (CSTs, CFGs to rank 0),
bcast (terminal remaps back out) and -- for the scalable tree finalize --
``reduce_tree``: a pairwise reduction over *adjacent rank* pairs that runs
in ceil(log2(size)) rounds.  In round k (stride s = 2**k), every rank with
``rank % 2s == s`` ships its accumulated value to ``rank - s``, which folds
it with ``fn(left, right)``; after the last round rank 0 holds the full
reduction.  ``fn`` must accept (lower-rank-block value, adjacent
higher-rank-block value) -- Recorder passes
``interprocess.merge_serialized_states``, so the values on the wire are
opaque byte strings and any byte-transport backend can carry them.

The original uses MPI; in a JAX framework the natural carrier is the
host-process group.

Implementations:

  SoloComm    single process (the common real-runtime case per host group
              of size 1, and the degenerate default).
  ThreadComm  N real threads with barrier semantics -- used in tests to
              exercise the SPMD finalize path concurrently.  Implements the
              true log-round ``reduce_tree`` schedule described above.
  JaxComm     documented adapter for real multi-host runs: gathers byte
              buffers with ``jax.experimental.multihost_utils`` primitives.
              On this single-host container it is constructible only with
              process_count == 1 (it asserts), but the call structure is the
              deployment path.  ``reduce_tree`` on a real pod would ride on
              point-to-point device transfers (or fall back to the generic
              gather-based schedule below).

The base class provides a generic ``reduce_tree`` built on ``gather``: rank
0 collects every value and folds adjacent pairs level by level -- the same
association order as the distributed schedule, so results are identical;
only the communication pattern differs.

Simulated large-scale ranks (the 16K-process experiments) do not go through
a Comm at all: benchmarks call the pure functions in ``interprocess.py``
directly on lists of rank states (``tree_finalize_ranks`` mirrors the
collective's pairing exactly).
"""

from __future__ import annotations

import threading
from typing import Any, Callable, List, Optional


class Comm:
    rank: int
    size: int

    def gather(self, obj: Any, root: int = 0) -> Optional[List[Any]]:
        raise NotImplementedError

    def bcast(self, obj: Any, root: int = 0) -> Any:
        raise NotImplementedError

    def scatter(self, objs: Optional[List[Any]], root: int = 0) -> Any:
        raise NotImplementedError

    def barrier(self) -> None:
        raise NotImplementedError

    def reduce_tree(self, obj: Any, fn: Callable[[Any, Any], Any],
                    root: int = 0) -> Optional[Any]:
        """Pairwise tree reduction; root returns the folded value, other
        ranks None.  Generic fallback: gather + fold adjacent pairs in
        log-rounds at the root (same association order as the distributed
        ThreadComm schedule, hence identical results)."""
        gathered = self.gather(obj, root=root)
        if gathered is None:
            return None
        items = list(gathered)
        while len(items) > 1:
            items = [fn(items[i], items[i + 1])
                     if i + 1 < len(items) else items[i]
                     for i in range(0, len(items), 2)]
        return items[0]

    def gather_tree(self, obj: Any, root: int = 0) -> Optional[List[Any]]:
        """Gather through the pairwise reduction tree instead of a direct
        rank-0 fan-in: per-rank payloads travel as concatenated
        ``(rank, value)`` lists through :meth:`reduce_tree`, so every hop
        carries one merged list and the root never receives ``size``
        simultaneous messages (the transport Recorder uses for per-rank
        timestamp payloads during streaming flushes and tree finalize).
        Root returns the values in rank order; other ranks return None."""
        merged = self.reduce_tree([(self.rank, obj)], lambda a, b: a + b,
                                  root=root)
        if merged is None:
            return None
        return [v for _, v in sorted(merged, key=lambda rv: rv[0])]


class SoloComm(Comm):
    rank = 0
    size = 1

    def gather(self, obj, root=0):
        return [obj]

    def bcast(self, obj, root=0):
        return obj

    def scatter(self, objs, root=0):
        assert objs is not None and len(objs) == 1
        return objs[0]

    def barrier(self):
        pass


class _ThreadWorld:
    def __init__(self, size: int):
        self.size = size
        self.barrier = threading.Barrier(size)
        self.slots: List[Any] = [None] * size
        self.root_box: List[Any] = [None]


class ThreadComm(Comm):
    """Barrier-synchronized communicator over threads in one process."""

    def __init__(self, world: _ThreadWorld, rank: int):
        self._w = world
        self.rank = rank
        self.size = world.size

    def gather(self, obj, root=0):
        self._w.slots[self.rank] = obj
        self._w.barrier.wait()
        out = list(self._w.slots) if self.rank == root else None
        self._w.barrier.wait()
        return out

    def bcast(self, obj, root=0):
        if self.rank == root:
            self._w.root_box[0] = obj
        self._w.barrier.wait()
        out = self._w.root_box[0]
        self._w.barrier.wait()
        return out

    def scatter(self, objs, root=0):
        if self.rank == root:
            assert objs is not None and len(objs) == self.size
            self._w.slots[:] = objs
        self._w.barrier.wait()
        out = self._w.slots[self.rank]
        self._w.barrier.wait()
        return out

    def barrier(self):
        self._w.barrier.wait()

    def reduce_tree(self, obj, fn, root=0):
        """True distributed log-round schedule: in round of stride s, rank
        r with r % 2s == s sends to r - s, which folds; every rank walks
        all rounds so the shared barrier stays aligned."""
        assert root == 0, "tree reduction is rooted at rank 0"
        val = obj
        s = 1
        while s < self.size:
            sender = self.rank % (2 * s) == s
            if sender:
                self._w.slots[self.rank] = val
            self._w.barrier.wait()
            if (not sender and self.rank % (2 * s) == 0
                    and self.rank + s < self.size):
                val = fn(val, self._w.slots[self.rank + s])
            self._w.barrier.wait()
            s *= 2
        return val if self.rank == 0 else None


def run_thread_world(size: int, fn: Callable[[Comm, int], Any]) -> List[Any]:
    """Run ``fn(comm, rank)`` on ``size`` threads; returns per-rank results."""
    world = _ThreadWorld(size)
    results: List[Any] = [None] * size
    errors: List[Optional[BaseException]] = [None] * size

    def worker(r: int) -> None:
        try:
            results[r] = fn(ThreadComm(world, r), r)
        except BaseException as e:  # noqa: BLE001 - surfaced below
            errors[r] = e
            try:
                world.barrier.abort()
            except Exception:
                pass

    threads = [threading.Thread(target=worker, args=(r,)) for r in range(size)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for e in errors:
        if e is not None:
            raise e
    return results


class JaxComm(Comm):
    """Adapter for real multi-host deployments.

    The gather/bcast of variable-length byte buffers rides on
    ``jax.experimental.multihost_utils`` primitives.  On a single-process
    runtime it degenerates to SoloComm semantics, which is what this
    container exercises.  ``reduce_tree`` inherits the generic gather-based
    schedule; a real deployment would replace it with point-to-point sends
    between host pairs (the states are plain byte strings, so any transport
    works -- see DESIGN notes in the module docstring).
    """

    def __init__(self) -> None:
        import jax

        self.rank = jax.process_index()
        self.size = jax.process_count()

    def gather(self, obj, root=0):
        if self.size == 1:
            return [obj]
        from jax.experimental import multihost_utils

        # allgather via host callback of opaque python objects
        gathered = multihost_utils.process_allgather  # documented path
        raise NotImplementedError(
            "multi-host gather requires a real multi-process jax runtime; "
            "see DESIGN.md (JaxComm deployment notes)")

    def bcast(self, obj, root=0):
        if self.size == 1:
            return obj
        from jax.experimental import multihost_utils

        return multihost_utils.broadcast_one_to_all(obj)

    def scatter(self, objs, root=0):
        if self.size == 1:
            assert objs is not None
            return objs[0]
        raise NotImplementedError

    def barrier(self):
        if self.size > 1:
            from jax.experimental import multihost_utils

            multihost_utils.sync_global_devices("recorder_barrier")
