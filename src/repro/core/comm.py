"""Communicator abstraction for the finalization collectives (paper §3.3).

Recorder's inter-process compression needs gather (CSTs, CFGs to rank 0) and
bcast (terminal remaps back out).  The original uses MPI; in a JAX framework
the natural carrier is the host-process group.

Implementations:

  SoloComm    single process (the common real-runtime case per host group
              of size 1, and the degenerate default).
  ThreadComm  N real threads with barrier semantics -- used in tests to
              exercise the SPMD finalize path concurrently.
  JaxComm     documented adapter for real multi-host runs: gathers byte
              buffers with ``jax.experimental.multihost_utils`` primitives.
              On this single-host container it is constructible only with
              process_count == 1 (it asserts), but the call structure is the
              deployment path.

Simulated large-scale ranks (the 16K-process experiments) do not go through
a Comm at all: benchmarks call the pure functions in ``interprocess.py``
directly on lists of rank states, which is bit-identical to what rank 0
computes after a gather.
"""

from __future__ import annotations

import threading
from typing import Any, Callable, List, Optional


class Comm:
    rank: int
    size: int

    def gather(self, obj: Any, root: int = 0) -> Optional[List[Any]]:
        raise NotImplementedError

    def bcast(self, obj: Any, root: int = 0) -> Any:
        raise NotImplementedError

    def scatter(self, objs: Optional[List[Any]], root: int = 0) -> Any:
        raise NotImplementedError

    def barrier(self) -> None:
        raise NotImplementedError


class SoloComm(Comm):
    rank = 0
    size = 1

    def gather(self, obj, root=0):
        return [obj]

    def bcast(self, obj, root=0):
        return obj

    def scatter(self, objs, root=0):
        assert objs is not None and len(objs) == 1
        return objs[0]

    def barrier(self):
        pass


class _ThreadWorld:
    def __init__(self, size: int):
        self.size = size
        self.barrier = threading.Barrier(size)
        self.slots: List[Any] = [None] * size
        self.root_box: List[Any] = [None]


class ThreadComm(Comm):
    """Barrier-synchronized communicator over threads in one process."""

    def __init__(self, world: _ThreadWorld, rank: int):
        self._w = world
        self.rank = rank
        self.size = world.size

    def gather(self, obj, root=0):
        self._w.slots[self.rank] = obj
        self._w.barrier.wait()
        out = list(self._w.slots) if self.rank == root else None
        self._w.barrier.wait()
        return out

    def bcast(self, obj, root=0):
        if self.rank == root:
            self._w.root_box[0] = obj
        self._w.barrier.wait()
        out = self._w.root_box[0]
        self._w.barrier.wait()
        return out

    def scatter(self, objs, root=0):
        if self.rank == root:
            assert objs is not None and len(objs) == self.size
            self._w.slots[:] = objs
        self._w.barrier.wait()
        out = self._w.slots[self.rank]
        self._w.barrier.wait()
        return out

    def barrier(self):
        self._w.barrier.wait()


def run_thread_world(size: int, fn: Callable[[Comm, int], Any]) -> List[Any]:
    """Run ``fn(comm, rank)`` on ``size`` threads; returns per-rank results."""
    world = _ThreadWorld(size)
    results: List[Any] = [None] * size
    errors: List[Optional[BaseException]] = [None] * size

    def worker(r: int) -> None:
        try:
            results[r] = fn(ThreadComm(world, r), r)
        except BaseException as e:  # noqa: BLE001 - surfaced below
            errors[r] = e
            try:
                world.barrier.abort()
            except Exception:
                pass

    threads = [threading.Thread(target=worker, args=(r,)) for r in range(size)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for e in errors:
        if e is not None:
            raise e
    return results


class JaxComm(Comm):
    """Adapter for real multi-host deployments.

    The gather/bcast of variable-length byte buffers rides on
    ``jax.experimental.multihost_utils.broadcast_one_to_all`` and
    process-level allgather.  On a single-process runtime it degenerates to
    SoloComm semantics, which is what this container exercises.
    """

    def __init__(self) -> None:
        import jax

        self.rank = jax.process_index()
        self.size = jax.process_count()

    def gather(self, obj, root=0):
        if self.size == 1:
            return [obj]
        from jax.experimental import multihost_utils

        # allgather via host callback of opaque python objects
        gathered = multihost_utils.process_allgather  # documented path
        raise NotImplementedError(
            "multi-host gather requires a real multi-process jax runtime; "
            "see DESIGN.md (JaxComm deployment notes)")

    def bcast(self, obj, root=0):
        if self.size == 1:
            return obj
        from jax.experimental import multihost_utils

        return multihost_utils.broadcast_one_to_all(obj)

    def scatter(self, objs, root=0):
        if self.size == 1:
            assert objs is not None
            return objs[0]
        raise NotImplementedError

    def barrier(self):
        if self.size > 1:
            from jax.experimental import multihost_utils

            multihost_utils.sync_global_devices("recorder_barrier")
