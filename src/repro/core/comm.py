"""Communicator abstraction for the finalization collectives (paper §3.3).

Recorder's inter-process compression needs gather (CSTs, CFGs to rank 0),
bcast (terminal remaps back out) and -- for the scalable tree finalize --
``reduce_tree``: a pairwise reduction over *adjacent rank* pairs that runs
in ceil(log2(size)) rounds.  In round k (stride s = 2**k), every rank with
``rank % 2s == s`` ships its accumulated value to ``rank - s``, which folds
it with ``fn(left, right)``; after the last round rank 0 holds the full
reduction.  ``fn`` must accept (lower-rank-block value, adjacent
higher-rank-block value) -- Recorder passes
``interprocess.merge_serialized_states``, so the values on the wire are
opaque byte strings and any byte-transport backend can carry them.

The original uses MPI; in a JAX framework the natural carrier is the
host-process group.

Implementations:

  SoloComm    single process (the common real-runtime case per host group
              of size 1, and the degenerate default).
  ThreadComm  N real threads with barrier semantics -- used in tests to
              exercise the SPMD finalize path concurrently.  Implements
              true point-to-point ``send``/``recv`` over per-pair
              mailboxes, so ``reduce_tree`` runs the genuine log-round
              pairwise schedule (no shared-slot barrier walk).
  JaxComm     adapter for real multi-host runs.  ``reduce_tree`` rides
              :func:`reduce_tree_via_exchange`: the same log-round
              schedule, but each round's payloads move together through
              one COLLECTIVE byte exchange (``distributed.sharding.
              PpermuteByteTransport`` -- a shard_map ppermute over a 1-D
              host mesh), because jax has no independent pairwise sends.
              On this single-process container the schedule is empty and
              it degenerates to SoloComm semantics.

Point-to-point transports advertise ``has_p2p``; the base ``reduce_tree``
then runs the distributed schedule directly on ``send``/``recv``.
Transports without p2p fall back to gather + fold adjacent pairs in
log-rounds at the root -- the same association order, hence byte-identical
results; only the communication pattern differs.

``vote_any`` is the cadence collective of the streaming flusher: every
rank contributes a local boolean and all ranks learn the OR, so non-SPMD
ranks decide to flush (or to coalesce an epoch while a background commit
is in flight) in lockstep.  ``dup`` hands out an independent collective
context (the MPI_Comm_dup analogue): the Recorder's background flusher
runs its collectives on a dup'd comm so they can never interleave with
the application's foreground collectives on the primary one.

Simulated large-scale ranks (the 16K-process experiments) do not go through
a Comm at all: benchmarks call the pure functions in ``interprocess.py``
directly on lists of rank states (``tree_finalize_ranks`` mirrors the
collective's pairing exactly).
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

#: seconds a ThreadComm recv waits before concluding the peer is gone
#: (the default; per-call ``recv(..., timeout=)`` and the degraded
#: collectives override it with the configured flush timeout)
_RECV_TIMEOUT_S = 60.0


class CommTimeout(RuntimeError):
    """A point-to-point receive (or a timed collective built on one) gave
    up waiting for a peer.  The degraded flush protocol catches this to
    substitute an absent rank's contribution; anything else propagating it
    means a peer really is gone."""


def reduce_rounds(size: int) -> List[List[Tuple[int, int]]]:
    """The (src, dst) pairs of every round of the log-round tree schedule:
    in the round of stride ``s``, rank ``r`` with ``r % 2s == s`` ships its
    accumulated value to ``r - s``.  Shared by the p2p path, the collective
    exchange path and the ThreadComm tests, so every transport provably
    runs the same pairing (and therefore the same association order as the
    gather fallback)."""
    rounds: List[List[Tuple[int, int]]] = []
    s = 1
    while s < size:
        rounds.append([(r, r - s) for r in range(s, size, 2 * s)])
        s *= 2
    return rounds


def reduce_tree_via_exchange(rank: int, size: int, obj: Any,
                             fn: Callable[[Any, Any], Any],
                             exchange: Callable[[Optional[Any], list], Any],
                             root: int = 0) -> Optional[Any]:
    """The log-round schedule on a COLLECTIVE byte mover: every rank calls
    ``exchange(payload_or_None, perm)`` once per round with the identical
    perm list (SPMD -- e.g. a jax ppermute), and the call returns the
    payload addressed to this rank (None for non-receivers).  Senders ship
    their accumulated value and drop out; receivers fold.  Association
    order matches :func:`reduce_rounds`, hence byte-identical to every
    other topology."""
    assert root == 0, "tree reduction is rooted at rank 0"
    val = obj
    for perm in reduce_rounds(size):
        senders = {src for src, _ in perm}
        receivers = {dst for _, dst in perm}
        got = exchange(val if rank in senders else None, perm)
        if rank in senders:
            val = None
        elif rank in receivers:
            val = fn(val, got)
    return val if rank == 0 else None


class Comm:
    rank: int
    size: int
    #: transports with independent pairwise send/recv set this True; the
    #: base reduce_tree then runs the distributed schedule on them
    has_p2p: bool = False

    def gather(self, obj: Any, root: int = 0) -> Optional[List[Any]]:
        raise NotImplementedError

    def bcast(self, obj: Any, root: int = 0) -> Any:
        raise NotImplementedError

    def scatter(self, objs: Optional[List[Any]], root: int = 0) -> Any:
        raise NotImplementedError

    def barrier(self) -> None:
        raise NotImplementedError

    def send(self, obj: Any, dest: int) -> None:
        """Point-to-point send (only on transports with ``has_p2p``)."""
        raise NotImplementedError

    def recv(self, source: int, timeout: Optional[float] = None) -> Any:
        """Point-to-point receive (only on transports with ``has_p2p``).
        ``timeout`` overrides the transport's default patience; expiry
        raises :class:`CommTimeout`."""
        raise NotImplementedError

    def dup(self, key: str = "dup") -> "Comm":
        """An independent collective context over the same ranks (the
        MPI_Comm_dup analogue): collectives on the dup never interleave
        with collectives on the parent, so a background thread (the async
        epoch flusher) can safely run its own collective sequence.  Every
        rank must dup with the same ``key``.  The base implementation
        returns ``self`` -- correct for single-rank comms and for
        transports whose collectives are already tagged; concurrent
        multi-rank transports must override."""
        return self

    def vote_any(self, flag: bool) -> bool:
        """Collective boolean OR: every rank passes its local flag, every
        rank returns whether ANY rank's flag was set.  The streaming
        flusher's cadence collective (one barrier-sized exchange), so
        non-SPMD ranks flush in lockstep."""
        votes = self.gather(bool(flag))
        return bool(self.bcast(any(votes) if votes is not None else None))

    def reduce_tree(self, obj: Any, fn: Callable[[Any, Any], Any],
                    root: int = 0) -> Optional[Any]:
        """Pairwise tree reduction; root returns the folded value, other
        ranks None.  On p2p transports this runs the true distributed
        log-round schedule (:func:`reduce_rounds`): a sender ships its
        accumulated value once and is done; a receiver folds one incoming
        value per round.  Transports without p2p fall back to gather +
        fold adjacent pairs in log-rounds at the root (same association
        order, hence identical results)."""
        if self.has_p2p and self.size > 1:
            return self._reduce_tree_p2p(obj, fn, root)
        gathered = self.gather(obj, root=root)
        if gathered is None:
            return None
        items = list(gathered)
        while len(items) > 1:
            items = [fn(items[i], items[i + 1])
                     if i + 1 < len(items) else items[i]
                     for i in range(0, len(items), 2)]
        return items[0]

    def _reduce_tree_p2p(self, obj: Any, fn: Callable[[Any, Any], Any],
                         root: int = 0) -> Optional[Any]:
        assert root == 0, "tree reduction is rooted at rank 0"
        val = obj
        for perm in reduce_rounds(self.size):
            for src, dst in perm:
                if self.rank == src:
                    self.send(val, dst)
                    return None  # shipped: this rank is done contributing
                if self.rank == dst:
                    val = fn(val, self.recv(src))
                    break
        return val if self.rank == 0 else None

    def gather_tree(self, obj: Any, root: int = 0) -> Optional[List[Any]]:
        """Gather through the pairwise reduction tree instead of a direct
        rank-0 fan-in: per-rank payloads travel as concatenated
        ``(rank, value)`` lists through :meth:`reduce_tree`, so every hop
        carries one merged list and the root never receives ``size``
        simultaneous messages (the transport Recorder uses for per-rank
        timestamp payloads during streaming flushes and tree finalize).
        Root returns the values in rank order; other ranks return None."""
        merged = self.reduce_tree([(self.rank, obj)], lambda a, b: a + b,
                                  root=root)
        if merged is None:
            return None
        return [v for _, v in sorted(merged, key=lambda rv: rv[0])]

    # -- degraded (fault-tolerant) collectives --------------------------------
    #
    # The timed collectives below are entirely barrier-free: they run on
    # tagged point-to-point messages only, so a dead or unresponsive rank
    # stalls exactly the peers waiting on it for exactly the configured
    # timeout -- never the whole world forever.  Every invocation bumps a
    # per-comm sequence counter used as the message tag; because the alive
    # ranks invoke collectives in lockstep (collective call discipline),
    # a receiver can discard any message tagged below its expectation
    # (a straggler from an earlier, already-degraded collective) without
    # ambiguity.  Callers must therefore issue all timed collectives on
    # one comm object in the same order on every participating rank.

    def _bump_seq(self) -> int:
        s = getattr(self, "_p2p_seq", 0) + 1
        self._p2p_seq = s
        return s

    def _recv_tagged(self, source: int, tag: int,
                     timeout: Optional[float]) -> Any:
        """Receive from ``source`` discarding stale (lower-tagged)
        messages; raises :class:`CommTimeout` on expiry and RuntimeError
        on a future tag (a protocol bug, not a fault)."""
        while True:
            t, payload = self.recv(source, timeout=timeout)
            if t == tag:
                return payload
            if t > tag:
                raise RuntimeError(
                    f"rank {self.rank}: tag {t} from rank {source} is ahead "
                    f"of expected {tag} -- timed collectives were not "
                    f"invoked in lockstep")
            # t < tag: a delayed straggler from an earlier collective

    def reduce_tree_partial(self, obj: Any, fn: Callable[[Any, Any], Any],
                            absent: Callable[[int, int], Any],
                            timeout: Optional[float]) -> Optional[Any]:
        """The log-round tree reduction with per-hop receive timeouts:
        when the peer owning ranks ``[src, hi)`` never delivers, its whole
        subtree contribution is substituted with ``absent(src, hi)`` (an
        explicitly-empty block), so the fold stays structurally complete
        and rank 0 still finishes within O(log N) timeouts.  Root returns
        the folded value, other ranks None."""
        assert self.has_p2p, "reduce_tree_partial needs a p2p transport"
        tag = self._bump_seq()
        val = obj
        s = 1
        r = self.rank
        while s < self.size:
            if r % (2 * s) == s:
                self.send((tag, val), r - s)
                return None
            if r % (2 * s) == 0 and r + s < self.size:
                src = r + s
                try:
                    got = self._recv_tagged(src, tag, timeout)
                except CommTimeout:
                    got = absent(src, min(src + s, self.size))
                val = fn(val, got)
            s *= 2
        return val if r == 0 else None

    def verdict_patience(self, timeout: Optional[float]) -> Optional[float]:
        """How long a non-root rank should wait for rank 0's
        post-collective verdict.  Rank 0 may legitimately spend one full
        ``timeout`` per tree round absorbing dead subtrees before it can
        fan anything out, so a verdict wait equal to the per-hop timeout
        would race rank 0's own patience and spuriously self-degrade;
        scale it by tree depth plus one round of slack for rank 0's local
        work (the segment commit)."""
        if timeout is None:
            return None
        rounds = max(1, (self.size - 1).bit_length())
        return timeout * (rounds + 1)

    def bcast_p2p(self, obj: Any, timeout: Optional[float]) -> Any:
        """Rank 0 fans ``obj`` out over point-to-point sends; other ranks
        receive it with a timeout (:class:`CommTimeout` on expiry -- the
        caller decides what a missing verdict means).  A flat fan-out, not
        a tree: an absent interior rank must not cut its subtree off from
        the verdict."""
        assert self.has_p2p, "bcast_p2p needs a p2p transport"
        tag = self._bump_seq()
        if self.rank == 0:
            for dst in range(1, self.size):
                self.send((tag, obj), dst)
            return obj
        return self._recv_tagged(0, tag, timeout)

    def agree(self, flag: bool, timeout: Optional[float] = None
              ) -> Tuple[bool, frozenset]:
        """Survivor vote: boolean OR over the ranks that answered in time.

        Returns ``(verdict, present)`` where ``present`` is the set of
        ranks whose votes reached rank 0.  With no timeout (or no p2p
        transport) this is exactly :meth:`vote_any` with full presence;
        with a timeout it is the degraded protocol's barrier replacement:
        unresponsive ranks are voted around, and a rank that cannot even
        reach rank 0's verdict falls back to its own flag with
        self-only presence (its caller then treats the step as failed
        locally instead of deadlocking)."""
        if self.size == 1:
            return bool(flag), frozenset({self.rank})
        if timeout is None or not self.has_p2p:
            return self.vote_any(flag), frozenset(range(self.size))
        leaf = (bool(flag), (self.rank,))
        folded = self.reduce_tree_partial(
            leaf, lambda a, b: (a[0] or b[0], a[1] + b[1]),
            lambda lo, hi: (False, ()), timeout)
        if self.rank == 0:
            verdict, present = bool(folded[0]), frozenset(folded[1])
            self.bcast_p2p((verdict, sorted(present)), timeout)
            return verdict, present
        try:
            verdict, present = self.bcast_p2p(
                None, self.verdict_patience(timeout))
        except CommTimeout:
            return bool(flag), frozenset({self.rank})
        return bool(verdict), frozenset(present)


class SoloComm(Comm):
    rank = 0
    size = 1

    def gather(self, obj, root=0):
        return [obj]

    def bcast(self, obj, root=0):
        return obj

    def scatter(self, objs, root=0):
        assert objs is not None and len(objs) == 1
        return objs[0]

    def barrier(self):
        pass


class _ThreadWorld:
    def __init__(self, size: int,
                 failed: Optional[threading.Event] = None):
        self.size = size
        self.barrier = threading.Barrier(size)
        self.slots: List[Any] = [None] * size
        self.root_box: List[Any] = [None]
        # shared with sub-worlds: one rank failing must unblock every
        # barrier AND every pending point-to-point recv everywhere
        self.failed = failed if failed is not None else threading.Event()
        self._mail: Dict[Tuple[int, int], "queue.Queue[Any]"] = {}
        self._sub: Dict[str, "_ThreadWorld"] = {}
        self._lock = threading.Lock()

    def mailbox(self, src: int, dst: int) -> "queue.Queue[Any]":
        with self._lock:
            q = self._mail.get((src, dst))
            if q is None:
                q = self._mail[(src, dst)] = queue.Queue()
            return q

    def subworld(self, key: str) -> "_ThreadWorld":
        """The shared sub-world behind ``ThreadComm.dup(key)``: every rank
        duping with the same key lands on the same world object."""
        with self._lock:
            w = self._sub.get(key)
            if w is None:
                w = self._sub[key] = _ThreadWorld(self.size,
                                                 failed=self.failed)
            return w

    def abort(self) -> None:
        """Break every barrier (this world and all sub-worlds) and flag
        pending receives; called when any rank dies."""
        self.failed.set()
        try:
            self.barrier.abort()
        except Exception:
            pass
        with self._lock:
            subs = list(self._sub.values())
        for w in subs:
            w.abort()


class ThreadComm(Comm):
    """Barrier-synchronized communicator over threads in one process."""

    has_p2p = True

    def __init__(self, world: _ThreadWorld, rank: int):
        self._w = world
        self.rank = rank
        self.size = world.size

    def dup(self, key: str = "dup") -> "ThreadComm":
        return ThreadComm(self._w.subworld(key), self.rank)

    def send(self, obj: Any, dest: int) -> None:
        from . import faults

        plan = faults.get_active()
        q = self._w.mailbox(self.rank, dest)
        if plan is not None:
            act = plan.on_send(self.rank, dest)
            if act == "drop":
                return
            if isinstance(act, float):
                t = threading.Timer(act, q.put, args=(obj,))
                t.daemon = True
                t.start()
                return
        q.put(obj)

    def recv(self, source: int, timeout: Optional[float] = None) -> Any:
        """Blocking per-pair FIFO receive.  Each (src, dst) channel is its
        own queue, so a fast sender racing ahead into the next collective
        cannot overtake its earlier message; a failed peer (the world's
        ``failed`` flag, set by ``run_thread_world``) unblocks the wait
        with an error instead of deadlocking.  Polling backs off
        exponentially (1ms -> 50ms) so short timeouts stay responsive
        without spinning the long waits."""
        q = self._w.mailbox(source, self.rank)
        limit = _RECV_TIMEOUT_S if timeout is None else timeout
        waited = 0.0
        poll = 0.001
        while True:
            try:
                return q.get(timeout=poll)
            except queue.Empty:
                if self._w.failed.is_set():
                    raise RuntimeError(
                        f"rank {self.rank}: peer failed while receiving "
                        f"from rank {source}") from None
                waited += poll
                if waited >= limit:
                    raise CommTimeout(
                        f"rank {self.rank}: timed out receiving from rank "
                        f"{source} after {limit:g}s") from None
                poll = min(poll * 2, 0.05)

    def gather(self, obj, root=0):
        self._w.slots[self.rank] = obj
        self._w.barrier.wait()
        out = list(self._w.slots) if self.rank == root else None
        self._w.barrier.wait()
        return out

    def bcast(self, obj, root=0):
        if self.rank == root:
            self._w.root_box[0] = obj
        self._w.barrier.wait()
        out = self._w.root_box[0]
        self._w.barrier.wait()
        return out

    def scatter(self, objs, root=0):
        if self.rank == root:
            assert objs is not None and len(objs) == self.size
            self._w.slots[:] = objs
        self._w.barrier.wait()
        out = self._w.slots[self.rank]
        self._w.barrier.wait()
        return out

    def barrier(self):
        self._w.barrier.wait()

    def vote_any(self, flag):
        """Barrier-piggybacked OR: one slot write + two barrier waits
        (half the cost of gather + bcast), every rank reads the verdict."""
        self._w.slots[self.rank] = bool(flag)
        self._w.barrier.wait()
        out = any(self._w.slots)
        self._w.barrier.wait()
        return out


def run_thread_world(size: int, fn: Callable[[Comm, int], Any]) -> List[Any]:
    """Run ``fn(comm, rank)`` on ``size`` threads; returns per-rank results."""
    world = _ThreadWorld(size)
    results: List[Any] = [None] * size
    errors: List[Optional[BaseException]] = [None] * size

    def worker(r: int) -> None:
        try:
            results[r] = fn(ThreadComm(world, r), r)
        except BaseException as e:  # noqa: BLE001 - surfaced below
            errors[r] = e
            world.abort()

    threads = [threading.Thread(target=worker, args=(r,)) for r in range(size)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for e in errors:
        if e is not None:
            raise e
    return results


class JaxComm(Comm):
    """Adapter for real multi-host deployments.

    ``reduce_tree`` no longer falls back to gather-at-root: it runs the
    genuine O(log N) pairwise schedule through
    :func:`reduce_tree_via_exchange`, with each round's byte payloads
    moved between host pairs by a collective
    ``distributed.sharding.PpermuteByteTransport`` (length-prefixed uint8
    device arrays, shard_map ppermute over a 1-D host mesh -- jax's
    point-to-point primitive is a collective permutation, so every process
    participates in each round but only the round's pair payloads travel).
    Rank states are already stable serialized bytes
    (``interprocess.serialize_rank_state``), so the byte transport carries
    them unchanged and the result is byte-identical to every other
    topology (the schedule is :func:`reduce_rounds`).

    On a single-process runtime the schedule is empty and everything
    degenerates to SoloComm semantics, which is what this container
    exercises; the transport can be injected for testing.
    """

    def __init__(self, transport: Optional[Any] = None) -> None:
        import jax

        self.rank = jax.process_index()
        self.size = jax.process_count()
        self._transport = transport

    def _xport(self):
        if self._transport is None:
            from ..distributed.sharding import PpermuteByteTransport

            self._transport = PpermuteByteTransport()
        return self._transport

    def reduce_tree(self, obj, fn, root=0):
        if self.size == 1:
            return obj
        return reduce_tree_via_exchange(self.rank, self.size, obj, fn,
                                        self._xport().exchange, root=root)

    def vote_any(self, flag):
        if self.size == 1:
            return bool(flag)
        from ..distributed.sharding import global_any

        return global_any(flag)

    def gather(self, obj, root=0):
        if self.size == 1:
            return [obj]
        raise NotImplementedError(
            "multi-host gather requires a real multi-process jax runtime; "
            "reduce_tree/gather_tree cover the finalize collectives via "
            "the ppermute byte transport")

    def bcast(self, obj, root=0):
        if self.size == 1:
            return obj
        from jax.experimental import multihost_utils

        return multihost_utils.broadcast_one_to_all(obj)

    def scatter(self, objs, root=0):
        if self.size == 1:
            assert objs is not None
            return objs[0]
        raise NotImplementedError

    def barrier(self):
        if self.size > 1:
            from jax.experimental import multihost_utils

            multihost_utils.sync_global_devices("recorder_barrier")
