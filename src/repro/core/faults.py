"""Deterministic fault injection for the tracing stack.

Production tracing must assume ranks die mid-collective, nodes are
preempted mid-commit, and disks tear writes.  This module is the single
switchboard the rest of the core consults to *simulate* those failures
reproducibly, so the fault-tolerance properties ("every surviving trace
directory is either fully readable or reports degraded coverage -- never
silently wrong") are enforced by seeded tests and the
``benchmarks/fault_matrix.py`` scenario matrix instead of hoped for.

A :class:`FaultPlan` is installed process-wide (:func:`install` /
:func:`injected`); the hook points are:

  ``ThreadComm.send``            -> :meth:`FaultPlan.on_send` may drop a
                                    message or delay its delivery
  ``trace_format`` file writers  -> :meth:`FaultPlan.on_write` may raise
                                    ENOSPC or *mangle* the bytes that hit
                                    the disk (torn write: the writer still
                                    believes it wrote the intended data,
                                    so manifest sizes/CRCs record the
                                    intent -- exactly what a lying disk
                                    does)
  ``streaming.write_epoch_segment`` commit points
                                 -> :meth:`FaultPlan.on_commit_point` may
                                    raise :class:`SimulatedCrash`

Everything is seeded (``random.Random(seed)``) and counted, so a scenario
replays bit-identically and the driver can assert the faults actually
fired.  :func:`corrupt_file` / :func:`tear_file` are the post-commit
bit-rot/truncation helpers for faults that happen *after* a clean commit.
"""

from __future__ import annotations

import errno
import os
import random
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple


class SimulatedCrash(BaseException):
    """A simulated process death at a commit point.

    Deliberately NOT an ``Exception``: ordinary error recovery (e.g. the
    segment writer's ``.tmp`` cleanup) must not intercept it, so the
    debris left behind matches what a real kill would leave.
    """


@dataclass
class FaultPlan:
    """One seeded, replayable set of injected faults.

    ``dead_ranks`` simulates *unresponsive* peers: every p2p message sent
    by those ranks is silently dropped (the rank itself keeps running and
    will locally time out -- the preempted-but-not-yet-killed node).  A
    fully dead rank is simulated by simply not calling into the collective
    from that rank's thread.
    """

    seed: int = 0
    # -- comm faults ------------------------------------------------------
    dead_ranks: Tuple[int, ...] = ()
    drop_prob: float = 0.0           # per-message random drop
    delay_prob: float = 0.0          # per-message random delivery delay
    delay_s: float = 0.0             # how late a delayed message arrives
    # -- segment-writer faults -------------------------------------------
    #: raise ENOSPC on the Nth tracked trace-file write (1-based)
    fail_write_at: Optional[int] = None
    #: basename whose Nth write (``torn_at``, 1-based) hits the disk with
    #: its tail zeroed -- same length, wrong bytes: only checksums catch it
    torn_file: Optional[str] = None
    torn_at: int = 1
    #: raise SimulatedCrash at this commit point ("pre-rename",
    #: "pre-manifest", "post-commit"), optionally only for ``crash_epoch``
    crash_point: Optional[str] = None
    crash_epoch: Optional[int] = None
    # -- observability ----------------------------------------------------
    counters: Dict[str, int] = field(default_factory=dict)

    def __post_init__(self) -> None:
        self._rng = random.Random(self.seed)
        self._lock = threading.Lock()
        self._writes = 0
        self._torn_seen = 0

    def _bump(self, key: str) -> None:
        self.counters[key] = self.counters.get(key, 0) + 1

    # -- hook: ThreadComm.send -------------------------------------------

    def on_send(self, src: int, dst: int) -> Optional[Any]:
        """None = deliver normally, ``"drop"`` = vanish, a float = deliver
        that many seconds late."""
        with self._lock:
            if src in self.dead_ranks:
                self._bump("sends_dropped")
                return "drop"
            if self.drop_prob and self._rng.random() < self.drop_prob:
                self._bump("sends_dropped")
                return "drop"
            if self.delay_prob and self._rng.random() < self.delay_prob:
                self._bump("sends_delayed")
                return float(self.delay_s)
        return None

    # -- hook: trace file writes -----------------------------------------

    def on_write(self, path: str, data: bytes) -> bytes:
        """Called with the bytes ABOUT to be written to ``path``; returns
        the bytes that actually reach the disk, or raises ``OSError``."""
        base = os.path.basename(path)
        with self._lock:
            self._writes += 1
            if self.fail_write_at is not None \
                    and self._writes == self.fail_write_at:
                self._bump("writes_failed")
                raise OSError(errno.ENOSPC, "disk full (injected)", path)
            if self.torn_file is not None and base == self.torn_file:
                self._torn_seen += 1
                if self._torn_seen == self.torn_at and len(data) > 1:
                    self._bump("files_torn")
                    keep = len(data) // 2
                    return data[:keep] + b"\x00" * (len(data) - keep)
        return data

    # -- hook: segment commit points -------------------------------------

    def on_commit_point(self, point: str, epoch: int) -> None:
        if self.crash_point != point:
            return
        if self.crash_epoch is not None and epoch != self.crash_epoch:
            return
        with self._lock:
            self._bump("crashes")
        raise SimulatedCrash(f"injected crash at {point} (epoch {epoch})")


# ---------------------------------------------------------------------------
# process-wide installation (the hook points poll this slot)
# ---------------------------------------------------------------------------

_ACTIVE: List[Optional[FaultPlan]] = [None]


def install(plan: Optional[FaultPlan]) -> None:
    _ACTIVE[0] = plan


def uninstall() -> None:
    _ACTIVE[0] = None


def get_active() -> Optional[FaultPlan]:
    return _ACTIVE[0]


class injected:
    """``with faults.injected(plan): ...`` -- scoped installation."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan

    def __enter__(self) -> FaultPlan:
        install(self.plan)
        return self.plan

    def __exit__(self, *exc) -> None:
        uninstall()


# ---------------------------------------------------------------------------
# post-commit corruption helpers (bit rot / truncation after a clean commit)
# ---------------------------------------------------------------------------


def tear_file(path: str, keep_frac: float = 0.5) -> int:
    """Truncate ``path`` to ``keep_frac`` of its size (post-commit torn
    tail); returns the new size.  Caught by the manifest size check."""
    size = os.path.getsize(path)
    keep = max(0, int(size * keep_frac))
    with open(path, "r+b") as f:
        f.truncate(keep)
    return keep


def corrupt_file(path: str, seed: int = 0, n_flips: int = 8) -> None:
    """Flip ``n_flips`` deterministic bits of ``path`` WITHOUT changing its
    size -- classic bit rot: invisible to size checks, caught only by
    checksums."""
    rng = random.Random(seed)
    with open(path, "r+b") as f:
        data = bytearray(f.read())
        if not data:
            return
        for _ in range(n_flips):
            i = rng.randrange(len(data))
            data[i] ^= 1 << rng.randrange(8)
        f.seek(0)
        f.write(bytes(data))


# ---------------------------------------------------------------------------
# the enforced invariant: readable, or detectably partial -- never wrong
# ---------------------------------------------------------------------------


def check_trace_invariants(trace_dir: str) -> Dict[str, Any]:
    """Open ``trace_dir`` and force a full decode of every record it
    serves; returns a report dict.  The contract under any injected fault:
    either the directory reads cleanly, or the damage is *reported*
    (``skipped`` segments / ``degraded_epochs`` masks / a clean
    ``TraceFormatError``) -- a trace that decodes but misrepresents what
    happened is the one outcome this guard exists to rule out, and the
    callers (tests, ``benchmarks/fault_matrix.py``) assert on the report.
    """
    # local imports: faults is imported by the low-level writers, so the
    # reader stack must not be pulled in at module import time
    from .reader import TraceReader
    from .trace_format import TraceFormatError

    report: Dict[str, Any] = {"trace_dir": trace_dir, "readable": False,
                              "n_records": 0, "skipped": [],
                              "degraded_epochs": {}, "error": None}
    try:
        import warnings as _warnings
        with _warnings.catch_warnings():
            _warnings.simplefilter("ignore")
            reader = TraceReader(trace_dir, mode="stitched")
            n = 0
            for _rank, _rec in reader.all_records():
                n += 1
    except TraceFormatError as e:
        report["error"] = str(e)
        return report
    report["readable"] = True
    report["n_records"] = n
    report["skipped"] = list(reader.skipped)
    report["degraded_epochs"] = dict(getattr(reader, "degraded_epochs", {}))
    return report
