"""Compressed-domain Directly-Follows Graphs, phases, and divergence.

The per-rank trace is a Sequitur grammar (run-length exponents, rule 0 is
the start rule).  Sankaran et al. (arxiv 2408.07378) build a
Directly-Follows Graph -- nodes are operations, a weighted edge (a, b)
counts how often b immediately follows a -- over the *expanded* call
stream to expose phases, loops, and per-process divergence.  Because our
streams are already grammars, the DFG is a pure function of the grammar,
computable in O(|grammar|) with zero record expansion:

:func:`grammar_digrams`
    exact adjacent-pair counts of the expansion.  A rule body's internal
    adjacencies are weighted by the rule's expansion multiplicity
    (``sequitur.rule_weights``); the junction between consecutive items
    uses each item's first/last terminal (a bottom-up DP, like
    ``terminal_positions``); a symbol repeated ``e`` times contributes
    its (last, first) self-junction ``e - 1`` times.  Property-tested
    edge-for-edge identical to :func:`stream_digrams`, the per-record
    reference scan.

:func:`grammar_episodes` / :func:`phase_segments`
    phase segmentation without expansion.  The start rule's item list IS
    the trace's top-level temporal structure: inlining single-use
    (``exp == 1``) rule references yields a stream of *episodes* --
    single calls and repeated loop bodies -- each summarized by its
    record count and per-function profile (a bottom-up per-rule DP).
    Adjacent episodes with the same *dominant function set* merge into
    one phase.  Merging is associative, so an incrementally folded phase
    list (:func:`fold_phases`, used by ``TraceReader.refresh``) is
    value-identical to recomputing over the concatenated grammar.

:func:`project_edges` / :func:`dfg_distance`
    cross-rank comparison.  Terminal ids differ across merged/stitched
    reads and across ranks with irregular offsets, so divergence is
    scored on the (func, pattern-class) *label* projection, where SPMD
    ranks collapse to identical graphs.  ``dfg_distance`` is the total
    variation distance between edge-weight distributions (0 = identical
    shape, 1 = disjoint) -- a graph-edit-style score on weighted edge
    sets that is insensitive to record-count scale.

``TraceView.dfg() / phases() / rank_divergence()`` build on these; the
``traceserve`` query families ``dfg`` / ``phases`` / ``anomalies`` serve
them incrementally (one new epoch = one delta-sized grammar walk).
"""

from __future__ import annotations

from typing import Callable, Dict, Iterable, List, Optional, Tuple

from .sequitur import _topo_order, rule_weights
from .specs import DATA_FUNCS

Edges = Dict[Tuple[int, int], int]

#: default dominance cutoff: a function "dominates" an episode when it
#: accounts for at least this fraction of the episode's records
DOM_FRAC = 0.25

_WRITE_FUNCS = frozenset({"pwrite", "write", "shard_write_at"})
_READ_FUNCS = DATA_FUNCS - _WRITE_FUNCS


# ---------------------------------------------------------------------------
# DFG construction (O(|grammar|), zero expansion)
# ---------------------------------------------------------------------------


def grammar_digrams(rules: List[List[Tuple[int, int]]]
                    ) -> Tuple[Edges, Optional[int], Optional[int]]:
    """``(edges, first, last)`` of a parsed grammar's full expansion.

    ``edges[(a, b)]`` is the exact number of positions where terminal
    ``b`` immediately follows terminal ``a`` in the expanded stream;
    ``first``/``last`` are the stream's boundary terminals (None for an
    empty expansion) -- what :func:`fold_digrams` needs to stitch the
    junction digram when a new epoch segment is appended.

    One bottom-up pass derives each rule's first/last terminal, one
    weighted pass over rule bodies emits the edges: item junctions count
    ``w[rule]`` times, a symbol with exponent ``e`` adds its
    (last, first) self-junction ``w[rule] * (e - 1)`` times, and
    empty-expansion symbols are transparent.  Rule-internal adjacencies
    are NOT re-walked per reference -- they are counted once via the
    referenced rule's own weight.
    """
    if not rules:
        return {}, None, None
    w = rule_weights(rules)
    n = len(rules)
    firsts: List[Optional[int]] = [None] * n
    lasts: List[Optional[int]] = [None] * n
    for i in reversed(_topo_order(rules)):
        f = last = None
        for code, _exp in rules[i]:
            x = code >> 1
            sf, sl = (firsts[x], lasts[x]) if code & 1 else (x, x)
            if sf is None:
                continue
            if f is None:
                f = sf
            last = sl
        firsts[i], lasts[i] = f, last
    edges: Edges = {}
    for i, items in enumerate(rules):
        wi = w[i]
        if not wi:
            continue
        prev_last: Optional[int] = None
        for code, exp in items:
            x = code >> 1
            sf, sl = (firsts[x], lasts[x]) if code & 1 else (x, x)
            if sf is None:
                continue
            if prev_last is not None:
                k = (prev_last, sf)
                edges[k] = edges.get(k, 0) + wi
            if exp > 1:
                k = (sl, sf)
                edges[k] = edges.get(k, 0) + wi * (exp - 1)
            prev_last = sl
    return edges, firsts[0], lasts[0]


def stream_digrams(stream: Iterable[int]) -> Edges:
    """Per-record directly-follows scan of an expanded terminal stream --
    the brute-force reference :func:`grammar_digrams` is property-tested
    against (``tests/test_dfg.py``)."""
    edges: Edges = {}
    prev = None
    for t in stream:
        if prev is not None:
            k = (prev, t)
            edges[k] = edges.get(k, 0) + 1
        prev = t
    return edges


def fold_digrams(old: Tuple[Edges, Optional[int], Optional[int]],
                 seg: Tuple[Edges, Optional[int], Optional[int]],
                 toff: int) -> Tuple[Edges, Optional[int], Optional[int]]:
    """DFG of ``old stream ++ seg stream`` from the parts' DFGs.

    ``seg``'s terminal ids are local to its segment and shifted by
    ``toff`` (the CST splice offset); the single junction digram
    (old last, seg first) is added once.  This is what makes the DFG a
    per-epoch *fold* for ``TraceReader.refresh``: one delta-sized
    grammar walk per new segment, never a rescan of old ones.
    """
    old_e, old_f, old_l = old
    seg_e, seg_f, seg_l = seg
    edges = dict(old_e)
    for (a, b), c in seg_e.items():
        k = (a + toff, b + toff)
        edges[k] = edges.get(k, 0) + c
    if old_l is not None and seg_f is not None:
        k = (old_l, seg_f + toff)
        edges[k] = edges.get(k, 0) + 1
    first = old_f if old_f is not None else (
        None if seg_f is None else seg_f + toff)
    last = old_l if seg_l is None else seg_l + toff
    return edges, first, last


# ---------------------------------------------------------------------------
# label projection + divergence scoring
# ---------------------------------------------------------------------------


def pattern_class(sig) -> str:
    """Offset-encoding class of one call signature: ``plain`` (no
    offset-role slot), ``run`` (an IterPattern -- the call advances
    through an arithmetic offset run), or ``const`` (a fixed or purely
    rank-linear offset).  Rank-symbolic components do NOT change the
    class: SPMD ranks whose offsets differ only by the rank project to
    the same label."""
    if sig.enc is None:
        return "plain"
    return "run" if sig.enc[3] else "const"


def node_label(sig) -> Tuple[str, str]:
    """DFG node identity of a call signature: ``(func, pattern-class)``.
    Coarser than terminal ids (which differ across ranks with irregular
    offsets and across merged/stitched terminal spaces) but fine enough
    to separate e.g. a strided-write loop from a rewind-and-rewrite."""
    return sig.name, pattern_class(sig)


def project_edges(edges: Edges, label_of: Callable[[int], Tuple[str, str]]
                  ) -> Dict[Tuple[Tuple[str, str], Tuple[str, str]], int]:
    """Collapse terminal-level edges onto node labels (weights summed)."""
    out: Dict[Tuple[Tuple[str, str], Tuple[str, str]], int] = {}
    for (a, b), c in edges.items():
        k = (label_of(a), label_of(b))
        out[k] = out.get(k, 0) + c
    return out


def dfg_distance(a: Dict, b: Dict) -> float:
    """Total variation distance between two weighted edge sets' weight
    *distributions*, in [0, 1]: 0 for identically shaped graphs (any
    record-count scale), 1 for edge-disjoint ones.  Two empty graphs are
    identical; empty vs non-empty is maximal."""
    ta, tb = sum(a.values()), sum(b.values())
    if not ta and not tb:
        return 0.0
    if not ta or not tb:
        return 1.0
    keys = set(a) | set(b)
    return 0.5 * sum(abs(a.get(k, 0) / ta - b.get(k, 0) / tb) for k in keys)


# ---------------------------------------------------------------------------
# phase segmentation (episodes from the start rule, no expansion)
# ---------------------------------------------------------------------------


def grammar_episodes(rules: List[List[Tuple[int, int]]],
                     name_of: Callable[[int], str]
                     ) -> List[Tuple[int, Dict[str, int], bool]]:
    """The trace's top-level temporal structure as a list of episodes
    ``(n_records, per-func record counts, is_loop)``.

    The start rule's items are walked in order, inlining ``exp == 1``
    rule references (they are pure sequencing, not repetition); every
    remaining item -- a single terminal or a repeated symbol -- is one
    episode, profiled from a bottom-up per-rule (length, func-count) DP.
    A repeated symbol is atomic (a loop is ONE episode, not per-
    iteration alternation), flagged ``is_loop``.  O(|grammar|) total.

    Because ``sequitur.concat_grammars`` splices the parts' start-rule
    items into the combined start rule (exponents preserved), the
    episode list of a concatenated grammar is exactly the concatenation
    of the parts' episode lists -- the identity :func:`fold_phases`
    builds on.
    """
    if not rules:
        return []
    n = len(rules)
    lengths = [0] * n
    profiles: List[Dict[str, int]] = [{} for _ in range(n)]
    for i in reversed(_topo_order(rules)):
        ln = 0
        prof: Dict[str, int] = {}
        for code, exp in rules[i]:
            x = code >> 1
            if code & 1:
                ln += exp * lengths[x]
                for f, c in profiles[x].items():
                    prof[f] = prof.get(f, 0) + exp * c
            else:
                ln += exp
                f = name_of(x)
                prof[f] = prof.get(f, 0) + exp
        lengths[i] = ln
        profiles[i] = prof
    episodes: List[Tuple[int, Dict[str, int], bool]] = []
    # iterative inline walk of the start rule (no recursion limit)
    stack: List[Tuple[List[Tuple[int, int]], int]] = [(rules[0], 0)]
    while stack:
        items, idx = stack.pop()
        while idx < len(items):
            code, exp = items[idx]
            idx += 1
            x = code >> 1
            if code & 1:
                if exp == 1:
                    stack.append((items, idx))
                    items, idx = rules[x], 0
                    continue
                if lengths[x]:
                    episodes.append((exp * lengths[x],
                                     {f: exp * c
                                      for f, c in profiles[x].items()},
                                     True))
            else:
                episodes.append((exp, {name_of(x): exp}, exp > 1))
    return episodes


def _dominant(counts: Dict[str, int], n_records: int,
              dom_frac: float) -> frozenset:
    cut = dom_frac * n_records
    dom = frozenset(f for f, c in counts.items() if c >= cut)
    if dom:
        return dom
    top = max(counts.values())
    return frozenset(f for f, c in counts.items() if c == top)


def phase_segments(episodes: List[Tuple[int, Dict[str, int], bool]],
                   dom_frac: float = DOM_FRAC) -> List[Dict]:
    """Cut the episode stream where the dominant function set shifts.

    Adjacent episodes sharing one dominant set D merge into a phase
    whose dominant set IS D (the shared set, not recomputed from the
    summed profile) -- that definition makes the merge associative, so
    folding per-epoch phase lists (:func:`fold_phases`) equals
    segmenting the whole stream at once.  Raw phase rows carry
    ``start``/``end`` (record positions, end exclusive), the dominant
    frozenset, the summed ``func_counts``, ``n_episodes`` and a loop
    flag; :func:`phase_report` turns them into the public shape.
    """
    phases: List[Dict] = []
    pos = 0
    for n_rec, counts, loop in episodes:
        if not n_rec:
            continue
        dom = _dominant(counts, n_rec, dom_frac)
        prev = phases[-1] if phases else None
        if prev is not None and prev["dominant"] == dom:
            prev["end"] = pos + n_rec
            for f, c in counts.items():
                prev["func_counts"][f] = prev["func_counts"].get(f, 0) + c
            prev["n_episodes"] += 1
            prev["loop"] = prev["loop"] or loop
        else:
            phases.append({"start": pos, "end": pos + n_rec,
                           "dominant": dom, "func_counts": dict(counts),
                           "n_episodes": 1, "loop": loop})
        pos += n_rec
    return phases


def fold_phases(old: List[Dict], seg: List[Dict], base: int) -> List[Dict]:
    """Phase list of ``old stream ++ seg stream`` from the parts' lists.

    ``seg``'s record positions are shifted by ``base`` (the old stream's
    record count); the single boundary pair merges when its dominant
    sets are equal -- by associativity of the :func:`phase_segments`
    merge this is value-identical to re-segmenting the concatenated
    episode stream.  Inputs are not mutated.
    """
    out = [dict(p, func_counts=dict(p["func_counts"])) for p in old]
    for p in seg:
        row = dict(p, start=p["start"] + base, end=p["end"] + base,
                   func_counts=dict(p["func_counts"]))
        prev = out[-1] if out else None
        if prev is not None and prev["dominant"] == row["dominant"]:
            prev["end"] = row["end"]
            for f, c in row["func_counts"].items():
                prev["func_counts"][f] = prev["func_counts"].get(f, 0) + c
            prev["n_episodes"] += row["n_episodes"]
            prev["loop"] = prev["loop"] or row["loop"]
        else:
            out.append(row)
    return out


def phase_label(dominant: frozenset, loop: bool) -> str:
    """Human label of a phase from its dominant functions: ``write`` /
    ``read`` / ``data`` (mixed directions) when every dominant call
    moves data, ``metadata`` when none does, ``mixed`` otherwise; a
    ``-loop`` suffix marks repeated structure."""
    if dominant <= _WRITE_FUNCS:
        base = "write"
    elif dominant <= _READ_FUNCS:
        base = "read"
    elif dominant <= DATA_FUNCS:
        base = "data"
    elif not dominant & DATA_FUNCS:
        base = "metadata"
    else:
        base = "mixed"
    return base + "-loop" if loop else base


def phase_report(phases: List[Dict]) -> List[Dict]:
    """JSON-friendly public rows for a raw :func:`phase_segments` list:
    ``[(start_record, end_record, dominant_funcs, label), ...]`` plus
    record/episode counts and the loop flag."""
    return [{
        "start_record": p["start"],
        "end_record": p["end"],
        "n_records": p["end"] - p["start"],
        "n_episodes": p["n_episodes"],
        "dominant_funcs": sorted(p["dominant"]),
        "label": phase_label(p["dominant"], p["loop"]),
        "loop": p["loop"],
    } for p in phases]
