"""Backend dispatch for the batched encode/fit hot paths (ROADMAP dir. 2).

The arithmetic-dense stages of the tracing pipeline -- timestamp
delta+zigzag, varint packing, arithmetic-run boundary detection and
rank-linear column fitting -- exist in three interchangeable
implementations:

``python``
    The scalar reference loops.  Slowest, but trivially auditable; the
    property suite (``tests/test_encode_kernels.py``) pins every other
    backend byte-identical to them.

``numpy``
    Vectorized host implementations (this module).  The fastest choice on
    CPU-only hosts for any non-trivial batch.

``pallas``
    The TPU kernels under ``repro.kernels`` (``delta_encode``,
    ``grammar_stats``), run in ``interpret=True`` mode when no accelerator
    is attached so CPU-only CI still exercises the kernel arithmetic.

``auto`` (the default) crosses over by batch size: tiny batches stay on
the Python loop (below NumPy's fixed per-call overhead), everything else
runs NumPy, and batches of ``PALLAS_MIN_BATCH``+ move to the kernels when
a non-CPU device is present.  Every backend produces byte-identical
output -- the switch is purely a performance knob
(``RecorderConfig.encode_backend`` / ``RECORDER_ENCODE_BACKEND``).

jax is imported lazily: the ``python`` and ``numpy`` paths must work (and
the core package must import) on hosts without a usable jax install.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .encoding import VarintRangeError, write_uvarint

BACKENDS = ("auto", "python", "numpy", "pallas")

# crossover points for the "auto" backend (see benchmarks/kernel_bench.py;
# the measured sweep lands in artifacts/bench/encode_kernels.json)
NUMPY_MIN_BATCH = 64         # below: NumPy call overhead beats the loop win
PALLAS_MIN_BATCH = 1 << 16   # below: kernel launch + transfer dominates

_U64_MAX = (1 << 64) - 1
_I32_SAFE = 1 << 31

_default_backend = "auto"
_accel: Optional[bool] = None


def default_backend() -> str:
    return _default_backend


def set_default_backend(backend: str) -> None:
    """Set the module-wide default used when callers pass ``backend=None``
    (the Recorder threads its config through explicitly; this knob serves
    benchmarks and ad-hoc analysis code)."""
    global _default_backend
    if backend not in BACKENDS:
        raise ValueError(f"encode backend must be one of {BACKENDS}, "
                         f"got {backend!r}")
    _default_backend = backend


def has_accelerator() -> bool:
    """True when jax sees a non-CPU device (memoized; False when jax is
    missing entirely, so ``auto`` degrades to numpy)."""
    global _accel
    if _accel is None:
        try:
            import jax
            _accel = any(d.platform != "cpu" for d in jax.devices())
        except Exception:
            _accel = False
    return _accel


def interpret_mode() -> bool:
    """Kernels run under the Pallas interpreter when no accelerator is
    attached -- CPU-only CI exercises the kernel arithmetic this way."""
    return not has_accelerator()


def resolve(backend: Optional[str], n: int) -> str:
    """Effective backend for a batch of ``n`` elements: explicit choices
    win; ``auto`` applies the size crossover."""
    b = backend if backend is not None else _default_backend
    if b not in BACKENDS:
        raise ValueError(f"encode backend must be one of {BACKENDS}, "
                         f"got {b!r}")
    if b != "auto":
        return b
    if n < NUMPY_MIN_BATCH:
        return "python"
    if n >= PALLAS_MIN_BATCH and has_accelerator():
        return "pallas"
    return "numpy"


# ---------------------------------------------------------------------------
# delta + zigzag (timestamp pipeline stage)
# ---------------------------------------------------------------------------


def _delta_zigzag_py(flat: np.ndarray) -> np.ndarray:
    """Scalar reference: first-order delta wrapped mod 2^32 -> zigzag u32."""
    out = np.empty(len(flat), np.uint32)
    prev = 0
    for i, v in enumerate(flat.tolist()):
        d = v if i == 0 else v - prev
        prev = v
        d = ((d + (1 << 31)) % (1 << 32)) - (1 << 31)
        out[i] = ((d << 1) ^ (d >> 63)) & 0xFFFFFFFF
    return out


def _delta_zigzag_np(flat: np.ndarray) -> np.ndarray:
    flat = flat.astype(np.int64)        # wrap arithmetic needs headroom
    deltas = np.empty_like(flat)
    deltas[0] = flat[0]
    deltas[1:] = flat[1:] - flat[:-1]
    deltas = ((deltas + (1 << 31)) % (1 << 32)) - (1 << 31)
    zz = (deltas << 1) ^ (deltas >> 63)
    return (zz & 0xFFFFFFFF).astype(np.uint32)


def _delta_zigzag_pallas(flat: np.ndarray) -> np.ndarray:
    import jax.numpy as jnp
    from ..kernels.delta_encode.ops import delta_zigzag
    zz = delta_zigzag(jnp.asarray(flat.astype(np.uint32)),
                      interpret=interpret_mode())
    return np.asarray(zz, dtype=np.uint32)


def delta_zigzag(flat: np.ndarray, backend: Optional[str] = None
                 ) -> np.ndarray:
    """Flat int64 tick stream -> zigzag'd u32 deltas, backend-dispatched.
    All backends are bit-identical (the kernel's int32 two's-complement
    arithmetic matches the mod-2^32 wrap of the reference)."""
    if flat.size == 0:
        return np.empty((0,), np.uint32)
    eff = resolve(backend, flat.size)
    if eff == "python":
        return _delta_zigzag_py(flat)
    if eff == "pallas":
        return _delta_zigzag_pallas(flat)
    return _delta_zigzag_np(flat)


# ---------------------------------------------------------------------------
# varint packing (u64-guarded; see encoding.pack_uvarints)
# ---------------------------------------------------------------------------


def _emit_varint_bytes(lens: np.ndarray, planes: np.ndarray) -> bytes:
    """Scatter per-element byte planes into the packed varint stream.

    ``planes`` is (n_planes, n): plane j holds byte j of every element with
    its continuation bit already set; ``lens`` the per-element byte counts.
    The exclusive-scan offsets + masked scatter are the host half of the
    two-pass byte-emit (the kernels produce lens/planes, shapes static)."""
    lens = np.asarray(lens, np.int64)
    n = len(lens)
    n_planes = planes.shape[0]
    offs = np.zeros(n + 1, np.int64)
    np.cumsum(lens, out=offs[1:])
    out = np.zeros(int(offs[-1]), np.uint8)
    starts = offs[:-1]
    for j in range(n_planes):       # plane-major: <= 10 vector scatters
        sel = lens > j
        if not sel.any():
            break
        out[starts[sel] + j] = planes[j][sel].astype(np.uint8, copy=False)
    return out.tobytes()


def _uvarint_planes_np(v: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(lens, planes) of a u64 value array -- the NumPy mirror of the
    kernel's per-element varint pass."""
    n = v.size
    lens = np.ones(n, np.int64)
    for k in range(1, 10):
        lens += (v >= np.uint64(1 << (7 * k))).astype(np.int64)
    shifts = np.uint64(7) * np.arange(10, dtype=np.uint64)
    b = ((v[None, :] >> shifts[:, None]) & np.uint64(0x7F)).astype(np.uint8)
    cont = np.arange(10, dtype=np.int64)[:, None] < (lens - 1)[None, :]
    return lens, np.where(cont, b | 0x80, b)


def _to_u64(values: Sequence[int]) -> np.ndarray:
    try:
        return np.asarray(values, dtype=np.uint64)
    except (OverflowError, ValueError, TypeError) as e:
        raise VarintRangeError(
            f"uvarint batch contains a value outside [0, 2^64): {e}"
        ) from None


def pack_uvarints_batch(values: Sequence[int], backend: str) -> bytes:
    """Batched uvarint packing, byte-identical to the ``write_uvarint``
    loop; values outside u64 raise :class:`encoding.VarintRangeError` (the
    kernels assume u64 -- arbitrary-precision ints keep their own tagged
    path through ``encode_value``)."""
    v = _to_u64(values)
    if v.size == 0:
        return b""
    if backend == "pallas":
        lens, planes = _uvarint_planes_pallas(v)
    else:
        lens, planes = _uvarint_planes_np(v)
    return _emit_varint_bytes(lens, planes)


def _uvarint_planes_pallas(v: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    import jax.numpy as jnp
    from ..kernels.delta_encode.ops import uvarint_encode64
    lo = (v & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    hi = (v >> np.uint64(32)).astype(np.uint32)
    lens, planes = uvarint_encode64(jnp.asarray(lo), jnp.asarray(hi),
                                    interpret=interpret_mode())
    return np.asarray(lens, np.int64), np.asarray(planes)


# ---------------------------------------------------------------------------
# fused tick encode: delta -> zigzag -> varint bytes
# ---------------------------------------------------------------------------


def _encode_ticks_varint_py(flat: np.ndarray) -> bytes:
    out = bytearray()
    prev = 0
    for i, t in enumerate(flat.tolist()):
        d = t if i == 0 else t - prev
        prev = t
        d = ((d + (1 << 31)) % (1 << 32)) - (1 << 31)
        write_uvarint(out, ((d << 1) ^ (d >> 63)) & 0xFFFFFFFF)
    return bytes(out)


def encode_ticks_varint(ticks: np.ndarray, backend: Optional[str] = None
                        ) -> bytes:
    """Fused delta -> zigzag -> varint byte-emit over a tick array.

    The variable-length stream is ~35-45% smaller than the fixed ``<u4``
    layout before zlib; the trace format keeps the fixed layout for
    byte-compat, so this op serves the benchmark sweep and future compact
    segment layouts.  All backends are byte-identical."""
    flat = np.asarray(ticks).reshape(-1).astype(np.int64)
    if flat.size == 0:
        return b""
    eff = resolve(backend, flat.size)
    if eff == "python":
        return _encode_ticks_varint_py(flat)
    if eff == "pallas":
        import jax.numpy as jnp
        from ..kernels.delta_encode.ops import delta_zigzag_varint
        _zz, lens, planes = delta_zigzag_varint(
            jnp.asarray(flat.astype(np.uint32)), interpret=interpret_mode())
        return _emit_varint_bytes(np.asarray(lens, np.int64),
                                  np.asarray(planes))
    zz = _delta_zigzag_np(flat).astype(np.uint64)
    lens, planes = _uvarint_planes_np(zz)
    return _emit_varint_bytes(lens, planes[:5])


# ---------------------------------------------------------------------------
# arithmetic-run boundaries (arith_segments / Sequitur RLE pre-tokenization)
# ---------------------------------------------------------------------------


def _run_boundaries_py(V: np.ndarray) -> np.ndarray:
    rows = V.tolist()
    mask = np.zeros(len(rows), bool)
    mask[0] = True
    for i in range(1, len(rows)):
        mask[i] = rows[i] != rows[i - 1]
    return mask


def run_boundaries(V: np.ndarray, backend: Optional[str] = None
                   ) -> np.ndarray:
    """Row-change mask of a (n, k) matrix: ``mask[i]`` iff row i differs
    from row i-1 (``mask[0]`` always True).  The shared building block of
    ``interprocess.arith_segments`` (over row diffs) and
    ``Sequitur.push_stream`` (over the raw terminal column)."""
    V = np.asarray(V)
    if V.ndim == 1:
        V = V[:, None]
    n = V.shape[0]
    if n == 0:
        return np.zeros(0, bool)
    eff = resolve(backend, V.size)
    if eff == "python":
        return _run_boundaries_py(V)
    if eff == "pallas" and np.abs(V).max(initial=0) < _I32_SAFE:
        import jax.numpy as jnp
        from ..kernels.grammar_stats.ops import row_boundaries
        out = row_boundaries(jnp.asarray(V.astype(np.int32)),
                             interpret=interpret_mode())
        return np.asarray(out).astype(bool)
    mask = np.empty(n, bool)
    mask[0] = True
    if n > 1:
        mask[1:] = (V[1:] != V[:-1]).any(axis=1)
    return mask


# ---------------------------------------------------------------------------
# rank-linear column classification (interprocess.batch_fit_columns)
# ---------------------------------------------------------------------------


def fit_classify(V: np.ndarray, backend: Optional[str] = None
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-column (const_mask, linear_mask, first_diff) of a (C, R) int64
    value matrix with R >= 2 -- the vectorized core of the rank-linear
    fitter.  The pallas path runs one kernel call over padded column tiles
    and falls back to NumPy when values do not fit int32 (TPU-native
    width)."""
    if backend == "pallas" and np.abs(V).max(initial=0) < _I32_SAFE:
        import jax.numpy as jnp
        from ..kernels.delta_encode.ops import fit_columns
        flags, d0 = fit_columns(jnp.asarray(V.astype(np.int32)),
                                interpret=interpret_mode())
        flags = np.asarray(flags)[: V.shape[0]]
        d0 = np.asarray(d0)[: V.shape[0]].astype(np.int64)
        return flags == 1, flags == 2, d0
    d = V[:, 1:] - V[:, :-1]
    const = (d == 0).all(axis=1)
    linear = (d == d[:, :1]).all(axis=1) & (d[:, 0] != 0)
    return const, linear, d[:, 0]


# ---------------------------------------------------------------------------
# symbol-stream statistics (Sequitur / TraceView digram profiles)
# ---------------------------------------------------------------------------


def terminal_histogram(stream: np.ndarray, n_bins: int,
                       backend: Optional[str] = None) -> np.ndarray:
    """Occurrence counts of terminals ``0..n_bins-1`` over a symbol
    stream, processed in blocks (kernel: one accumulating pallas_call)."""
    stream = np.asarray(stream, np.int64).reshape(-1)
    if stream.size == 0:
        return np.zeros(n_bins, np.int64)
    eff = resolve(backend, stream.size)
    if eff == "pallas" and stream.max(initial=0) < _I32_SAFE:
        import jax.numpy as jnp
        from ..kernels.grammar_stats.ops import histogram
        out = histogram(jnp.asarray(stream.astype(np.int32)), n_bins,
                        interpret=interpret_mode())
        return np.asarray(out).astype(np.int64)
    if eff == "python":
        out = np.zeros(n_bins, np.int64)
        for t in stream.tolist():
            if 0 <= t < n_bins:
                out[t] += 1
        return out
    return np.bincount(stream[(stream >= 0) & (stream < n_bins)],
                       minlength=n_bins)[:n_bins].astype(np.int64)


def digram_histogram(stream: np.ndarray, n_terminals: int,
                     backend: Optional[str] = None) -> Dict[Tuple[int, int],
                                                            int]:
    """Directly-follows (digram) counts over a terminal stream.

    The kernel computes blocked pair codes ``a * n_terminals + b`` with a
    cross-block carry of the previous element; the host bincounts the
    codes.  Backends agree exactly."""
    stream = np.asarray(stream, np.int64).reshape(-1)
    if stream.size < 2:
        return {}
    eff = resolve(backend, stream.size)
    if (eff == "pallas"
            and n_terminals * (n_terminals + 1) < _I32_SAFE):
        import jax.numpy as jnp
        from ..kernels.grammar_stats.ops import digram_codes
        codes = np.asarray(digram_codes(
            jnp.asarray(stream.astype(np.int32)), n_terminals,
            interpret=interpret_mode())).astype(np.int64)
        codes = codes[codes >= 0]
    elif eff == "python":
        counts: Dict[Tuple[int, int], int] = {}
        prev = None
        for t in stream.tolist():
            if prev is not None:
                k = (prev, t)
                counts[k] = counts.get(k, 0) + 1
            prev = t
        return counts
    else:
        codes = stream[:-1] * n_terminals + stream[1:]
    hist = np.bincount(codes)
    nz = np.flatnonzero(hist)
    return {(int(c) // n_terminals, int(c) % n_terminals): int(hist[c])
            for c in nz}
