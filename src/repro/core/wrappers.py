"""Automatic tracing-wrapper generation (paper §2.1, Listing 1).

The original Recorder generates a C wrapper per function from a signature
file and loads them as plugins via GOTCHA.  Here ``generate_wrappers``
*generates Python source* for a three-phase wrapper per ``FnSpec`` and
``exec``s it -- the Python analogue of code generation + plugin compilation.
The generated wrapper is:

    def <name>(<args...>):
        rec = _active[0]
        if rec is None or not <layer enabled>:
            return _impl(<args...>)          # tracing off: passthrough
        t0 = rec.now()                        # -- prologue
        depth = rec.enter()
        try:
            ret = _impl(<args...>)            # -- the real call
        except BaseException as e:
            rec.exit(); t1 = rec.now()
            rec.record(FID, (<args...>), ('err', type(e).__name__), depth, t0, t1)
            raise
        rec.exit()
        t1 = rec.now()                        # -- epilogue
        rec.record(FID, (<args...>), ret, depth, t0, t1)
        return ret

Handle lifetime: wrappers for specs named ``close*`` also drop the handle
mapping after recording.
"""

from __future__ import annotations

from types import SimpleNamespace
from typing import Callable, Dict, List, Optional

from .recorder import _active
from .specs import REGISTRY, FnSpec, FunctionRegistry


_TEMPLATE = '''
def {name}({params}):
    rec = _active[0]
    if rec is None or not rec.layer_enabled({layer!r}):
        return _impl({params})
    t0 = rec.now()
    depth = rec.enter()
    try:
        ret = _impl({params})
    except BaseException as e:
        rec.exit()
        t1 = rec.now()
        rec.record({fid}, ({argtuple}), ("err", type(e).__name__), depth, t0, t1)
        raise
    rec.exit()
    t1 = rec.now()
    rec.record({fid}, ({argtuple}), ret, depth, t0, t1)
    {post}
    return ret
'''


def generate_wrapper(spec: FnSpec, fid: int, impl: Callable) -> Callable:
    params = ", ".join(a.name for a in spec.args)
    argtuple = ", ".join(a.name for a in spec.args)
    if len(spec.args) == 1:
        argtuple += ","
    post = ""
    if spec.name.startswith("close") or spec.name.endswith("close") or \
            "_close" in spec.name:
        first_handle = next((a.name for a in spec.args), None)
        if first_handle:
            post = f"rec.forget_handle({first_handle})"
    src = _TEMPLATE.format(name=spec.name, params=params, fid=fid,
                           argtuple=argtuple, layer=spec.layer,
                           post=post or "pass")
    ns: Dict[str, object] = {"_active": _active, "_impl": impl}
    code = compile(src, f"<recorder-wrapper:{spec.name}>", "exec")
    exec(code, ns)  # noqa: S102 - code generation is the point (paper §2.1)
    fn = ns[spec.name]
    fn.__recorder_spec__ = spec  # type: ignore[attr-defined]
    return fn  # type: ignore[return-value]


def generate_wrappers(specs: List[FnSpec],
                      registry: FunctionRegistry = REGISTRY,
                      impls: Optional[Dict[str, Callable]] = None
                      ) -> SimpleNamespace:
    """Register specs and generate one wrapper per function.

    ``impls`` overrides per-function implementations (used by the simulated
    I/O layers in benchmarks); otherwise ``spec.impl`` is used.
    """
    ns = SimpleNamespace()
    for spec in specs:
        impl = (impls or {}).get(spec.name, spec.impl)
        if impl is None:
            raise ValueError(f"no implementation for {spec.name}")
        fid = registry.id_of(spec.name) if spec.name in registry._by_name \
            else registry.register(spec)
        setattr(ns, spec.name, generate_wrapper(spec, fid, impl))
    return ns
