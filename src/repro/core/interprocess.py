"""Inter-process I/O pattern recognition and compression (paper §3.2.2, §3.3).

At finalization each rank holds a local CST and CFG that are *almost*
identical across ranks: only rank-dependent offsets differ.  The inter-process
pass

  1. groups CST entries whose signatures are identical once OFFSET-role
     values are masked,
  2. within each group matches the k-th occurrence of every rank and checks
     whether each offset component is linear in the rank, ``v_r = r*a + b``
     (components of an ``IterPattern`` are checked separately, paper Fig 3c),
  3. rewrites matching entries into one shared signature containing
     ``RankPattern`` values, producing a single **merged CST**,
  4. remaps every rank's CFG terminals and deduplicates identical CFGs
     (paper Fig 3d: unique-CFGs file + CFG-index file + merged-CST file).

Two finalize topologies are provided:

``flat``
    The original gather-at-root pass: every rank's CST/CFG lands on rank 0
    and :func:`finalize_ranks` runs the three passes above over all ranks at
    once.  O(ranks x calls) work on a single process; kept as the bit-compat
    reference and for tiny worlds.

``tree`` (default in :class:`~repro.core.recorder.RecorderConfig`)
    A hierarchical reduction.  Each rank builds a compact
    :class:`RankState` from its local CST/CFG (:func:`make_rank_state`);
    adjacent *contiguous* rank blocks are then merged pairwise
    (:func:`merge_rank_states`) in O(log N) rounds -- through
    ``Comm.reduce_tree`` on real runs, or :func:`tree_reduce_states` on
    simulated rank lists.  A merged state keeps, per masked-signature
    occurrence group, either an exact *linear summary* (base + slope per
    offset slot, O(1) per group regardless of block size) or -- only once
    linearity is broken -- the explicit per-rank offsets.  Identical
    per-rank terminal streams are deduplicated inside the state, so for
    SPMD workloads the state size is constant in the number of ranks.
    :func:`materialize_state` finally emits a merged CST + deduped CFGs
    that are **byte-identical** to the flat pass (property-tested in
    ``tests/test_tree_finalize.py``).  States serialize to stable bytes
    (:func:`serialize_rank_state`) for transport between tree hops.

    One documented divergence: offset leaves that are not plain ``int``s
    (e.g. ``bool``) are never rank-fitted by the tree path, while the flat
    pass coerces them through ``int()``.  The runtime record path coerces
    offsets to ``int`` before encoding, so real traces are unaffected.

Rank-linear fitting is available in two modes: ``python`` (the original
per-occurrence scalar loop) and ``vectorized`` (default; NumPy batched
slope/intercept fitting over every candidate column at once,
:func:`batch_fit_columns`).  Both produce identical results; the benchmark
``benchmarks/ior_pattern.py::finalize_scaling`` sweeps topology x fit mode.

All functions here are pure (lists in, lists out); the SPMD wrapper in
``recorder.py`` moves data through a ``Comm``, and the benchmark drivers call
these directly on simulated rank states.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .encoding import (IterPattern, RankPattern, decode_signature,
                       decode_value, encode_signature, encode_value,
                       read_blob, read_uvarint, write_blob, write_uvarint)
from .sequitur import remap_grammar
from .specs import FunctionRegistry, Role

_MASK = "MASK"  # private-use sentinel replacing masked offset leaves


# ---------------------------------------------------------------------------
# signature masking
# ---------------------------------------------------------------------------


def _split_offsets(registry: FunctionRegistry, sig: bytes):
    """Decode ``sig`` and pull out OFFSET-role values (args and, for
    OFFSET-role returns such as lseek's, the return value).

    Returns (func_id, tid, depth, masked_args, ret, offsets, ret_masked);
    masked positions are replaced by the mask sentinel, and a masked return
    contributes the *last* element of ``offsets``.
    """
    func_id, tid, depth, args, ret = decode_signature(sig)
    spec = registry.spec(func_id)
    off_pos = spec.offset_positions
    offsets = [args[i] for i in off_pos if i < len(args)]
    masked = tuple(_MASK if i in off_pos else v for i, v in enumerate(args))
    ret_masked = (spec.ret_role == Role.OFFSET
                  and isinstance(ret, (int, IterPattern)))
    if ret_masked:
        offsets.append(ret)
    return func_id, tid, depth, masked, ret, tuple(offsets), ret_masked


def _masked_bytes(func_id: int, tid: int, depth: int, masked: tuple, ret: Any,
                  ret_masked: bool) -> bytes:
    return encode_signature(func_id, tid, depth, masked,
                            _MASK if ret_masked else ret)


# ---------------------------------------------------------------------------
# rank-linear fitting (scalar + vectorized)
# ---------------------------------------------------------------------------


def _fit_component(values: Sequence[int]) -> Optional[Any]:
    """Fit ``v_r = r*a + b`` over ranks; int if constant, RankPattern if
    linear with a != 0, None if not linear."""
    v0 = values[0]
    if all(v == v0 for v in values):
        return int(v0)
    if len(values) < 2:
        return None
    a = values[1] - values[0]
    if a == 0:
        return None
    for r, v in enumerate(values):
        if v != v0 + r * a:
            return None
    return RankPattern(a, v0)


# offsets larger than this cannot be diffed safely in int64
_I64_SAFE = 1 << 62


def batch_fit_columns(columns: List[Sequence[int]],
                      backend: Optional[str] = None) -> List[Optional[Any]]:
    """Vectorized :func:`_fit_component` over many equal-length columns.

    One pass classifies every column as constant (-> int), exactly
    rank-linear with nonzero slope (-> RankPattern) or neither (-> None).
    ``backend`` picks the classifier (``encode_backend.fit_classify``:
    NumPy, or a single pallas_call over padded column tiles); results are
    identical.  Falls back to the scalar loop when values do not fit
    safely in int64.
    """
    if not columns:
        return []
    try:
        V = np.asarray(columns, dtype=np.int64)
    except (OverflowError, ValueError, TypeError):
        return [_fit_component(c) for c in columns]
    if V.ndim != 2 or np.abs(V).max(initial=0) >= _I64_SAFE:
        return [_fit_component(c) for c in columns]
    if V.shape[1] < 2:
        return [int(c[0]) for c in columns]
    from . import encode_backend as _eb
    eff = _eb.resolve(backend, V.size)
    if eff == "python":
        return [_fit_component(c) for c in columns]
    const, linear, d0 = _eb.fit_classify(V, eff)
    d = d0[:, None]  # only d[:, 0] is consumed below
    out: List[Optional[Any]] = []
    for i in range(V.shape[0]):
        if const[i]:
            out.append(int(V[i, 0]))
        elif linear[i]:
            out.append(RankPattern(int(d[i, 0]), int(V[i, 0])))
        else:
            out.append(None)
    return out


def _fit_offsets(per_rank: List[tuple]) -> Optional[tuple]:
    """Fit each offset slot across ranks.  ``per_rank[r]`` is the tuple of
    offset values of rank r for this occurrence.  Values are ints or
    IterPattern with int components."""
    n_slots = len(per_rank[0])
    if any(len(v) != n_slots for v in per_rank):
        return None
    out = []
    for s in range(n_slots):
        col = [pr[s] for pr in per_rank]
        if all(isinstance(v, int) for v in col):
            fit = _fit_component(col)  # type: ignore[arg-type]
            if fit is None:
                return None
            out.append(fit)
        elif all(isinstance(v, IterPattern) for v in col):
            a_fit = _fit_component([int(v.a) for v in col])  # type: ignore[union-attr]
            b_fit = _fit_component([int(v.b) for v in col])  # type: ignore[union-attr]
            if a_fit is None or b_fit is None:
                return None
            out.append(IterPattern(a_fit, b_fit))
        else:
            return None  # mixed kinds across ranks: no merge
    return tuple(out)


def _fit_offsets_batch(all_per_rank: List[List[tuple]],
                       backend: Optional[str] = None
                       ) -> List[Optional[tuple]]:
    """Batched :func:`_fit_offsets`: gather every int / IterPattern-component
    column from every candidate group, fit them in one vectorized pass, then
    reassemble per-group fits.  Result-equivalent to the scalar path."""
    columns: List[List[int]] = []
    plans: List[Optional[List[tuple]]] = []
    for per_rank in all_per_rank:
        n_slots = len(per_rank[0])
        if any(len(v) != n_slots for v in per_rank):
            plans.append(None)
            continue
        desc: List[tuple] = []
        ok = True
        for s in range(n_slots):
            col = [pr[s] for pr in per_rank]
            if all(isinstance(v, int) for v in col):
                desc.append(("i", len(columns)))
                columns.append(col)  # type: ignore[arg-type]
            elif all(isinstance(v, IterPattern) for v in col):
                ia = len(columns)
                columns.append([int(v.a) for v in col])  # type: ignore[union-attr]
                ib = len(columns)
                columns.append([int(v.b) for v in col])  # type: ignore[union-attr]
                desc.append(("p", ia, ib))
            else:
                ok = False
                break
        plans.append(desc if ok else None)
    col_fits = batch_fit_columns(columns, backend=backend)
    out: List[Optional[tuple]] = []
    for plan in plans:
        if plan is None:
            out.append(None)
            continue
        fit: List[Any] = []
        for d in plan:
            if d[0] == "i":
                f = col_fits[d[1]]
                if f is None:
                    fit = []
                    break
                fit.append(f)
            else:
                fa, fb = col_fits[d[1]], col_fits[d[2]]
                if fa is None or fb is None:
                    fit = []
                    break
                fit.append(IterPattern(fa, fb))
        out.append(tuple(fit) if fit else None)
    return out


# ---------------------------------------------------------------------------
# arithmetic-run segmentation (the vectorized-fitting building block shared
# with patterns.IntraPatternTracker.encode_many, which imports it)
# ---------------------------------------------------------------------------


def arith_segments(V: np.ndarray,
                   backend: Optional[str] = None) -> List[Tuple[int, int]]:
    """Greedy arithmetic-run segmentation of a (n, k) value matrix.

    Returns half-open ``(start, end)`` element segments such that within a
    segment every consecutive row difference equals the segment's first
    difference (the run stride), mirroring the streaming protocol of
    ``IntraPatternTracker``: a run's stride is set by its second element and
    the run breaks at the first non-matching row.  ``backend`` dispatches
    the change-point scan (``encode_backend.run_boundaries`` over the diff
    rows); segmentation is identical across backends.
    """
    n = len(V)
    if n == 0:
        return []
    if n == 1:
        return [(0, 1)]
    d = V[1:] - V[:-1]
    if d.ndim == 1:
        d = d[:, None]
    # cp[j] for j >= 1: diff j differs from diff j-1
    from . import encode_backend as _eb
    mask = _eb.run_boundaries(d, backend)
    mask[0] = False  # position 0 is forced True by the boundary op
    cp = np.flatnonzero(mask)
    segs: List[Tuple[int, int]] = []
    s = 0
    while s < n:
        if s >= n - 1:
            segs.append((s, n))
            break
        # largest run of equal diffs starting at diff index s
        k = int(np.searchsorted(cp, s, side="right"))
        c = int(cp[k]) if k < len(cp) else n - 1
        segs.append((s, c + 1))
        s = c + 1
    return segs


# ---------------------------------------------------------------------------
# CST merge (flat topology)
# ---------------------------------------------------------------------------


@dataclass
class MergeResult:
    merged_entries: List[bytes]          # the merged CST, terminal order
    remaps: List[Dict[int, int]]         # per rank: old terminal -> new
    n_rank_patterns: int                 # how many entries used RankPattern


def merge_csts(rank_csts: List[List[bytes]], registry: FunctionRegistry,
               inter_patterns: bool = True, fit_mode: str = "vectorized"
               ) -> MergeResult:
    """Merge per-rank CSTs into one (paper §3.3.1).

    ``fit_mode`` selects the rank-linear fitter: ``"python"`` (per-group
    scalar loop), ``"vectorized"`` (NumPy batch) or ``"pallas"`` (the
    ``kernels/delta_encode`` column-fit kernel, interpret-mode on CPU).
    Output is identical across modes.
    """
    nranks = len(rank_csts)
    # -- pass 1: decode + group by (masked signature, occurrence index) ------
    decoded: List[List[tuple]] = []        # [rank][t] = (masked_key, parts)
    groups: Dict[Tuple[bytes, int], Dict[int, tuple]] = {}
    group_order: List[Tuple[bytes, int]] = []
    for r, cst in enumerate(rank_csts):
        occ_counter: Dict[bytes, int] = {}
        rank_rows = []
        for t, sig in enumerate(cst):
            (func_id, tid, depth, masked, ret, offsets,
             ret_masked) = _split_offsets(registry, sig)
            mkey = _masked_bytes(func_id, tid, depth, masked, ret, ret_masked)
            j = occ_counter.get(mkey, 0)
            occ_counter[mkey] = j + 1
            gkey = (mkey, j)
            g = groups.get(gkey)
            if g is None:
                g = {}
                groups[gkey] = g
                group_order.append(gkey)
            g[r] = (t, offsets)
            rank_rows.append((gkey, (func_id, tid, depth, masked, ret,
                                     offsets, ret_masked)))
        decoded.append(rank_rows)

    # -- pass 2: fit rank-linear groups --------------------------------------
    merged_offsets: Dict[Tuple[bytes, int], tuple] = {}
    n_rank_patterns = 0
    if inter_patterns and nranks > 1:
        candidates: List[Tuple[Tuple[bytes, int], List[tuple]]] = []
        for gkey in group_order:
            g = groups[gkey]
            if len(g) != nranks:
                continue  # not present on every rank: no fit (paper: collective I/O case)
            per_rank = [g[r][1] for r in range(nranks)]
            if not per_rank[0]:
                continue  # no offset args: identical signatures merge by interning
            candidates.append((gkey, per_rank))
        if fit_mode == "python":
            fits = [_fit_offsets(pr) for _, pr in candidates]
        else:
            fits = _fit_offsets_batch(
                [pr for _, pr in candidates],
                backend="pallas" if fit_mode == "pallas" else None)
        for (gkey, _), fit in zip(candidates, fits):
            if fit is not None:
                merged_offsets[gkey] = fit
                if _fit_has_rank_pattern(fit):
                    n_rank_patterns += 1

    # -- pass 3: build merged table + per-rank remaps ------------------------
    table: Dict[bytes, int] = {}
    merged_entries: List[bytes] = []
    remaps: List[Dict[int, int]] = [dict() for _ in range(nranks)]

    def intern(sig: bytes) -> int:
        t = table.get(sig)
        if t is None:
            t = len(merged_entries)
            table[sig] = t
            merged_entries.append(sig)
        return t

    for r, rank_rows in enumerate(decoded):
        for old_t, (gkey, parts) in enumerate(rank_rows):
            func_id, tid, depth, masked, ret, offsets, ret_masked = parts
            fit = merged_offsets.get(gkey)
            use_offsets = fit if fit is not None else offsets
            it = iter(use_offsets)
            args = tuple(next(it) if v is _MASK else v for v in masked)
            if ret_masked:
                ret = next(it)
            sig = encode_signature(func_id, tid, depth, args, ret)
            remaps[r][old_t] = intern(sig)

    return MergeResult(merged_entries=merged_entries, remaps=remaps,
                       n_rank_patterns=n_rank_patterns)


def _fit_has_rank_pattern(fit: tuple) -> bool:
    return any(isinstance(v, RankPattern) or
               (isinstance(v, IterPattern) and
                (isinstance(v.a, RankPattern) or isinstance(v.b, RankPattern)))
               for v in fit)


# ---------------------------------------------------------------------------
# CFG remap + dedupe
# ---------------------------------------------------------------------------


@dataclass
class CfgResult:
    unique_cfgs: List[bytes]
    cfg_index: List[int]  # per rank, index into unique_cfgs


def dedupe_cfgs(rank_cfgs: List[bytes]) -> CfgResult:
    """Keep one copy of each distinct CFG (paper §3.3.2)."""
    table: Dict[bytes, int] = {}
    unique: List[bytes] = []
    index: List[int] = []
    for buf in rank_cfgs:
        i = table.get(buf)
        if i is None:
            i = len(unique)
            table[buf] = i
            unique.append(buf)
        index.append(i)
    return CfgResult(unique_cfgs=unique, cfg_index=index)


def finalize_ranks(rank_csts: List[List[bytes]], rank_cfgs: List[bytes],
                   registry: FunctionRegistry, inter_patterns: bool = True,
                   fit_mode: str = "vectorized"
                   ) -> Tuple[MergeResult, CfgResult]:
    """The full root-side FLAT finalization: merge CSTs, remap CFGs, dedupe.

    This is the pure core shared by the SPMD path (``Recorder.finalize``
    with ``finalize_topology="flat"``) and the simulated-rank drivers in
    benchmarks/tests.  See :func:`tree_finalize_ranks` for the scalable
    topology that produces byte-identical output.
    """
    merge = merge_csts(rank_csts, registry, inter_patterns=inter_patterns,
                       fit_mode=fit_mode)
    remapped = [remap_grammar(cfg, merge.remaps[r])
                for r, cfg in enumerate(rank_cfgs)]
    cfgs = dedupe_cfgs(remapped)
    return merge, cfgs


# ---------------------------------------------------------------------------
# tree topology: incremental rank states
# ---------------------------------------------------------------------------
#
# A RankState summarizes the CST/CFG of a *contiguous block* of ranks
# [base, base + n).  Per masked-signature occurrence group it keeps either
#
#   lin  an exact linear summary: per offset slot, (value at local rank 0,
#        slope per rank).  Present iff the group occurs on every rank of the
#        block, slot kinds/arities agree, and every slot is exactly linear
#        in the local rank index.  O(1) per group regardless of block size.
#   raw  explicit {global_rank: offsets} for groups whose linearity (or
#        full presence) is broken.  This is the only part that can grow
#        with the block size -- exactly the entries the flat merge would
#        keep per-rank anyway.
#
# Per-rank terminal streams (the CFG bytes plus the per-terminal group-key
# sequence) are deduplicated inside the state, so N identical SPMD ranks
# cost one stream, not N.


# per-slot linear summaries:
#   ("i", v0, slope)                      plain-int slot
#   ("p", (a0, sa), (b0, sb))             IterPattern slot, per component
# a slope of None means "undetermined" (single-rank block).


@dataclass
class _Group:
    parts: tuple                 # (func_id, tid, depth, masked, ret, ret_masked)
    count: int                   # ranks of the block where the group occurs
    lin: Optional[tuple]         # per-slot linear summaries, or None
    raw: Optional[Dict[int, tuple]]  # global rank -> offsets (when lin dead)


@dataclass
class RankState:
    base: int                    # first global rank covered
    n: int                       # number of contiguous ranks covered
    groups: Dict[Tuple[bytes, int], _Group]
    streams: List[Tuple[bytes, tuple]]   # unique (cfg bytes, per-terminal gkeys)
    stream_of: List[int]         # per local rank -> index into streams


def _leaf_lin(offsets: tuple) -> Optional[tuple]:
    """Single-rank linear summary; None when any leaf is not fit-eligible."""
    slots = []
    for v in offsets:
        if type(v) is int:
            slots.append(("i", v, None))
        elif (isinstance(v, IterPattern) and type(v.a) is int
              and type(v.b) is int):
            slots.append(("p", (v.a, None), (v.b, None)))
        else:
            return None
    return tuple(slots)


def make_rank_state(rank: int, cst: List[bytes], cfg: bytes,
                    registry: FunctionRegistry) -> RankState:
    """Build the leaf state for one rank from its local CST and CFG."""
    rows: List[Tuple[bytes, int]] = []
    occ_counter: Dict[bytes, int] = {}
    groups: Dict[Tuple[bytes, int], _Group] = {}
    for sig in cst:
        (func_id, tid, depth, masked, ret, offsets,
         ret_masked) = _split_offsets(registry, sig)
        mkey = _masked_bytes(func_id, tid, depth, masked, ret, ret_masked)
        j = occ_counter.get(mkey, 0)
        occ_counter[mkey] = j + 1
        gkey = (mkey, j)
        rows.append(gkey)
        # a masked return is rewritten from the offsets at materialize time,
        # so normalize it out of the shared parts (determinism across ranks)
        parts = (func_id, tid, depth, masked,
                 None if ret_masked else ret, ret_masked)
        lin = _leaf_lin(offsets)
        groups[gkey] = _Group(parts=parts, count=1, lin=lin,
                              raw=None if lin is not None else {rank: offsets})
    return RankState(base=rank, n=1, groups=groups,
                     streams=[(cfg, tuple(rows))], stream_of=[0])


def _combine_comp(v0: int, sl: Optional[int], nl: int,
                  w0: int, sr: Optional[int], nr: int
                  ) -> Optional[Tuple[int, int]]:
    """Combine two exact-linear component summaries over adjacent blocks of
    sizes nl / nr; returns (v0, slope) for the combined block or None."""
    if nl == 1 and nr == 1:
        return (v0, w0 - v0)
    if nl == 1:                               # sr determined (nr > 1)
        return (v0, sr) if w0 - v0 == sr else None
    if nr == 1:                               # sl determined (nl > 1)
        return (v0, sl) if w0 == v0 + nl * sl else None
    if sl == sr and w0 == v0 + nl * sl:
        return (v0, sl)
    return None


def _combine_lin(ll: tuple, lr: tuple, nl: int, nr: int) -> Optional[tuple]:
    out = []
    for sl_l, sl_r in zip(ll, lr):
        if sl_l[0] != sl_r[0]:
            return None
        if sl_l[0] == "i":
            c = _combine_comp(sl_l[1], sl_l[2], nl, sl_r[1], sl_r[2], nr)
            if c is None:
                return None
            out.append(("i", c[0], c[1]))
        else:
            ca = _combine_comp(sl_l[1][0], sl_l[1][1], nl,
                               sl_r[1][0], sl_r[1][1], nr)
            cb = _combine_comp(sl_l[2][0], sl_l[2][1], nl,
                               sl_r[2][0], sl_r[2][1], nr)
            if ca is None or cb is None:
                return None
            out.append(("p", ca, cb))
    return tuple(out)


def _lin_values(lin: tuple, j: int) -> tuple:
    """Materialize the offsets tuple of local rank ``j`` from a summary."""
    out = []
    for slot in lin:
        if slot[0] == "i":
            out.append(slot[1] + j * (slot[2] or 0))
        else:
            (a0, sa), (b0, sb) = slot[1], slot[2]
            out.append(IterPattern(a0 + j * (sa or 0), b0 + j * (sb or 0)))
    return tuple(out)


def _explode(g: _Group, state: RankState) -> Dict[int, tuple]:
    """Per-rank offsets of a group (reconstructed from the summary when
    linear -- exact by the lin invariant)."""
    if g.raw is not None:
        return dict(g.raw)
    return {state.base + j: _lin_values(g.lin, j) for j in range(state.n)}


def merge_rank_states(left: RankState, right: RankState) -> RankState:
    """Merge two already-merged states over ADJACENT contiguous rank blocks.

    O(groups + broken-group ranks) per call; the reduction driver applies it
    pairwise in O(log N) rounds.  Associativity over contiguous splits makes
    the result independent of pairing order, so the threaded collective and
    the sequential simulator produce identical states.
    """
    if left.base + left.n != right.base:
        raise ValueError(
            f"merge_rank_states requires adjacent blocks, got "
            f"[{left.base},{left.base + left.n}) + "
            f"[{right.base},{right.base + right.n})")
    groups: Dict[Tuple[bytes, int], _Group] = {}
    for gkey, gl in left.groups.items():
        gr = right.groups.get(gkey)
        if gr is None:
            groups[gkey] = _Group(gl.parts, gl.count, None, _explode(gl, left))
            continue
        count = gl.count + gr.count
        lin = None
        if (gl.lin is not None and gr.lin is not None
                and len(gl.lin) == len(gr.lin)):
            lin = _combine_lin(gl.lin, gr.lin, left.n, right.n)
        if lin is not None:
            groups[gkey] = _Group(gl.parts, count, lin, None)
        else:
            raw = _explode(gl, left)
            raw.update(_explode(gr, right))
            groups[gkey] = _Group(gl.parts, count, None, raw)
    for gkey, gr in right.groups.items():
        if gkey not in left.groups:
            groups[gkey] = _Group(gr.parts, gr.count, None,
                                  _explode(gr, right))
    # streams: keep left's unique streams, append right's unseen ones
    streams = list(left.streams)
    stream_table = {s: i for i, s in enumerate(streams)}
    right_remap = []
    for s in right.streams:
        i = stream_table.get(s)
        if i is None:
            i = len(streams)
            stream_table[s] = i
            streams.append(s)
        right_remap.append(i)
    stream_of = list(left.stream_of) + [right_remap[i]
                                        for i in right.stream_of]
    return RankState(base=left.base, n=left.n + right.n, groups=groups,
                     streams=streams, stream_of=stream_of)


def tree_reduce_states(states: List[RankState]) -> RankState:
    """Reduce adjacent states pairwise until one remains (O(log N) rounds)."""
    if not states:
        raise ValueError("no states to reduce")
    while len(states) > 1:
        nxt = []
        for i in range(0, len(states), 2):
            if i + 1 < len(states):
                nxt.append(merge_rank_states(states[i], states[i + 1]))
            else:
                nxt.append(states[i])
        states = nxt
    return states[0]


def _finalize_slot(slot: tuple) -> Any:
    if slot[0] == "i":
        a = slot[2] or 0
        return int(slot[1]) if a == 0 else RankPattern(a, slot[1])
    (a0, sa), (b0, sb) = slot[1], slot[2]
    a_fit = int(a0) if (sa or 0) == 0 else RankPattern(sa, a0)
    b_fit = int(b0) if (sb or 0) == 0 else RankPattern(sb, b0)
    return IterPattern(a_fit, b_fit)


def _final_fits(state: RankState) -> Dict[Tuple[bytes, int], tuple]:
    """Fits for every fully-present, still-linear group of the root state.

    The heavy per-rank column fitting already happened incrementally
    during the merges (each group carries an O(1) linear summary), so the
    root only classifies slopes -- O(groups) regardless of fit mode.
    """
    nranks = state.n
    return {gkey: tuple(_finalize_slot(s) for s in g.lin)
            for gkey, g in state.groups.items()
            if g.lin is not None and g.count == nranks and g.lin}


def _build_sig(parts: tuple, offsets: tuple) -> bytes:
    func_id, tid, depth, masked, ret, ret_masked = parts
    it = iter(offsets)
    args = tuple(next(it) if v is _MASK else v for v in masked)
    if ret_masked:
        ret = next(it)
    return encode_signature(func_id, tid, depth, args, ret)


def _values_for_rank(g: _Group, state: RankState, rank: int) -> tuple:
    if g.raw is not None:
        return g.raw[rank]
    return _lin_values(g.lin, rank - state.base)


def materialize_state(state: RankState, inter_patterns: bool = True,
                      fit_mode: str = "vectorized",
                      cache_streams: bool = True
                      ) -> Tuple[MergeResult, CfgResult]:
    """Emit the merged CST + deduped CFGs from a fully-reduced state.

    Byte-identical to :func:`finalize_ranks` on the same rank data: the
    intern pass walks ranks in order and terminals in stream order, exactly
    like the flat pass 3.  Streams whose groups all materialize to
    rank-independent signatures are interned once and their remap reused,
    which makes this O(unique streams + ranks) for SPMD workloads.
    Near-uniform streams (a few rank-dependent rows in an otherwise
    uniform stream) share the uniform rows' remap too: later ranks copy it
    and re-sign only the irregular rows.  Both reuses preserve the flat
    pass's intern order exactly -- a uniform row's intern at a later rank
    is always a table hit, so skipping it cannot shift terminal ids
    (property-tested cached vs uncached in ``tests/test_interprocess.py``).

    ``cache_streams=False`` disables both reuses (every rank walks every
    row) -- the reference path the property tests compare against.

    ``fit_mode`` is accepted for API symmetry with :func:`finalize_ranks`
    but does not change the work done here: tree fitting happens
    incrementally during the merges, so materialization is
    fit-mode-independent (the benchmark sweep reports both labels).
    """
    del fit_mode
    nranks = state.n
    merged_offsets: Dict[Tuple[bytes, int], tuple] = {}
    n_rank_patterns = 0
    if inter_patterns and nranks > 1:
        merged_offsets = _final_fits(state)
        for fit in merged_offsets.values():
            if _fit_has_rank_pattern(fit):
                n_rank_patterns += 1

    table: Dict[bytes, int] = {}
    merged_entries: List[bytes] = []

    def intern(sig: bytes) -> int:
        t = table.get(sig)
        if t is None:
            t = len(merged_entries)
            table[sig] = t
            merged_entries.append(sig)
        return t

    # a group's signature is rank-independent when it is fitted, or when its
    # linear summary has zero slope everywhere (identical values on every
    # rank); such signatures are computed once
    _NOT_UNIFORM = object()
    uniform_cache: Dict[Tuple[bytes, int], Any] = {}

    def uniform_sig(gkey: Tuple[bytes, int], g: _Group) -> Any:
        got = uniform_cache.get(gkey, _NOT_UNIFORM)
        if got is not _NOT_UNIFORM:
            return got
        fit = merged_offsets.get(gkey)
        if fit is not None:
            sig: Any = _build_sig(g.parts, fit)
        elif g.lin is not None and all(
                (s[2] or 0) == 0 if s[0] == "i"
                else ((s[1][1] or 0) == 0 and (s[2][1] or 0) == 0)
                for s in g.lin):
            sig = _build_sig(g.parts, _lin_values(g.lin, 0))
        else:
            sig = None
        uniform_cache[gkey] = sig
        return sig

    stream_cache: Dict[int, Tuple[Dict[int, int], bytes]] = {}
    # near-uniform streams: the first rank's remap plus which rows are
    # rank-dependent; later ranks copy the remap and re-sign only those
    partial_cache: Dict[int, Tuple[Dict[int, int], List[int]]] = {}
    remaps: List[Dict[int, int]] = []
    remapped_cfgs: List[bytes] = []
    for j in range(nranks):
        si = state.stream_of[j]
        cached = stream_cache.get(si) if cache_streams else None
        if cached is not None:
            remaps.append(cached[0])
            remapped_cfgs.append(cached[1])
            continue
        cfg_bytes, rows = state.streams[si]
        part = partial_cache.get(si) if cache_streams else None
        if part is not None:
            base_remap, irr_rows = part
            remap = dict(base_remap)
            for old_t in irr_rows:
                g = state.groups[rows[old_t]]
                remap[old_t] = intern(_build_sig(
                    g.parts, _values_for_rank(g, state, state.base + j)))
            remaps.append(remap)
            remapped_cfgs.append(remap_grammar(cfg_bytes, remap))
            continue
        remap = {}
        irr_rows = []
        for old_t, gkey in enumerate(rows):
            g = state.groups[gkey]
            sig = uniform_sig(gkey, g)
            if sig is None:
                irr_rows.append(old_t)
                sig = _build_sig(g.parts,
                                 _values_for_rank(g, state, state.base + j))
            remap[old_t] = intern(sig)
        remapped = remap_grammar(cfg_bytes, remap)
        if not irr_rows:
            stream_cache[si] = (remap, remapped)
        else:
            partial_cache[si] = (remap, irr_rows)
        remaps.append(remap)
        remapped_cfgs.append(remapped)

    merge = MergeResult(merged_entries=merged_entries, remaps=remaps,
                        n_rank_patterns=n_rank_patterns)
    return merge, dedupe_cfgs(remapped_cfgs)


def tree_finalize_ranks(rank_csts: List[List[bytes]], rank_cfgs: List[bytes],
                        registry: FunctionRegistry,
                        inter_patterns: bool = True,
                        fit_mode: str = "vectorized"
                        ) -> Tuple[MergeResult, CfgResult]:
    """Tree-topology finalization over simulated rank lists.

    Builds one leaf state per rank, reduces pairwise in O(log N) rounds and
    materializes -- byte-identical to :func:`finalize_ranks`.
    """
    states = [make_rank_state(r, cst, cfg, registry)
              for r, (cst, cfg) in enumerate(zip(rank_csts, rank_cfgs))]
    root = tree_reduce_states(states)
    return materialize_state(root, inter_patterns=inter_patterns,
                             fit_mode=fit_mode)


# ---------------------------------------------------------------------------
# incremental (cross-epoch) state append -- the streaming finalize core
# ---------------------------------------------------------------------------
#
# A streaming flush reduces only the epoch's DELTA across ranks (O(delta)),
# then folds the resulting epoch state into a persisted cumulative state
# with append_epoch_state: occurrence indices of the delta's groups are
# shifted past the occurrences already accumulated (a per-masked-key
# counter maintained incrementally, so the fold never rescans the
# cumulative groups), per-rank terminal streams are concatenated (their
# grammars via sequitur.concat_grammars, terminal ids shifted past the
# cumulative rows), and group payloads are inserted untouched.  Per flush
# this is O(delta groups + unique stream pairs), never O(total);
# materialize_state over the cumulative state emits a merged trace that is
# value-identical (records, analyses) to a one-shot finalize of the full
# call history -- the ROADMAP "incremental finalize" item.


def epoch_occ_counts(state: RankState) -> Dict[bytes, int]:
    """Occurrences per masked signature in one state (dense 0..k-1 group
    indices, so the count is the number of keys per mkey)."""
    counts: Dict[bytes, int] = {}
    for mkey, _occ in state.groups:
        counts[mkey] = counts.get(mkey, 0) + 1
    return counts


def append_epoch_state(cum: Optional[RankState],
                       occ_counts: Optional[Dict[bytes, int]],
                       delta: RankState
                       ) -> Tuple[RankState, Dict[bytes, int]]:
    """Fold one epoch's cross-rank merged state into the cumulative state.

    ``cum`` covers the same contiguous rank block as ``delta`` but earlier
    epochs; ``occ_counts`` is the running per-mkey occurrence counter of
    ``cum`` (pass the pair returned by the previous call, or ``(None,
    None)`` to seed from the first epoch).  Returns the new
    ``(state, occ_counts)``; ``delta`` is absorbed and must not be reused.
    """
    from .sequitur import concat_grammars

    if cum is None:
        return delta, epoch_occ_counts(delta)
    if occ_counts is None:
        occ_counts = epoch_occ_counts(cum)
    if (cum.base, cum.n) != (delta.base, delta.n):
        raise ValueError(
            f"append_epoch_state requires matching rank blocks, got "
            f"[{cum.base},{cum.base + cum.n}) + "
            f"[{delta.base},{delta.base + delta.n})")
    groups = dict(cum.groups)
    key_map: Dict[Tuple[bytes, int], Tuple[bytes, int]] = {}
    for (mkey, occ), g in delta.groups.items():
        nk = (mkey, occ_counts.get(mkey, 0) + occ)
        key_map[(mkey, occ)] = nk
        groups[nk] = g
    for mkey, cnt in epoch_occ_counts(delta).items():
        occ_counts[mkey] = occ_counts.get(mkey, 0) + cnt

    streams: List[Tuple[bytes, tuple]] = []
    stream_table: Dict[Tuple[bytes, tuple], int] = {}
    pair_cache: Dict[Tuple[int, int], int] = {}
    stream_of: List[int] = []
    for j in range(cum.n):
        pair = (cum.stream_of[j], delta.stream_of[j])
        si = pair_cache.get(pair)
        if si is None:
            cfg_a, rows_a = cum.streams[pair[0]]
            cfg_b, rows_b = delta.streams[pair[1]]
            cfg = concat_grammars([(cfg_a, 0), (cfg_b, len(rows_a))])
            rows = rows_a + tuple(key_map[k] for k in rows_b)
            s = (cfg, rows)
            si = stream_table.get(s)
            if si is None:
                si = len(streams)
                stream_table[s] = si
                streams.append(s)
            pair_cache[pair] = si
        stream_of.append(si)
    return (RankState(base=cum.base, n=cum.n, groups=groups,
                      streams=streams, stream_of=stream_of), occ_counts)


# ---------------------------------------------------------------------------
# stable state (de)serialization for tree hops
# ---------------------------------------------------------------------------

_STATE_VERSION = 1


def _enc_comp(out: bytearray, comp: Tuple[int, Optional[int]]) -> None:
    encode_value(out, comp[0])
    if comp[1] is None:
        out.append(0)
    else:
        out.append(1)
        encode_value(out, comp[1])


def _dec_comp(buf: bytes, pos: int) -> Tuple[Tuple[int, Optional[int]], int]:
    v0, pos = decode_value(buf, pos)
    has = buf[pos]
    pos += 1
    if has:
        s, pos = decode_value(buf, pos)
        return (v0, s), pos
    return (v0, None), pos


def serialize_rank_state(state: RankState) -> bytes:
    """Deterministic byte form of a RankState (groups sorted by key), used
    to ship states between tree-reduction hops over a byte-transport Comm."""
    out = bytearray()
    write_uvarint(out, _STATE_VERSION)
    write_uvarint(out, state.base)
    write_uvarint(out, state.n)
    gkeys = sorted(state.groups)
    gindex = {k: i for i, k in enumerate(gkeys)}
    write_uvarint(out, len(gkeys))
    for mkey, occ in gkeys:
        g = state.groups[(mkey, occ)]
        write_blob(out, mkey)
        write_uvarint(out, occ)
        func_id, tid, depth, masked, ret, ret_masked = g.parts
        write_uvarint(out, func_id)
        write_uvarint(out, tid)
        write_uvarint(out, depth)
        mask_pos = tuple(i for i, v in enumerate(masked) if v is _MASK)
        encode_value(out, tuple(None if v is _MASK else v for v in masked))
        encode_value(out, mask_pos)
        encode_value(out, ret)
        out.append(1 if ret_masked else 0)
        write_uvarint(out, g.count)
        if g.lin is not None:
            out.append(0)
            write_uvarint(out, len(g.lin))
            for slot in g.lin:
                if slot[0] == "i":
                    out.append(0)
                    _enc_comp(out, (slot[1], slot[2]))
                else:
                    out.append(1)
                    _enc_comp(out, slot[1])
                    _enc_comp(out, slot[2])
        else:
            out.append(1)
            write_uvarint(out, len(g.raw))
            for rank in sorted(g.raw):
                write_uvarint(out, rank)
                encode_value(out, g.raw[rank])
    write_uvarint(out, len(state.streams))
    for cfg_bytes, rows in state.streams:
        write_blob(out, cfg_bytes)
        write_uvarint(out, len(rows))
        for gkey in rows:
            write_uvarint(out, gindex[gkey])
    write_uvarint(out, len(state.stream_of))
    for si in state.stream_of:
        write_uvarint(out, si)
    return bytes(out)


def deserialize_rank_state(buf: bytes) -> RankState:
    pos = 0
    version, pos = read_uvarint(buf, pos)
    if version != _STATE_VERSION:
        raise ValueError(f"unsupported rank-state version {version}")
    base, pos = read_uvarint(buf, pos)
    n, pos = read_uvarint(buf, pos)
    n_groups, pos = read_uvarint(buf, pos)
    groups: Dict[Tuple[bytes, int], _Group] = {}
    gkeys: List[Tuple[bytes, int]] = []
    for _ in range(n_groups):
        mkey, pos = read_blob(buf, pos)
        occ, pos = read_uvarint(buf, pos)
        func_id, pos = read_uvarint(buf, pos)
        tid, pos = read_uvarint(buf, pos)
        depth, pos = read_uvarint(buf, pos)
        masked_raw, pos = decode_value(buf, pos)
        mask_pos, pos = decode_value(buf, pos)
        ret, pos = decode_value(buf, pos)
        ret_masked = bool(buf[pos])
        pos += 1
        masked = tuple(_MASK if i in mask_pos else v
                       for i, v in enumerate(masked_raw))
        count, pos = read_uvarint(buf, pos)
        tag = buf[pos]
        pos += 1
        lin: Optional[tuple] = None
        raw: Optional[Dict[int, tuple]] = None
        if tag == 0:
            n_slots, pos = read_uvarint(buf, pos)
            slots = []
            for _ in range(n_slots):
                kind = buf[pos]
                pos += 1
                if kind == 0:
                    c, pos = _dec_comp(buf, pos)
                    slots.append(("i", c[0], c[1]))
                else:
                    ca, pos = _dec_comp(buf, pos)
                    cb, pos = _dec_comp(buf, pos)
                    slots.append(("p", ca, cb))
            lin = tuple(slots)
        else:
            n_raw, pos = read_uvarint(buf, pos)
            raw = {}
            for _ in range(n_raw):
                rank, pos = read_uvarint(buf, pos)
                offs, pos = decode_value(buf, pos)
                raw[rank] = offs
        gkey = (mkey, occ)
        gkeys.append(gkey)
        groups[gkey] = _Group((func_id, tid, depth, masked, ret, ret_masked),
                              count, lin, raw)
    n_streams, pos = read_uvarint(buf, pos)
    streams: List[Tuple[bytes, tuple]] = []
    for _ in range(n_streams):
        cfg_bytes, pos = read_blob(buf, pos)
        n_rows, pos = read_uvarint(buf, pos)
        rows = []
        for _ in range(n_rows):
            gi, pos = read_uvarint(buf, pos)
            rows.append(gkeys[gi])
        streams.append((cfg_bytes, tuple(rows)))
    n_ranks, pos = read_uvarint(buf, pos)
    stream_of = []
    for _ in range(n_ranks):
        si, pos = read_uvarint(buf, pos)
        stream_of.append(si)
    return RankState(base=base, n=n, groups=groups, streams=streams,
                     stream_of=stream_of)


def merge_serialized_states(left: bytes, right: bytes) -> bytes:
    """Byte-level pairwise merge: the reduction function handed to
    ``Comm.reduce_tree`` by ``Recorder.finalize`` (states travel as bytes
    between hops, so any byte-transport collective can carry them)."""
    return serialize_rank_state(
        merge_rank_states(deserialize_rank_state(left),
                          deserialize_rank_state(right)))
