"""Inter-process I/O pattern recognition and compression (paper §3.2.2, §3.3).

At finalization each rank holds a local CST and CFG that are *almost*
identical across ranks: only rank-dependent offsets differ.  The inter-process
pass

  1. groups CST entries whose signatures are identical once OFFSET-role
     values are masked,
  2. within each group matches the k-th occurrence of every rank and checks
     whether each offset component is linear in the rank, ``v_r = r*a + b``
     (components of an ``IterPattern`` are checked separately, paper Fig 3c),
  3. rewrites matching entries into one shared signature containing
     ``RankPattern`` values, producing a single **merged CST**,
  4. remaps every rank's CFG terminals and deduplicates identical CFGs
     (paper Fig 3d: unique-CFGs file + CFG-index file + merged-CST file).

All functions here are pure (lists in, lists out); the SPMD wrapper in
``recorder.py`` moves data through a ``Comm``, and the benchmark drivers call
these directly on simulated rank states.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .encoding import (IterPattern, RankPattern, decode_signature,
                       encode_signature)
from .sequitur import remap_grammar
from .specs import FunctionRegistry, Role

_MASK = "MASK"  # private-use sentinel replacing masked offset leaves


# ---------------------------------------------------------------------------
# signature masking
# ---------------------------------------------------------------------------


def _split_offsets(registry: FunctionRegistry, sig: bytes):
    """Decode ``sig`` and pull out OFFSET-role values (args and, for
    OFFSET-role returns such as lseek's, the return value).

    Returns (func_id, tid, depth, masked_args, ret, offsets, ret_masked);
    masked positions are replaced by the mask sentinel, and a masked return
    contributes the *last* element of ``offsets``.
    """
    func_id, tid, depth, args, ret = decode_signature(sig)
    spec = registry.spec(func_id)
    off_pos = spec.offset_positions
    offsets = [args[i] for i in off_pos if i < len(args)]
    masked = tuple(_MASK if i in off_pos else v for i, v in enumerate(args))
    ret_masked = (spec.ret_role == Role.OFFSET
                  and isinstance(ret, (int, IterPattern)))
    if ret_masked:
        offsets.append(ret)
    return func_id, tid, depth, masked, ret, tuple(offsets), ret_masked


def _masked_bytes(func_id: int, tid: int, depth: int, masked: tuple, ret: Any,
                  ret_masked: bool) -> bytes:
    return encode_signature(func_id, tid, depth, masked,
                            _MASK if ret_masked else ret)


# ---------------------------------------------------------------------------
# rank-linear fitting
# ---------------------------------------------------------------------------


def _fit_component(values: Sequence[int]) -> Optional[Any]:
    """Fit ``v_r = r*a + b`` over ranks; int if constant, RankPattern if
    linear with a != 0, None if not linear."""
    v0 = values[0]
    if all(v == v0 for v in values):
        return int(v0)
    if len(values) < 2:
        return None
    a = values[1] - values[0]
    if a == 0:
        return None
    for r, v in enumerate(values):
        if v != v0 + r * a:
            return None
    return RankPattern(a, v0)


def _fit_offsets(per_rank: List[tuple]) -> Optional[tuple]:
    """Fit each offset slot across ranks.  ``per_rank[r]`` is the tuple of
    offset values of rank r for this occurrence.  Values are ints or
    IterPattern with int components."""
    n_slots = len(per_rank[0])
    if any(len(v) != n_slots for v in per_rank):
        return None
    out = []
    for s in range(n_slots):
        col = [pr[s] for pr in per_rank]
        if all(isinstance(v, int) for v in col):
            fit = _fit_component(col)  # type: ignore[arg-type]
            if fit is None:
                return None
            out.append(fit)
        elif all(isinstance(v, IterPattern) for v in col):
            a_fit = _fit_component([int(v.a) for v in col])  # type: ignore[union-attr]
            b_fit = _fit_component([int(v.b) for v in col])  # type: ignore[union-attr]
            if a_fit is None or b_fit is None:
                return None
            out.append(IterPattern(a_fit, b_fit))
        else:
            return None  # mixed kinds across ranks: no merge
    return tuple(out)


# ---------------------------------------------------------------------------
# CST merge
# ---------------------------------------------------------------------------


@dataclass
class MergeResult:
    merged_entries: List[bytes]          # the merged CST, terminal order
    remaps: List[Dict[int, int]]         # per rank: old terminal -> new
    n_rank_patterns: int                 # how many entries used RankPattern


def merge_csts(rank_csts: List[List[bytes]], registry: FunctionRegistry,
               inter_patterns: bool = True) -> MergeResult:
    """Merge per-rank CSTs into one (paper §3.3.1)."""
    nranks = len(rank_csts)
    # -- pass 1: decode + group by (masked signature, occurrence index) ------
    decoded: List[List[tuple]] = []        # [rank][t] = (masked_key, parts)
    groups: Dict[Tuple[bytes, int], Dict[int, tuple]] = {}
    group_order: List[Tuple[bytes, int]] = []
    for r, cst in enumerate(rank_csts):
        occ_counter: Dict[bytes, int] = {}
        rank_rows = []
        for t, sig in enumerate(cst):
            (func_id, tid, depth, masked, ret, offsets,
             ret_masked) = _split_offsets(registry, sig)
            mkey = _masked_bytes(func_id, tid, depth, masked, ret, ret_masked)
            j = occ_counter.get(mkey, 0)
            occ_counter[mkey] = j + 1
            gkey = (mkey, j)
            g = groups.get(gkey)
            if g is None:
                g = {}
                groups[gkey] = g
                group_order.append(gkey)
            g[r] = (t, offsets)
            rank_rows.append((gkey, (func_id, tid, depth, masked, ret,
                                     offsets, ret_masked)))
        decoded.append(rank_rows)

    # -- pass 2: fit rank-linear groups --------------------------------------
    merged_offsets: Dict[Tuple[bytes, int], tuple] = {}
    n_rank_patterns = 0
    if inter_patterns and nranks > 1:
        for gkey in group_order:
            g = groups[gkey]
            if len(g) != nranks:
                continue  # not present on every rank: no fit (paper: collective I/O case)
            per_rank = [g[r][1] for r in range(nranks)]
            if not per_rank[0]:
                continue  # no offset args: identical signatures merge by interning
            fit = _fit_offsets(per_rank)
            if fit is not None:
                merged_offsets[gkey] = fit
                if any(isinstance(v, RankPattern) or
                       (isinstance(v, IterPattern) and
                        (isinstance(v.a, RankPattern) or isinstance(v.b, RankPattern)))
                       for v in fit):
                    n_rank_patterns += 1

    # -- pass 3: build merged table + per-rank remaps ------------------------
    table: Dict[bytes, int] = {}
    merged_entries: List[bytes] = []
    remaps: List[Dict[int, int]] = [dict() for _ in range(nranks)]

    def intern(sig: bytes) -> int:
        t = table.get(sig)
        if t is None:
            t = len(merged_entries)
            table[sig] = t
            merged_entries.append(sig)
        return t

    for r, rank_rows in enumerate(decoded):
        for old_t, (gkey, parts) in enumerate(rank_rows):
            func_id, tid, depth, masked, ret, offsets, ret_masked = parts
            fit = merged_offsets.get(gkey)
            use_offsets = fit if fit is not None else offsets
            it = iter(use_offsets)
            args = tuple(next(it) if v is _MASK else v for v in masked)
            if ret_masked:
                ret = next(it)
            sig = encode_signature(func_id, tid, depth, args, ret)
            remaps[r][old_t] = intern(sig)

    return MergeResult(merged_entries=merged_entries, remaps=remaps,
                       n_rank_patterns=n_rank_patterns)


# ---------------------------------------------------------------------------
# CFG remap + dedupe
# ---------------------------------------------------------------------------


@dataclass
class CfgResult:
    unique_cfgs: List[bytes]
    cfg_index: List[int]  # per rank, index into unique_cfgs


def dedupe_cfgs(rank_cfgs: List[bytes]) -> CfgResult:
    """Keep one copy of each distinct CFG (paper §3.3.2)."""
    table: Dict[bytes, int] = {}
    unique: List[bytes] = []
    index: List[int] = []
    for buf in rank_cfgs:
        i = table.get(buf)
        if i is None:
            i = len(unique)
            table[buf] = i
            unique.append(buf)
        index.append(i)
    return CfgResult(unique_cfgs=unique, cfg_index=index)


def finalize_ranks(rank_csts: List[List[bytes]], rank_cfgs: List[bytes],
                   registry: FunctionRegistry, inter_patterns: bool = True
                   ) -> Tuple[MergeResult, CfgResult]:
    """The full root-side finalization: merge CSTs, remap CFGs, dedupe.

    This is the pure core shared by the SPMD path (``Recorder.finalize``)
    and the simulated-rank drivers in benchmarks/tests.
    """
    merge = merge_csts(rank_csts, registry, inter_patterns=inter_patterns)
    remapped = [remap_grammar(cfg, merge.remaps[r])
                for r, cfg in enumerate(rank_cfgs)]
    cfgs = dedupe_cfgs(remapped)
    return merge, cfgs
