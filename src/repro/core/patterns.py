"""Intra-process I/O pattern recognition (paper Section 3.2.1).

Offsets of repeated calls often follow ``offset_i = i*a + b``.  Recorder
checks, at interception time, whether the current offset extends the active
arithmetic run for this call's *pattern key* (function, thread, handle, and
all non-offset arguments).  If it does, the offset is encoded as the pair
``(a, b)`` so that every call of the run shares one call signature; otherwise
the concrete offset is stored and a new run begins.

Encoding protocol (mirrored exactly by the trace reader):

  i == 0           -> concrete offset ``b`` (starts a run)
  i >= 1, matches  -> ``IterPattern(a, b)`` with ``a = off_1 - off_0``
  mismatch         -> concrete offset, run restarts at i == 0

Calls with multiple OFFSET-role arguments are tracked jointly (a shared run
index with per-component strides), so e.g. ``(offset, whence)`` pairs or
framework step counters compress with the same machinery.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from .encoding import IterPattern


@dataclass
class _RunState:
    index: int                      # how many calls matched this run so far
    base: Tuple[int, ...]           # offsets of call 0
    stride: Optional[Tuple[int, ...]]  # set at call 1


Encoded = Union[int, IterPattern]


class IntraPatternTracker:
    """Per-process tracker; keys must be hashable and derivable by the reader
    from decoded records (it uses the same key function on decoded args)."""

    def __init__(self, enabled: bool = True) -> None:
        self.enabled = enabled
        self._runs: Dict[Any, _RunState] = {}

    def encode(self, key: Any, offsets: Sequence[int]) -> List[Encoded]:
        """Encode the OFFSET-role values of one call."""
        vals = tuple(int(v) for v in offsets)
        if not self.enabled or not vals:
            return list(vals)
        run = self._runs.get(key)
        if run is None:
            self._runs[key] = _RunState(index=1, base=vals, stride=None)
            return list(vals)
        if run.stride is None:
            stride = tuple(v - b for v, b in zip(vals, run.base))
            run.stride = stride
            run.index = 2
            return [IterPattern(a, b) for a, b in zip(stride, run.base)]
        expected = tuple(b + run.index * a for a, b in zip(run.stride, run.base))
        if vals == expected:
            run.index += 1
            return [IterPattern(a, b) for a, b in zip(run.stride, run.base)]
        # run broken: restart
        self._runs[key] = _RunState(index=1, base=vals, stride=None)
        return list(vals)


class IntraPatternDecoder:
    """Reader-side inverse of :class:`IntraPatternTracker`.

    The decoder tracks, per pattern key, the occurrence index of the active
    run and materializes concrete offsets from ``IterPattern`` values.
    """

    def __init__(self) -> None:
        self._runs: Dict[Any, Tuple[int, Tuple]] = {}  # key -> (index, pattern sig)

    def decode(self, key: Any, encoded: Sequence[Encoded]) -> List[int]:
        if not encoded:
            return []
        if not any(isinstance(v, IterPattern) for v in encoded):
            # concrete call: (re)starts a run at index 0
            self._runs[key] = (1, None)
            return [int(v) for v in encoded]  # type: ignore[arg-type]
        patsig = tuple((v.a, v.b) if isinstance(v, IterPattern) else v
                       for v in encoded)
        idx, prev_sig = self._runs.get(key, (1, None))
        if prev_sig is not None and prev_sig == patsig:
            idx += 1
        # else: this is the first encoded call of the run (index 1)
        out: List[int] = []
        for v in encoded:
            if isinstance(v, IterPattern):
                out.append(int(v.b) + idx * int(v.a))
            else:
                out.append(int(v))
        self._runs[key] = (idx, patsig)
        return out


def pattern_key(func_id: int, thread_id: int, handle_ids: Tuple, other_args: Tuple) -> Tuple:
    """The pattern key shared by tracker and decoder."""
    return (func_id, thread_id, handle_ids, other_args)
