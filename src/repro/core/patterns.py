"""Intra-process I/O pattern recognition (paper Section 3.2.1).

Offsets of repeated calls often follow ``offset_i = i*a + b``.  Recorder
checks, at interception time, whether the current offset extends the active
arithmetic run for this call's *pattern key* (function, thread, handle, and
all non-offset arguments).  If it does, the offset is encoded as the pair
``(a, b)`` so that every call of the run shares one call signature; otherwise
the concrete offset is stored and a new run begins.

Encoding protocol (mirrored exactly by the trace reader):

  i == 0           -> concrete offset ``b`` (starts a run)
  i >= 1, matches  -> ``IterPattern(a, b)`` with ``a = off_1 - off_0``
  mismatch         -> concrete offset, run restarts at i == 0

Calls with multiple OFFSET-role arguments are tracked jointly (a shared run
index with per-component strides), so e.g. ``(offset, whence)`` pairs or
framework step counters compress with the same machinery.

``IntraPatternTracker.encode_many`` is the batched entry point: it encodes a
whole sequence of calls for one key at once, finding arithmetic runs with
the shared NumPy segmentation helper (``interprocess.arith_segments``) and
is result- and state-equivalent to calling :meth:`encode` per call.  The
benchmark drivers use it to synthesize large simulated-rank streams.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .encoding import IterPattern
from .interprocess import arith_segments


@dataclass
class _RunState:
    index: int                      # how many calls matched this run so far
    base: Tuple[int, ...]           # offsets of call 0
    stride: Optional[Tuple[int, ...]]  # set at call 1


Encoded = Union[int, IterPattern]


class IntraPatternTracker:
    """Per-process tracker; keys must be hashable and derivable by the reader
    from decoded records (it uses the same key function on decoded args)."""

    def __init__(self, enabled: bool = True) -> None:
        self.enabled = enabled
        self._runs: Dict[Any, _RunState] = {}

    def encode(self, key: Any, offsets: Sequence[int]) -> List[Encoded]:
        """Encode the OFFSET-role values of one call."""
        vals = tuple(int(v) for v in offsets)
        if not self.enabled or not vals:
            return list(vals)
        run = self._runs.get(key)
        if run is None:
            self._runs[key] = _RunState(index=1, base=vals, stride=None)
            return list(vals)
        if run.stride is None:
            stride = tuple(v - b for v, b in zip(vals, run.base))
            run.stride = stride
            run.index = 2
            return [IterPattern(a, b) for a, b in zip(stride, run.base)]
        expected = tuple(b + run.index * a for a, b in zip(run.stride, run.base))
        if vals == expected:
            run.index += 1
            return [IterPattern(a, b) for a, b in zip(run.stride, run.base)]
        # run broken: restart
        self._runs[key] = _RunState(index=1, base=vals, stride=None)
        return list(vals)

    def encode_many(self, key: Any, rows: Sequence[Sequence[int]],
                    backend: Optional[str] = None) -> List[List[Encoded]]:
        """Batched :meth:`encode`: one call per row, vectorized.

        Equivalent (outputs and final run state) to
        ``[self.encode(key, r) for r in rows]``, but arithmetic runs are
        found with one segmentation pass (``backend`` dispatches it:
        NumPy or the grammar_stats boundary kernel) instead of per-call
        Python work.  Falls back to the scalar loop for ragged/empty
        arities or values outside the int64-safe range.
        """
        rows = [tuple(int(v) for v in r) for r in rows]
        if not self.enabled or not rows:
            return [list(r) for r in rows]
        k = len(rows[0])
        if k == 0 or any(len(r) != k for r in rows):
            return [self.encode(key, r) for r in rows]
        try:
            V = np.asarray(rows, dtype=np.int64)
        except (OverflowError, ValueError):
            return [self.encode(key, r) for r in rows]
        if np.abs(V).max(initial=0) >= (1 << 62):
            return [self.encode(key, r) for r in rows]

        out: List[List[Encoded]] = []
        n = len(rows)
        p = 0  # rows consumed by continuing a pre-existing run
        run = self._runs.get(key)
        if run is not None and len(run.base) == k:
            if run.stride is None:
                # second element of the active run: always matches and
                # fixes the stride
                stride = tuple(v - b for v, b in zip(rows[0], run.base))
                run.stride = stride
                run.index = 2
                out.append([IterPattern(a, b)
                            for a, b in zip(stride, run.base)])
                p = 1
            if p < n and run.stride is not None:
                # keep b + i*a exact in int64 (else defer to Python ints)
                bound = (max(abs(v) for v in run.base)
                         + (run.index + n) * max(
                             (abs(a) for a in run.stride), default=0))
                if bound >= (1 << 62):
                    return out + [self.encode(key, r) for r in rows[p:]]
                base = np.asarray(run.base, dtype=np.int64)
                stride = np.asarray(run.stride, dtype=np.int64)
                idx = run.index + np.arange(n - p, dtype=np.int64)
                expected = base[None, :] + idx[:, None] * stride[None, :]
                bad = (V[p:] != expected).any(axis=1)
                m = int(np.argmax(bad)) if bad.any() else n - p
                if m:
                    pat = [IterPattern(a, b)
                           for a, b in zip(run.stride, run.base)]
                    out.extend(list(pat) for _ in range(m))
                    run.index += m
                    p += m
                if p < n:
                    run = None  # run broken: remaining rows start fresh
        elif run is not None:
            # arity changed mid-stream: defer to the scalar protocol
            return out + [self.encode(key, r) for r in rows]

        if p < n:
            W = V[p:]
            segs = arith_segments(W, backend=backend)
            for s, e in segs:
                base = tuple(int(v) for v in W[s])
                out.append(list(base))
                if e - s >= 2:
                    stride = tuple(int(v) for v in (W[s + 1] - W[s]))
                    pat = [IterPattern(a, b) for a, b in zip(stride, base)]
                    out.extend(list(pat) for _ in range(e - s - 1))
                    self._runs[key] = _RunState(index=e - s, base=base,
                                                stride=stride)
                else:
                    self._runs[key] = _RunState(index=1, base=base,
                                                stride=None)
        return out


class IntraPatternDecoder:
    """Reader-side inverse of :class:`IntraPatternTracker`.

    The decoder tracks, per pattern key, the occurrence index of the active
    run and materializes concrete offsets from ``IterPattern`` values.
    """

    def __init__(self) -> None:
        self._runs: Dict[Any, Tuple[int, Tuple]] = {}  # key -> (index, pattern sig)

    def decode(self, key: Any, encoded: Sequence[Encoded]) -> List[int]:
        if not encoded:
            return []
        if not any(isinstance(v, IterPattern) for v in encoded):
            # concrete call: (re)starts a run at index 0
            self._runs[key] = (1, None)
            return [int(v) for v in encoded]  # type: ignore[arg-type]
        patsig = tuple((v.a, v.b) if isinstance(v, IterPattern) else v
                       for v in encoded)
        idx, prev_sig = self._runs.get(key, (1, None))
        if prev_sig is not None and prev_sig == patsig:
            idx += 1
        # else: this is the first encoded call of the run (index 1)
        out: List[int] = []
        for v in encoded:
            if isinstance(v, IterPattern):
                out.append(int(v.b) + idx * int(v.a))
            else:
                out.append(int(v))
        self._runs[key] = (idx, patsig)
        return out


def pattern_key(func_id: int, thread_id: int, handle_ids: Tuple, other_args: Tuple) -> Tuple:
    """The pattern key shared by tracker and decoder."""
    return (func_id, thread_id, handle_ids, other_args)
