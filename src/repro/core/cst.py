"""Call Signature Table (paper Section 3.1).

The CST associates each unique call signature with a terminal symbol.  It is
a hash table keyed on the deterministic signature bytes; values are terminal
ids handed to the Sequitur grammar.
"""

from __future__ import annotations

from typing import Dict, List

from .encoding import read_uvarint, write_uvarint


class CST:
    def __init__(self) -> None:
        self._table: Dict[bytes, int] = {}
        self._entries: List[bytes] = []

    def intern(self, sig: bytes) -> int:
        """Return the terminal for ``sig``, creating a new entry if needed."""
        t = self._table.get(sig)
        if t is None:
            t = len(self._entries)
            self._table[sig] = t
            self._entries.append(sig)
        return t

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def entries(self) -> List[bytes]:
        return self._entries

    def signature(self, terminal: int) -> bytes:
        return self._entries[terminal]

    # serialization ---------------------------------------------------------

    def serialize(self) -> bytes:
        out = bytearray()
        write_uvarint(out, len(self._entries))
        for e in self._entries:
            write_uvarint(out, len(e))
            out.extend(e)
        return bytes(out)

    @classmethod
    def deserialize(cls, buf: bytes) -> "CST":
        cst = cls()
        pos = 0
        n, pos = read_uvarint(buf, pos)
        for _ in range(n):
            ln, pos = read_uvarint(buf, pos)
            cst.intern(bytes(buf[pos : pos + ln]))
            pos += ln
        return cst
