"""Trace reader: lossless reconstruction of per-rank call streams.

Inverts the whole compression pipeline (paper §2.3 notes that CFG/CST traces
need decoding for analysis -- this module and the converters are that
post-processing support):

  CFG index -> unique CFG -> expand grammar -> terminals
  terminal  -> merged CST -> signature bytes -> decode
  RankPattern values      -> resolved with the reader's rank
  IterPattern values      -> resolved with a per-pattern-key run counter
                             (exact mirror of the runtime tracker)
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Iterator, List, Optional, Tuple

import numpy as np

from .encoding import Handle, IterPattern, RankPattern, decode_signature
from .patterns import IntraPatternDecoder
from .sequitur import expand_grammar, parse_grammar
from .timestamps import decompress_timestamps
from .trace_format import read_trace_files


@dataclass
class Record:
    func: str
    layer: str
    args: tuple
    arg_names: tuple
    ret: Any
    thread: int
    depth: int
    t_entry: Optional[int] = None
    t_exit: Optional[int] = None
    roles: tuple = ()

    def arg(self, name: str) -> Any:
        return self.args[self.arg_names.index(name)]


def _resolve_rank(v: Any, rank: int) -> Any:
    if isinstance(v, RankPattern):
        return v.value_for(rank)
    if isinstance(v, IterPattern):
        return IterPattern(_resolve_rank(v.a, rank), _resolve_rank(v.b, rank))
    if isinstance(v, tuple):
        return tuple(_resolve_rank(x, rank) for x in v)
    return v


class TraceReader:
    def __init__(self, trace_dir: str):
        data = read_trace_files(trace_dir)
        self.meta = data["meta"]
        self.merged_cst: List[bytes] = data["merged_cst"]
        self.unique_cfgs = [parse_grammar(c) for c in data["unique_cfgs"]]
        self.cfg_index: List[int] = data["cfg_index"]
        self.rank_ts = data["rank_timestamps"]
        self.functions = {int(k): v for k, v in self.meta["functions"].items()}
        self.nranks = self.meta["nranks"]
        # decode each CST entry once
        self._decoded = [decode_signature(sig) for sig in self.merged_cst]

    def n_records(self, rank: int) -> int:
        total = 0
        for _ in expand_grammar(self.unique_cfgs[self.cfg_index[rank]]):
            total += 1
        return total

    def iter_records(self, rank: int, timestamps: bool = True
                     ) -> Iterator[Record]:
        grammar = self.unique_cfgs[self.cfg_index[rank]]
        decoder = IntraPatternDecoder()
        ts: Optional[np.ndarray] = None
        if timestamps and rank < len(self.rank_ts) and self.rank_ts[rank]:
            ts = decompress_timestamps(self.rank_ts[rank])
        for i, terminal in enumerate(expand_grammar(grammar)):
            func_id, tidx, depth, args, ret = self._decoded[terminal]
            finfo = self.functions[func_id]
            roles = finfo["arg_roles"]
            # resolve rank patterns everywhere
            args = tuple(_resolve_rank(a, rank) for a in args)
            ret = _resolve_rank(ret, rank)
            # resolve iteration patterns on OFFSET-role slots (and returns)
            off_slots = [j for j, r in enumerate(roles) if r == "offset"
                         and j < len(args)]
            ret_is_offset = (finfo["ret_role"] == "offset"
                             and isinstance(ret, (int, IterPattern)))
            if off_slots or ret_is_offset:
                handle_ids: List[int] = []
                keyparts: List[Any] = []
                for j, a in enumerate(args):
                    role = roles[j] if j < len(roles) else "val"
                    if role == "offset":
                        continue
                    if isinstance(a, Handle):
                        handle_ids.append(a.id)
                    else:
                        keyparts.append(a)
                key_ret = None if ret_is_offset else (
                    ("h", ret.id) if isinstance(ret, Handle) else ret)
                key = (func_id, tidx, tuple(handle_ids), tuple(keyparts), key_ret)
                enc = [args[j] for j in off_slots]
                if ret_is_offset:
                    enc.append(ret)
                dec = decoder.decode(key, enc)
                args = list(args)
                for j, v in zip(off_slots, dec):
                    args[j] = v
                args = tuple(args)
                if ret_is_offset:
                    ret = dec[-1]
            t0 = int(ts[i, 0]) if ts is not None else None
            t1 = int(ts[i, 1]) if ts is not None else None
            yield Record(func=finfo["name"], layer=finfo["layer"], args=args,
                         arg_names=tuple(finfo["arg_names"]), ret=ret,
                         thread=tidx, depth=depth, t_entry=t0, t_exit=t1,
                         roles=tuple(roles))

    def all_records(self, timestamps: bool = True) -> Iterator[Tuple[int, Record]]:
        for r in range(self.nranks):
            for rec in self.iter_records(r, timestamps=timestamps):
                yield r, rec
