"""Trace reader: lossless reconstruction of per-rank call streams.

Inverts the whole compression pipeline (paper §2.3 notes that CFG/CST traces
need decoding for analysis -- this module and the converters are that
post-processing support):

  CFG index -> unique CFG -> expand grammar -> terminals
  terminal  -> merged CST -> signature bytes -> decode
  RankPattern values      -> resolved with the reader's rank
  IterPattern values      -> resolved with a per-pattern-key run counter
                             (exact mirror of the runtime tracker)

The record-expansion methods here are thin compatibility shims over
:class:`repro.core.traceview.TraceView` (``self.view()``), which holds the
batch-decoded columns and answers aggregate queries straight from the
compressed representation -- prefer it for analysis work.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Iterator, List, Optional, Tuple

from .encoding import IterPattern, RankPattern
from .sequitur import parse_grammar
from .trace_format import read_trace_files


@dataclass
class Record:
    func: str
    layer: str
    args: tuple
    arg_names: tuple
    ret: Any
    thread: int
    depth: int
    t_entry: Optional[int] = None
    t_exit: Optional[int] = None
    roles: tuple = ()

    def arg(self, name: str) -> Any:
        return self.args[self.arg_names.index(name)]


def _resolve_rank(v: Any, rank: int) -> Any:
    if isinstance(v, RankPattern):
        return v.value_for(rank)
    if isinstance(v, IterPattern):
        return IterPattern(_resolve_rank(v.a, rank), _resolve_rank(v.b, rank))
    if isinstance(v, tuple):
        return tuple(_resolve_rank(x, rank) for x in v)
    return v


class TraceReader:
    def __init__(self, trace_dir: str):
        data = read_trace_files(trace_dir)
        self.meta = data["meta"]
        self.merged_cst: List[bytes] = data["merged_cst"]
        self.unique_cfgs = [parse_grammar(c) for c in data["unique_cfgs"]]
        self.cfg_index: List[int] = data["cfg_index"]
        self.rank_ts = data["rank_timestamps"]
        self.functions = {int(k): v for k, v in self.meta["functions"].items()}
        self.nranks = self.meta["nranks"]
        self._view = None

    def view(self) -> "TraceView":  # noqa: F821  (lazy import below)
        """The compressed-domain columnar query API over this trace
        (:class:`repro.core.traceview.TraceView`), built once, memoized."""
        if self._view is None:
            from .traceview import TraceView
            self._view = TraceView(self)
        return self._view

    def n_records(self, rank: int) -> int:
        """O(|grammar|) record count from rule expansion weights -- the
        seed expand-and-count loop is gone."""
        return self.view().n_records(rank)

    def iter_records(self, rank: int, timestamps: bool = True
                     ) -> Iterator[Record]:
        return self.view().iter_records(rank, timestamps=timestamps)

    def all_records(self, timestamps: bool = True
                    ) -> Iterator[Tuple[int, Record]]:
        return self.view().all_records(timestamps=timestamps)
