"""Trace reader: lossless reconstruction of per-rank call streams.

Inverts the whole compression pipeline (paper §2.3 notes that CFG/CST traces
need decoding for analysis -- this module and the converters are that
post-processing support):

  CFG index -> unique CFG -> expand grammar -> terminals
  terminal  -> merged CST -> signature bytes -> decode
  RankPattern values      -> resolved with the reader's rank
  IterPattern values      -> resolved with a per-pattern-key run counter
                             (exact mirror of the runtime tracker)

The record-expansion methods here are thin compatibility shims over
:class:`repro.core.traceview.TraceView` (``self.view()``), which holds the
batch-decoded columns and answers aggregate queries straight from the
compressed representation -- prefer it for analysis work.

**Streaming traces** (multi-segment directories written by
``Recorder.flush``) open through the same class: committed epoch segments
are stitched into one logical trace (``streaming.stitch_segments``),
value-identical to a one-shot finalize of the same calls.  ``mode``
selects what is read:

  ``auto``      the merged trace when a clean finalize wrote one (and it
                is intact), else the stitched segments; plain single-file
                traces read as before.
  ``stitched``  always stitch the committed segments.
  ``tail``      only the newest committed segment (live monitoring of a
                running job).
  ``merged``    require the merged trace; error if absent/corrupt.

Segments that fail their manifest size check (post-commit truncation) are
skipped and reported in ``self.skipped`` -- the reader still serves every
intact committed epoch.
"""

from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Any, Dict, Iterator, List, Optional, Tuple

from . import streaming, trace_format
from .encoding import IterPattern, RankPattern
from .sequitur import concat_grammars, parse_grammar
from .trace_format import TraceFormatError, read_trace_files


@dataclass
class Record:
    func: str
    layer: str
    args: tuple
    arg_names: tuple
    ret: Any
    thread: int
    depth: int
    t_entry: Optional[int] = None
    t_exit: Optional[int] = None
    roles: tuple = ()

    def arg(self, name: str) -> Any:
        return self.args[self.arg_names.index(name)]


def _resolve_rank(v: Any, rank: int) -> Any:
    if isinstance(v, RankPattern):
        return v.value_for(rank)
    if isinstance(v, IterPattern):
        return IterPattern(_resolve_rank(v.a, rank), _resolve_rank(v.b, rank))
    if isinstance(v, tuple):
        return tuple(_resolve_rank(x, rank) for x in v)
    return v


_MODES = ("auto", "stitched", "tail", "merged")


class TraceReader:
    def __init__(self, trace_dir: str, mode: str = "auto"):
        if mode not in _MODES:
            raise ValueError(f"mode must be one of {_MODES}, got {mode!r}")
        self.mode = mode
        self.trace_dir = trace_dir
        self.skipped: List[Dict[str, str]] = []
        # degraded (rank-failure) epochs this reader serves: segment name ->
        # sorted ranks whose contribution made it into that epoch
        self.degraded_epochs: Dict[str, List[int]] = {}
        self.n_segments = 1
        # refresh bookkeeping: what this reader currently serves
        # ("single" | "merged" | "stitched" | "tail"), the highest epoch
        # number consumed (committed OR skipped), the serialized stitched
        # CFGs (the incremental fold splices new epochs onto them), and
        # the newest segment name a tail reader serves
        self._serving = "single"
        self._epoch_high = -1
        self._unique_bytes: List[bytes] = []
        self._tail_name: Optional[str] = None
        if trace_format.is_stream_dir(trace_dir):
            self._init_stream(trace_dir, mode)
        else:
            if mode != "auto":
                raise TraceFormatError(
                    f"mode {mode!r} needs a streaming trace directory, but "
                    f"{trace_dir!r} is a plain single-segment trace")
            self._init_single(read_trace_files(trace_dir))
        self.functions = {int(k): v for k, v in self.meta["functions"].items()}
        self.nranks = self.meta["nranks"]
        self._view = None

    def _init_single(self, data: Dict[str, Any]) -> None:
        self.meta = data["meta"]
        # a merged trace carries the degraded map in its metadata; a plain
        # single-segment trace has neither key
        self.degraded_epochs = {
            str(k): list(v)
            for k, v in (self.meta.get("degraded_epochs") or {}).items()}
        self.merged_cst: List[bytes] = data["merged_cst"]
        self.unique_cfgs = [parse_grammar(c) for c in data["unique_cfgs"]]
        self.cfg_index: List[int] = data["cfg_index"]
        self.ts_store = streaming.make_ts_store(data)

    def _read_segment(self, trace_dir: str,
                      entry: Dict[str, Any]) -> Optional[Dict[str, Any]]:
        """One manifest entry via :func:`trace_format.load_segment`; on
        failure, record the reason in ``self.skipped`` and return None."""
        data, reason = trace_format.load_segment(trace_dir, entry)
        if data is None:
            self.skipped.append({"segment": entry["name"], "reason": reason})
        return data

    def _init_stream(self, trace_dir: str, mode: str) -> None:
        # decode lazily per mode: `merged` / `tail` open O(1) segments no
        # matter how many epochs the run committed; only a stitched read
        # pays O(total).  The cheap metadata-only version check always runs.
        manifest = trace_format.read_manifest(trace_dir)
        entries = manifest.get("segments", [])
        trace_format.check_segment_versions(trace_dir, entries)
        if entries:
            self._epoch_high = max(e["epoch"] for e in entries)
        merged_entry = manifest.get("merged")
        if mode in ("auto", "merged") and merged_entry is not None:
            reason = trace_format.validate_segment(trace_dir, merged_entry)
            if reason is None:
                try:
                    self._init_single(read_trace_files(
                        os.path.join(trace_dir, merged_entry["name"])))
                    self._serving = "merged"
                    return
                except (TraceFormatError, ValueError, IndexError,
                        OSError) as e:
                    # validate-then-read race: a concurrent writer may pop
                    # and reclaim the stale merged trace while committing
                    # a new epoch -- fall back to the segments
                    reason = (f"{merged_entry['name']} is unreadable: {e}")
            if mode == "merged":
                raise TraceFormatError(
                    f"merged trace of {trace_dir!r} is unusable: {reason}")
            self.skipped.append({"segment": merged_entry["name"],
                                 "reason": reason})
        elif mode == "merged":
            raise TraceFormatError(
                f"{trace_dir!r} has no merged trace (the run was not "
                f"cleanly finalized, or retention pruning disabled it); "
                f"use mode='stitched' for the committed epochs")
        if mode == "tail":
            # newest intact segment: walk backwards, stop at first success
            datas = []
            for entry in reversed(entries):
                data = self._read_segment(trace_dir, entry)
                if data is not None:
                    datas = [data]
                    self._tail_name = entry["name"]
                    if "ranks_present" in entry:
                        self.degraded_epochs[entry["name"]] = \
                            list(entry["ranks_present"])
                    break
        else:
            # full stitch: the one shared definition of "read a stream
            # directory" (trace_format.read_stream_trace) owns the loop
            stream = trace_format.read_stream_trace(trace_dir)
            self.skipped.extend(stream["skipped"])
            datas = [s["data"] for s in stream["segments"]]
            for s in stream["segments"]:
                if "ranks_present" in s["entry"]:
                    self.degraded_epochs[s["entry"]["name"]] = \
                        list(s["entry"]["ranks_present"])
        if not datas:
            raise TraceFormatError(
                f"no intact epoch segments in {trace_dir!r} "
                f"(skipped: {[s['reason'] for s in self.skipped]})")
        st = streaming.stitch_segments(datas)
        self.meta = st["meta"]
        self.merged_cst = st["merged_cst"]
        self._unique_bytes = st["unique_cfgs"]
        self.unique_cfgs = [parse_grammar(c) for c in st["unique_cfgs"]]
        self.cfg_index = st["cfg_index"]
        self.ts_store = st["ts_store"]
        self.n_segments = st["n_segments"]
        self._serving = "tail" if mode == "tail" else "stitched"

    @property
    def degraded(self) -> bool:
        """True when this reader serves PARTIAL coverage: rank-failure
        (degraded) epochs missing some ranks' windows, or committed
        segments skipped for corruption.  Analyses over a degraded trace
        are exact for what is present but not the full job's history."""
        return bool(self.degraded_epochs or self.skipped)

    @property
    def ranks_partial(self) -> List[int]:
        """Ranks absent from at least one served epoch (their record
        streams have gaps where a degraded flush committed without
        them)."""
        out: set = set()
        for present in self.degraded_epochs.values():
            out |= set(range(self.nranks)) - set(present)
        return sorted(out)

    def coverage(self) -> Dict[str, Any]:
        """What this reader actually serves, for tooling and reports:
        degraded epochs (with their present-rank masks), ranks with
        gapped streams, skipped-corrupt segments, and an overall
        ``complete`` verdict."""
        return {
            "mode": self.mode,
            "n_segments": self.n_segments,
            "complete": not self.degraded,
            "degraded_epochs": {k: list(v)
                                for k, v in self.degraded_epochs.items()},
            "ranks_partial": self.ranks_partial,
            "skipped": list(self.skipped),
        }

    def refresh(self) -> int:
        """Fold newly committed epoch segments into this reader WITHOUT
        reconstructing it; returns the number of segments folded.

        The incremental path (stitched serving) is O(delta): only the new
        segments are read and decoded, their CSTs appended, each rank's
        CFG spliced via :func:`sequitur.concat_grammars`, and -- when a
        view had been built -- its per-unique-CFG memos folded forward
        (:func:`traceview.refreshed_view`), so one new epoch costs one
        segment fold, never a rescan of already-loaded segments.  A tail
        reader re-reads only the (one) newest intact segment when it
        changed; an auto reader that had been serving a merged trace
        superseded by new epochs falls back to a full stitched build once.

        Previously handed-out :meth:`view` objects keep serving the
        snapshot they were built from; :meth:`view` after a refresh serves
        the updated trace.  Not safe to call concurrently with attribute
        access on this reader itself -- callers that share a reader across
        threads (the trace service cache) serialize refreshes and query
        the snapshot views.
        """
        if self._serving == "single":
            return 0  # plain single-segment trace: immutable once written
        manifest = trace_format.read_manifest(self.trace_dir)
        entries = manifest.get("segments", [])
        if self._serving == "merged":
            if manifest.get("merged") is not None:
                return 0  # still finalized: the merged trace covers all
            if self.mode == "merged":
                raise TraceFormatError(
                    f"merged trace of {self.trace_dir!r} was superseded by "
                    f"newly committed epochs (the run restarted); reopen "
                    f"with mode='auto' or 'stitched'")
            self._reinit()
            return self.n_segments
        new_entries = [e for e in entries if e["epoch"] > self._epoch_high]
        if not new_entries:
            return 0
        trace_format.check_segment_versions(self.trace_dir, new_entries)
        if self._serving == "tail":
            old_name = self._tail_name
            self._epoch_high = max(e["epoch"] for e in new_entries)
            self._reinit()
            return 0 if self._tail_name == old_name else 1
        folds = []
        for entry in new_entries:
            self._epoch_high = entry["epoch"]
            data = self._read_segment(self.trace_dir, entry)
            if data is None:
                continue  # reported in self.skipped; never retried
            if data["meta"]["nranks"] != self.nranks:
                raise TraceFormatError(
                    f"segment {entry['name']} covers "
                    f"{data['meta']['nranks']} ranks, this reader serves "
                    f"{self.nranks}")
            folds.append(self._fold_segment(entry, data))
        if not folds:
            return 0
        self.functions = {int(k): v
                         for k, v in self.meta["functions"].items()}
        if self._view is not None:
            from .traceview import refreshed_view
            self._view = refreshed_view(self._view, self, folds)
        return len(folds)

    def _fold_segment(self, entry: Dict[str, Any],
                      data: Dict[str, Any]) -> tuple:
        """Splice ONE newly committed segment onto the stitched state.

        Every container is REPLACED, never mutated in place, so views
        built before the fold keep consistent references to the old state.
        Returns the ``(data, toff, pairs, seg_store)`` fold record
        :func:`traceview.refreshed_view` consumes.
        """
        toff = len(self.merged_cst)
        seg_store = streaming.make_ts_store(data)
        pair_table: Dict[tuple, int] = {}
        new_bytes: List[bytes] = []
        new_parsed = []
        pairs: List[tuple] = []
        new_index: List[int] = []
        for r in range(self.nranks):
            key = (self.cfg_index[r], data["cfg_index"][r])
            i = pair_table.get(key)
            if i is None:
                i = len(new_bytes)
                pair_table[key] = i
                cat = concat_grammars(
                    [(self._unique_bytes[key[0]], 0),
                     (data["unique_cfgs"][key[1]], toff)])
                new_bytes.append(cat)
                new_parsed.append(parse_grammar(cat))
                pairs.append(key)
            new_index.append(i)
        self.merged_cst = self.merged_cst + list(data["merged_cst"])
        self._unique_bytes = new_bytes
        self.unique_cfgs = new_parsed
        self.cfg_index = new_index
        self.ts_store = streaming.StitchedTimestampStore(
            list(self.ts_store._stores) + [seg_store])
        meta = dict(data["meta"])  # newest segment: superset function table
        meta["nranks"] = self.nranks
        self.meta = meta
        self.n_segments += 1
        if "ranks_present" in entry:
            self.degraded_epochs = {**self.degraded_epochs,
                                    entry["name"]:
                                        list(entry["ranks_present"])}
        return (data, toff, pairs, seg_store)

    def _reinit(self) -> None:
        """Full re-open in place (tail advance, merged -> stitched
        fallback): cheap for tail (one segment), one-time for the merged
        transition."""
        self.skipped = []
        self.degraded_epochs = {}
        self.n_segments = 1
        self._tail_name = None
        self._init_stream(self.trace_dir, self.mode)
        self.functions = {int(k): v
                         for k, v in self.meta["functions"].items()}
        self.nranks = self.meta["nranks"]
        self._view = None

    def view(self) -> "TraceView":  # noqa: F821  (lazy import below)
        """The compressed-domain columnar query API over this trace
        (:class:`repro.core.traceview.TraceView`), built once, memoized."""
        if self._view is None:
            from .traceview import TraceView
            self._view = TraceView(self)
        return self._view

    def n_records(self, rank: int) -> int:
        """O(|grammar|) record count from rule expansion weights -- the
        seed expand-and-count loop is gone."""
        return self.view().n_records(rank)

    def iter_records(self, rank: int, timestamps: bool = True
                     ) -> Iterator[Record]:
        return self.view().iter_records(rank, timestamps=timestamps)

    def all_records(self, timestamps: bool = True
                    ) -> Iterator[Tuple[int, Record]]:
        return self.view().all_records(timestamps=timestamps)
