"""Timestamp storage (paper §2.2.1).

Recorder stores entry and exit times of every call as 4-byte deltas relative
to the application's start, buffered in memory and compressed with zlib at
finalization.  We store uint32 *microsecond* ticks since recorder init
(wraps at ~71.6 minutes -- fine for the traced phases; the wrap policy is
recorded in metadata).  The compression pipeline is

    ticks -> first-order delta -> zigzag -> little-endian u32 -> zlib

The delta+zigzag stage is the arithmetic hot loop; ``repro.kernels.
delta_encode`` provides the TPU (Pallas) version of it, validated against
the numpy path used here.

**Block-indexed storage** (streaming traces): instead of one zlib blob per
rank, :func:`compress_timestamps_blocked` splits the tick array into
fixed-record blocks, each independently delta+zigzag+zlib encoded and
carrying ``(n_records, t_min, t_max[, n_bytes])`` index metadata.
Time-windowed queries then decompress only the blocks whose
``[t_min, t_max]`` span intersects the window
(:class:`BlockedTimestampStore.window`); the single-blob layout stays
readable through :class:`TimestampStore`, which presents the same
interface with one "block" per rank.  Both stores count
``blocks_touched`` so callers (benchmarks, tests) can assert that windowed
queries really skip untouched blocks.

**Sized blocks** (exact windowed bandwidth): the recorder appends a third
per-record column -- the call's data-transfer byte count (0 for metadata
calls) -- and each block's index entry carries the column's sum.  A
windowed byte query (:meth:`BlockedTimestampStore.window_stats`) then
reads fully-covered blocks straight off the index and decompresses only
the boundary blocks it would have decompressed anyway, making windowed
bandwidth EXACT at the same decompression cost (the old trace-wide
min/max bounds survive only for legacy 2-column traces).

**Tick wrap**: ticks are uint32 microseconds and wrap every ~71.6 minutes.
Per epoch the recorder stores the wrap count of the epoch's first record
(``tick_wraps`` in segment metadata); :func:`unwrap_ticks` rebases a
store's ticks to int64 with that counter and repairs intra-store wraps
from the monotone entry column (a drop of more than 2^31 between
consecutive entries is a wrap, never a reordering -- call durations are
far below 35 minutes), so days-long streamed runs read back monotonic
64-bit timestamps (:meth:`TimestampStore.load_unwrapped`).
"""

from __future__ import annotations

import zlib
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .encoding import read_uvarint, write_uvarint

# records per zlib block in blocked storage (a block holds whole records --
# an (entry, exit) pair never straddles blocks, so per-block [t_min, t_max]
# bounds are exact for call-interval intersection tests)
DEFAULT_BLOCK_RECORDS = 4096


class TimestampBuffer:
    """Append-only (entry, exit, data bytes) tick buffer for one rank.

    The third column is the call's data-transfer size (0 for metadata
    calls), kept out of the legacy single-blob layout (:meth:`as_array`
    stays two-column) but flushed into sized timestamp blocks so windowed
    bandwidth queries are exact without expansion."""

    def __init__(self) -> None:
        self._chunks: List[np.ndarray] = []
        self._cur = np.empty((4096, 3), dtype=np.uint32)
        self._n = 0

    def append(self, t_entry: int, t_exit: int, nbytes: int = 0) -> None:
        if self._n == len(self._cur):
            self._chunks.append(self._cur)
            self._cur = np.empty((4096, 3), dtype=np.uint32)
            self._n = 0
        self._cur[self._n, 0] = t_entry & 0xFFFFFFFF
        self._cur[self._n, 1] = t_exit & 0xFFFFFFFF
        self._cur[self._n, 2] = nbytes & 0xFFFFFFFF
        self._n += 1

    def __len__(self) -> int:
        return sum(len(c) for c in self._chunks) + self._n

    def _full(self) -> np.ndarray:
        parts = self._chunks + [self._cur[: self._n]]
        return np.concatenate(parts, axis=0) if parts \
            else np.empty((0, 3), np.uint32)

    def as_array(self) -> np.ndarray:
        """(n, 2) entry/exit ticks -- the legacy one-shot layout."""
        return self._full()[:, :2]

    def take(self) -> np.ndarray:
        """Snapshot the buffered (n, 3) rows and reset the buffer (epoch
        flush)."""
        arr = self._full()
        self._chunks = []
        self._cur = np.empty((4096, 3), dtype=np.uint32)
        self._n = 0
        return arr


def delta_zigzag_encode(ticks: np.ndarray,
                        backend: Optional[str] = None) -> np.ndarray:
    """Flattened interleaved (entry, exit) stream -> delta -> zigzag u32.

    Deltas are wrapped into signed 32-bit range (mod 2^32) BEFORE zigzag:
    ticks are u32, so a raw delta can need 33 bits; the wrap keeps the
    encoding exactly 4 bytes and the mod-2^32 cumsum decode is lossless.
    (This also matches the Pallas kernel's int32 arithmetic bit-for-bit.)

    ``backend`` selects the python/numpy/pallas implementation (see
    ``encode_backend``); output is bit-identical across all of them.
    """
    flat = ticks.reshape(-1).astype(np.int64)
    if flat.size == 0:
        return np.empty((0,), np.uint32)
    # timestamps are monotone per column but interleaved entry/exit deltas
    # may be negative -> zigzag
    from . import encode_backend as _eb
    return _eb.delta_zigzag(flat, backend)


def delta_zigzag_decode(zz: np.ndarray, ncols: int = 2) -> np.ndarray:
    u = zz.astype(np.int64)
    deltas = (u >> 1) ^ -(u & 1)
    flat = np.cumsum(deltas)          # mod-2^32 recovery via the u32 cast
    return flat.astype(np.uint32).reshape(-1, ncols)


def compress_timestamps(ticks: np.ndarray,
                        backend: Optional[str] = None) -> bytes:
    zz = delta_zigzag_encode(ticks, backend)
    return zlib.compress(zz.astype("<u4").tobytes(), level=6)


def decompress_timestamps(buf: bytes, ncols: int = 2) -> np.ndarray:
    raw = zlib.decompress(buf)
    zz = np.frombuffer(raw, dtype="<u4").astype(np.uint32)
    return delta_zigzag_decode(zz, ncols)


# ---------------------------------------------------------------------------
# block-indexed storage (streaming traces / time-windowed queries)
# ---------------------------------------------------------------------------

# one block: (zlib blob, n_records, t_min, t_max, n_bytes); t_min is the
# earliest entry tick, t_max the latest effective exit tick (a zero exit
# tick falls back to the entry tick, mirroring the seed `or` in the
# analyses); n_bytes is the block's summed data-transfer size, or None for
# blocks encoded from a 2-column (legacy) tick array
TsBlock = Tuple[bytes, int, int, int, Optional[int]]


def effective_exit(ticks: np.ndarray) -> np.ndarray:
    ent = ticks[:, 0].astype(np.int64)
    ext = ticks[:, 1].astype(np.int64)
    return np.where(ext != 0, ext, ent)


def compress_timestamps_blocked(ticks: np.ndarray,
                                block_records: int = DEFAULT_BLOCK_RECORDS,
                                backend: Optional[str] = None
                                ) -> List[TsBlock]:
    """Split ``ticks`` -- (n, 2) entry/exit or (n, 3) with a data-bytes
    column -- into independently-decodable zlib blocks.

    Each block is delta+zigzag encoded from scratch (its first value is
    absolute), so any block decompresses without touching its neighbours.
    Sized (3-column) inputs produce blocks carrying the summed byte
    counter; the column count is recovered at decode time from the block's
    record count.
    """
    if block_records <= 0:
        raise ValueError("block_records must be positive")
    sized = ticks.ndim == 2 and ticks.shape[1] >= 3
    blocks: List[TsBlock] = []
    for s in range(0, len(ticks), block_records):
        blk = ticks[s : s + block_records]
        t_min = int(blk[:, 0].astype(np.int64).min())
        t_max = int(effective_exit(blk).max())
        n_bytes = int(blk[:, 2].astype(np.int64).sum()) if sized else None
        blocks.append((compress_timestamps(blk, backend), len(blk), t_min,
                       t_max, n_bytes))
    return blocks


def pack_ts_blocks(blocks: Sequence[TsBlock]) -> bytes:
    """Stable byte envelope of one rank's block list (tree-hop transport)."""
    out = bytearray()
    write_uvarint(out, len(blocks))
    for blob, n, t_min, t_max, n_bytes in blocks:
        write_uvarint(out, len(blob))
        out.extend(blob)
        write_uvarint(out, n)
        write_uvarint(out, t_min)
        write_uvarint(out, t_max)
        write_uvarint(out, 0 if n_bytes is None else 1)
        if n_bytes is not None:
            write_uvarint(out, n_bytes)
    return bytes(out)


def unpack_ts_blocks(buf: bytes) -> List[TsBlock]:
    pos = 0
    n_blocks, pos = read_uvarint(buf, pos)
    blocks: List[TsBlock] = []
    for _ in range(n_blocks):
        ln, pos = read_uvarint(buf, pos)
        blob = bytes(buf[pos : pos + ln])
        pos += ln
        n, pos = read_uvarint(buf, pos)
        t_min, pos = read_uvarint(buf, pos)
        t_max, pos = read_uvarint(buf, pos)
        has_bytes, pos = read_uvarint(buf, pos)
        n_bytes: Optional[int] = None
        if has_bytes:
            n_bytes, pos = read_uvarint(buf, pos)
        blocks.append((blob, n, t_min, t_max, n_bytes))
    return blocks


def unwrap_ticks(ticks: np.ndarray, base_wraps: int = 0) -> np.ndarray:
    """(n, 2) uint32 ticks -> monotonic int64 microseconds.

    ``base_wraps`` rebases the first entry (the per-epoch ``tick_wraps``
    counter from segment metadata); wraps WITHIN the array are recovered
    from the monotone entry column -- a drop of more than 2^31 between
    consecutive entries can only be a wrap, since real reordering (nested
    calls appended child-first) is bounded by call durations, far below 35
    minutes.  A non-zero exit below its entry wrapped mid-call and is
    bumped one extra period; the zero-exit sentinel is preserved.
    """
    out = np.empty((len(ticks), 2), np.int64)
    if not len(ticks):
        return out
    ent = ticks[:, 0].astype(np.int64)
    ext = ticks[:, 1].astype(np.int64)
    wraps = np.zeros(len(ent), np.int64)
    if len(ent) > 1:
        wraps[1:] = np.cumsum(np.diff(ent) < -(1 << 31))
    off = (base_wraps + wraps) << 32
    out[:, 0] = ent + off
    out[:, 1] = np.where(
        ext == 0, 0,
        ext + off + (((ext != 0) & (ext < ent)).astype(np.int64) << 32))
    return out


def window_rows(ticks: np.ndarray, t0: int, t1: int) -> np.ndarray:
    """Rows whose call interval [entry, effective exit] intersects the
    half-open window [t0, t1) -- the shared filter of every windowed query."""
    ent = ticks[:, 0].astype(np.int64)
    return ticks[(ent < t1) & (effective_exit(ticks) >= t0)]


class TimestampStore:
    """Per-rank timestamp access over the single-blob (legacy) layout.

    One zlib blob per rank == one block per rank: ``window`` still has to
    decompress the whole rank, but the interface (and the
    ``blocks_touched`` counter) is shared with the blocked store so readers
    and views are layout-agnostic.
    """

    def __init__(self, rank_blobs: Sequence[bytes], tick_wraps: int = 0):
        self._blobs = rank_blobs
        self.blocks_touched = 0
        self.tick_wraps = tick_wraps

    def n_blocks(self, rank: int) -> int:
        return 1 if (rank < len(self._blobs) and self._blobs[rank]) else 0

    def load(self, rank: int) -> Optional[np.ndarray]:
        """Full (n, 2) tick array of one rank, or None when absent."""
        blob = self._blobs[rank] if rank < len(self._blobs) else None
        if not blob:
            return None
        self.blocks_touched += 1
        return decompress_timestamps(blob)

    def load_unwrapped(self, rank: int) -> Optional[np.ndarray]:
        """Monotonic int64 (n, 2) microseconds of one rank: the store's
        ``tick_wraps`` base plus heuristic intra-store unwrapping."""
        ts = self.load(rank)
        return None if ts is None else unwrap_ticks(ts, self.tick_wraps)

    def window(self, rank: int, t0: int, t1: int) -> Optional[np.ndarray]:
        """Rows of calls overlapping [t0, t1); decompresses only the blocks
        whose [t_min, t_max] span intersects the window."""
        ts = self.load(rank)
        return None if ts is None else window_rows(ts, t0, t1)

    def window_stats(self, rank: int, t0: int, t1: int
                     ) -> Optional[Tuple[int, Optional[int]]]:
        """(n_calls, n_bytes) of the window; ``n_bytes`` is None when the
        layout carries no per-record sizes (legacy single blob), the whole
        result None when the rank is absent."""
        w = self.window(rank, t0, t1)
        return None if w is None else (len(w), None)


class BlockedTimestampStore(TimestampStore):
    """Block-indexed store: ``index[rank]`` lists ``[offset, length,
    n_records, t_min, t_max]`` (legacy) or ``[..., n_bytes]`` (sized)
    entries into the raw ``timestamps.bin`` bytes; windowed queries
    decompress only intersecting blocks."""

    def __init__(self, raw: bytes, index: Sequence[Sequence[Sequence[int]]],
                 tick_wraps: int = 0,
                 wrap_spans: Optional[Sequence[Sequence[Sequence[int]]]]
                 = None):
        self._raw = raw
        self._index = index
        self.blocks_touched = 0
        self.tick_wraps = tick_wraps
        # merged multi-epoch traces: per rank a list of [n_blocks, wraps]
        # spans -- each source segment's block count with ITS OWN wrap
        # base, so unwrapping stays exact even when consecutive epochs are
        # separated by >= 2 whole wrap periods (undetectable from the tick
        # values alone; see write_merged_trace)
        self._wrap_spans = wrap_spans

    def n_blocks(self, rank: int) -> int:
        return len(self._index[rank]) if rank < len(self._index) else 0

    def _decode_entry(self, e) -> np.ndarray:
        """One block's full column array; the column count (2 legacy, 3
        sized) is recovered from the encoded length / record count."""
        self.blocks_touched += 1
        raw = zlib.decompress(self._raw[e[0] : e[0] + e[1]])
        zz = np.frombuffer(raw, dtype="<u4").astype(np.uint32)
        n = int(e[2])
        ncols = len(zz) // n if n else 2
        return delta_zigzag_decode(zz, ncols)

    def _decompress(self, entries) -> Optional[np.ndarray]:
        if not entries:
            return None
        return np.concatenate([self._decode_entry(e)[:, :2]
                               for e in entries], axis=0)

    def load(self, rank: int) -> Optional[np.ndarray]:
        if rank >= len(self._index):
            return None
        return self._decompress(self._index[rank])

    def load_unwrapped(self, rank: int) -> Optional[np.ndarray]:
        """Monotonic int64 ticks; with per-segment ``wrap_spans`` each
        source epoch's blocks unwrap against that epoch's own recorded
        base (exact across arbitrary inter-epoch gaps), otherwise the
        store-wide base plus intra-array drop detection."""
        spans = self._wrap_spans[rank] \
            if self._wrap_spans is not None and rank < len(self._wrap_spans) \
            else None
        if not spans:
            return super().load_unwrapped(rank)
        entries = self._index[rank] if rank < len(self._index) else []
        parts: List[np.ndarray] = []
        i = 0
        for n_blocks, base in spans:
            sub = entries[i : i + n_blocks]
            i += n_blocks
            if sub:
                parts.append(unwrap_ticks(self._decompress(sub), int(base)))
        if i < len(entries):  # spans out of step with the index: fall back
            tail = self._decompress(entries[i:])
            parts.append(unwrap_ticks(tail, int(spans[-1][1])))
        if not parts:
            return None
        return parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)

    def window(self, rank: int, t0: int, t1: int) -> Optional[np.ndarray]:
        if rank >= len(self._index):
            return None
        entries = [e for e in self._index[rank] if e[3] < t1 and e[4] >= t0]
        if not entries:
            # rank has blocks but none intersect: an empty row set, not None
            return (np.empty((0, 2), np.uint32) if self._index[rank] else None)
        return window_rows(self._decompress(entries), t0, t1)

    def window_stats(self, rank: int, t0: int, t1: int
                     ) -> Optional[Tuple[int, Optional[int]]]:
        """Exact (n_calls, n_bytes) over [t0, t1) at the SAME decompression
        cost as :meth:`window`: blocks whose [t_min, t_max] span lies fully
        inside the window contribute their indexed record count and byte
        counter without decompression (every row of such a block passes the
        interval filter -- entries never exceed effective exits within an
        epoch); only boundary blocks are decoded and filtered row-wise.
        ``n_bytes`` falls back to None when any touched block predates the
        sized layout."""
        if rank >= len(self._index) or not self._index[rank]:
            return None
        n_calls = 0
        n_bytes = 0
        exact = True
        for e in self._index[rank]:
            if not (e[3] < t1 and e[4] >= t0):
                continue
            if t0 <= e[3] and e[4] < t1:  # fully covered: index-only
                n_calls += int(e[2])
                nb = e[5] if len(e) > 5 else None
                if nb is None:
                    exact = False
                else:
                    n_bytes += int(nb)
                continue
            full = self._decode_entry(e)
            keep = (full[:, 0].astype(np.int64) < t1) \
                & (effective_exit(full[:, :2]) >= t0)
            n_calls += int(keep.sum())
            if full.shape[1] >= 3:
                n_bytes += int(full[keep, 2].astype(np.int64).sum())
            else:
                exact = False
        return (n_calls, n_bytes if exact else None)
