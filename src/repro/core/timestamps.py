"""Timestamp storage (paper §2.2.1).

Recorder stores entry and exit times of every call as 4-byte deltas relative
to the application's start, buffered in memory and compressed with zlib at
finalization.  We store uint32 *microsecond* ticks since recorder init
(wraps at ~71.6 minutes -- fine for the traced phases; the wrap policy is
recorded in metadata).  The compression pipeline is

    ticks -> first-order delta -> zigzag -> little-endian u32 -> zlib

The delta+zigzag stage is the arithmetic hot loop; ``repro.kernels.
delta_encode`` provides the TPU (Pallas) version of it, validated against
the numpy path used here.
"""

from __future__ import annotations

import zlib
from typing import List, Tuple

import numpy as np


class TimestampBuffer:
    """Append-only (entry, exit) tick buffer for one rank."""

    def __init__(self) -> None:
        self._chunks: List[np.ndarray] = []
        self._cur = np.empty((4096, 2), dtype=np.uint32)
        self._n = 0

    def append(self, t_entry: int, t_exit: int) -> None:
        if self._n == len(self._cur):
            self._chunks.append(self._cur)
            self._cur = np.empty((4096, 2), dtype=np.uint32)
            self._n = 0
        self._cur[self._n, 0] = t_entry & 0xFFFFFFFF
        self._cur[self._n, 1] = t_exit & 0xFFFFFFFF
        self._n += 1

    def __len__(self) -> int:
        return sum(len(c) for c in self._chunks) + self._n

    def as_array(self) -> np.ndarray:
        parts = self._chunks + [self._cur[: self._n]]
        return np.concatenate(parts, axis=0) if parts else np.empty((0, 2), np.uint32)


def delta_zigzag_encode(ticks: np.ndarray) -> np.ndarray:
    """Flattened interleaved (entry, exit) stream -> delta -> zigzag u32.

    Deltas are wrapped into signed 32-bit range (mod 2^32) BEFORE zigzag:
    ticks are u32, so a raw delta can need 33 bits; the wrap keeps the
    encoding exactly 4 bytes and the mod-2^32 cumsum decode is lossless.
    (This also matches the Pallas kernel's int32 arithmetic bit-for-bit.)
    """
    flat = ticks.reshape(-1).astype(np.int64)
    if flat.size == 0:
        return np.empty((0,), np.uint32)
    deltas = np.empty_like(flat)
    deltas[0] = flat[0]
    # timestamps are monotone per column but interleaved entry/exit deltas
    # may be negative -> zigzag
    deltas[1:] = flat[1:] - flat[:-1]
    deltas = ((deltas + (1 << 31)) % (1 << 32)) - (1 << 31)
    zz = (deltas << 1) ^ (deltas >> 63)
    return (zz & 0xFFFFFFFF).astype(np.uint32)


def delta_zigzag_decode(zz: np.ndarray) -> np.ndarray:
    u = zz.astype(np.int64)
    deltas = (u >> 1) ^ -(u & 1)
    flat = np.cumsum(deltas)          # mod-2^32 recovery via the u32 cast
    return flat.astype(np.uint32).reshape(-1, 2)


def compress_timestamps(ticks: np.ndarray) -> bytes:
    zz = delta_zigzag_encode(ticks)
    return zlib.compress(zz.astype("<u4").tobytes(), level=6)


def decompress_timestamps(buf: bytes) -> np.ndarray:
    raw = zlib.decompress(buf)
    zz = np.frombuffer(raw, dtype="<u4").astype(np.uint32)
    return delta_zigzag_decode(zz)
