"""Timestamp storage (paper §2.2.1).

Recorder stores entry and exit times of every call as 4-byte deltas relative
to the application's start, buffered in memory and compressed with zlib at
finalization.  We store uint32 *microsecond* ticks since recorder init
(wraps at ~71.6 minutes -- fine for the traced phases; the wrap policy is
recorded in metadata).  The compression pipeline is

    ticks -> first-order delta -> zigzag -> little-endian u32 -> zlib

The delta+zigzag stage is the arithmetic hot loop; ``repro.kernels.
delta_encode`` provides the TPU (Pallas) version of it, validated against
the numpy path used here.

**Block-indexed storage** (streaming traces): instead of one zlib blob per
rank, :func:`compress_timestamps_blocked` splits the (n, 2) tick array into
fixed-record blocks, each independently delta+zigzag+zlib encoded and
carrying ``(n_records, t_min, t_max)`` index metadata.  Time-windowed
queries then decompress only the blocks whose ``[t_min, t_max]`` span
intersects the window (:class:`BlockedTimestampStore.window`); the
single-blob layout stays readable through :class:`TimestampStore`, which
presents the same interface with one "block" per rank.  Both stores count
``blocks_touched`` so callers (benchmarks, tests) can assert that windowed
queries really skip untouched blocks.
"""

from __future__ import annotations

import zlib
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .encoding import read_uvarint, write_uvarint

# records per zlib block in blocked storage (a block holds whole records --
# an (entry, exit) pair never straddles blocks, so per-block [t_min, t_max]
# bounds are exact for call-interval intersection tests)
DEFAULT_BLOCK_RECORDS = 4096


class TimestampBuffer:
    """Append-only (entry, exit) tick buffer for one rank."""

    def __init__(self) -> None:
        self._chunks: List[np.ndarray] = []
        self._cur = np.empty((4096, 2), dtype=np.uint32)
        self._n = 0

    def append(self, t_entry: int, t_exit: int) -> None:
        if self._n == len(self._cur):
            self._chunks.append(self._cur)
            self._cur = np.empty((4096, 2), dtype=np.uint32)
            self._n = 0
        self._cur[self._n, 0] = t_entry & 0xFFFFFFFF
        self._cur[self._n, 1] = t_exit & 0xFFFFFFFF
        self._n += 1

    def __len__(self) -> int:
        return sum(len(c) for c in self._chunks) + self._n

    def as_array(self) -> np.ndarray:
        parts = self._chunks + [self._cur[: self._n]]
        return np.concatenate(parts, axis=0) if parts else np.empty((0, 2), np.uint32)

    def take(self) -> np.ndarray:
        """Snapshot the buffered ticks and reset the buffer (epoch flush)."""
        arr = self.as_array()
        self._chunks = []
        self._cur = np.empty((4096, 2), dtype=np.uint32)
        self._n = 0
        return arr


def delta_zigzag_encode(ticks: np.ndarray) -> np.ndarray:
    """Flattened interleaved (entry, exit) stream -> delta -> zigzag u32.

    Deltas are wrapped into signed 32-bit range (mod 2^32) BEFORE zigzag:
    ticks are u32, so a raw delta can need 33 bits; the wrap keeps the
    encoding exactly 4 bytes and the mod-2^32 cumsum decode is lossless.
    (This also matches the Pallas kernel's int32 arithmetic bit-for-bit.)
    """
    flat = ticks.reshape(-1).astype(np.int64)
    if flat.size == 0:
        return np.empty((0,), np.uint32)
    deltas = np.empty_like(flat)
    deltas[0] = flat[0]
    # timestamps are monotone per column but interleaved entry/exit deltas
    # may be negative -> zigzag
    deltas[1:] = flat[1:] - flat[:-1]
    deltas = ((deltas + (1 << 31)) % (1 << 32)) - (1 << 31)
    zz = (deltas << 1) ^ (deltas >> 63)
    return (zz & 0xFFFFFFFF).astype(np.uint32)


def delta_zigzag_decode(zz: np.ndarray) -> np.ndarray:
    u = zz.astype(np.int64)
    deltas = (u >> 1) ^ -(u & 1)
    flat = np.cumsum(deltas)          # mod-2^32 recovery via the u32 cast
    return flat.astype(np.uint32).reshape(-1, 2)


def compress_timestamps(ticks: np.ndarray) -> bytes:
    zz = delta_zigzag_encode(ticks)
    return zlib.compress(zz.astype("<u4").tobytes(), level=6)


def decompress_timestamps(buf: bytes) -> np.ndarray:
    raw = zlib.decompress(buf)
    zz = np.frombuffer(raw, dtype="<u4").astype(np.uint32)
    return delta_zigzag_decode(zz)


# ---------------------------------------------------------------------------
# block-indexed storage (streaming traces / time-windowed queries)
# ---------------------------------------------------------------------------

# one block: (zlib blob, n_records, t_min, t_max); t_min is the earliest
# entry tick, t_max the latest effective exit tick (a zero exit tick falls
# back to the entry tick, mirroring the seed `or` in the analyses)
TsBlock = Tuple[bytes, int, int, int]


def effective_exit(ticks: np.ndarray) -> np.ndarray:
    ent = ticks[:, 0].astype(np.int64)
    ext = ticks[:, 1].astype(np.int64)
    return np.where(ext != 0, ext, ent)


def compress_timestamps_blocked(ticks: np.ndarray,
                                block_records: int = DEFAULT_BLOCK_RECORDS
                                ) -> List[TsBlock]:
    """Split ``ticks`` into independently-decodable zlib blocks.

    Each block is delta+zigzag encoded from scratch (its first value is
    absolute), so any block decompresses without touching its neighbours.
    """
    if block_records <= 0:
        raise ValueError("block_records must be positive")
    blocks: List[TsBlock] = []
    for s in range(0, len(ticks), block_records):
        blk = ticks[s : s + block_records]
        t_min = int(blk[:, 0].astype(np.int64).min())
        t_max = int(effective_exit(blk).max())
        blocks.append((compress_timestamps(blk), len(blk), t_min, t_max))
    return blocks


def pack_ts_blocks(blocks: Sequence[TsBlock]) -> bytes:
    """Stable byte envelope of one rank's block list (tree-hop transport)."""
    out = bytearray()
    write_uvarint(out, len(blocks))
    for blob, n, t_min, t_max in blocks:
        write_uvarint(out, len(blob))
        out.extend(blob)
        write_uvarint(out, n)
        write_uvarint(out, t_min)
        write_uvarint(out, t_max)
    return bytes(out)


def unpack_ts_blocks(buf: bytes) -> List[TsBlock]:
    pos = 0
    n_blocks, pos = read_uvarint(buf, pos)
    blocks: List[TsBlock] = []
    for _ in range(n_blocks):
        ln, pos = read_uvarint(buf, pos)
        blob = bytes(buf[pos : pos + ln])
        pos += ln
        n, pos = read_uvarint(buf, pos)
        t_min, pos = read_uvarint(buf, pos)
        t_max, pos = read_uvarint(buf, pos)
        blocks.append((blob, n, t_min, t_max))
    return blocks


def window_rows(ticks: np.ndarray, t0: int, t1: int) -> np.ndarray:
    """Rows whose call interval [entry, effective exit] intersects the
    half-open window [t0, t1) -- the shared filter of every windowed query."""
    ent = ticks[:, 0].astype(np.int64)
    return ticks[(ent < t1) & (effective_exit(ticks) >= t0)]


class TimestampStore:
    """Per-rank timestamp access over the single-blob (legacy) layout.

    One zlib blob per rank == one block per rank: ``window`` still has to
    decompress the whole rank, but the interface (and the
    ``blocks_touched`` counter) is shared with the blocked store so readers
    and views are layout-agnostic.
    """

    def __init__(self, rank_blobs: Sequence[bytes]):
        self._blobs = rank_blobs
        self.blocks_touched = 0

    def n_blocks(self, rank: int) -> int:
        return 1 if (rank < len(self._blobs) and self._blobs[rank]) else 0

    def load(self, rank: int) -> Optional[np.ndarray]:
        """Full (n, 2) tick array of one rank, or None when absent."""
        blob = self._blobs[rank] if rank < len(self._blobs) else None
        if not blob:
            return None
        self.blocks_touched += 1
        return decompress_timestamps(blob)

    def window(self, rank: int, t0: int, t1: int) -> Optional[np.ndarray]:
        """Rows of calls overlapping [t0, t1); decompresses only the blocks
        whose [t_min, t_max] span intersects the window."""
        ts = self.load(rank)
        return None if ts is None else window_rows(ts, t0, t1)


class BlockedTimestampStore(TimestampStore):
    """Block-indexed store: ``index[rank]`` lists ``[offset, length,
    n_records, t_min, t_max]`` entries into the raw ``timestamps.bin``
    bytes; windowed queries decompress only intersecting blocks."""

    def __init__(self, raw: bytes, index: Sequence[Sequence[Sequence[int]]]):
        self._raw = raw
        self._index = index
        self.blocks_touched = 0

    def n_blocks(self, rank: int) -> int:
        return len(self._index[rank]) if rank < len(self._index) else 0

    def _decompress(self, entries) -> Optional[np.ndarray]:
        if not entries:
            return None
        parts = []
        for off, ln, _n, _t_min, _t_max in entries:
            self.blocks_touched += 1
            parts.append(decompress_timestamps(self._raw[off : off + ln]))
        return np.concatenate(parts, axis=0)

    def load(self, rank: int) -> Optional[np.ndarray]:
        if rank >= len(self._index):
            return None
        return self._decompress(self._index[rank])

    def window(self, rank: int, t0: int, t1: int) -> Optional[np.ndarray]:
        if rank >= len(self._index):
            return None
        entries = [e for e in self._index[rank] if e[3] < t1 and e[4] >= t0]
        if not entries:
            # rank has blocks but none intersect: an empty row set, not None
            return (np.empty((0, 2), np.uint32) if self._index[rank] else None)
        return window_rows(self._decompress(entries), t0, t1)
