"""Deterministic binary encoding for call signatures and trace files.

Recorder stores every function parameter of every intercepted call (paper
Section 2).  Signatures must be *byte-deterministic* so that the Call
Signature Table (CST) can key on them and the inter-process merge can compare
them across ranks.  We use a small tagged varint format rather than a generic
serializer: it is reproducible, compact, and supports the two pattern value
kinds introduced by the compression algorithm (paper Section 3.2):

  * ``IterPattern(a, b)``  -- intra-process offsets following ``i*a + b``
  * ``RankPattern(a, b)``  -- inter-process components following ``rank*a + b``

Pattern components may nest (Fig. 3(c): ``lseek((20, (10, 0)))`` encodes an
iteration stride of 20 whose base is rank-linear ``10*rank + 0``).
"""

from __future__ import annotations

import struct
from dataclasses import dataclass
from typing import Any, Iterable, List, Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# varint / zigzag primitives
# ---------------------------------------------------------------------------


class VarintRangeError(ValueError):
    """A batched uvarint value falls outside [0, 2^64).

    The batched packers (NumPy / Pallas, see ``encode_backend``) operate on
    u64 lanes, so a >64-bit int cannot take the accelerated path.  Callers
    that legitimately carry arbitrary-precision ints (``encode_value`` /
    ``write_svarint`` tagged values) keep using the scalar
    :func:`write_uvarint`, which stays arbitrary-precision."""


_U64_MAX = (1 << 64) - 1


def zigzag(n: int) -> int:
    """Map signed -> unsigned (0,-1,1,-2,... -> 0,1,2,3,...)."""
    return (n << 1) ^ (n >> 63) if -(1 << 63) <= n < (1 << 63) else _zigzag_big(n)


def _zigzag_big(n: int) -> int:
    # arbitrary precision fallback (offsets are < 2^63 in practice)
    return n << 1 if n >= 0 else ((-n) << 1) - 1


def unzigzag(u: int) -> int:
    return (u >> 1) ^ -(u & 1)


def write_uvarint(out: bytearray, u: int) -> None:
    if u < 0:
        raise ValueError("uvarint must be non-negative")
    while True:
        b = u & 0x7F
        u >>= 7
        if u:
            out.append(b | 0x80)
        else:
            out.append(b)
            return


def write_svarint(out: bytearray, n: int) -> None:
    write_uvarint(out, zigzag(n))


def read_uvarint(buf: bytes, pos: int) -> Tuple[int, int]:
    shift = 0
    result = 0
    while True:
        b = buf[pos]
        pos += 1
        result |= (b & 0x7F) << shift
        if not b & 0x80:
            return result, pos
        shift += 7


def read_svarint(buf: bytes, pos: int) -> Tuple[int, int]:
    u, pos = read_uvarint(buf, pos)
    return unzigzag(u), pos


def write_blob(out: bytearray, b: bytes) -> None:
    """Length-prefixed byte string (uvarint length + raw bytes)."""
    write_uvarint(out, len(b))
    out.extend(b)


def read_blob(buf: bytes, pos: int) -> Tuple[bytes, int]:
    n, pos = read_uvarint(buf, pos)
    return bytes(buf[pos : pos + n]), pos + n


def pack_uvarints(values: Iterable[int],
                  backend: Optional[str] = None) -> bytes:
    """Concatenated uvarints of ``values`` (all in [0, 2^64) -- the
    batched backends mirror the kernels' u64 lane width, and the scalar
    path enforces the same bound so every backend agrees; a wider int
    raises :class:`VarintRangeError`).

    Large batches dispatch to the vectorized packers in
    ``encode_backend`` (``backend=None`` -> auto crossover); output is
    byte-identical across backends."""
    if not isinstance(values, (list, tuple)):
        values = list(values)
    from . import encode_backend as _eb
    eff = _eb.resolve(backend, len(values))
    if eff != "python":
        return _eb.pack_uvarints_batch(values, eff)
    out = bytearray()
    for v in values:
        if not 0 <= v <= _U64_MAX:
            raise VarintRangeError(
                f"uvarint batch value outside [0, 2^64): {v!r}")
        write_uvarint(out, v)
    return bytes(out)


def unpack_uvarints(buf: bytes) -> List[int]:
    pos = 0
    out = []
    n = len(buf)
    while pos < n:
        v, pos = read_uvarint(buf, pos)
        out.append(v)
    return out


# ---------------------------------------------------------------------------
# pattern value types (paper Section 3.2)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class IterPattern:
    """Value of the i-th call in a run equals ``i*a + b`` (intra-process)."""

    a: Any  # stride  (int or RankPattern)
    b: Any  # base    (int or RankPattern)


@dataclass(frozen=True)
class RankPattern:
    """Value for rank ``r`` equals ``r*a + b`` (inter-process)."""

    a: int
    b: int

    def value_for(self, rank: int) -> int:
        return rank * self.a + self.b


@dataclass(frozen=True)
class Handle:
    """Unified file-handle id (paper Section 3.2.2: opaque MPI_File handles
    are replaced by a group-wide unique id at open time)."""

    id: int


# value tags
_T_NONE = 0
_T_INT = 1
_T_FLOAT = 2
_T_STR = 3
_T_BYTES = 4
_T_TRUE = 5
_T_FALSE = 6
_T_HANDLE = 7
_T_ITERPAT = 8
_T_RANKPAT = 9
_T_TUPLE = 10
_T_DICT = 11


def encode_value(out: bytearray, v: Any) -> None:
    if v is None:
        out.append(_T_NONE)
    elif v is True:
        out.append(_T_TRUE)
    elif v is False:
        out.append(_T_FALSE)
    elif isinstance(v, int):
        out.append(_T_INT)
        write_svarint(out, v)
    elif isinstance(v, float):
        out.append(_T_FLOAT)
        out.extend(struct.pack("<d", v))
    elif isinstance(v, str):
        raw = v.encode("utf-8")
        out.append(_T_STR)
        write_uvarint(out, len(raw))
        out.extend(raw)
    elif isinstance(v, (bytes, bytearray)):
        out.append(_T_BYTES)
        write_uvarint(out, len(v))
        out.extend(v)
    elif isinstance(v, Handle):
        out.append(_T_HANDLE)
        write_uvarint(out, v.id)
    elif isinstance(v, IterPattern):
        out.append(_T_ITERPAT)
        encode_value(out, v.a)
        encode_value(out, v.b)
    elif isinstance(v, RankPattern):
        out.append(_T_RANKPAT)
        write_svarint(out, v.a)
        write_svarint(out, v.b)
    elif isinstance(v, (tuple, list)):
        out.append(_T_TUPLE)
        write_uvarint(out, len(v))
        for item in v:
            encode_value(out, item)
    elif isinstance(v, dict):
        # insertion-order encoding: deterministic for deterministically
        # built dicts (used by the tree-finalize state serialization)
        out.append(_T_DICT)
        write_uvarint(out, len(v))
        for k, item in v.items():
            encode_value(out, k)
            encode_value(out, item)
    else:
        # last resort: stringified (keeps tracing robust for odd arg types)
        encode_value(out, repr(v))


def decode_value(buf: bytes, pos: int) -> Tuple[Any, int]:
    tag = buf[pos]
    pos += 1
    if tag == _T_NONE:
        return None, pos
    if tag == _T_TRUE:
        return True, pos
    if tag == _T_FALSE:
        return False, pos
    if tag == _T_INT:
        return read_svarint(buf, pos)
    if tag == _T_FLOAT:
        return struct.unpack_from("<d", buf, pos)[0], pos + 8
    if tag == _T_STR:
        n, pos = read_uvarint(buf, pos)
        return buf[pos : pos + n].decode("utf-8"), pos + n
    if tag == _T_BYTES:
        n, pos = read_uvarint(buf, pos)
        return bytes(buf[pos : pos + n]), pos + n
    if tag == _T_HANDLE:
        hid, pos = read_uvarint(buf, pos)
        return Handle(hid), pos
    if tag == _T_ITERPAT:
        a, pos = decode_value(buf, pos)
        b, pos = decode_value(buf, pos)
        return IterPattern(a, b), pos
    if tag == _T_RANKPAT:
        a, pos = read_svarint(buf, pos)
        b, pos = read_svarint(buf, pos)
        return RankPattern(a, b), pos
    if tag == _T_TUPLE:
        n, pos = read_uvarint(buf, pos)
        items = []
        for _ in range(n):
            item, pos = decode_value(buf, pos)
            items.append(item)
        return tuple(items), pos
    if tag == _T_DICT:
        n, pos = read_uvarint(buf, pos)
        d = {}
        for _ in range(n):
            k, pos = decode_value(buf, pos)
            item, pos = decode_value(buf, pos)
            d[k] = item
        return d, pos
    raise ValueError(f"bad value tag {tag} at {pos - 1}")


# ---------------------------------------------------------------------------
# call signatures
# ---------------------------------------------------------------------------


def encode_signature(func_id: int, thread_id: int, depth: int, args: tuple,
                     ret: Any) -> bytes:
    """A call signature is function id + thread id + call depth + all
    arguments + return value (paper Section 3.1)."""
    out = bytearray()
    write_uvarint(out, func_id)
    write_uvarint(out, thread_id)
    write_uvarint(out, depth)
    write_uvarint(out, len(args))
    for a in args:
        encode_value(out, a)
    encode_value(out, ret)
    return bytes(out)


def decode_signature(buf: bytes) -> Tuple[int, int, int, tuple, Any]:
    pos = 0
    func_id, pos = read_uvarint(buf, pos)
    thread_id, pos = read_uvarint(buf, pos)
    depth, pos = read_uvarint(buf, pos)
    nargs, pos = read_uvarint(buf, pos)
    args = []
    for _ in range(nargs):
        v, pos = decode_value(buf, pos)
        args.append(v)
    ret, pos = decode_value(buf, pos)
    if pos != len(buf):
        raise ValueError("trailing bytes in signature")
    return func_id, thread_id, depth, tuple(args), ret


# ---------------------------------------------------------------------------
# batched signature decoding (columnar trace reads)
# ---------------------------------------------------------------------------


def _batch_read_uvarints(buf: np.ndarray, start: np.ndarray, n_fields: int
                         ) -> Tuple[np.ndarray, np.ndarray]:
    """Read ``n_fields`` consecutive uvarints at every position in ``start``.

    Vectorized over the starts: each inner iteration consumes one byte of
    every still-unfinished varint, so the loop depth is the longest varint
    (<= 10 bytes), not the number of signatures.  Returns the decoded
    ``(len(start), n_fields)`` int64 matrix and the positions just past the
    last field.
    """
    pos = start.astype(np.int64).copy()
    out = np.zeros((len(pos), n_fields), dtype=np.int64)
    for f in range(n_fields):
        val = np.zeros(len(pos), dtype=np.int64)
        shift = np.zeros(len(pos), dtype=np.int64)
        active = np.ones(len(pos), dtype=bool)
        while active.any():
            idx = np.flatnonzero(active)
            b = buf[pos[idx]].astype(np.int64)
            val[idx] |= (b & 0x7F) << shift[idx]
            pos[idx] += 1
            shift[idx] += 7
            active[idx[(b & 0x80) == 0]] = False
        out[:, f] = val
    return out, pos


@dataclass
class SignatureColumns:
    """Column-oriented decode of many call signatures (one row per CST
    entry): fixed header fields as NumPy arrays, argument tuples and return
    values as aligned Python lists (they are heterogeneous tagged values,
    possibly nested patterns)."""

    func_id: np.ndarray   # (n,) int64
    thread: np.ndarray    # (n,) int64
    depth: np.ndarray     # (n,) int64
    nargs: np.ndarray     # (n,) int64
    args: List[tuple]
    ret: List[Any]

    def __len__(self) -> int:
        return len(self.args)


def decode_signatures_batch(sigs: Sequence[bytes]) -> SignatureColumns:
    """Decode a whole CST at once into :class:`SignatureColumns`.

    The four header uvarints (func id, thread, depth, argc) of every entry
    are decoded in one vectorized NumPy pass over the concatenated buffer
    (:func:`_batch_read_uvarints`); the tagged argument/return values --
    variable arity, nestable patterns -- are decoded per entry from where
    the header pass stopped.  Result-identical to mapping
    :func:`decode_signature` over ``sigs``.
    """
    n = len(sigs)
    if n == 0:
        z = np.zeros(0, dtype=np.int64)
        return SignatureColumns(z, z.copy(), z.copy(), z.copy(), [], [])
    lens = np.fromiter((len(s) for s in sigs), dtype=np.int64, count=n)
    starts = np.zeros(n, dtype=np.int64)
    starts[1:] = np.cumsum(lens)[:-1]
    buf = np.frombuffer(b"".join(sigs), dtype=np.uint8)
    heads, pos = _batch_read_uvarints(buf, starts, 4)
    args_col: List[tuple] = []
    ret_col: List[Any] = []
    for i, sig in enumerate(sigs):
        p = int(pos[i] - starts[i])
        args = []
        for _ in range(int(heads[i, 3])):
            v, p = decode_value(sig, p)
            args.append(v)
        ret, p = decode_value(sig, p)
        if p != len(sig):
            raise ValueError("trailing bytes in signature")
        args_col.append(tuple(args))
        ret_col.append(ret)
    return SignatureColumns(func_id=heads[:, 0].copy(),
                            thread=heads[:, 1].copy(),
                            depth=heads[:, 2].copy(),
                            nargs=heads[:, 3].copy(),
                            args=args_col, ret=ret_col)


def concat_signature_columns(a: SignatureColumns,
                             b: SignatureColumns) -> SignatureColumns:
    """Row-wise concatenation of two column sets (incremental reader
    refresh: the already-decoded prefix is reused, only the new segments'
    entries are decoded and appended).  Equal to decoding the concatenated
    signature list in one shot."""
    return SignatureColumns(
        func_id=np.concatenate([a.func_id, b.func_id]),
        thread=np.concatenate([a.thread, b.thread]),
        depth=np.concatenate([a.depth, b.depth]),
        nargs=np.concatenate([a.nargs, b.nargs]),
        args=a.args + b.args,
        ret=a.ret + b.ret)
