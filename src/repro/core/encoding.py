"""Deterministic binary encoding for call signatures and trace files.

Recorder stores every function parameter of every intercepted call (paper
Section 2).  Signatures must be *byte-deterministic* so that the Call
Signature Table (CST) can key on them and the inter-process merge can compare
them across ranks.  We use a small tagged varint format rather than a generic
serializer: it is reproducible, compact, and supports the two pattern value
kinds introduced by the compression algorithm (paper Section 3.2):

  * ``IterPattern(a, b)``  -- intra-process offsets following ``i*a + b``
  * ``RankPattern(a, b)``  -- inter-process components following ``rank*a + b``

Pattern components may nest (Fig. 3(c): ``lseek((20, (10, 0)))`` encodes an
iteration stride of 20 whose base is rank-linear ``10*rank + 0``).
"""

from __future__ import annotations

import struct
from dataclasses import dataclass
from typing import Any, Iterable, List, Tuple

# ---------------------------------------------------------------------------
# varint / zigzag primitives
# ---------------------------------------------------------------------------


def zigzag(n: int) -> int:
    """Map signed -> unsigned (0,-1,1,-2,... -> 0,1,2,3,...)."""
    return (n << 1) ^ (n >> 63) if -(1 << 63) <= n < (1 << 63) else _zigzag_big(n)


def _zigzag_big(n: int) -> int:
    # arbitrary precision fallback (offsets are < 2^63 in practice)
    return n << 1 if n >= 0 else ((-n) << 1) - 1


def unzigzag(u: int) -> int:
    return (u >> 1) ^ -(u & 1)


def write_uvarint(out: bytearray, u: int) -> None:
    if u < 0:
        raise ValueError("uvarint must be non-negative")
    while True:
        b = u & 0x7F
        u >>= 7
        if u:
            out.append(b | 0x80)
        else:
            out.append(b)
            return


def write_svarint(out: bytearray, n: int) -> None:
    write_uvarint(out, zigzag(n))


def read_uvarint(buf: bytes, pos: int) -> Tuple[int, int]:
    shift = 0
    result = 0
    while True:
        b = buf[pos]
        pos += 1
        result |= (b & 0x7F) << shift
        if not b & 0x80:
            return result, pos
        shift += 7


def read_svarint(buf: bytes, pos: int) -> Tuple[int, int]:
    u, pos = read_uvarint(buf, pos)
    return unzigzag(u), pos


def write_blob(out: bytearray, b: bytes) -> None:
    """Length-prefixed byte string (uvarint length + raw bytes)."""
    write_uvarint(out, len(b))
    out.extend(b)


def read_blob(buf: bytes, pos: int) -> Tuple[bytes, int]:
    n, pos = read_uvarint(buf, pos)
    return bytes(buf[pos : pos + n]), pos + n


def pack_uvarints(values: Iterable[int]) -> bytes:
    out = bytearray()
    for v in values:
        write_uvarint(out, v)
    return bytes(out)


def unpack_uvarints(buf: bytes) -> List[int]:
    pos = 0
    out = []
    n = len(buf)
    while pos < n:
        v, pos = read_uvarint(buf, pos)
        out.append(v)
    return out


# ---------------------------------------------------------------------------
# pattern value types (paper Section 3.2)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class IterPattern:
    """Value of the i-th call in a run equals ``i*a + b`` (intra-process)."""

    a: Any  # stride  (int or RankPattern)
    b: Any  # base    (int or RankPattern)


@dataclass(frozen=True)
class RankPattern:
    """Value for rank ``r`` equals ``r*a + b`` (inter-process)."""

    a: int
    b: int

    def value_for(self, rank: int) -> int:
        return rank * self.a + self.b


@dataclass(frozen=True)
class Handle:
    """Unified file-handle id (paper Section 3.2.2: opaque MPI_File handles
    are replaced by a group-wide unique id at open time)."""

    id: int


# value tags
_T_NONE = 0
_T_INT = 1
_T_FLOAT = 2
_T_STR = 3
_T_BYTES = 4
_T_TRUE = 5
_T_FALSE = 6
_T_HANDLE = 7
_T_ITERPAT = 8
_T_RANKPAT = 9
_T_TUPLE = 10
_T_DICT = 11


def encode_value(out: bytearray, v: Any) -> None:
    if v is None:
        out.append(_T_NONE)
    elif v is True:
        out.append(_T_TRUE)
    elif v is False:
        out.append(_T_FALSE)
    elif isinstance(v, int):
        out.append(_T_INT)
        write_svarint(out, v)
    elif isinstance(v, float):
        out.append(_T_FLOAT)
        out.extend(struct.pack("<d", v))
    elif isinstance(v, str):
        raw = v.encode("utf-8")
        out.append(_T_STR)
        write_uvarint(out, len(raw))
        out.extend(raw)
    elif isinstance(v, (bytes, bytearray)):
        out.append(_T_BYTES)
        write_uvarint(out, len(v))
        out.extend(v)
    elif isinstance(v, Handle):
        out.append(_T_HANDLE)
        write_uvarint(out, v.id)
    elif isinstance(v, IterPattern):
        out.append(_T_ITERPAT)
        encode_value(out, v.a)
        encode_value(out, v.b)
    elif isinstance(v, RankPattern):
        out.append(_T_RANKPAT)
        write_svarint(out, v.a)
        write_svarint(out, v.b)
    elif isinstance(v, (tuple, list)):
        out.append(_T_TUPLE)
        write_uvarint(out, len(v))
        for item in v:
            encode_value(out, item)
    elif isinstance(v, dict):
        # insertion-order encoding: deterministic for deterministically
        # built dicts (used by the tree-finalize state serialization)
        out.append(_T_DICT)
        write_uvarint(out, len(v))
        for k, item in v.items():
            encode_value(out, k)
            encode_value(out, item)
    else:
        # last resort: stringified (keeps tracing robust for odd arg types)
        encode_value(out, repr(v))


def decode_value(buf: bytes, pos: int) -> Tuple[Any, int]:
    tag = buf[pos]
    pos += 1
    if tag == _T_NONE:
        return None, pos
    if tag == _T_TRUE:
        return True, pos
    if tag == _T_FALSE:
        return False, pos
    if tag == _T_INT:
        return read_svarint(buf, pos)
    if tag == _T_FLOAT:
        return struct.unpack_from("<d", buf, pos)[0], pos + 8
    if tag == _T_STR:
        n, pos = read_uvarint(buf, pos)
        return buf[pos : pos + n].decode("utf-8"), pos + n
    if tag == _T_BYTES:
        n, pos = read_uvarint(buf, pos)
        return bytes(buf[pos : pos + n]), pos + n
    if tag == _T_HANDLE:
        hid, pos = read_uvarint(buf, pos)
        return Handle(hid), pos
    if tag == _T_ITERPAT:
        a, pos = decode_value(buf, pos)
        b, pos = decode_value(buf, pos)
        return IterPattern(a, b), pos
    if tag == _T_RANKPAT:
        a, pos = read_svarint(buf, pos)
        b, pos = read_svarint(buf, pos)
        return RankPattern(a, b), pos
    if tag == _T_TUPLE:
        n, pos = read_uvarint(buf, pos)
        items = []
        for _ in range(n):
            item, pos = decode_value(buf, pos)
            items.append(item)
        return tuple(items), pos
    if tag == _T_DICT:
        n, pos = read_uvarint(buf, pos)
        d = {}
        for _ in range(n):
            k, pos = decode_value(buf, pos)
            item, pos = decode_value(buf, pos)
            d[k] = item
        return d, pos
    raise ValueError(f"bad value tag {tag} at {pos - 1}")


# ---------------------------------------------------------------------------
# call signatures
# ---------------------------------------------------------------------------


def encode_signature(func_id: int, thread_id: int, depth: int, args: tuple,
                     ret: Any) -> bytes:
    """A call signature is function id + thread id + call depth + all
    arguments + return value (paper Section 3.1)."""
    out = bytearray()
    write_uvarint(out, func_id)
    write_uvarint(out, thread_id)
    write_uvarint(out, depth)
    write_uvarint(out, len(args))
    for a in args:
        encode_value(out, a)
    encode_value(out, ret)
    return bytes(out)


def decode_signature(buf: bytes) -> Tuple[int, int, int, tuple, Any]:
    pos = 0
    func_id, pos = read_uvarint(buf, pos)
    thread_id, pos = read_uvarint(buf, pos)
    depth, pos = read_uvarint(buf, pos)
    nargs, pos = read_uvarint(buf, pos)
    args = []
    for _ in range(nargs):
        v, pos = decode_value(buf, pos)
        args.append(v)
    ret, pos = decode_value(buf, pos)
    if pos != len(buf):
        raise ValueError("trailing bytes in signature")
    return func_id, thread_id, depth, tuple(args), ret
