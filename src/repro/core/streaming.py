"""Streaming trace subsystem: epoch flushes and incremental finalize.

The one-shot pipeline (record -> ``Recorder.finalize`` at exit) gives a
long-running job no trace at all if it is preempted mid-run.  This module
adds **run-while-tracing** durability on top of the paper's compression
machinery (the mergeable :class:`~repro.core.interprocess.RankState`s of
Section 3.2.2/3.3):

``Recorder.flush`` (a collective)
    snapshots every rank's live CST/CFG/timestamp state into an **epoch
    delta** without stopping tracing, reduces ONLY that delta across ranks
    through ``Comm.reduce_tree`` (O(log N) rounds over serialized states),
    and commits one crash-durable **epoch segment** -- a complete five-file
    mini trace of the flush window, plus the epoch's serialized cross-rank
    state (``state.bin``).  Per-rank timestamp payloads ride the same
    reduction tree (``Comm.gather_tree``) as block-indexed zlib blocks, so
    rank 0 never absorbs ``size`` simultaneous messages.

:class:`CumulativeState` (incremental finalize)
    rank 0 folds each epoch's reduced delta into a running cross-epoch
    state in **O(delta)** -- groups are inserted into one mutable dict and
    per-rank terminal streams are kept as lists of epoch parts whose
    concatenation is deferred to :meth:`CumulativeState.to_rank_state`.  A
    clean ``finalize`` therefore materializes the full merged trace from
    the already-merged state instead of re-reducing the whole history
    (``merged/`` in the trace directory).  The pure reference semantics
    live in :func:`interprocess.append_epoch_state`; the two are
    property-tested to produce identical states.

Multi-segment trace directory (``trace_format`` streaming layout)
    ``manifest.json`` lists committed segments with per-file byte sizes;
    segments are written under ``.tmp`` names and committed by atomic
    rename + atomic manifest rewrite, so a crash can never expose a
    half-written segment, and post-commit corruption (truncation) is
    detected from the recorded sizes and the segment skipped on read.

:func:`stitch_segments` (the read side)
    concatenates committed segments back into ONE logical trace: merged
    CSTs are concatenated (per-segment terminal offsets), per-rank CFGs
    are spliced with :func:`sequitur.concat_grammars` (expansion ==
    concatenation of the epochs' streams), and timestamps are served by a
    :class:`StitchedTimestampStore` over the per-segment block indexes --
    so every existing ``TraceView`` query runs unchanged on a streaming
    trace, value-identical to a one-shot finalize of the same calls
    (property-tested in ``tests/test_streaming.py``).
"""

from __future__ import annotations

import os
import shutil
import warnings
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import faults, trace_format
from .comm import CommTimeout
from .interprocess import (CfgResult, MergeResult, RankState,
                           deserialize_rank_state, epoch_occ_counts,
                           make_rank_state, materialize_state,
                           merge_serialized_states, serialize_rank_state)
from .sequitur import Sequitur, concat_grammars, parse_grammar, terminal_counts
from .specs import FunctionRegistry
from .timestamps import (BlockedTimestampStore, TimestampStore, TsBlock,
                         compress_timestamps_blocked, pack_ts_blocks,
                         unpack_ts_blocks)

MERGED_DIR = "merged"


# ---------------------------------------------------------------------------
# incremental cross-epoch accumulation (rank 0)
# ---------------------------------------------------------------------------


class CumulativeState:
    """O(delta)-per-epoch accumulator of reduced epoch states.

    Semantically equivalent to folding epochs through the pure reference
    :func:`interprocess.append_epoch_state` (the two produce byte-identical
    serialized states), but built for streaming: ``append`` never rescans
    earlier epochs.  Groups land in one mutable dict keyed by
    occurrence-shifted ``(masked signature, occ)``; per-rank terminal
    streams are kept as sequences of deduplicated **epoch parts** and only
    concatenated (grammars via :func:`sequitur.concat_grammars`) when
    :meth:`to_rank_state` materializes the final merged state.
    """

    def __init__(self) -> None:
        self.base: Optional[int] = None
        self.n: Optional[int] = None
        self.groups: Dict[Tuple[bytes, int], Any] = {}
        self.occ_counts: Dict[bytes, int] = {}
        # unique (cfg bytes, occurrence-shifted row gkeys) epoch stream parts
        self.parts: List[Tuple[bytes, tuple]] = []
        self.rank_parts: List[List[int]] = []  # per local rank: part indices
        self.n_epochs = 0

    def append(self, delta: RankState) -> None:
        """Fold one epoch's cross-rank reduced state in.  O(delta groups +
        delta stream rows + nranks); ``delta`` is absorbed."""
        if self.n is None:
            self.base, self.n = delta.base, delta.n
            self.rank_parts = [[] for _ in range(delta.n)]
        elif (self.base, self.n) != (delta.base, delta.n):
            raise ValueError(
                f"epoch covers ranks [{delta.base},{delta.base + delta.n}), "
                f"cumulative state covers [{self.base},{self.base + self.n})")
        occ = self.occ_counts
        key_map: Dict[Tuple[bytes, int], Tuple[bytes, int]] = {}
        for (mkey, j), g in delta.groups.items():
            nk = (mkey, occ.get(mkey, 0) + j)
            key_map[(mkey, j)] = nk
            self.groups[nk] = g
        for mkey, cnt in epoch_occ_counts(delta).items():
            occ[mkey] = occ.get(mkey, 0) + cnt
        part_of = []
        for cfg_e, rows_e in delta.streams:
            part_of.append(len(self.parts))
            self.parts.append((cfg_e, tuple(key_map[k] for k in rows_e)))
        for j, si in enumerate(delta.stream_of):
            self.rank_parts[j].append(part_of[si])
        self.n_epochs += 1

    def to_rank_state(self) -> RankState:
        """Materialize the cross-epoch merged state (O(total), finalize
        only): per rank, splice its epoch parts into one stream.  Ranks
        sharing the same part sequence share one stitched stream, so SPMD
        workloads still cost one concatenation, not N."""
        if self.n is None:
            raise ValueError("no epochs appended")
        streams: List[Tuple[bytes, tuple]] = []
        table: Dict[tuple, int] = {}
        stream_of: List[int] = []
        for j in range(self.n):
            combo = tuple(self.rank_parts[j])
            si = table.get(combo)
            if si is None:
                rows: List[Tuple[bytes, int]] = []
                gparts: List[Tuple[bytes, int]] = []
                for pi in combo:
                    cfg_e, rows_e = self.parts[pi]
                    gparts.append((cfg_e, len(rows)))
                    rows.extend(rows_e)
                si = len(streams)
                table[combo] = si
                streams.append((concat_grammars(gparts), tuple(rows)))
            stream_of.append(si)
        return RankState(base=self.base, n=self.n, groups=dict(self.groups),
                         streams=streams, stream_of=stream_of)


# ---------------------------------------------------------------------------
# segment commit + manifest maintenance (rank 0)
# ---------------------------------------------------------------------------


def _load_or_init_manifest(trace_dir: str, nranks: int) -> Dict[str, Any]:
    if trace_format.is_stream_dir(trace_dir):
        return trace_format.read_manifest(trace_dir)
    return {"format_version": trace_format.FORMAT_VERSION,
            "nranks": nranks, "segments": []}


def write_epoch_segment(trace_dir: str, epoch: int, *,
                        registry: FunctionRegistry, merge: MergeResult,
                        cfgs: CfgResult,
                        rank_ts_blocks: List[Sequence[TsBlock]],
                        state_blob: bytes, n_records: int,
                        meta_extra: Optional[Dict[str, Any]] = None,
                        ranks_present: Optional[List[int]] = None
                        ) -> Dict[str, Any]:
    """Commit one epoch segment: write the five-file mini trace plus
    ``state.bin`` under a ``.tmp`` name, atomically rename it in, then
    atomically rewrite the manifest with the segment's file sizes and
    CRC32 checksums (the crash-recovery and bit-rot ground truth).
    Returns the manifest entry.

    A failed write (ENOSPC and friends) removes the ``.tmp`` staging
    directory and raises :class:`trace_format.SegmentWriteError` -- the
    trace directory is left exactly as it was.  (A hard crash mid-write
    still leaves ``.tmp`` debris; the next attempt sweeps it.)

    A restarted job may reuse the trace directory of a preempted run: the
    committed epoch number always continues past the manifest's newest
    segment (whatever the caller's local counter says), so run B's epochs
    append after run A's instead of colliding with them, and any stale
    ``merged`` trace (it no longer covers every epoch) is dropped from the
    manifest before the new segment becomes visible.

    ``ranks_present`` marks a *degraded* commit: the sorted ranks whose
    contributions made it into the epoch.  It is recorded in the manifest
    entry (and segment metadata) only when partial, so readers can report
    exactly which ranks' windows are missing.
    """
    os.makedirs(trace_dir, exist_ok=True)
    manifest = _load_or_init_manifest(trace_dir, len(cfgs.cfg_index))
    segments = manifest.get("segments", [])
    if segments:
        epoch = max(epoch, max(e["epoch"] for e in segments) + 1)
    name = trace_format.segment_name(epoch)
    tmp = os.path.join(trace_dir, name + ".tmp")
    if os.path.exists(tmp):  # debris from a crashed earlier attempt
        shutil.rmtree(tmp)
    partial = (ranks_present is not None
               and len(ranks_present) < len(cfgs.cfg_index))
    if partial:
        meta_extra = {**(meta_extra or {}),
                      "ranks_present": list(ranks_present)}
    try:
        sizes, crcs = trace_format.write_trace(
            tmp, registry=registry, merged_cst=merge.merged_entries,
            unique_cfgs=cfgs.unique_cfgs, cfg_index=cfgs.cfg_index,
            rank_ts_blocks=rank_ts_blocks, meta_extra=meta_extra,
            checksums=True)
        crcs[trace_format.STATE_FILE] = trace_format.write_file(
            os.path.join(tmp, trace_format.STATE_FILE), state_blob)
        sizes[trace_format.STATE_FILE] = len(state_blob)
    except Exception as e:
        # a clean failure (not a crash): leave no debris behind and report
        # a typed error -- SimulatedCrash is a BaseException and skips this,
        # leaving .tmp exactly as a real kill would
        shutil.rmtree(tmp, ignore_errors=True)
        raise trace_format.SegmentWriteError(
            f"failed to write epoch segment {name!r} in {trace_dir!r}: "
            f"{e}") from e
    plan = faults.get_active()
    if plan is not None:
        plan.on_commit_point("pre-rename", epoch)
    final = os.path.join(trace_dir, name)
    if os.path.exists(final):
        # an orphan not listed in the manifest (e.g. pruned entry whose
        # directory removal failed); no reader can reference it
        shutil.rmtree(final)
    os.replace(tmp, final)
    entry = {"name": name, "epoch": epoch, "n_records": n_records,
             "cst_entries": len(merge.merged_entries), "files": sizes,
             "crcs": crcs}
    if partial:
        entry["ranks_present"] = list(ranks_present)
    manifest["segments"] = segments + [entry]
    if plan is not None:
        plan.on_commit_point("pre-manifest", epoch)
    stale_merged = manifest.pop("merged", None)  # no longer covers all epochs
    trace_format.write_manifest(trace_dir, manifest)
    if stale_merged is not None:
        # unlisted above (manifest first, so no reader holds an entry for
        # it); now reclaim the stale directory instead of leaking it
        shutil.rmtree(os.path.join(trace_dir, stale_merged["name"]),
                      ignore_errors=True)
    if plan is not None:
        plan.on_commit_point("post-commit", epoch)
    return entry


def prune_epochs(trace_dir: str, keep: int) -> List[str]:
    """Retention ring for live monitoring: keep only the newest ``keep``
    committed segments.  The manifest is rewritten BEFORE directories are
    deleted, so a reader never sees a listed-but-missing segment; returns
    the dropped segment names."""
    if keep <= 0:
        raise ValueError("keep must be positive")
    manifest = trace_format.read_manifest(trace_dir)
    segs = manifest.get("segments", [])
    if len(segs) <= keep:
        return []
    drop, manifest["segments"] = segs[:-keep], segs[-keep:]
    trace_format.write_manifest(trace_dir, manifest)
    for e in drop:
        shutil.rmtree(os.path.join(trace_dir, e["name"]), ignore_errors=True)
    return [e["name"] for e in drop]


# ---------------------------------------------------------------------------
# crash-resume: rebuild rank 0's cumulative state from committed segments
# ---------------------------------------------------------------------------


def resume_cumulative_state(trace_dir: str) -> CumulativeState:
    """Rebuild the cross-epoch :class:`CumulativeState` of a preempted run
    by folding the committed segments' ``state.bin`` deltas in epoch order
    -- the crash-resume path: a restarted job that reuses its trace
    directory keeps appending epochs AND still gets a clean-finalize
    ``merged/`` covering the FULL history, instead of permanently losing
    the incremental-finalize payoff.

    O(sum of delta sizes), state blobs only -- no CST/CFG/timestamp decode.
    Raises :class:`trace_format.TraceFormatError` when any committed
    segment is unusable (failed checksum, truncation, missing state): a
    merged trace must cover every epoch exactly, so the caller falls back
    to a fresh state (stitched reads still serve the intact segments).
    """
    cum = CumulativeState()
    manifest = trace_format.read_manifest(trace_dir)
    for entry in manifest.get("segments", []):
        reason = trace_format.validate_segment(trace_dir, entry)
        if reason is not None:
            raise trace_format.TraceFormatError(
                f"cannot resume cumulative state from {trace_dir!r}: "
                f"{reason}")
        path = os.path.join(trace_dir, entry["name"],
                            trace_format.STATE_FILE)
        try:
            with open(path, "rb") as f:
                blob = f.read()
            delta = deserialize_rank_state(blob)
        except (OSError, ValueError, IndexError) as e:
            raise trace_format.TraceFormatError(
                f"cannot resume cumulative state from {trace_dir!r}: "
                f"{entry['name']}/state.bin is unreadable: {e}") from e
        cum.append(delta)
    return cum


# ---------------------------------------------------------------------------
# the collective flush (called by Recorder.flush on every rank)
# ---------------------------------------------------------------------------


def run_flush(comm, *, entries: List[bytes], cfg: bytes, ticks: np.ndarray,
              registry: FunctionRegistry, trace_dir: str, epoch: int,
              cum: CumulativeState, inter_patterns: bool = True,
              ts_block_records: int = 4096,
              max_epochs_retained: Optional[int] = None,
              meta_extra: Optional[Dict[str, Any]] = None,
              encode_backend: Optional[str] = None
              ) -> Optional[Dict[str, Any]]:
    """One epoch flush over ``comm``.  Every rank contributes its delta
    (local CST entries, serialized CFG, raw ticks); rank 0 folds the
    reduced delta into ``cum``, commits the segment and returns its
    manifest entry (other ranks return None).  Collective: all ranks must
    call it in the same order."""
    leaf = make_rank_state(comm.rank, entries, cfg, registry)
    blob = comm.reduce_tree(serialize_rank_state(leaf),
                            merge_serialized_states)
    blocks = compress_timestamps_blocked(ticks, ts_block_records,
                                         backend=encode_backend) \
        if len(ticks) else []
    packed = comm.gather_tree(pack_ts_blocks(blocks))
    if comm.rank != 0:
        comm.barrier()
        return None
    delta = deserialize_rank_state(blob)
    # records per unique stream from grammar expansion weights (O(|grammar|)
    # each), summed over ranks by stream multiplicity
    per_stream = [sum(terminal_counts(parse_grammar(cfg_e)).values())
                  for cfg_e, _rows in delta.streams]
    n_records = sum(per_stream[si] for si in delta.stream_of)
    merge, cfgs = materialize_state(delta, inter_patterns=inter_patterns)
    entry = write_epoch_segment(
        trace_dir, epoch, registry=registry, merge=merge, cfgs=cfgs,
        rank_ts_blocks=[unpack_ts_blocks(p) for p in packed],
        state_blob=blob, n_records=n_records, meta_extra=meta_extra)
    # fold into the cumulative state only after the segment committed, so a
    # failed write never desyncs the in-memory state from the directory
    # (the epoch's records are lost either way -- they were snapshotted out
    # of the live recorder -- but every later flush and the final merged
    # trace stay consistent with what is actually on disk).  Under ring
    # retention the cumulative state is never consumed (a merged trace
    # cannot cover pruned epochs), so skip the fold entirely: rank-0 memory
    # stays bounded by the ring, matching the live-monitoring use case.
    if max_epochs_retained is None:
        cum.append(delta)
    else:
        prune_epochs(trace_dir, max_epochs_retained)
    comm.barrier()
    return entry


# ---------------------------------------------------------------------------
# degraded (fault-tolerant) flush: survivors commit around dead ranks
# ---------------------------------------------------------------------------


@dataclass
class FlushOutcome:
    """What one degraded flush attempt did, from this rank's view.

    ``lost_local`` is the signal the Recorder acts on: this rank's delta
    did NOT make it into a committed segment (the commit failed, or the
    commit succeeded without this rank's contribution), so the snapshot
    must be restored into the live recorder for the next attempt --
    exactly-once across retries, no loss and no duplication.
    """

    ok: bool
    entry: Optional[Dict[str, Any]] = None     # rank 0 only
    ranks_present: List[int] = field(default_factory=list)
    error: Optional[str] = None
    exc: Optional[BaseException] = None        # rank 0 local commit failure
    lost_local: bool = False


def _empty_block_blob(base: int, n: int) -> bytes:
    """Serialized stand-in for an absent rank block [base, base+n): empty
    grammar, no groups, one shared empty stream.  Structurally a normal
    contiguous block, so the tree fold stays full-width and
    ``merge_rank_states``'s adjacency invariant holds; semantically 'these
    ranks contributed nothing', which the ``ranks_present`` mask reports."""
    return serialize_rank_state(RankState(
        base=base, n=n, groups={},
        streams=[(Sequitur().serialize(), ())], stream_of=[0] * n))


def run_flush_degraded(comm, *, entries: List[bytes], cfg: bytes,
                       ticks: np.ndarray, registry: FunctionRegistry,
                       trace_dir: str, epoch: int, cum: CumulativeState,
                       inter_patterns: bool = True,
                       ts_block_records: int = 4096,
                       max_epochs_retained: Optional[int] = None,
                       meta_extra: Optional[Dict[str, Any]] = None,
                       timeout_s: float = 30.0,
                       encode_backend: Optional[str] = None) -> FlushOutcome:
    """One epoch flush that survives unresponsive ranks.

    Same reduction tree and association order as :func:`run_flush` (a
    fault-free degraded flush commits a byte-identical segment), but built
    ONLY from tagged point-to-point messages with per-hop timeouts -- no
    barriers, so a dead rank can never wedge the survivors:

      1. tree-reduce ``(present_ranks, state blob, ts payloads)`` with
         :meth:`Comm.reduce_tree_partial`; a subtree that misses its
         timeout is substituted by an explicitly-empty block,
      2. rank 0 commits the segment, with a ``ranks_present`` mask when
         partial, and folds the delta into ``cum`` (degraded epochs ARE
         part of the history the merged trace covers),
      3. rank 0 fans the verdict out (:meth:`Comm.bcast_p2p`); a rank that
         is absent from the mask -- it was alive but too slow -- or that
         never hears a verdict reports ``lost_local`` so its caller
         restores the snapshot for the next flush.

    Collective-call discipline: all alive ranks must call this (and every
    other timed collective on ``comm``) in the same order; the message
    tags assume lockstep invocation counts.
    """
    leaf_state = make_rank_state(comm.rank, entries, cfg, registry)
    blocks = compress_timestamps_blocked(ticks, ts_block_records,
                                         backend=encode_backend) \
        if len(ticks) else []
    leaf = ((comm.rank,), serialize_rank_state(leaf_state),
            ((comm.rank, pack_ts_blocks(blocks)),))

    def fold(a, b):
        return (a[0] + b[0], merge_serialized_states(a[1], b[1]),
                a[2] + b[2])

    def absent(lo, hi):
        return ((), _empty_block_blob(lo, hi - lo), ())

    folded = comm.reduce_tree_partial(leaf, fold, absent, timeout_s)
    if comm.rank != 0:
        patience = comm.verdict_patience(timeout_s)
        try:
            ack = comm.bcast_p2p(None, patience)
        except CommTimeout:
            return FlushOutcome(
                ok=False, lost_local=True,
                error=f"no commit verdict from rank 0 within {patience:g}s")
        if ack[0] != "ok":
            return FlushOutcome(ok=False, lost_local=True, error=ack[1])
        present = list(ack[1])
        return FlushOutcome(ok=True, ranks_present=present,
                            lost_local=comm.rank not in present)
    present, blob, ts_items = folded
    present = sorted(present)
    try:
        delta = deserialize_rank_state(blob)
        per_stream = [sum(terminal_counts(parse_grammar(cfg_e)).values())
                      for cfg_e, _rows in delta.streams]
        n_records = sum(per_stream[si] for si in delta.stream_of)
        merge, cfgs = materialize_state(delta, inter_patterns=inter_patterns)
        rank_blocks: List[List[TsBlock]] = [[] for _ in range(delta.n)]
        for r, packed in ts_items:
            rank_blocks[r - delta.base] = unpack_ts_blocks(packed)
        entry = write_epoch_segment(
            trace_dir, epoch, registry=registry, merge=merge, cfgs=cfgs,
            rank_ts_blocks=rank_blocks, state_blob=blob,
            n_records=n_records, meta_extra=meta_extra,
            ranks_present=present)
        if max_epochs_retained is None:
            cum.append(delta)
        else:
            prune_epochs(trace_dir, max_epochs_retained)
    except Exception as e:
        # commit failed locally: tell the survivors (one fan-out either
        # way, preserving the lockstep tag count), then report the failure
        # with the original exception for the caller to re-raise
        try:
            comm.bcast_p2p(("err", f"{type(e).__name__}: {e}"), timeout_s)
        except Exception:  # pragma: no cover - fan-out itself failing
            pass
        return FlushOutcome(ok=False, error=str(e), exc=e, lost_local=True)
    comm.bcast_p2p(("ok", present), timeout_s)
    return FlushOutcome(ok=True, entry=entry, ranks_present=present)


# ---------------------------------------------------------------------------
# merged trace at clean exit (the incremental-finalize payoff)
# ---------------------------------------------------------------------------


def write_merged_trace(trace_dir: str, cum: CumulativeState, *,
                       registry: FunctionRegistry, inter_patterns: bool = True,
                       meta_extra: Optional[Dict[str, Any]] = None
                       ) -> Optional[Dict[str, Any]]:
    """Materialize the cumulative state into ``<trace_dir>/merged`` -- a
    plain five-file trace covering every epoch, produced WITHOUT
    re-reducing the history (the merge already happened incrementally,
    O(delta) per flush).  Timestamps are reassembled from the committed
    segments' already-compressed blocks (byte concatenation, no
    recompression).  Returns the manifest entry, or None when the segment
    history is incomplete (retention pruned or corrupted epochs): a merged
    trace must cover exactly the epochs the state covers."""
    def skip(reason: str) -> None:
        warnings.warn(
            f"no merged trace written for {trace_dir!r}: {reason} -- the "
            f"committed epoch segments remain readable via "
            f"TraceReader(mode='stitched')", RuntimeWarning)

    manifest = trace_format.read_manifest(trace_dir)
    entries = manifest.get("segments", [])
    if len(entries) != cum.n_epochs:
        skip(f"the directory holds {len(entries)} segments but this run's "
             f"cumulative state covers {cum.n_epochs} epochs (restarted "
             f"run, pruning, or a failed flush)")
        return None
    nranks = cum.n
    rank_blocks: List[List[TsBlock]] = [[] for _ in range(nranks)]
    # per rank, per source segment: [n_blocks, that segment's wrap base] --
    # readers unwrap each epoch's blocks against its OWN base, so
    # inter-epoch gaps of >= 2 whole wrap periods (undetectable from tick
    # values) stay exact in merged mode, matching stitched mode
    wrap_spans: List[List[List[int]]] = [[] for _ in range(nranks)]
    base_wraps: Optional[int] = None
    degraded_epochs: Dict[str, List[int]] = {}
    for entry in entries:
        # only each segment's timestamp payload is needed here -- the
        # CST/CFG already live merged inside `cum` -- so skip the full
        # blob decode a read_stream_trace would pay
        reason = trace_format.validate_segment(trace_dir, entry)
        if reason is not None:
            skip(reason)
            return None
        raw, index, seg_meta = trace_format.read_trace_timestamps(
            os.path.join(trace_dir, entry["name"]))
        if index is None:  # legacy single-blob segment: not block-indexed
            skip(f"{entry['name']} has no block-indexed timestamps")
            return None
        seg_wraps = int(seg_meta.get("tick_wraps", 0) or 0)
        if base_wraps is None:
            # the merged trace's store-wide base stays the FIRST epoch's
            # (back-compat for readers unaware of tick_wrap_spans)
            base_wraps = seg_wraps
        if "ranks_present" in entry:
            degraded_epochs[entry["name"]] = list(entry["ranks_present"])
        for r in range(min(nranks, len(index))):
            rank_blocks[r].extend(
                (raw[e[0] : e[0] + e[1]], e[2], e[3], e[4],
                 e[5] if len(e) > 5 else None)
                for e in index[r])
            wrap_spans[r].append([len(index[r]), seg_wraps])
    state = cum.to_rank_state()
    merge, cfgs = materialize_state(state, inter_patterns=inter_patterns)
    tmp = os.path.join(trace_dir, MERGED_DIR + ".tmp")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    meta_extra = dict(meta_extra or {})
    if base_wraps:
        meta_extra["tick_wraps"] = base_wraps
    if any(len(spans) > 1 or (spans and spans[0][1])
           for spans in wrap_spans):
        meta_extra["tick_wrap_spans"] = wrap_spans
    if degraded_epochs:
        meta_extra["degraded_epochs"] = degraded_epochs
    sizes, crcs = trace_format.write_trace(
        tmp, registry=registry, merged_cst=merge.merged_entries,
        unique_cfgs=cfgs.unique_cfgs, cfg_index=cfgs.cfg_index,
        rank_ts_blocks=rank_blocks, meta_extra=meta_extra or None,
        checksums=True)
    state_blob = serialize_rank_state(state)
    crcs[trace_format.STATE_FILE] = trace_format.write_file(
        os.path.join(tmp, trace_format.STATE_FILE), state_blob)
    sizes[trace_format.STATE_FILE] = len(state_blob)
    final = os.path.join(trace_dir, MERGED_DIR)
    manifest = trace_format.read_manifest(trace_dir)
    if os.path.exists(final):
        # a stale merged trace from a previous run using this directory:
        # unlist it first (atomic manifest write), so no reader ever holds
        # an entry for a directory mid-replacement
        if manifest.pop("merged", None) is not None:
            trace_format.write_manifest(trace_dir, manifest)
        shutil.rmtree(final)
    os.replace(tmp, final)
    entry = {"name": MERGED_DIR, "n_epochs": cum.n_epochs, "files": sizes,
             "crcs": crcs}
    manifest["merged"] = entry
    trace_format.write_manifest(trace_dir, manifest)
    return entry


# ---------------------------------------------------------------------------
# read side: stitch committed segments into one logical trace
# ---------------------------------------------------------------------------


class StitchedTimestampStore:
    """Per-rank timestamp access across epoch segments: delegates to each
    segment's store (block-indexed or legacy) in epoch order and
    concatenates the rows.  ``blocks_touched`` sums the children, so the
    only-touched-blocks property of windowed queries is observable across
    the whole stitched trace."""

    def __init__(self, stores: Sequence[Any]):
        self._stores = list(stores)

    @property
    def blocks_touched(self) -> int:
        return sum(s.blocks_touched for s in self._stores)

    def n_blocks(self, rank: int) -> int:
        return sum(s.n_blocks(rank) for s in self._stores)

    def _concat(self, parts: List[Optional[np.ndarray]]
                ) -> Optional[np.ndarray]:
        parts = [p for p in parts if p is not None]
        if not parts:
            return None
        return parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)

    def load(self, rank: int) -> Optional[np.ndarray]:
        return self._concat([s.load(rank) for s in self._stores])

    def load_unwrapped(self, rank: int) -> Optional[np.ndarray]:
        """Concatenated int64 unwrapped ticks across segments -- each
        segment unwraps against its own per-epoch wrap base, so epochs
        separated by multiple wrap periods still come out monotonic."""
        return self._concat([s.load_unwrapped(rank) for s in self._stores])

    def window(self, rank: int, t0: int, t1: int) -> Optional[np.ndarray]:
        return self._concat([s.window(rank, t0, t1) for s in self._stores])

    def window_stats(self, rank: int, t0: int, t1: int
                     ) -> Optional[Tuple[int, Optional[int]]]:
        """Summed ``(n_calls, n_bytes)`` over the segments; ``n_bytes`` is
        None unless every contributing segment carries byte counters."""
        parts = [s.window_stats(rank, t0, t1) for s in self._stores]
        parts = [p for p in parts if p is not None]
        if not parts:
            return None
        n_calls = sum(p[0] for p in parts)
        exact = all(p[1] is not None for p in parts if p[0])
        n_bytes = sum(p[1] or 0 for p in parts) if exact else None
        return n_calls, n_bytes


def make_ts_store(data: Dict[str, Any]):
    """The timestamp store for one ``read_trace_files`` payload: block-
    indexed when the segment carries ``ts_index``, legacy single-blob
    otherwise (same interface either way).  The segment's per-epoch
    ``tick_wraps`` counter (how many times the uint32 microsecond clock had
    already wrapped when the epoch began) seeds the unwrap base."""
    wraps = int(data["meta"].get("tick_wraps", 0) or 0)
    if data.get("ts_index") is not None:
        return BlockedTimestampStore(
            data["ts_raw"], data["ts_index"], tick_wraps=wraps,
            wrap_spans=data["meta"].get("tick_wrap_spans"))
    return TimestampStore(data["rank_timestamps"], tick_wraps=wraps)


def stitch_segments(datas: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Concatenate committed segments (``read_trace_files`` payloads, epoch
    order) into one logical trace, value-identical to a one-shot finalize
    of the same calls.

    The stitched merged CST is the concatenation of the segments' CSTs
    (epoch ``e``'s terminals shifted past the earlier rows); each rank's
    stitched CFG splices its per-epoch grammars with
    :func:`sequitur.concat_grammars` -- ranks sharing the same per-epoch
    CFG sequence share one stitched CFG, so SPMD dedup survives stitching.
    The function table is taken from the NEWEST segment (the registry only
    grows during a run, so it is the superset).
    """
    if not datas:
        raise trace_format.TraceFormatError("no segments to stitch")
    nranks_set = {d["meta"]["nranks"] for d in datas}
    if len(nranks_set) != 1:
        raise trace_format.TraceFormatError(
            f"segments disagree on nranks: {sorted(nranks_set)}")
    nranks = nranks_set.pop()
    merged_cst: List[bytes] = []
    toffs: List[int] = []
    for d in datas:
        toffs.append(len(merged_cst))
        merged_cst.extend(d["merged_cst"])
    combo_table: Dict[tuple, int] = {}
    unique_cfgs: List[bytes] = []
    cfg_index: List[int] = []
    for r in range(nranks):
        combo = tuple(d["cfg_index"][r] for d in datas)
        i = combo_table.get(combo)
        if i is None:
            i = len(unique_cfgs)
            combo_table[combo] = i
            unique_cfgs.append(concat_grammars(
                [(datas[s]["unique_cfgs"][u], toffs[s])
                 for s, u in enumerate(combo)]))
        cfg_index.append(i)
    meta = dict(datas[-1]["meta"])
    meta["nranks"] = nranks
    return {
        "meta": meta,
        "merged_cst": merged_cst,
        "unique_cfgs": unique_cfgs,
        "cfg_index": cfg_index,
        "ts_store": StitchedTimestampStore([make_ts_store(d) for d in datas]),
        "n_segments": len(datas),
    }
