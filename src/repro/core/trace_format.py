"""On-disk trace format (paper Fig 3(d)).

A trace directory holds the five files produced by the inter-process
compression stage:

  unique_cfgs.bin   one copy of each distinct per-rank grammar
  cfg_index.bin     for each rank, which unique CFG it uses
  merged_cst.bin    the merged call-signature table
  timestamps.bin    per-rank zlib blocks of delta+zigzag u32 ticks
  metadata.json     function table, options, app info, block offsets

`make_signature` is re-exported here so the record path and the readers share
one definition site for the signature layout.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional

from .encoding import (encode_signature, pack_uvarints, read_uvarint,
                       unpack_uvarints, write_uvarint)
from .specs import FunctionRegistry

FORMAT_VERSION = 3  # "Recorder 3" -- the paper's major revision

make_signature = encode_signature


class TraceFormatError(Exception):
    """A trace directory is unreadable: missing files, malformed metadata,
    or a format_version this reader does not understand."""


_TRACE_FILES = ("metadata.json", "merged_cst.bin", "unique_cfgs.bin",
                "cfg_index.bin", "timestamps.bin")


def _write_blob_list(path: str, blobs: List[bytes]) -> None:
    out = bytearray()
    write_uvarint(out, len(blobs))
    for b in blobs:
        write_uvarint(out, len(b))
        out.extend(b)
    with open(path, "wb") as f:
        f.write(bytes(out))


def _read_blob_list(path: str) -> List[bytes]:
    with open(path, "rb") as f:
        buf = f.read()
    pos = 0
    n, pos = read_uvarint(buf, pos)
    blobs = []
    for _ in range(n):
        ln, pos = read_uvarint(buf, pos)
        blobs.append(buf[pos : pos + ln])
        pos += ln
    return blobs


def write_trace(trace_dir: str, *, registry: FunctionRegistry,
                merged_cst: List[bytes], unique_cfgs: List[bytes],
                cfg_index: List[int], rank_timestamps: List[bytes],
                meta_extra: Optional[Dict[str, Any]] = None) -> Dict[str, int]:
    """Write the trace directory; returns per-file sizes in bytes."""
    os.makedirs(trace_dir, exist_ok=True)
    _write_blob_list(os.path.join(trace_dir, "merged_cst.bin"), merged_cst)
    _write_blob_list(os.path.join(trace_dir, "unique_cfgs.bin"), unique_cfgs)
    with open(os.path.join(trace_dir, "cfg_index.bin"), "wb") as f:
        f.write(pack_uvarints(cfg_index))
    ts_offsets = []
    off = 0
    with open(os.path.join(trace_dir, "timestamps.bin"), "wb") as f:
        for blob in rank_timestamps:
            ts_offsets.append([off, len(blob)])
            f.write(blob)
            off += len(blob)
    meta = {
        "format_version": FORMAT_VERSION,
        "functions": {str(i): {
            "name": s.name,
            "layer": s.layer,
            "arg_names": [a.name for a in s.args],
            "arg_roles": [a.role.value for a in s.args],
            "ret_role": s.ret_role.value,
        } for i, s in ((i, registry.spec(i)) for i in range(len(registry)))},
        "ts_offsets": ts_offsets,
        "nranks": len(cfg_index),
    }
    if meta_extra:
        meta.update(meta_extra)
    with open(os.path.join(trace_dir, "metadata.json"), "w") as f:
        json.dump(meta, f)
    sizes = {}
    for name in ("merged_cst.bin", "unique_cfgs.bin", "cfg_index.bin",
                 "timestamps.bin", "metadata.json"):
        sizes[name] = os.path.getsize(os.path.join(trace_dir, name))
    return sizes


def read_trace_files(trace_dir: str) -> Dict[str, Any]:
    missing = [n for n in _TRACE_FILES
               if not os.path.exists(os.path.join(trace_dir, n))]
    if missing:
        raise TraceFormatError(
            f"not a readable trace directory: {trace_dir!r} is missing "
            f"{', '.join(missing)}")
    with open(os.path.join(trace_dir, "metadata.json")) as f:
        try:
            meta = json.load(f)
        except ValueError as e:
            raise TraceFormatError(
                f"malformed metadata.json in {trace_dir!r}: {e}") from e
    version = meta.get("format_version")
    if version != FORMAT_VERSION:
        raise TraceFormatError(
            f"unsupported format_version {version!r} in {trace_dir!r} "
            f"(this reader understands {FORMAT_VERSION})")
    merged_cst = _read_blob_list(os.path.join(trace_dir, "merged_cst.bin"))
    unique_cfgs = _read_blob_list(os.path.join(trace_dir, "unique_cfgs.bin"))
    with open(os.path.join(trace_dir, "cfg_index.bin"), "rb") as f:
        cfg_index = unpack_uvarints(f.read())
    with open(os.path.join(trace_dir, "timestamps.bin"), "rb") as f:
        ts_raw = f.read()
    rank_ts = [ts_raw[o : o + n] for o, n in meta["ts_offsets"]]
    return {
        "meta": meta,
        "merged_cst": merged_cst,
        "unique_cfgs": unique_cfgs,
        "cfg_index": cfg_index,
        "rank_timestamps": rank_ts,
    }


def trace_size_report(trace_dir: str) -> Dict[str, int]:
    """Per-file sizes; 'pattern_files' = CFG+CST (what §5.1/§5.2 report),
    'total' = everything (§5.3)."""
    sizes = {}
    for name in ("merged_cst.bin", "unique_cfgs.bin", "cfg_index.bin",
                 "timestamps.bin", "metadata.json"):
        p = os.path.join(trace_dir, name)
        sizes[name] = os.path.getsize(p) if os.path.exists(p) else 0
    sizes["pattern_files"] = sizes["merged_cst.bin"] + sizes["unique_cfgs.bin"]
    sizes["total"] = sum(v for k, v in sizes.items()
                         if k not in ("pattern_files", "total"))
    return sizes
