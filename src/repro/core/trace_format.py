"""On-disk trace format (paper Fig 3(d)).

A trace directory holds the five files produced by the inter-process
compression stage:

  unique_cfgs.bin   one copy of each distinct per-rank grammar
  cfg_index.bin     for each rank, which unique CFG it uses
  merged_cst.bin    the merged call-signature table
  timestamps.bin    per-rank zlib blocks of delta+zigzag u32 ticks
  metadata.json     function table, options, app info, block offsets

`make_signature` is re-exported here so the record path and the readers share
one definition site for the signature layout.

**Streaming layout** (the multi-segment trace directory written by
``Recorder.flush``): a ``manifest.json`` at the top level lists committed
**epoch segments**, each a complete five-file mini trace of one flush
window (plus ``state.bin``, the epoch's serialized cross-rank
``RankState``) living in its own ``epoch_NNNNN/`` subdirectory.  Segments
are written under a ``.tmp`` name and committed by atomic rename followed
by an atomic manifest rewrite, so a crash can never leave a half-written
segment visible; the manifest records every segment file's byte size, so
post-commit corruption (truncation) is detected and the segment skipped on
read.  Segment timestamps use the block-indexed layout (``ts_index`` in
the segment metadata instead of the legacy per-rank ``ts_offsets``).
"""

from __future__ import annotations

import json
import os
import zlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .encoding import (encode_signature, pack_uvarints, read_uvarint,
                       unpack_uvarints, write_uvarint)
from .specs import FunctionRegistry
from .timestamps import TsBlock

FORMAT_VERSION = 3  # "Recorder 3" -- the paper's major revision

make_signature = encode_signature


class TraceFormatError(Exception):
    """A trace directory is unreadable: missing files, malformed metadata,
    or a format_version this reader does not understand."""


class SegmentWriteError(OSError):
    """An epoch segment could not be written (ENOSPC, a vanished trace
    directory, ...).  The ``.tmp`` staging directory has been cleaned up
    and nothing was committed -- the trace directory is exactly as it was
    before the attempt.  Subclasses OSError so callers treating flush
    failures as I/O errors keep working."""


_TRACE_FILES = ("metadata.json", "merged_cst.bin", "unique_cfgs.bin",
                "cfg_index.bin", "timestamps.bin")

MANIFEST_FILE = "manifest.json"
SEGMENT_PREFIX = "epoch_"
STATE_FILE = "state.bin"


def segment_name(epoch: int) -> str:
    return f"{SEGMENT_PREFIX}{epoch:05d}"


def _blob_list_bytes(blobs: List[bytes]) -> bytes:
    out = bytearray()
    write_uvarint(out, len(blobs))
    for b in blobs:
        write_uvarint(out, len(b))
        out.extend(b)
    return bytes(out)


def write_file(path: str, data: bytes) -> int:
    """Write one trace file (through the fault-injection hook) and return
    the CRC32 of the INTENDED bytes.  Under an injected torn write the
    disk receives different bytes than the checksum records -- exactly the
    lying-disk case :func:`validate_segment` must catch, so the checksum
    is deliberately computed from the intent, not from what hit the
    platter."""
    from . import faults

    plan = faults.get_active()
    to_disk = data if plan is None else plan.on_write(path, data)
    with open(path, "wb") as f:
        f.write(to_disk)
    return zlib.crc32(data) & 0xFFFFFFFF


def _read_blob_list(path: str) -> List[bytes]:
    with open(path, "rb") as f:
        buf = f.read()
    pos = 0
    n, pos = read_uvarint(buf, pos)
    blobs = []
    for _ in range(n):
        ln, pos = read_uvarint(buf, pos)
        blobs.append(buf[pos : pos + ln])
        pos += ln
    return blobs


def write_trace(trace_dir: str, *, registry: FunctionRegistry,
                merged_cst: List[bytes], unique_cfgs: List[bytes],
                cfg_index: List[int],
                rank_timestamps: Optional[List[bytes]] = None,
                rank_ts_blocks: Optional[List[Sequence[TsBlock]]] = None,
                meta_extra: Optional[Dict[str, Any]] = None,
                checksums: bool = False) -> Any:
    """Write the trace directory; returns per-file sizes in bytes, or
    ``(sizes, crcs)`` when ``checksums`` is set (per-file CRC32s of the
    written bytes -- the streaming writer records them in the manifest so
    post-commit bit rot and torn writes are detected, not parsed).

    Timestamps are passed either as ``rank_timestamps`` (legacy: one zlib
    blob per rank, indexed by ``ts_offsets``) or ``rank_ts_blocks``
    (block-indexed: per rank a list of
    ``(blob, n_records, t_min, t_max, n_bytes)`` blocks from
    :func:`timestamps.compress_timestamps_blocked`, indexed by ``ts_index``
    entries ``[offset, length, n_records, t_min, t_max]`` plus an optional
    sixth field -- the block's summed data-byte counter -- when the writer
    recorded per-call sizes).
    """
    if (rank_timestamps is None) == (rank_ts_blocks is None):
        raise ValueError(
            "pass exactly one of rank_timestamps / rank_ts_blocks")
    os.makedirs(trace_dir, exist_ok=True)
    files: Dict[str, bytes] = {
        "merged_cst.bin": _blob_list_bytes(merged_cst),
        "unique_cfgs.bin": _blob_list_bytes(unique_cfgs),
        "cfg_index.bin": pack_uvarints(cfg_index),
    }
    ts_meta: Dict[str, Any] = {}
    ts_buf = bytearray()
    if rank_timestamps is not None:
        ts_offsets = []
        for blob in rank_timestamps:
            ts_offsets.append([len(ts_buf), len(blob)])
            ts_buf.extend(blob)
        ts_meta["ts_offsets"] = ts_offsets
    else:
        ts_index = []
        for blocks in rank_ts_blocks:
            entries = []
            for blob, n, t_min, t_max, n_bytes in blocks:
                e = [len(ts_buf), len(blob), n, t_min, t_max]
                if n_bytes is not None:
                    e.append(n_bytes)
                entries.append(e)
                ts_buf.extend(blob)
            ts_index.append(entries)
        ts_meta["ts_index"] = ts_index
    files["timestamps.bin"] = bytes(ts_buf)
    meta = {
        "format_version": FORMAT_VERSION,
        "functions": {str(i): {
            "name": s.name,
            "layer": s.layer,
            "arg_names": [a.name for a in s.args],
            "arg_roles": [a.role.value for a in s.args],
            "ret_role": s.ret_role.value,
        } for i, s in ((i, registry.spec(i)) for i in range(len(registry)))},
        "nranks": len(cfg_index),
        **ts_meta,
    }
    if meta_extra:
        meta.update(meta_extra)
    files["metadata.json"] = json.dumps(meta).encode("utf-8")
    sizes: Dict[str, int] = {}
    crcs: Dict[str, int] = {}
    for name, data in files.items():
        crcs[name] = write_file(os.path.join(trace_dir, name), data)
        sizes[name] = len(data)
    return (sizes, crcs) if checksums else sizes


def read_trace_files(trace_dir: str) -> Dict[str, Any]:
    missing = [n for n in _TRACE_FILES
               if not os.path.exists(os.path.join(trace_dir, n))]
    if missing:
        raise TraceFormatError(
            f"not a readable trace directory: {trace_dir!r} is missing "
            f"{', '.join(missing)}")
    with open(os.path.join(trace_dir, "metadata.json")) as f:
        try:
            meta = json.load(f)
        except ValueError as e:
            raise TraceFormatError(
                f"malformed metadata.json in {trace_dir!r}: {e}") from e
    version = meta.get("format_version")
    if version != FORMAT_VERSION:
        raise TraceFormatError(
            f"unsupported format_version {version!r} in {trace_dir!r} "
            f"(this reader understands {FORMAT_VERSION})")
    merged_cst = _read_blob_list(os.path.join(trace_dir, "merged_cst.bin"))
    unique_cfgs = _read_blob_list(os.path.join(trace_dir, "unique_cfgs.bin"))
    with open(os.path.join(trace_dir, "cfg_index.bin"), "rb") as f:
        cfg_index = unpack_uvarints(f.read())
    with open(os.path.join(trace_dir, "timestamps.bin"), "rb") as f:
        ts_raw = f.read()
    if "ts_index" in meta:
        rank_ts = None
    elif "ts_offsets" in meta:
        rank_ts = [ts_raw[o : o + n] for o, n in meta["ts_offsets"]]
    else:
        raise TraceFormatError(
            f"metadata.json in {trace_dir!r} has neither ts_offsets nor "
            f"ts_index")
    return {
        "meta": meta,
        "merged_cst": merged_cst,
        "unique_cfgs": unique_cfgs,
        "cfg_index": cfg_index,
        "rank_timestamps": rank_ts,
        "ts_raw": ts_raw,
        "ts_index": meta.get("ts_index"),
    }


# ---------------------------------------------------------------------------
# multi-segment (streaming) trace directories
# ---------------------------------------------------------------------------


def is_stream_dir(trace_dir: str) -> bool:
    """A streaming trace directory carries a top-level manifest; a legacy
    single-segment trace carries metadata.json directly."""
    return os.path.exists(os.path.join(trace_dir, MANIFEST_FILE))


def read_manifest(trace_dir: str) -> Dict[str, Any]:
    path = os.path.join(trace_dir, MANIFEST_FILE)
    if not os.path.exists(path):
        raise TraceFormatError(
            f"not a streaming trace directory: {trace_dir!r} has no "
            f"{MANIFEST_FILE}")
    with open(path) as f:
        try:
            manifest = json.load(f)
        except ValueError as e:
            raise TraceFormatError(
                f"malformed {MANIFEST_FILE} in {trace_dir!r}: {e}") from e
    version = manifest.get("format_version")
    if version != FORMAT_VERSION:
        raise TraceFormatError(
            f"unsupported format_version {version!r} in {trace_dir!r} "
            f"manifest (this reader understands {FORMAT_VERSION})")
    return manifest


def write_manifest(trace_dir: str, manifest: Dict[str, Any]) -> None:
    """Atomic + durable manifest rewrite: readers see either the old or
    the new segment list, never a torn one.  The tmp file and the
    directory entry are fsynced around the rename -- a torn manifest would
    make the WHOLE trace unreadable (far worse than losing one segment,
    whose truncation the per-file sizes already catch), so this one file
    pays the full durability cost."""
    tmp = os.path.join(trace_dir, MANIFEST_FILE + ".tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(trace_dir, MANIFEST_FILE))
    try:
        dir_fd = os.open(trace_dir, os.O_RDONLY)
    except OSError:  # pragma: no cover - platform without dir fds
        return
    try:
        os.fsync(dir_fd)
    finally:
        os.close(dir_fd)


def _segment_meta_version(seg_dir: str) -> Optional[int]:
    """format_version of a segment's metadata.json, or None when the file
    is missing/unparseable (then corruption handling owns the error)."""
    try:
        with open(os.path.join(seg_dir, "metadata.json")) as f:
            return json.load(f).get("format_version")
    except (OSError, ValueError):
        return None


def check_segment_versions(trace_dir: str,
                           entries: Sequence[Dict[str, Any]]) -> None:
    """Reject mixed ``format_version`` across the segments of one trace
    directory (:class:`TraceFormatError`): a trace assembled from
    incompatible writers must not be silently half-read.  Cheap -- only
    each segment's metadata.json is opened, never the blob files."""
    versions = {FORMAT_VERSION}
    for entry in entries:
        v = _segment_meta_version(os.path.join(trace_dir, entry["name"]))
        if v is not None:
            versions.add(v)
    if len(versions) > 1:
        raise TraceFormatError(
            f"mixed format_version across segments of {trace_dir!r}: "
            f"{sorted(versions, key=repr)} (all segments of one trace "
            f"directory must share the manifest's version)")


def validate_segment(trace_dir: str, entry: Dict[str, Any]) -> Optional[str]:
    """Check one manifest segment entry against the on-disk files; returns
    a human-readable reason when the segment must be skipped, else None.

    The manifest records every file's byte size at commit time, so a
    truncated (or grown) file -- the post-commit crash case -- is caught
    before any decode is attempted; the per-file CRC32s (``crcs``, written
    by the streaming commit path) additionally catch same-size damage --
    bit rot and torn writes -- that no size check can see.
    """
    seg_dir = os.path.join(trace_dir, entry["name"])
    if not os.path.isdir(seg_dir):
        return f"segment directory {entry['name']!r} is missing"
    for fname, want in entry.get("files", {}).items():
        path = os.path.join(seg_dir, fname)
        try:
            got = os.path.getsize(path)
        except OSError:
            # the segment can vanish between the manifest read and this
            # stat (retention pruning under a live reader): report it as
            # skippable, never let the race escape as FileNotFoundError
            return f"{entry['name']}/{fname} is missing"
        if got != want:
            return (f"{entry['name']}/{fname} is {got} bytes, manifest "
                    f"recorded {want} (truncated or corrupt)")
    for fname, want in entry.get("crcs", {}).items():
        path = os.path.join(seg_dir, fname)
        try:
            crc = 0
            with open(path, "rb") as f:
                for chunk in iter(lambda: f.read(1 << 20), b""):
                    crc = zlib.crc32(chunk, crc)
        except OSError as e:
            return f"{entry['name']}/{fname} is unreadable: {e}"
        if crc & 0xFFFFFFFF != want:
            return (f"{entry['name']}/{fname} fails its checksum (crc32 "
                    f"{crc & 0xFFFFFFFF:#010x}, manifest recorded "
                    f"{want:#010x}: bit rot or torn write)")
    return None


def read_trace_timestamps(trace_dir: str
                          ) -> Tuple[bytes, Optional[List[Any]],
                                     Dict[str, Any]]:
    """Only a trace directory's ``(timestamps.bin bytes, ts_index, meta)``
    -- ``ts_index`` is None for the legacy single-blob layout.  Lets
    callers that reassemble timestamps (the merged-trace writer) skip
    decoding the CST/CFG blobs entirely; the metadata rides along so wrap
    counters (``tick_wraps``) survive the merge."""
    try:
        with open(os.path.join(trace_dir, "metadata.json")) as f:
            meta = json.load(f)
        with open(os.path.join(trace_dir, "timestamps.bin"), "rb") as f:
            ts_raw = f.read()
    except (OSError, ValueError) as e:
        raise TraceFormatError(
            f"cannot read timestamps of {trace_dir!r}: {e}") from e
    return ts_raw, meta.get("ts_index"), meta


def load_segment(trace_dir: str, entry: Dict[str, Any]
                 ) -> Tuple[Optional[Dict[str, Any]], Optional[str]]:
    """Validate + decode one manifest segment entry: ``(data, None)`` on
    success, ``(None, reason)`` when the segment must be skipped.  The
    single definition of what counts as an unusable segment -- shared by
    :func:`read_stream_trace` and the lazy per-entry reads in
    ``reader.TraceReader``."""
    reason = validate_segment(trace_dir, entry)
    if reason is None:
        try:
            return read_trace_files(os.path.join(trace_dir,
                                                 entry["name"])), None
        except (TraceFormatError, ValueError, IndexError, OSError) as e:
            # OSError covers the validate-then-read race: a concurrent
            # pruner may delete the segment directory between the size/CRC
            # check and the blob reads
            reason = f"{entry['name']} is unreadable: {e}"
    return None, reason


def read_stream_trace(trace_dir: str) -> Dict[str, Any]:
    """Read a multi-segment trace: the manifest plus every committed,
    intact segment's decoded payload.

    Partially-written segments never appear (atomic rename commit); a
    committed segment whose files were later corrupted is skipped and
    reported in ``skipped``.  Segments whose metadata carries a
    format_version different from the manifest's are a hard error
    (:class:`TraceFormatError`): mixing format versions inside one trace
    directory means the trace was assembled from incompatible writers.
    """
    manifest = read_manifest(trace_dir)
    entries = manifest.get("segments", [])
    check_segment_versions(trace_dir, entries)
    segments: List[Dict[str, Any]] = []
    skipped: List[Dict[str, str]] = []
    for entry in entries:
        data, reason = load_segment(trace_dir, entry)
        if data is None:
            skipped.append({"segment": entry["name"], "reason": reason})
            continue
        segments.append({"entry": entry, "data": data})
    return {"manifest": manifest, "segments": segments, "skipped": skipped}


def trace_size_report(trace_dir: str) -> Dict[str, int]:
    """Per-file sizes; 'pattern_files' = CFG+CST (what §5.1/§5.2 report),
    'total' = everything (§5.3)."""
    sizes = {}
    for name in ("merged_cst.bin", "unique_cfgs.bin", "cfg_index.bin",
                 "timestamps.bin", "metadata.json"):
        p = os.path.join(trace_dir, name)
        sizes[name] = os.path.getsize(p) if os.path.exists(p) else 0
    sizes["pattern_files"] = sizes["merged_cst.bin"] + sizes["unique_cfgs.bin"]
    sizes["total"] = sum(v for k, v in sizes.items()
                         if k not in ("pattern_files", "total"))
    return sizes
