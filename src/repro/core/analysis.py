"""Trace analyses enabled by full-parameter traces (paper Section 4).

Counter-based profilers cannot answer these; Recorder traces can, because
every call keeps its offsets, sizes, flags, call depth, thread id and
entry/exit times:

  io_summary        per-file bytes/calls/bandwidth, metadata-call ratio
  size_histogram    request-size distribution (the paper's "small request"
                    Montage finding)
  call_chains       cross-layer cause-and-effect (who triggers each write)
  overlap_ratio     asynchronous-I/O overlap between threads (Section 2.2)
  consistency_pairs conflicting (overlapping, cross-rank) write extents --
                    the file-system consistency-semantics study [27, 28]

All five run on :class:`repro.core.traceview.TraceView` -- the
compressed-domain columnar query layer -- so the aggregates are
grammar-weighted sums over distinct signatures (O(|grammar| + |CST|)) and
the sequential analyses cost one stream walk per *unique CFG* instead of a
per-record Python iteration per rank.  Results are value-identical to the
record-iterator path (property-tested in ``tests/test_traceview.py``),
with one deliberate fix: ``consistency_pairs`` now reports ALL overlapping
cross-rank pairs via an active-interval sweep, where the seed's
adjacent-pair scan dropped conflicts between non-adjacent spans.
"""

from __future__ import annotations

from typing import Any, Dict, List, Union

from .reader import TraceReader
from .traceview import _DATA_FUNCS, TraceView, sweep_conflicts  # noqa: F401

Readable = Union[TraceReader, TraceView]


def _view(reader: Readable) -> TraceView:
    return reader if isinstance(reader, TraceView) else reader.view()


def io_summary(reader: Readable) -> Dict[str, Any]:
    """Aggregate transfer sizes, call mix, and per-rank bandwidth."""
    return _view(reader).io_summary()


def size_histogram(reader: Readable,
                   edges=(512, 4096, 65536, 1 << 20)) -> Dict[str, int]:
    """Request-size distribution of data calls."""
    return _view(reader).size_histogram(edges)


def call_chains(reader: Readable, targets=_DATA_FUNCS,
                rank: int = 0) -> Dict[str, int]:
    """Cross-layer call chains ending in a data op (uses call depth).

    Records are emitted at call COMPLETION (children before parents), so
    the stream is post-order; the view streams it in reverse straight from
    the grammar -- parents first, without materializing the forward record
    list -- and the depth-indexed stack reconstructs each ancestry chain."""
    return _view(reader).call_chains(targets, rank=rank)


def overlap_ratio(reader: Readable, rank: int = 0) -> float:
    """Fraction of traced I/O time where >= 2 threads were inside calls
    simultaneously (asynchronous-I/O overlap, paper Section 2.2)."""
    return _view(reader).overlap_ratio(rank)


def consistency_pairs(reader: Readable) -> List[Dict[str, Any]]:
    """Cross-rank overlapping write extents per file handle id: the cases
    whose ordering a file system's consistency model must define.

    Uses an active-interval sweep (:func:`traceview.sweep_conflicts`), so a
    long extent is checked against EVERY later overlapping span -- the
    seed's adjacent-pair scan missed e.g. rank 0 writing [0, 100) against
    rank 2 writing [30, 40) whenever rank 1 wrote in between.
    """
    return _view(reader).consistency_pairs()
