"""Trace analyses enabled by full-parameter traces (paper Section 4).

Counter-based profilers cannot answer these; Recorder traces can, because
every call keeps its offsets, sizes, flags, call depth, thread id and
entry/exit times:

  io_summary        per-file bytes/calls/bandwidth, metadata-call ratio
  size_histogram    request-size distribution (the paper's "small request"
                    Montage finding)
  call_chains       cross-layer cause-and-effect (who triggers each write)
  overlap_ratio     asynchronous-I/O overlap between threads (Section 2.2)
  consistency_pairs conflicting (overlapping, cross-rank) write extents --
                    the file-system consistency-semantics study [27, 28]
"""

from __future__ import annotations

from collections import defaultdict
from typing import Any, Dict, List, Optional, Tuple

from .reader import Record, TraceReader

_DATA_FUNCS = {"pwrite", "write", "pread", "read", "shard_write_at",
               "shard_read_at"}


def _size_of(rec: Record) -> int:
    for name, v, role in zip(rec.arg_names, rec.args, rec.roles):
        if role in ("buf", "size") and isinstance(v, int):
            return v
    return rec.ret if isinstance(rec.ret, int) else 0


def _offset_of(rec: Record) -> Optional[int]:
    for v, role in zip(rec.args, rec.roles):
        if role == "offset" and isinstance(v, int):
            return v
    return None


def io_summary(reader: TraceReader) -> Dict[str, Any]:
    """Aggregate transfer sizes, call mix, and per-rank bandwidth."""
    per_file: Dict[Any, Dict[str, int]] = defaultdict(
        lambda: {"bytes": 0, "calls": 0})
    handles: Dict[Tuple[int, int], str] = {}
    n_meta = n_data = 0
    t_lo, t_hi = float("inf"), 0
    total_bytes = 0
    for r, rec in reader.all_records():
        if rec.func in ("open", "shard_open"):
            h = rec.ret
            if hasattr(h, "id"):
                handles[(r, h.id)] = str(rec.args[0])
        if rec.func in _DATA_FUNCS:
            n_data += 1
            sz = _size_of(rec)
            total_bytes += sz
            key = next((handles.get((r, v.id)) for v, role in
                        zip(rec.args, rec.roles)
                        if role == "handle" and hasattr(v, "id")), "?")
            per_file[key]["bytes"] += sz
            per_file[key]["calls"] += 1
        elif rec.layer in ("posix", "shardio"):
            n_meta += 1
        if rec.t_entry is not None:
            t_lo = min(t_lo, rec.t_entry)
            t_hi = max(t_hi, rec.t_exit or rec.t_entry)
    wall_us = max(t_hi - t_lo, 1)
    return {
        "files": dict(per_file),
        "n_data_calls": n_data,
        "n_metadata_calls": n_meta,
        "metadata_ratio": n_meta / max(n_data + n_meta, 1),
        "total_bytes": total_bytes,
        "aggregate_MBps": total_bytes / wall_us,  # bytes/us == MB/s
    }


def size_histogram(reader: TraceReader,
                   edges=(512, 4096, 65536, 1 << 20)) -> Dict[str, int]:
    """Request-size distribution of data calls."""
    buckets = {f"<{e}": 0 for e in edges}
    buckets[f">={edges[-1]}"] = 0
    for _, rec in reader.all_records(timestamps=False):
        if rec.func not in _DATA_FUNCS:
            continue
        sz = _size_of(rec)
        for e in edges:
            if sz < e:
                buckets[f"<{e}"] += 1
                break
        else:
            buckets[f">={edges[-1]}"] += 1
    return buckets


def call_chains(reader: TraceReader, targets=_DATA_FUNCS,
                rank: int = 0) -> Dict[str, int]:
    """Cross-layer call chains ending in a data op (uses call depth).

    Records are emitted at call COMPLETION (children before parents), so
    the stream is post-order; walking it in reverse yields parents first
    and the depth-indexed stack reconstructs each ancestry chain."""
    chains: Dict[str, int] = defaultdict(int)
    stack: List[str] = []
    for rec in reversed(list(reader.iter_records(rank, timestamps=False))):
        del stack[rec.depth:]
        stack.append(rec.func)
        if rec.func in targets:
            chains["->".join(stack)] += 1
    return dict(chains)


def overlap_ratio(reader: TraceReader, rank: int = 0) -> float:
    """Fraction of traced I/O time where >= 2 threads were inside calls
    simultaneously (asynchronous-I/O overlap, paper Section 2.2)."""
    events = []
    for rec in reader.iter_records(rank):
        if rec.t_entry is None or rec.t_exit is None:
            continue
        events.append((rec.t_entry, 1))
        events.append((rec.t_exit, -1))
    if not events:
        return 0.0
    events.sort()
    busy = overlap = 0
    depth = 0
    last = events[0][0]
    for t, d in events:
        if depth >= 1:
            busy += t - last
        if depth >= 2:
            overlap += t - last
        depth += d
        last = t
    return overlap / busy if busy else 0.0


def consistency_pairs(reader: TraceReader) -> List[Dict[str, Any]]:
    """Cross-rank overlapping write extents per file handle id: the cases
    whose ordering a file system's consistency model must define."""
    writes: Dict[int, List[Tuple[int, int, int]]] = defaultdict(list)
    for r, rec in reader.all_records(timestamps=False):
        if rec.func not in ("pwrite", "shard_write_at"):
            continue
        off = _offset_of(rec)
        if off is None:
            continue
        writes[next((v.id for v, role in zip(rec.args, rec.roles)
                     if role == "handle" and hasattr(v, "id")), -1)] \
            .append((r, off, off + _size_of(rec)))
    conflicts = []
    for hid, spans in writes.items():
        spans.sort(key=lambda s: s[1])
        for (r1, a1, b1), (r2, a2, b2) in zip(spans, spans[1:]):
            if r1 != r2 and a2 < b1:
                conflicts.append({"handle": hid, "ranks": (r1, r2),
                                  "extent": (a2, min(b1, b2))})
    return conflicts
