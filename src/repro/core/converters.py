"""Trace-format converters (paper Section 2.3).

``to_chrome_timeline``  Recorder trace -> Chrome trace-event JSON
                        (loadable in chrome://tracing / perfetto).
``to_columnar``         Recorder trace -> column-oriented dataset in 64K-row
                        groups with per-column compression -- the Parquet
                        converter adapted to this container (pyarrow is not
                        installed offline, so we emit the same columnar
                        layout in a self-describing .npz-style format and
                        keep the row-group + column-compression semantics;
                        a deployment note covers swapping in pyarrow).
``read_columnar``       loads a columnar dataset back into numpy columns.
"""

from __future__ import annotations

import json
import os
import zlib
from typing import Any, Dict, Iterator, List, Optional

import numpy as np

from .encoding import Handle, IterPattern, RankPattern
from .reader import TraceReader

ROW_GROUP = 65536  # records per group (paper: "group of 64K records")


def _arg_str(v: Any) -> str:
    if isinstance(v, Handle):
        return f"h{v.id}"
    if isinstance(v, (IterPattern, RankPattern)):
        return repr(v)
    return str(v)


def to_chrome_timeline(trace_dir: str, out_path: str,
                       ranks: Optional[List[int]] = None) -> int:
    """Write Chrome trace-event JSON; returns the number of events."""
    reader = TraceReader(trace_dir)
    ranks = ranks if ranks is not None else list(range(reader.nranks))
    n = 0
    with open(out_path, "w") as f:
        f.write('{"traceEvents":[\n')
        first = True
        for r in ranks:
            for rec in reader.iter_records(r):
                ev = {
                    "name": rec.func,
                    "cat": rec.layer,
                    "ph": "X",
                    "pid": r,
                    "tid": rec.thread,
                    "ts": rec.t_entry if rec.t_entry is not None else 0,
                    "dur": ((rec.t_exit - rec.t_entry)
                            if rec.t_entry is not None else 0),
                    "args": {k: _arg_str(v) for k, v in
                             zip(rec.arg_names, rec.args)},
                }
                ev["args"]["depth"] = rec.depth
                f.write(("" if first else ",\n") + json.dumps(ev))
                first = False
                n += 1
        f.write('\n]}')
    return n


# ---------------------------------------------------------------------------
# columnar converter
# ---------------------------------------------------------------------------

_COLUMNS = ("rank", "func_id", "thread", "depth", "t_entry", "t_exit",
            "offset", "size", "path_id")


def _record_cols(reader: TraceReader, r: int) -> Iterator[Dict[str, Any]]:
    for rec in reader.iter_records(r):
        offset = size = -1
        path_id = -1
        for name, v, role in zip(rec.arg_names, rec.args, rec.roles):
            if role == "offset" and isinstance(v, (int, np.integer)):
                offset = int(v)
            elif role in ("size", "buf") and isinstance(v, (int, np.integer)):
                size = int(v)
        yield {"rank": r, "func": rec.func, "thread": rec.thread,
               "depth": rec.depth, "t_entry": rec.t_entry or 0,
               "t_exit": rec.t_exit or 0, "offset": offset, "size": size,
               "path": next((str(v) for v, role in zip(rec.args, rec.roles)
                             if role == "path"), None)}


def to_columnar(trace_dir: str, out_dir: str) -> Dict[str, int]:
    """Column-oriented dataset: one compressed block per column per 64K-row
    group + a dataset manifest.  Returns {file: bytes}."""
    reader = TraceReader(trace_dir)
    os.makedirs(out_dir, exist_ok=True)
    func_ids: Dict[str, int] = {}
    path_ids: Dict[str, int] = {}
    rows: List[Dict[str, Any]] = []
    group = 0
    sizes: Dict[str, int] = {}

    def flush():
        nonlocal group, rows
        if not rows:
            return
        cols = {
            "rank": np.array([r["rank"] for r in rows], np.int32),
            "func_id": np.array([func_ids.setdefault(r["func"],
                                                     len(func_ids))
                                 for r in rows], np.int32),
            "thread": np.array([r["thread"] for r in rows], np.int32),
            "depth": np.array([r["depth"] for r in rows], np.int16),
            "t_entry": np.array([r["t_entry"] for r in rows], np.uint32),
            "t_exit": np.array([r["t_exit"] for r in rows], np.uint32),
            "offset": np.array([r["offset"] for r in rows], np.int64),
            "size": np.array([r["size"] for r in rows], np.int64),
            "path_id": np.array(
                [-1 if r["path"] is None
                 else path_ids.setdefault(r["path"], len(path_ids))
                 for r in rows], np.int32),
        }
        fn = os.path.join(out_dir, f"group_{group:05d}.cols")
        with open(fn, "wb") as f:
            header = {}
            blobs = []
            off = 0
            for name, arr in cols.items():
                blob = zlib.compress(arr.tobytes(), 6)  # snappy-role codec
                header[name] = {"dtype": str(arr.dtype), "n": len(arr),
                                "off": off, "len": len(blob)}
                blobs.append(blob)
                off += len(blob)
            hj = json.dumps(header).encode()
            f.write(len(hj).to_bytes(4, "little"))
            f.write(hj)
            for b in blobs:
                f.write(b)
        sizes[os.path.basename(fn)] = os.path.getsize(fn)
        group += 1
        rows = []

    for r in range(reader.nranks):
        for row in _record_cols(reader, r):
            rows.append(row)
            if len(rows) >= ROW_GROUP:
                flush()
    flush()
    manifest = {"n_groups": group, "columns": list(_COLUMNS),
                "functions": {v: k for k, v in func_ids.items()},
                "paths": {v: k for k, v in path_ids.items()}}
    mp = os.path.join(out_dir, "dataset.json")
    with open(mp, "w") as f:
        json.dump(manifest, f)
    sizes["dataset.json"] = os.path.getsize(mp)
    return sizes


def read_columnar(out_dir: str) -> Dict[str, np.ndarray]:
    with open(os.path.join(out_dir, "dataset.json")) as f:
        manifest = json.load(f)
    cols: Dict[str, List[np.ndarray]] = {}
    for g in range(manifest["n_groups"]):
        fn = os.path.join(out_dir, f"group_{g:05d}.cols")
        with open(fn, "rb") as f:
            hlen = int.from_bytes(f.read(4), "little")
            header = json.loads(f.read(hlen))
            base = f.tell()
            for name, h in header.items():
                f.seek(base + h["off"])
                raw = zlib.decompress(f.read(h["len"]))
                cols.setdefault(name, []).append(
                    np.frombuffer(raw, dtype=h["dtype"]))
    return {k: np.concatenate(v) for k, v in cols.items()}
