"""Production meshes (DESIGN.md Section 4).

Defined as functions, not module constants, so importing this module never
touches jax device state.  The single-pod mesh is a TPU v5e pod slice
(16 x 16 = 256 chips); the multi-pod mesh adds a leading "pod" axis
(2 x 16 x 16 = 512 chips) whose collectives ride the inter-pod DCN/ICI
links.  Axis roles:

  pod    outer data parallelism (+ compressed cross-pod gradient reduce)
  data   data parallelism within a pod
  model  tensor / expert / sequence parallelism
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for tests (requires xla_force_host_platform_device_count)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
