"""CLI for the always-on trace query service.

Point it at a root directory that holds many trace directories (one per
job) and either list them, answer one query, rank jobs by bandwidth,
find stragglers, or run a watch loop that keeps printing a live league
table as jobs commit new epochs.

    python -m repro.launch.traceserve --root runs/ --list
    python -m repro.launch.traceserve --root runs/ --job job_a \\
        --query io_summary
    python -m repro.launch.traceserve --root runs/ --job job_a \\
        --query overlap_ratio --rank 2 --t0 0 --t1 500000
    python -m repro.launch.traceserve --root runs/ --league
    python -m repro.launch.traceserve --root runs/ --job job_a --stragglers
    python -m repro.launch.traceserve --root runs/ --job job_a --phases
    python -m repro.launch.traceserve --root runs/ --job job_a --anomalies
    python -m repro.launch.traceserve --root runs/ --job job_a \\
        --query dfg --top 10
    python -m repro.launch.traceserve --root runs/ --watch --interval 2 \\
        --iterations 10

Output is JSON on stdout (one document per watch iteration).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from typing import Any, Dict

from ..traceserve import QUERY_FAMILIES, TraceService


def _job_rows(service: TraceService) -> Dict[str, Any]:
    return {name: dataclasses.asdict(info)
            for name, info in service.jobs().items()}


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="repro.launch.traceserve",
        description="Live compressed-domain queries over many trace jobs")
    p.add_argument("--root", required=True,
                   help="directory holding one trace directory per job")
    p.add_argument("--mode", default="auto",
                   choices=("auto", "stitched", "tail", "merged"))
    p.add_argument("--staleness", type=float, default=1.0, metavar="S",
                   help="serve snapshots at most S seconds stale")
    p.add_argument("--no-validate", action="store_true",
                   help="skip per-segment CRC validation during scans")
    act = p.add_argument_group("actions (pick one)")
    act.add_argument("--list", action="store_true",
                     help="scan the root and list every job")
    act.add_argument("--query", metavar="FAMILY", choices=QUERY_FAMILIES,
                     help=f"one of {', '.join(QUERY_FAMILIES)}")
    act.add_argument("--league", action="store_true",
                     help="bandwidth league table across all jobs")
    act.add_argument("--stragglers", action="store_true",
                     help="per-rank reasons-attached straggler report "
                          "for --job")
    act.add_argument("--phases", action="store_true",
                     help="phase segmentation of --job (--rank, default 0)")
    act.add_argument("--anomalies", action="store_true",
                     help="cross-rank DFG divergence report for --job")
    act.add_argument("--watch", action="store_true",
                     help="repeatedly print jobs + league table")
    p.add_argument("--job", help="job name (for --query / --stragglers / "
                                 "--phases / --anomalies)")
    p.add_argument("--rank", type=int, default=None)
    p.add_argument("--t0", type=int, default=None)
    p.add_argument("--t1", type=int, default=None)
    p.add_argument("--top", type=int, default=None,
                   help="edge cutoff for --query dfg / digram_counts")
    p.add_argument("--threshold", type=float, default=0.5,
                   help="straggler cutoff as a fraction of the median")
    p.add_argument("--divergence", type=float, default=0.25,
                   help="DFG divergence cutoff (--anomalies / "
                        "--stragglers)")
    p.add_argument("--interval", type=float, default=2.0,
                   help="--watch period in seconds")
    p.add_argument("--iterations", type=int, default=0,
                   help="--watch iterations (0 = until interrupted)")
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    with TraceService(args.root, mode=args.mode,
                      max_staleness_s=args.staleness,
                      validate=not args.no_validate) as service:
        if args.list:
            out: Any = {"root": args.root, "jobs": _job_rows(service)}
        elif args.query:
            if not args.job:
                print("--query needs --job", file=sys.stderr)
                return 2
            params: Dict[str, Any] = {}
            if args.rank is not None:
                params["rank"] = args.rank
            if args.t0 is not None:
                params["t0"] = args.t0
            if args.t1 is not None:
                params["t1"] = args.t1
            if args.top is not None:
                params["top"] = args.top
            out = service.query(args.job, args.query, params).to_dict()
        elif args.league:
            out = {"league": service.league_table(),
                   "stats": service.stats()}
        elif args.stragglers:
            if not args.job:
                print("--stragglers needs --job", file=sys.stderr)
                return 2
            out = service.stragglers(args.job, threshold=args.threshold,
                                     divergence=args.divergence)
        elif args.phases:
            if not args.job:
                print("--phases needs --job", file=sys.stderr)
                return 2
            out = service.phases(args.job, rank=args.rank or 0).to_dict()
        elif args.anomalies:
            if not args.job:
                print("--anomalies needs --job", file=sys.stderr)
                return 2
            out = service.anomalies(
                args.job, threshold=args.divergence).to_dict()
        elif args.watch:
            i = 0
            try:
                while args.iterations == 0 or i < args.iterations:
                    if i:
                        time.sleep(args.interval)
                    doc = {"iteration": i,
                           "jobs": _job_rows(service),
                           "league": service.league_table(),
                           "stats": service.stats()}
                    print(json.dumps(doc, default=str), flush=True)
                    i += 1
            except KeyboardInterrupt:
                pass
            return 0
        else:
            print("pick an action: --list / --query / --league / "
                  "--stragglers / --phases / --anomalies / --watch",
                  file=sys.stderr)
            return 2
        print(json.dumps(out, indent=2, default=str))
    return 0


if __name__ == "__main__":
    sys.exit(main())
