"""Step builders + sharding specs for train / prefill / decode.

This is the GSPMD contract of the framework: every jit entry point gets
explicit in/out shardings derived here.  Conventions:

  params        TP-sharded over "model" (distributed.param_sharding_rules)
  opt state     ZeRO-1: params' spec + the largest divisible free dim
                sharded over "data" (zero1_spec)
  activations   batch over ("pod","data"); constraints inside the model
  kv caches     batch over ("pod","data"), sequence over "model"
                (flash-decoding layout -- valid for every head count)
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distributed.sharding import mesh_context, tree_param_specs
from ..models import ModelAPI, get_model
from ..models.config import ModelConfig
from ..optim import AdamWConfig, adamw_init, adamw_update
from .shapes import ShapeSpec, batch_specs, decode_specs


# ---------------------------------------------------------------------------
# spec derivation
# ---------------------------------------------------------------------------


def _div(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


def _dp_axes(mesh: Mesh, n: int) -> Optional[Tuple[str, ...]]:
    """Largest prefix of ("pod","data") whose product divides n."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    best: Tuple[str, ...] = ()
    prod = 1
    for a in axes:
        prod *= mesh.shape[a]
        if _div(n, prod):
            best = tuple(axes[: axes.index(a) + 1])
    return best or None


def zero1_spec(pspec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Add ZeRO-1 sharding: put ("data",) (and "pod" if present) on the
    largest dim not already sharded, if divisible."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    parts = list(pspec) + [None] * (len(shape) - len(pspec))
    cand = [(shape[i], i) for i in range(len(shape))
            if parts[i] is None and _div(shape[i], dp_size)]
    if not cand:
        return P(*parts)
    _, i = max(cand)
    parts[i] = dp
    return P(*parts)


def train_state_specs(state_shapes, param_specs, mesh: Mesh):
    """Sharding tree for {master, mu, nu, step}."""
    def z(tree_shapes):
        return jax.tree.map(
            lambda sds, ps: zero1_spec(ps, sds.shape, mesh),
            tree_shapes, param_specs)

    return {
        "master": z(state_shapes["master"]),
        "mu": z(state_shapes["mu"]),
        "nu": z(state_shapes["nu"]),
        "step": P(),
    }


def batch_pspecs(cfg: ModelConfig, specs: Dict[str, Any], mesh: Mesh):
    out = {}
    for k, v in specs.items():
        dp = _dp_axes(mesh, v.shape[0])
        out[k] = P(dp, *([None] * (len(v.shape) - 1)))
    return out


def cache_pspecs(cfg: ModelConfig, cache_shapes, mesh: Mesh):
    """Sharding for decode caches, by leaf path + rank."""
    tp = mesh.shape.get("model", 1)

    def leaf_spec(path, sds):
        names = [str(getattr(p, "key", getattr(p, "idx", ""))) for p in path]
        leaf = names[-1]
        shape = sds.shape
        lead = 1 if (names[0] == "layers" and leaf != "pos") else 0
        if leaf == "pos":
            return P()
        b_idx = lead  # batch dim position
        dp = _dp_axes(mesh, shape[b_idx])
        parts = [None] * len(shape)
        parts[b_idx] = dp
        if leaf in ("k", "v", "xk", "xv"):
            # KV-head sharding when divisible: the per-token cache update
            # and the attention dots stay fully local (measured 0.04 ms
            # collective/step on qwen1.5 vs 64 ms seq-sharded).  For
            # kv % tp != 0 (qwen3/llava kv=8, chatglm kv=2) sequence
            # sharding measured cheapest (257 vs 513 MiB/chip hd-sharded,
            # 905 MiB batch-only on qwen3-L2).
            if _div(shape[lead + 2], tp):
                parts[lead + 2] = "model"
            elif _div(shape[lead + 1], tp):
                parts[lead + 1] = "model"
        elif leaf in ("c", "kr"):
            # MLA latent: SEQ sharding measured 2.7 MiB/chip collective
            # per 2 layers vs 76.2 feature-sharded (score psums) and
            # 210.2 batch-only -- the latent has no head axis, so the
            # flash-decoding score combine stays tiny per seq shard.
            if _div(shape[lead + 1], tp):
                parts[lead + 1] = "model"
            elif _div(shape[-1], tp):
                parts[-1] = "model"
        elif leaf == "h":        # (lead, B, nh, ns, hd)
            if _div(shape[lead + 1], tp):
                parts[lead + 1] = "model"
        elif leaf == "conv":     # (lead, B, W-1, C)
            if _div(shape[-1], tp):
                parts[-1] = "model"
        return P(*parts)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_shapes)


def sanitize_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop sharded axes whose extent does not divide the dim (hymba's
    in_proj width 6482, seamless' padded-but-odd tails, ...)."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, part in zip(shape, parts):
        if part is None:
            out.append(None)
            continue
        axes = part if isinstance(part, tuple) else (part,)
        keep = []
        prod = 1
        for a in axes:
            if dim % (prod * mesh.shape[a]) == 0:
                keep.append(a)
                prod *= mesh.shape[a]
        out.append(tuple(keep) if len(keep) > 1 else (keep[0] if keep
                                                      else None))
    return P(*out)


def sanitize_tree(shapes_tree, spec_tree, mesh: Mesh):
    return jax.tree.map(
        lambda sds, s: sanitize_spec(s, sds.shape, mesh),
        shapes_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _sh(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------


def cast_params(master, dtype):
    """f32 master -> compute dtype (>=2-d weights only; norms stay f32)."""
    return jax.tree.map(
        lambda p: p.astype(dtype) if p.ndim >= 2 else p, master)


def make_train_step(cfg: ModelConfig, ocfg: AdamWConfig,
                    param_specs=None, accum_steps: int = 1,
                    grad_specs=None):
    """Mixed-precision train step.

    The ZeRO-1 mechanics, made explicit:
      * ``cast_params`` on the (data x model)-sharded f32 master, constrained
        to the TP-only compute sharding, IS the ZeRO-1 all-gather -- and it
        happens in bf16 (half the gather bytes of gathering f32),
      * gradients are taken w.r.t. the bf16 compute params (bf16 DP
        all-reduce / reduce-scatter -- half the wire bytes), and only
        upcast to f32 inside the optimizer on the ZeRO-sharded view.
    ``accum_steps > 1`` scans over microbatches, dividing activation
    memory by the accumulation factor.
    """
    model = get_model(cfg)
    dtype = jnp.dtype(cfg.param_dtype)

    def cast_and_gather(master):
        """bf16 cast pinned at the ZeRO sharding, THEN regathered to the
        compute sharding -- forces the ZeRO-1 all-gather to move bf16, not
        f32 (2x wire + 2x buffer otherwise; measured on llava-34b)."""
        if param_specs is None or grad_specs is None:
            params = cast_params(master, dtype)
            if param_specs is not None:
                params = jax.tree.map(
                    lambda p, s: jax.lax.with_sharding_constraint(p, s)
                    if p.ndim >= 2 else p, params, param_specs)
            return params

        def one(p, zspec, pspec):
            if p.ndim < 2:
                return p
            p16 = jax.lax.with_sharding_constraint(p.astype(dtype), zspec)
            return jax.lax.with_sharding_constraint(p16, pspec)

        return jax.tree.map(one, master, grad_specs, param_specs)

    def train_step(state, batch):
        params = cast_and_gather(state["master"])

        if accum_steps == 1:
            (loss, metrics), grads = jax.value_and_grad(
                model.loss_fn, has_aux=True)(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)

            def body(acc, mb):
                (l, m), g = jax.value_and_grad(
                    model.loss_fn, has_aux=True)(params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return acc, (l, m)

            zeros = jax.tree.map(jnp.zeros_like, params)
            grads, (ls, ms) = jax.lax.scan(body, zeros, micro,
                                           unroll=cfg.unroll_scans)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = ls.mean()
            metrics = jax.tree.map(lambda x: x.mean(), ms)

        if grad_specs is not None:
            # force the ZeRO-1 reduce-scatter onto the gradients BEFORE the
            # optimizer math; otherwise XLA reshards mu/nu up to the grads'
            # TP-only sharding and the update runs 16x over-replicated
            grads = jax.tree.map(jax.lax.with_sharding_constraint, grads,
                                 grad_specs)
        new_state, om = adamw_update(ocfg, state, grads)
        metrics = dict(metrics, loss=loss, **om)
        return new_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    model = get_model(cfg)

    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    model = get_model(cfg)

    def decode_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    return decode_step


# ---------------------------------------------------------------------------
# AOT lowering for one (arch x shape x mesh) cell
# ---------------------------------------------------------------------------


def lower_cell(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
               ocfg: Optional[AdamWConfig] = None, accum_steps: int = 1):
    """Build shardings + ``jax.jit(...).lower(...)`` for one cell.

    Returns (lowered, meta) -- nothing is allocated (ShapeDtypeStructs only).
    """
    model = get_model(cfg)
    rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_shapes = jax.eval_shape(
        lambda r: model.init_params(r), rng)
    param_specs = sanitize_tree(params_shapes,
                                tree_param_specs(params_shapes), mesh)
    param_sh = _sh(mesh, param_specs)
    meta: Dict[str, Any] = {"arch": cfg.name, "shape": shape.name,
                            "mesh": dict(mesh.shape)}

    if shape.kind == "train":
        ocfg = ocfg or AdamWConfig()
        state_shapes = jax.eval_shape(adamw_init, params_shapes)
        st_specs = train_state_specs(state_shapes, param_specs, mesh)
        st_sh = _sh(mesh, st_specs)
        bspecs = batch_specs(cfg, shape)
        b_sh = _sh(mesh, batch_pspecs(cfg, bspecs, mesh))
        grad_sh = _sh(mesh, st_specs["master"])
        step = make_train_step(cfg, ocfg, param_specs=param_specs,
                               grad_specs=grad_sh,
                               accum_steps=accum_steps)
        with mesh_context(mesh):
            lowered = jax.jit(
                step,
                in_shardings=(st_sh, b_sh),
                out_shardings=(st_sh, NamedSharding(mesh, P())),
                donate_argnums=(0,),
            ).lower(state_shapes, bspecs)
        return lowered, meta

    if shape.kind == "prefill":
        bspecs = batch_specs(cfg, shape)
        b_sh = _sh(mesh, batch_pspecs(cfg, bspecs, mesh))
        step = make_prefill_step(cfg)
        with mesh_context(mesh):
            lowered = jax.jit(
                step, in_shardings=(param_sh, b_sh),
            ).lower(params_shapes, bspecs)
        return lowered, meta

    # decode
    dspecs = decode_specs(cfg, shape)
    cache_sh = _sh(mesh, cache_pspecs(cfg, dspecs["cache"], mesh))
    tok_dp = _dp_axes(mesh, shape.global_batch)
    tok_sh = NamedSharding(mesh, P(tok_dp, None))
    step = make_decode_step(cfg)
    with mesh_context(mesh):
        lowered = jax.jit(
            step,
            in_shardings=(param_sh, cache_sh, tok_sh),
            out_shardings=(tok_sh, cache_sh),
            donate_argnums=(1,),
        ).lower(params_shapes, dspecs["cache"], dspecs["tokens"])
    return lowered, meta
