"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --smoke --steps 50 --batch 4 --seq 64 --trace-dir /tmp/trace

On a real multi-host pod this process runs once per host
(jax.distributed.initialize picks rank/coordinator from env); on this
container it drives the same code path single-host.  ``--smoke`` selects
the reduced config so the example trains in CPU-minutes.
"""

from __future__ import annotations

import argparse
import json
import os

from ..configs import get_config, get_smoke_config
from ..core.recorder import RecorderConfig, session
from ..data import SyntheticConfig, synthetic_batch
from ..optim import AdamWConfig
from ..train import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--async-ckpt", action="store_true")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--trace-dir", default=None,
                    help="Recorder trace output (enables tracing)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    dcfg = SyntheticConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                           batch_size=args.batch)

    def data(step):
        b = synthetic_batch(dcfg, step)
        if cfg.family == "vlm":
            import numpy as np
            b["patches"] = np.zeros((args.batch, cfg.n_patches, cfg.d_model),
                                    np.float32)
        if cfg.family == "encdec":
            import numpy as np
            b["frames"] = np.random.RandomState(step).randn(
                args.batch, args.seq, cfg.d_model).astype(np.float32)
        return b

    tcfg = TrainerConfig(num_steps=args.steps, ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every,
                         async_ckpt=args.async_ckpt,
                         accum_steps=args.accum)
    ocfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                       total_steps=args.steps)

    def run():
        tr = Trainer(cfg, tcfg, ocfg, data=data)
        res = tr.run()
        print(json.dumps({"result": res,
                          "loss_first": tr.metrics_log[0]["loss"],
                          "loss_last": tr.metrics_log[-1]["loss"]},
                         indent=1))

    if args.trace_dir:
        with session(RecorderConfig(trace_dir=args.trace_dir)) as rec:
            run()
            print(f"traced {rec.n_records} records "
                  f"({len(rec.cst)} unique signatures) -> {args.trace_dir}")
    else:
        run()


if __name__ == "__main__":
    main()
