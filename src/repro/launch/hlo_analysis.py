"""Post-SPMD HLO analysis: collective bytes + roofline terms.

``compiled.as_text()`` is the per-device partitioned module; we sum operand
bytes of every collective op (all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute), giving per-chip bytes moved through the
interconnect per step.  ``cost_analysis()`` supplies per-device FLOPs and
bytes accessed.  Roofline constants are TPU v5e.
"""

from __future__ import annotations

import re
from typing import Any, Dict, List, Optional

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link (1 link-equivalent per chip)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  bf16[16,128]{1,0}  /  f32[]  /  (bf16[8,4], f32[8])
_SHAPE_RE = re.compile(r"\b([a-z]+\d*)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+"
                     r"([a-z][a-z0-9\-]*)\(")
_NAME_RE = re.compile(r"%[\w\.\-]+")


def collective_stats(hlo_text: str) -> Dict[str, Any]:
    """Per-op-kind operand bytes + counts from partitioned HLO text.

    Operands are name references; a first pass builds a symbol table from
    every instruction's result type (tuple types sum their element shapes).
    Async forms (``all-reduce-start``/``-done``) count once at ``-start``.
    """
    defs: Dict[str, int] = {}
    rows: List[tuple] = []
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rtype, op = m.groups()
        defs[name] = sum(_shape_bytes(d, s)
                         for d, s in _SHAPE_RE.findall(rtype))
        base = op[:-6] if op.endswith("-start") else op
        if base in _COLLECTIVES and not op.endswith("-done"):
            # operand names: inside the first paren group after the op name
            call = line[m.end():]
            depth, buf = 1, []
            for ch in call:
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
                buf.append(ch)
            rows.append((base, _NAME_RE.findall("".join(buf))))
    stats: Dict[str, Any] = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for kind, operands in rows:
        b = sum(defs.get(o, 0) for o in operands)
        stats[kind]["count"] += 1
        stats[kind]["bytes"] += b
    stats["total_bytes"] = sum(v["bytes"] for v in stats.values()
                               if isinstance(v, dict))
    return stats


def roofline_terms(cost: Optional[Dict[str, float]], coll_bytes: int,
                   model_flops_per_chip: float = 0.0,
                   analytic_bytes_per_chip: float = 0.0) -> Dict[str, float]:
    """Three roofline terms in seconds (per-chip quantities in, time out).

    Two memory terms are reported: ``t_memory_hlo_s`` from cost_analysis
    "bytes accessed" (on the CPU backend this sums per-instruction operand
    bytes with little fusion and f32-upcast bf16 -- a loose upper bound),
    and ``t_memory_s`` from the analytic traffic model (weights + optimizer
    + boundary activations + caches), which is what a fused TPU program
    actually moves.  Bottleneck/fraction use the analytic term.
    """
    flops = float(cost.get("flops", 0.0)) if cost else 0.0
    mem_hlo = float(cost.get("bytes accessed", 0.0)) if cost else 0.0
    t_compute = flops / PEAK_FLOPS
    t_mem_hlo = mem_hlo / HBM_BW
    t_memory = (analytic_bytes_per_chip / HBM_BW
                if analytic_bytes_per_chip else t_mem_hlo)
    t_coll = coll_bytes / ICI_BW
    dom = max((t_compute, "compute"), (t_memory, "memory"),
              (t_coll, "collective"))[1]
    out = {
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": mem_hlo,
        "analytic_bytes_per_chip": analytic_bytes_per_chip,
        "coll_bytes_per_chip": float(coll_bytes),
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_memory_hlo_s": t_mem_hlo,
        "t_collective_s": t_coll,
        "bottleneck": dom,
    }
    if model_flops_per_chip:
        out["model_flops_per_chip"] = model_flops_per_chip
        out["useful_flop_ratio"] = (model_flops_per_chip / flops
                                    if flops else 0.0)
        peak_t = model_flops_per_chip / PEAK_FLOPS
        tot = max(t_compute, t_memory, t_coll)
        out["roofline_fraction"] = peak_t / tot if tot else 0.0
    return out


def memory_analysis_dict(compiled) -> Dict[str, Any]:
    try:
        m = compiled.memory_analysis()
    except Exception:
        return {}
    if m is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(m, k, None)
        if v is not None:
            out[k] = int(v)
    if out:
        live = out.get("argument_size_in_bytes", 0) \
            + out.get("output_size_in_bytes", 0) \
            + out.get("temp_size_in_bytes", 0) \
            - out.get("alias_size_in_bytes", 0)
        out["peak_live_bytes_est"] = live
    return out
