"""Serving launcher: prefill a batch of prompts, greedy-decode, report
tokens/s; optionally trace the serving loop with Recorder.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-370m \
        --smoke --batch 4 --prompt-len 32 --new-tokens 32
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from ..configs import get_config, get_smoke_config
from ..core.recorder import RecorderConfig, session
from ..models import get_model
from ..serve import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--trace-dir", default=None)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    batch = {"tokens": rng.randint(0, cfg.vocab_size,
                                   size=(args.batch, args.prompt_len)
                                   ).astype(np.int32)}
    if cfg.family == "vlm":
        batch["patches"] = np.zeros(
            (args.batch, cfg.n_patches, cfg.d_model), np.float32)
    if cfg.family == "encdec":
        batch["frames"] = rng.randn(args.batch, args.prompt_len,
                                    cfg.d_model).astype(np.float32)

    def run():
        eng = ServeEngine(cfg, params, max_seq=args.max_seq)
        t0 = time.perf_counter()
        toks = eng.generate(batch, args.new_tokens)
        dt = time.perf_counter() - t0
        print(json.dumps({
            "generated_shape": list(toks.shape),
            "tokens_per_s": round(toks.size / dt, 1),
            "first_sequence": toks[0][:16].tolist(),
        }, indent=1))

    if args.trace_dir:
        with session(RecorderConfig(trace_dir=args.trace_dir)) as rec:
            run()
            print(f"traced {rec.n_records} records -> {args.trace_dir}")
    else:
        run()


if __name__ == "__main__":
    main()
