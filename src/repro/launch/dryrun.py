import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input shape) cell, lower + compile the step on
the production meshes and record memory / cost / collective analysis:

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b \
        --shape train_4k --mesh single            # one cell
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Artifacts land in artifacts/dryrun/<arch>__<shape>__<mesh>.json.  The
``XLA_FLAGS`` override above MUST run before any jax import -- jax locks
the device count at first init (which is why only this module sets it).
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax

from ..configs import all_arch_names, get_config
from ..models.config import ModelConfig
from . import hlo_analysis
from .mesh import make_production_mesh
from .shapes import SHAPES, applicable
from .steps import lower_cell

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "artifacts", "dryrun")


def model_flops_per_chip(cfg: ModelConfig, shape, n_chips: int) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (prefill) / 2*N per token (decode),
    with N = active params (MoE uses activated experts only)."""
    n_active = cfg.param_counts()["active"]
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n_active * toks / n_chips
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n_active * toks / n_chips
    toks = shape.global_batch  # one token per sequence
    return 2.0 * n_active * toks / n_chips


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             save: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = applicable(cfg, shape_name)
    result: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                              "mesh": mesh_kind}
    if not ok:
        result["status"] = "skip"
        result["reason"] = reason
        return result
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.size
    # memory policy: wide models microbatch the 1M-token train step
    accum = 4 if (shape.kind == "train" and cfg.d_model >= 5120) else 1
    result["accum_steps"] = accum
    t0 = time.time()
    try:
        lowered, meta = lower_cell(cfg, shape, mesh, accum_steps=accum)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        cost = compiled.cost_analysis()
        mema = hlo_analysis.memory_analysis_dict(compiled)
        coll = hlo_analysis.collective_stats(compiled.as_text())
        mf = model_flops_per_chip(cfg, shape, n_chips)
        terms = hlo_analysis.roofline_terms(cost, coll["total_bytes"], mf)
        result.update({
            "status": "ok",
            "n_chips": n_chips,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory": mema,
            "collectives": coll,
            "roofline": terms,
            "cost_keys": {k: cost[k] for k in ("flops", "bytes accessed")
                          if k in cost} if cost else {},
        })
    except Exception as e:  # deliberate: a failing cell is a bug report
        result["status"] = "fail"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-3000:]
    if save:
        os.makedirs(ARTIFACTS, exist_ok=True)
        fn = os.path.join(ARTIFACTS,
                          f"{arch}__{shape_name}__{mesh_kind}.json")
        with open(fn, "w") as f:
            json.dump(result, f, indent=1, default=str)
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    archs = all_arch_names() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                r = run_cell(arch, shape, mk)
                if r["status"] == "ok":
                    rt = r["roofline"]
                    print(f"OK   {arch:24s} {shape:12s} {mk:6s} "
                          f"compile={r['compile_s']:7.1f}s "
                          f"bottleneck={rt['bottleneck']:10s} "
                          f"frac={rt.get('roofline_fraction', 0):.3f}",
                          flush=True)
                    if r.get("memory"):
                        print(f"     mem/chip: "
                              f"args={r['memory'].get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
                              f"temp={r['memory'].get('temp_size_in_bytes', 0)/2**30:.2f}GiB",
                              flush=True)
                elif r["status"] == "skip":
                    print(f"SKIP {arch:24s} {shape:12s} {mk:6s} {r['reason']}",
                          flush=True)
                else:
                    failures += 1
                    print(f"FAIL {arch:24s} {shape:12s} {mk:6s} {r['error']}",
                          flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
