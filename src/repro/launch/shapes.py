"""Assigned input shapes x applicability, and ShapeDtypeStruct input specs.

Four shapes per LM architecture (40 cells total):

  train_4k      seq 4096,   global_batch 256   -> train_step
  prefill_32k   seq 32768,  global_batch 32    -> prefill
  decode_32k    seq 32768,  global_batch 128   -> decode_step (1 new token)
  long_500k     seq 524288, global_batch 1     -> decode_step

``long_500k`` requires sub-quadratic attention: only the SSM (mamba2) and
hybrid-SWA (hymba) architectures run it; pure full-attention archs record a
SKIP (DESIGN.md SectionArch-applicability).  Every cell is well-defined:
``input_specs`` returns weak-type-correct ShapeDtypeStructs, no allocation.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import get_model
from ..models.config import ModelConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped)."""
    if shape == "long_500k":
        if cfg.family == "ssm" or (cfg.hybrid and cfg.sliding_window):
            return True, ""
        return False, ("full O(S^2) attention at 524k tokens: skipped per "
                       "assignment rule (sub-quadratic archs only)")
    return True, ""


def _i32(*shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _act(cfg: ModelConfig, *shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.dtype(cfg.dtype))


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStructs for the data batch of a train/prefill step."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        st = S - cfg.n_patches
        out = {"tokens": _i32(B, st), "patches": _act(cfg, B, cfg.n_patches,
                                                      cfg.d_model)}
    elif cfg.family == "encdec":
        out = {"tokens": _i32(B, S),
               "frames": _act(cfg, B, S, cfg.d_model)}
    else:
        out = {"tokens": _i32(B, S)}
    if shape.kind == "train":
        out["labels"] = _i32(*out["tokens"].shape)
    return out


def decode_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Specs for one decode step: current tokens + full KV/state cache."""
    B, S = shape.global_batch, shape.seq_len
    model = get_model(cfg)
    cache = jax.eval_shape(partial(model.init_cache, B, S))
    return {"tokens": _i32(B, 1), "cache": cache}
