"""The paper's headline result, live: a parallel checkpoint workload traced
across 4 -> 512 simulated hosts compresses to a CONSTANT-size trace, while
the peephole baseline (Recorder-old) grows linearly.

    PYTHONPATH=src python examples/constant_trace_scaling.py
"""

import os
import shutil
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.workloads import flash_rank, run_ranks
from repro.core.baselines import RecorderOld, ToolAdapter
from repro.core.recorder import RecorderConfig


def main() -> None:
    print(f"{'ranks':>6s} {'records':>9s} {'Recorder CFG+CST':>17s} "
          f"{'Recorder-old':>13s} {'ratio':>7s}")
    for nprocs in (4, 16, 64, 256, 512):
        d = tempfile.mkdtemp()
        try:
            r = run_ranks(flash_rank, nprocs,
                          RecorderConfig(timestamps=False), data_dir=d,
                          iterations=60)
            old_total = 0
            for rank in range(nprocs):
                tool = RecorderOld(rank)
                flash_rank(ToolAdapter(tool, rank=rank), rank, nprocs,
                           data_dir=d, iterations=60)
                old_total += tool.nbytes
        finally:
            shutil.rmtree(d, ignore_errors=True)
        print(f"{nprocs:6d} {r['n_records']:9d} "
              f"{r['pattern_bytes']:15d} B {old_total:11d} B "
              f"{old_total / max(r['pattern_bytes'], 1):6.1f}x")
    print("\nRecorder's pattern files stay flat as ranks grow; the"
          " record-at-a-time baseline grows linearly (paper Figs 5-6).")


if __name__ == "__main__":
    main()
