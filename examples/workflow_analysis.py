"""Workflow I/O analysis (paper Section 4): trace a two-stage workflow --
train (writes checkpoints), then serve (reads nothing, emits serve_step
events) -- convert the trace to Chrome-timeline + columnar form, and answer
analysis questions that counter-based tools cannot (exact offsets, call
chains, per-thread activity).

    PYTHONPATH=src python examples/workflow_analysis.py
"""

import json
import os
import sys
import tempfile

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_smoke_config
from repro.core.converters import read_columnar, to_chrome_timeline, \
    to_columnar
from repro.core.recorder import RecorderConfig, session
from repro.core.reader import TraceReader
from repro.data import SyntheticConfig, synthetic_batch
from repro.launch.steps import cast_params
from repro.optim import AdamWConfig
from repro.serve import ServeEngine
from repro.train import Trainer, TrainerConfig


def main() -> None:
    cfg = get_smoke_config("qwen1.5-0.5b")
    dcfg = SyntheticConfig(vocab_size=cfg.vocab_size, seq_len=48,
                           batch_size=4)
    work = tempfile.mkdtemp(prefix="repro_workflow_")
    trace_dir = os.path.join(work, "trace")

    with session(RecorderConfig(trace_dir=trace_dir)) as rec:
        tr = Trainer(cfg, TrainerConfig(num_steps=20,
                                        ckpt_dir=os.path.join(work, "ck"),
                                        ckpt_every=10, async_ckpt=True),
                     AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20),
                     data=lambda s: synthetic_batch(dcfg, s))
        tr.run()
        params = cast_params(tr.state["master"], cfg.param_dtype)
        eng = ServeEngine(cfg, params, max_seq=96)
        eng.generate({"tokens": synthetic_batch(dcfg, 99)["tokens"]}, 12)

    # --- conversions (paper Section 2.3) ---------------------------------
    chrome = os.path.join(work, "timeline.json")
    n = to_chrome_timeline(trace_dir, chrome)
    cols_dir = os.path.join(work, "columnar")
    sizes = to_columnar(trace_dir, cols_dir)
    print(f"chrome timeline: {n} events -> {chrome} "
          f"({os.path.getsize(chrome)} B)")
    print(f"columnar dataset: {sum(sizes.values())} B in {len(sizes)} files")

    # --- analyses only a full-parameter trace supports -------------------
    cols = read_columnar(cols_dir)
    reader = TraceReader(trace_dir)
    writes = [(o, s) for o, s in zip(cols["offset"], cols["size"])
              if o >= 0 and s > 0]
    print(f"\n{len(writes)} offset-carrying data ops; "
          f"max file extent touched: {max(o + s for o, s in writes)} B")
    depths = cols["depth"]
    print("call-depth histogram (cross-layer cause and effect):",
          {int(d): int((depths == d).sum()) for d in sorted(set(depths))})
    threads = cols["thread"]
    print(f"threads observed: {sorted(set(int(t) for t in threads))} "
          f"(async checkpoint thread shows up as its own tid)")
    # cause-of-write: which framework-level op encloses each posix write?
    from repro.core.analysis import call_chains
    chains = call_chains(reader, targets={"pwrite", "write"})
    print("\nwrite call-chains:")
    for c, k in sorted(chains.items(), key=lambda kv: -kv[1]):
        print(f"  {k:5d}  {c}")


if __name__ == "__main__":
    main()
