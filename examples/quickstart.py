"""Quickstart: train a small LM with the full substrate -- traced data
pipeline, AdamW, fault-tolerant checkpointing -- then read the I/O trace
back and print what Recorder captured.

    PYTHONPATH=src python examples/quickstart.py [--steps 60]

Uses the qwen1.5-family reduced config (~1M params) so it runs in CPU
minutes; pass ``--big`` for a ~100M-param variant (same code path) if you
have the patience or a real accelerator.
"""

import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_smoke_config
from repro.core.recorder import RecorderConfig, session
from repro.core.reader import TraceReader
from repro.data import SyntheticConfig, synthetic_batch
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--big", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config("qwen1.5-0.5b")
    if args.big:  # ~100M params: d_model 512, 8 layers, full vocab
        cfg = cfg.replace(n_layers=8, d_model=512, n_heads=8, n_kv_heads=8,
                          d_ff=1408, vocab_size=151936)
    dcfg = SyntheticConfig(vocab_size=cfg.vocab_size, seq_len=64,
                           batch_size=8)
    work = tempfile.mkdtemp(prefix="repro_quickstart_")
    trace_dir = os.path.join(work, "trace")

    with session(RecorderConfig(trace_dir=trace_dir)) as rec:
        trainer = Trainer(
            cfg,
            TrainerConfig(num_steps=args.steps,
                          ckpt_dir=os.path.join(work, "ckpt"),
                          ckpt_every=max(args.steps // 3, 1)),
            AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=args.steps),
            data=lambda s: synthetic_batch(dcfg, s))
        result = trainer.run()
        print(f"trained {result['final_step']} steps: "
              f"loss {trainer.metrics_log[0]['loss']:.3f} -> "
              f"{result['last_loss']:.3f}")

    reader = TraceReader(trace_dir)
    by_layer = {}
    for r, rec_ in reader.all_records(timestamps=False):
        by_layer.setdefault(rec_.layer, {}).setdefault(rec_.func, 0)
        by_layer[rec_.layer][rec_.func] += 1
    print(f"\nRecorder captured {reader.n_records(0)} calls; trace files:")
    for f in sorted(os.listdir(trace_dir)):
        print(f"  {f:18s} {os.path.getsize(os.path.join(trace_dir, f)):7d} B")
    print("\ncalls by layer (the framework's own I/O stack):")
    for layer, funcs in sorted(by_layer.items()):
        top = sorted(funcs.items(), key=lambda kv: -kv[1])[:4]
        print(f"  {layer:8s} " + "  ".join(f"{k}x{v}" for k, v in top))


if __name__ == "__main__":
    main()
