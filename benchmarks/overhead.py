"""Paper Fig 10: time overhead -- no tool vs Darshan-like vs Recorder vs
Recorder-old, same wrappers, same single-rank FLASH-analogue workload.

Reports normalized wall time (tool / no-tool) and per-call microseconds.
tmpfs I/O is far faster than Lustre, so the normalized ratios here are an
UPPER bound on the paper's (<=3% on a real file system); the per-call cost
is the portable number.
"""

from __future__ import annotations

import csv
import os
import shutil
import tempfile
import time
from typing import List

from repro.core.baselines import DarshanLike, RecorderOld, ToolAdapter
from repro.core.recorder import Recorder, RecorderConfig

from .workloads import flash_rank

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


class _NoTool:
    """Passthrough: wrappers see no active recorder (rec is None)."""


def _time_one(make_tool, iterations: int, repeats: int = 3) -> dict:
    best = float("inf")
    n_records = 0
    for _ in range(repeats):
        d = tempfile.mkdtemp()
        tool = make_tool()
        t0 = time.perf_counter()
        flash_rank(tool, 0, 1, iterations=iterations, data_dir=d)
        dt = time.perf_counter() - t0
        shutil.rmtree(d, ignore_errors=True)
        best = min(best, dt)
        if tool is not None:
            n_records = getattr(tool, "n_records", 0) or getattr(
                getattr(tool, "_tool", None), "n_records", 0)
    return {"seconds": best, "n_records": n_records}


def main(fast: bool = False) -> List[str]:
    os.makedirs(ART, exist_ok=True)
    iters = 200 if fast else 1000
    runs = {
        "none": _time_one(lambda: None, iters),
        "recorder": _time_one(lambda: Recorder(0, RecorderConfig()), iters),
        "recorder_old": _time_one(
            lambda: ToolAdapter(RecorderOld(0)), iters),
        "darshan": _time_one(lambda: ToolAdapter(DarshanLike(0)), iters),
    }
    base = runs["none"]["seconds"]
    nrec = max(runs["recorder"]["n_records"], 1)
    rows = []
    for name, r in runs.items():
        over_us = (r["seconds"] - base) * 1e6 / nrec if name != "none" else 0.0
        rows.append({"tool": name, "seconds": round(r["seconds"], 4),
                     "normalized": round(r["seconds"] / base, 3),
                     "overhead_us_per_call": round(over_us, 3),
                     "n_records": r["n_records"]})
    with open(os.path.join(ART, "overhead.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, rows[0].keys())
        w.writeheader()
        w.writerows(rows)
    rec = next(r for r in rows if r["tool"] == "recorder")
    old = next(r for r in rows if r["tool"] == "recorder_old")
    dar = next(r for r in rows if r["tool"] == "darshan")
    return [f"overhead,recorder_norm={rec['normalized']},"
            f"old_norm={old['normalized']},darshan_norm={dar['normalized']},"
            f"recorder_us_per_call={rec['overhead_us_per_call']}"]


if __name__ == "__main__":
    for line in main():
        print(line)
