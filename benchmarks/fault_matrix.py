"""Fault-injection scenario matrix: the durability contract, enforced.

Drives a fixed, seeded matrix of streamed tracing runs with faults
injected through :mod:`repro.core.faults` -- comm message drops/delays, a
rank going mute mid-run, mid-commit crashes at every commit point, torn
in-flight writes, post-commit bit rot and ENOSPC -- and asserts, for
every scenario, the one property the fault-tolerance work exists to
provide:

  the surviving trace directory is fully readable, or the damage is
  REPORTED (skipped segments / ``ranks_present`` degraded masks /
  a typed error) -- never a trace that decodes but lies;
  and no survivor deadlocks: every scenario completes within its
  timeout budget.

Record accounting is exact: each scenario states how many records MUST
be served (committed, intact epochs) and the decoded count is checked
against it, so a fault can neither silently drop a committed record nor
double-count a retried one.

Writes artifacts/bench/fault_matrix.json:
  {"config": ..., "rows": [one per scenario with the invariant report]}

    PYTHONPATH=src python -m benchmarks.fault_matrix [--smoke]
"""

from __future__ import annotations

import json
import os
import random
import shutil
import sys
import tempfile
import time
from typing import Any, Dict, List, Optional

from repro.core import faults, trace_format
from repro.core.comm import run_thread_world
from repro.core.faults import FaultPlan, SimulatedCrash
from repro.core.recorder import Recorder, RecorderConfig
from repro.core.specs import REGISTRY
import repro.core.apis  # noqa: F401  (populate registry)

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")

SEED = 20260808
FLUSH_TIMEOUT_S = 2.0
#: hard wall-clock ceiling per scenario -- the no-deadlock assertion
SCENARIO_BUDGET_S = 60.0


def _feed(rec: Recorder, rank: int, nranks: int, n: int, seed: int,
          tick_start: int = 0) -> int:
    fid = REGISTRY.id_of("pwrite")
    rng = random.Random(seed * 1000003 + rank)
    t = tick_start
    for i in range(n):
        off = rank * 4096 + i * nranks * 4096 + rng.randrange(16) * 512
        rec.record(fid, (f"fd-{rank}", b"x" * 4096, off), 4096, 0, t, t + 1)
        t += 2
    return t


def _run_scenario(name: str, *, nranks: int, plan: Optional[FaultPlan],
                  epochs: int = 3, records_per_epoch: int = 50,
                  uninstall_after_epoch: Optional[int] = None,
                  rot_file: Optional[str] = None) -> Dict[str, Any]:
    """One streamed run under ``plan``; returns the invariant report row.

    Crashes/ENOSPC are caught per rank exactly as a driver supervising a
    preempted job would observe them; ``uninstall_after_epoch`` models
    the fault clearing (node recovers, disk space freed) so later
    flushes can cover the retained deltas.
    """
    sd = tempfile.mkdtemp(prefix="fault_matrix_")
    t0 = time.perf_counter()
    if plan is not None:
        faults.install(plan)
    try:
        def worker(comm, rank):
            import warnings as W
            with W.catch_warnings():
                W.simplefilter("ignore")
                rec = Recorder(rank=rank, config=RecorderConfig(
                    trace_dir=sd,
                    flush_timeout_s=FLUSH_TIMEOUT_S if nranks > 1 else None))
                t, failures = 0, 0
                for e in range(epochs):
                    t = _feed(rec, rank, nranks, records_per_epoch,
                              SEED + e, t)
                    try:
                        rec.flush(comm)
                    except (OSError, SimulatedCrash):
                        failures += 1
                    if nranks > 1:
                        comm.barrier()
                    if rank == 0 and uninstall_after_epoch == e:
                        faults.uninstall()
                    if nranks > 1:
                        comm.barrier()
                try:
                    rec.finalize(comm)
                except (OSError, SimulatedCrash):
                    failures += 1
                return {"failures": failures,
                        "restored": rec.epochs_restored,
                        "degraded": rec.epochs_degraded}

        if nranks == 1:
            rank_stats = [worker(None, 0)]
        else:
            rank_stats = run_thread_world(nranks, worker)
    finally:
        faults.uninstall()
    if rot_file is not None:
        # post-commit bit rot on the oldest committed segment
        segs = trace_format.read_manifest(sd).get("segments", [])
        if segs:
            faults.corrupt_file(
                os.path.join(sd, segs[0]["name"], rot_file), seed=SEED)
    elapsed = time.perf_counter() - t0
    report = faults.check_trace_invariants(sd)
    manifest = trace_format.read_manifest(sd) \
        if trace_format.is_stream_dir(sd) else {"segments": []}
    # exact accounting: served records == sum of the intact committed
    # segments' manifest counts (a degraded epoch's count already reflects
    # only the present ranks)
    skipped = {s["segment"] for s in report["skipped"]}
    expected = sum(e["n_records"] for e in manifest["segments"]
                   if e["name"] not in skipped)
    row = {
        "scenario": name,
        "nranks": nranks,
        "plan": {k: v for k, v in (plan.__dict__.items() if plan else [])
                 if not k.startswith("_") and k != "counters" and v},
        "fault_counters": dict(plan.counters) if plan else {},
        "rank_stats": rank_stats,
        "elapsed_s": round(elapsed, 3),
        "within_budget": elapsed < SCENARIO_BUDGET_S,
        "n_committed_segments": len(manifest["segments"]),
        "invariants": report,
        "expected_records": expected,
        "accounting_exact": report["n_records"] == expected,
        "ok": (report["readable"] or report["error"] is not None)
        and report["n_records"] == expected
        and elapsed < SCENARIO_BUDGET_S,
    }
    shutil.rmtree(sd, ignore_errors=True)
    return row


def scenarios(fast: bool) -> List[Dict[str, Any]]:
    nr = 2 if fast else 4
    rows = [
        dict(name="baseline_no_faults", nranks=nr, plan=None),
        dict(name="enospc_then_recover", nranks=1,
             plan=FaultPlan(seed=SEED, fail_write_at=7),
             uninstall_after_epoch=1),
        dict(name="crash_pre_rename", nranks=1,
             plan=FaultPlan(seed=SEED, crash_point="pre-rename",
                            crash_epoch=1), uninstall_after_epoch=1),
        dict(name="crash_pre_manifest", nranks=1,
             plan=FaultPlan(seed=SEED, crash_point="pre-manifest",
                            crash_epoch=1), uninstall_after_epoch=1),
        dict(name="torn_write_in_flight", nranks=1,
             plan=FaultPlan(seed=SEED, torn_file="merged_cst.bin",
                            torn_at=2)),
        dict(name="bit_rot_post_commit", nranks=1, plan=None,
             rot_file="unique_cfgs.bin"),
        dict(name="dead_rank_degraded_commit", nranks=nr,
             plan=FaultPlan(seed=SEED, dead_ranks=(1,)),
             uninstall_after_epoch=0),
        dict(name="message_delays_within_timeout", nranks=nr,
             plan=FaultPlan(seed=SEED, delay_prob=0.5, delay_s=0.05),
             uninstall_after_epoch=2),
    ]
    if not fast:
        rows.append(dict(
            name="random_drops_survivors_commit", nranks=nr,
            plan=FaultPlan(seed=SEED, drop_prob=0.05),
            uninstall_after_epoch=1))
    return rows


def main(fast: bool = False) -> List[str]:
    os.makedirs(ART, exist_ok=True)
    rows = [_run_scenario(s.pop("name"), **s) for s in scenarios(fast)]
    out = {"config": {"fast": fast, "seed": SEED,
                      "flush_timeout_s": FLUSH_TIMEOUT_S,
                      "scenario_budget_s": SCENARIO_BUDGET_S},
           "rows": rows}
    with open(os.path.join(ART, "fault_matrix.json"), "w") as f:
        json.dump(out, f, indent=1)
    lines = []
    for row in rows:
        inv = row["invariants"]
        lines.append(
            f"fault_matrix,{row['scenario']},nranks={row['nranks']},"
            f"records={inv['n_records']}/{row['expected_records']},"
            f"skipped={len(inv['skipped'])},"
            f"degraded={len(inv['degraded_epochs'])},"
            f"elapsed_s={row['elapsed_s']},ok={row['ok']}")
        assert row["within_budget"], (
            f"{row['scenario']}: took {row['elapsed_s']}s -- a survivor "
            f"wedged past the timeout budget")
        assert row["ok"], (
            f"{row['scenario']}: trace neither fully readable nor "
            f"correctly reported ({inv})")
    baseline = rows[0]["invariants"]
    assert baseline["n_records"] > 0 and not baseline["skipped"], \
        "baseline scenario must serve a complete trace"
    return lines


if __name__ == "__main__":
    for line in main(fast="--smoke" in sys.argv or "--fast" in sys.argv):
        print(line)
