"""Compressed-domain DFG / phase observability: sublinear-in-records cost.

Two contracts, straight from the grammar (``repro.core.dfg``):

  * **flat wall time as records grow**: the directly-follows graph, the
    phase segmentation, and the cross-rank divergence report are all
    O(|grammar| + |CST|) walks -- growing the record count 100x at fixed
    grammar size (``synth_rank_states`` run-length shapes) may not grow
    the query wall time past ``FLAT_FACTOR`` x the smallest point plus an
    absolute slack.  A per-record scan would grow 100x.
  * **incremental fold accounting**: a live streaming job queried through
    the trace service answers ``dfg`` / ``phases`` / ``anomalies`` after
    every commit at exactly one segment fold per committed epoch
    (``stats["segment_folds"] == epochs - 1``) -- the fold walks only the
    delta grammar, never the stitched history.

Writes artifacts/bench/dfg_phase.json:
  {"config": ..., "rows": [...], "incremental": {...}}, one row per
  (records_per_rank, query) with wall_s, grammar_items, n_records_total.

    PYTHONPATH=src python -m benchmarks.dfg_bench [--smoke]
"""

from __future__ import annotations

import json
import os
import random
import shutil
import sys
import tempfile
import time
from typing import Any, Dict, List, Sequence, Tuple

from repro.core import trace_format
from repro.core.interprocess import tree_finalize_ranks
from repro.core.reader import TraceReader
from repro.core.recorder import Recorder, RecorderConfig
from repro.core.specs import REGISTRY
from repro.core.traceview import TraceView
from repro.traceserve import TraceService
import repro.core.apis  # noqa: F401  (populate registry)

from .workloads import synth_rank_states

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")

FLAT_FACTOR = 5.0     # largest point may cost at most this x the smallest
ABS_SLACK_S = 0.010   # plus this much absolute timing noise allowance


def _build_trace(records_per_rank: int, nranks: int, pattern: str,
                 n_groups: int, tmp: str) -> str:
    n_calls = max(1, records_per_rank // n_groups)
    csts, cfgs = synth_rank_states(nranks, n_groups=n_groups,
                                   n_calls=n_calls, pattern=pattern)
    merge, cfgres = tree_finalize_ranks(csts, cfgs, REGISTRY)
    d = os.path.join(tmp, f"dfg_{records_per_rank}_{nranks}_{pattern}")
    trace_format.write_trace(d, registry=REGISTRY,
                             merged_cst=merge.merged_entries,
                             unique_cfgs=cfgres.unique_cfgs,
                             cfg_index=cfgres.cfg_index,
                             rank_timestamps=[b""] * nranks, meta_extra={})
    return d


def _timed(fn) -> Tuple[float, Any]:
    t0 = time.perf_counter()
    res = fn()
    return time.perf_counter() - t0, res


def sweep(records_per_rank_list: Sequence[int], nranks: int = 8,
          pattern: str = "mixed_all", n_groups: int = 8) -> List[dict]:
    rows: List[dict] = []
    tmp = tempfile.mkdtemp(prefix="dfg_bench_")
    try:
        for rpr in records_per_rank_list:
            d = _build_trace(rpr, nranks, pattern, n_groups, tmp)
            reader = TraceReader(d)
            reader.view()  # columnar decode off the timed path
            grammar_items = sum(
                sum(len(items) for items in g) for g in reader.unique_cfgs)
            queries = [
                ("dfg", lambda v: v.dfg()),
                ("phases", lambda v: v.phases(0)),
                ("rank_divergence", lambda v: v.rank_divergence()),
            ]
            for qname, q in queries:
                view = TraceView(reader)  # fresh memos per query
                wall_s, res = _timed(lambda: q(view))
                rows.append({
                    "records_per_rank": rpr, "nranks": nranks,
                    "pattern": pattern, "query": qname,
                    "n_records_total": view.total_records(),
                    "grammar_items": grammar_items,
                    "wall_s": wall_s,
                    "result_size": len(res["edges"]) if qname == "dfg"
                    else len(res) if qname == "phases"
                    else len(res["per_rank"]),
                })
            shutil.rmtree(d, ignore_errors=True)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return rows


def _feed_epoch(rec: Recorder, rng: random.Random, epoch: int,
                calls: int) -> None:
    fids = {n: REGISTRY.id_of(n) for n in ("pwrite", "lseek", "write")}
    t = epoch * (calls + 1) * 2
    fd = "fd-0"
    if epoch == 0:
        rec.record(REGISTRY.id_of("open"), ("/data/f.bin", 2, 438), fd,
                   0, t, t + 1)
        t += 2
    for i in range(calls):
        kind = rng.random()
        if kind < 0.6:
            off = (epoch * calls + i) * 4096
            rec.record(fids["pwrite"], (fd, b"x" * 4096, off), 4096,
                       0, t, t + 1)
        elif kind < 0.8:
            rec.record(fids["lseek"], (fd, i * 256, 0), i * 256, 0, t, t + 1)
        else:
            rec.record(fids["write"], (fd, b"z" * 128), 128, 0, t, t + 1)
        t += 2


def incremental(epochs: int, calls_per_epoch: int) -> Dict[str, Any]:
    """Stream one job epoch by epoch; after every commit answer the three
    observability families from the service and account the folds."""
    root = tempfile.mkdtemp(prefix="dfg_bench_stream_")
    try:
        rec = Recorder(rank=0, config=RecorderConfig(
            trace_dir=os.path.join(root, "job")))
        rng = random.Random(7)
        _feed_epoch(rec, rng, 0, calls_per_epoch)
        rec.flush()
        lat: List[float] = []
        with TraceService(root, mode="stitched",
                          max_staleness_s=0.0) as svc:
            for e in range(epochs):
                if e:
                    _feed_epoch(rec, rng, e, calls_per_epoch)
                    rec.flush()
                t0 = time.perf_counter()
                svc.query("job", "dfg")
                svc.phases("job", rank=0)
                svc.anomalies("job")
                lat.append(time.perf_counter() - t0)
            folds = svc.stats()["cache"]["segment_folds"]
        assert folds == epochs - 1, (
            f"incremental contract broken: served {epochs} epochs with "
            f"{folds} segment folds (expected {epochs - 1})")
        return {"epochs": epochs, "calls_per_epoch": calls_per_epoch,
                "segment_folds": folds,
                "first_query_s": lat[0], "last_query_s": lat[-1]}
    finally:
        shutil.rmtree(root, ignore_errors=True)


def main(fast: bool = False) -> List[str]:
    os.makedirs(ART, exist_ok=True)
    sizes = (100, 1_000, 10_000) if fast else (1_000, 10_000, 100_000)
    rows = sweep(sizes)
    inc = incremental(epochs=4 if fast else 10,
                      calls_per_epoch=200 if fast else 1_000)
    out = {"config": {"fast": fast, "flat_factor": FLAT_FACTOR,
                      "abs_slack_s": ABS_SLACK_S},
           "rows": rows, "incremental": inc}
    with open(os.path.join(ART, "dfg_phase.json"), "w") as f:
        json.dump(out, f, indent=1)
    lines = []
    for qname in ("dfg", "phases", "rank_divergence"):
        pts = sorted((r for r in rows if r["query"] == qname),
                     key=lambda r: r["records_per_rank"])
        small, big = pts[0], pts[-1]
        growth = big["n_records_total"] / max(small["n_records_total"], 1)
        assert big["wall_s"] <= FLAT_FACTOR * small["wall_s"] + ABS_SLACK_S, (
            f"{qname} wall time grew with records at fixed grammar size: "
            f"{small['wall_s']:.6f}s -> {big['wall_s']:.6f}s "
            f"over {growth:.0f}x records")
        lines.append(
            f"dfg_bench,{qname},records={small['n_records_total']}"
            f"->{big['n_records_total']},wall_s={small['wall_s']:.6f}"
            f"->{big['wall_s']:.6f},records_growth={growth:.0f}x")
    lines.append(
        f"dfg_bench,incremental,epochs={inc['epochs']},"
        f"segment_folds={inc['segment_folds']},"
        f"last_query_s={inc['last_query_s']:.6f}")
    return lines


if __name__ == "__main__":
    for line in main(fast="--smoke" in sys.argv or "--fast" in sys.argv):
        print(line)
