"""Streaming flush scaling: per-flush cost must stay O(delta), not O(total).

Sweeps epochs x ranks over simulated IOR-shaped epoch workloads.  Each
epoch drives every rank's Recorder with a fixed-size call window, then
times the full flush critical path exactly as ``Recorder.flush`` runs it:

  take_epoch -> leaf RankState per rank -> pairwise tree reduction ->
  CumulativeState.append (the incremental cross-epoch fold) ->
  materialize the DELTA -> block-compress timestamps -> atomic segment
  commit + manifest rewrite.

Because the cumulative fold inserts only the epoch's groups and defers
stream concatenation to finalize, per-flush wall time must be roughly
constant in the epoch index -- a naive design that re-reduces (or even
copies) the accumulated history would grow linearly.  ``main`` asserts
flatness with noise-robust statistics: the MIN of the last three flushes
must stay within ``FLAT_FACTOR`` of the min of flushes 2-4 plus a small
absolute slack (min, not mean: a single scheduler stall on a shared CI
runner inflates one sample, not all three; the first flush is excluded
because it pays one-time imports/allocations).  A genuine O(total)
regression inflates EVERY late flush, so the min still catches it.

The emitted JSON also records a time-windowed read-side probe: a
``bandwidth_bounds`` query over one epoch's window on the stitched
``TraceView`` must decompress ONLY the timestamp blocks intersecting the
window (``ts_store.blocks_touched``), asserted here as well.

A second sweep measures the FOREGROUND STALL -- the application-visible
pause of one ``Recorder.flush`` call -- sync (commit inline) vs async
(``async_flush=True``: snapshot only, commit in the background executor).
Asserted: the async median stall is below the sync median (the pause a
tracer adds to the traced application shrank), and async stalls stay flat
as epochs accumulate (zero stall growth; same min-based robust statistic
as the flush-cost flatness check).

Writes artifacts/bench/streaming_flush.json:
  {"config": ..., "rows": [...], "window_probe": {...},
   "foreground_stall": {...}}, one row per (nranks, epoch) with flush_s
  and the flatness verdict per nranks.

    PYTHONPATH=src python -m benchmarks.streaming_flush [--smoke]
"""

from __future__ import annotations

import json
import os
import shutil
import sys
import tempfile
import time
from typing import Dict, List

from repro.core import streaming
from repro.core.interprocess import (make_rank_state, materialize_state,
                                     serialize_rank_state,
                                     tree_reduce_states)
from repro.core.reader import TraceReader
from repro.core.recorder import Recorder, RecorderConfig
from repro.core.specs import REGISTRY
from repro.core.timestamps import (compress_timestamps_blocked,
                                   pack_ts_blocks, unpack_ts_blocks)
import repro.core.apis  # noqa: F401  (populate registry)

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")

FLAT_FACTOR = 4.0  # late flushes may cost at most this x early flushes
ABS_SLACK_S = 0.010  # plus this much absolute noise allowance
TS_BLOCK_RECORDS = 256


def _feed_epoch(recs: List[Recorder], epoch: int, calls_per_epoch: int,
                chunk: int = 4096) -> None:
    """One IOR-shaped window per rank: strided pwrites whose offsets are
    rank-linear and advance with the epoch (fresh offsets every epoch, so
    every flush carries a real delta)."""
    fid = REGISTRY.id_of("pwrite")
    nranks = len(recs)
    t0 = epoch * calls_per_epoch * 2
    for r, rec in enumerate(recs):
        fd = "FD"
        base = r * chunk + epoch * calls_per_epoch * nranks * chunk
        for i in range(calls_per_epoch):
            off = base + i * nranks * chunk
            t = t0 + 2 * i
            rec.record(fid, (fd, b"x" * chunk, off), chunk, 0, t, t + 1)


def _flush_once(recs: List[Recorder], cum: streaming.CumulativeState,
                trace_dir: str, epoch: int, n_records: int) -> float:
    """The rank-0 flush critical path over simulated ranks (the same data
    path as Recorder.flush / streaming.run_flush, minus thread-barrier
    noise)."""
    t0 = time.perf_counter()
    leaves = []
    packed = []
    for r, rec in enumerate(recs):
        entries, cfg, ticks, _wraps = rec.take_epoch()
        leaves.append(make_rank_state(r, entries, cfg, REGISTRY))
        packed.append(pack_ts_blocks(
            compress_timestamps_blocked(ticks, TS_BLOCK_RECORDS)
            if len(ticks) else []))
    delta = tree_reduce_states(leaves)
    blob = serialize_rank_state(delta)
    cum.append(delta)
    merge, cfgs = materialize_state(delta)
    streaming.write_epoch_segment(
        trace_dir, epoch, registry=REGISTRY, merge=merge, cfgs=cfgs,
        rank_ts_blocks=[unpack_ts_blocks(p) for p in packed],
        state_blob=blob, n_records=n_records, meta_extra={})
    return time.perf_counter() - t0


def sweep(nranks_list, epochs: int, calls_per_epoch: int) -> Dict:
    rows = []
    flat: Dict[str, Dict] = {}
    tmp = tempfile.mkdtemp(prefix="streaming_flush_")
    window_probe = None
    try:
        for nranks in nranks_list:
            trace_dir = os.path.join(tmp, f"trace_{nranks}")
            recs = [Recorder(rank=r, config=RecorderConfig())
                    for r in range(nranks)]
            cum = streaming.CumulativeState()
            times = []
            for e in range(epochs):
                _feed_epoch(recs, e, calls_per_epoch)
                dt = _flush_once(recs, cum, trace_dir, e,
                                 nranks * calls_per_epoch)
                times.append(dt)
                rows.append({"nranks": nranks, "epoch": e, "flush_s": dt,
                             "calls_per_epoch": calls_per_epoch})
            early = min(times[1:4])
            late = min(times[-3:])
            flat[str(nranks)] = {
                "early_flush_s": early, "late_flush_s": late,
                "ratio": late / max(early, 1e-9),
                "flat": late <= FLAT_FACTOR * early + ABS_SLACK_S,
            }
            if window_probe is None:
                # read-side probe on the largest-so-far trace: one epoch's
                # time window must decompress only intersecting blocks
                view = TraceReader(trace_dir, mode="stitched").view()
                store = view.ts_store
                total = sum(store.n_blocks(r) for r in range(nranks))
                before = store.blocks_touched
                t_lo = (epochs - 1) * calls_per_epoch * 2
                bounds = view.bandwidth_bounds(t_lo, t_lo + 50)
                touched = store.blocks_touched - before
                window_probe = {
                    "blocks_total": total, "blocks_touched": touched,
                    "n_calls": bounds["n_calls"],
                    "only_touched_intersecting": 0 < touched < total,
                }
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return {"rows": rows, "flat": flat, "window_probe": window_probe}


def foreground_stall(epochs: int, calls_per_epoch: int) -> Dict:
    """Application-visible pause of one ``Recorder.flush`` call, sync vs
    async, over a real solo Recorder.  The async run drains AFTER each
    stall window closes, so both runs commit identical epoch sequences
    (no coalescing) and only the pause location differs."""
    stalls: Dict[str, List[float]] = {}
    tmp = tempfile.mkdtemp(prefix="streaming_stall_")
    try:
        for mode in ("sync", "async"):
            rec = Recorder(config=RecorderConfig(
                trace_dir=os.path.join(tmp, mode),
                ts_block_records=TS_BLOCK_RECORDS,
                async_flush=(mode == "async")))
            times = []
            for e in range(epochs):
                _feed_epoch([rec], e, calls_per_epoch)
                t0 = time.perf_counter()
                rec.flush()
                times.append(time.perf_counter() - t0)
                if mode == "async":
                    rec.drain()
            rec.finalize()
            stalls[mode] = times
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    def med(xs: List[float]) -> float:
        return sorted(xs)[len(xs) // 2]

    early = min(stalls["async"][1:4])
    late = min(stalls["async"][-3:])
    return {
        "sync_stall_s": stalls["sync"],
        "async_stall_s": stalls["async"],
        "sync_median_s": med(stalls["sync"]),
        "async_median_s": med(stalls["async"]),
        "reduced": med(stalls["async"]) < med(stalls["sync"]),
        "async_early_s": early,
        "async_late_s": late,
        "async_flat": late <= FLAT_FACTOR * early + ABS_SLACK_S,
    }


def main(fast: bool = False) -> List[str]:
    os.makedirs(ART, exist_ok=True)
    if fast:
        nranks_list, epochs, calls = (4, 16), 8, 400
    else:
        nranks_list, epochs, calls = (4, 16, 64), 16, 2000
    out = sweep(nranks_list, epochs, calls)
    out["foreground_stall"] = foreground_stall(epochs, calls)
    out["config"] = {"fast": fast, "epochs": epochs,
                     "calls_per_epoch": calls, "flat_factor": FLAT_FACTOR,
                     "abs_slack_s": ABS_SLACK_S,
                     "ts_block_records": TS_BLOCK_RECORDS}
    with open(os.path.join(ART, "streaming_flush.json"), "w") as f:
        json.dump(out, f, indent=1)
    lines = []
    for nranks, v in out["flat"].items():
        lines.append(
            f"streaming_flush,nranks={nranks},epochs={epochs},"
            f"early_s={v['early_flush_s']:.4f},late_s={v['late_flush_s']:.4f},"
            f"ratio={v['ratio']:.2f},flat={v['flat']}")
        assert v["flat"], (
            f"per-flush time grew {v['ratio']:.1f}x from early to late "
            f"epochs at {nranks} ranks -- incremental fold regressed")
    wp = out["window_probe"]
    lines.append(
        f"streaming_flush,window_blocks={wp['blocks_touched']}/"
        f"{wp['blocks_total']},only_intersecting="
        f"{wp['only_touched_intersecting']}")
    assert wp["only_touched_intersecting"], (
        "time-windowed query decompressed every timestamp block")
    st = out["foreground_stall"]
    lines.append(
        f"streaming_flush,stall_sync_med_s={st['sync_median_s']:.5f},"
        f"stall_async_med_s={st['async_median_s']:.5f},"
        f"reduced={st['reduced']},async_flat={st['async_flat']}")
    assert st["reduced"], (
        f"async flush did not reduce the foreground stall "
        f"(sync median {st['sync_median_s']:.5f}s, async median "
        f"{st['async_median_s']:.5f}s)")
    assert st["async_flat"], (
        f"async foreground stall grew across epochs "
        f"(early {st['async_early_s']:.5f}s -> late {st['async_late_s']:.5f}s)"
        f" -- the snapshot path stopped being O(delta)")
    return lines


if __name__ == "__main__":
    for line in main(fast="--smoke" in sys.argv or "--fast" in sys.argv):
        print(line)
