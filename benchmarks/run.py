"""Benchmark aggregator: one harness per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Prints one CSV summary line per experiment; full CSVs land in
artifacts/bench/.  --fast shrinks rank counts/iterations for CI.
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    fast = "--fast" in sys.argv
    from . import dfg_bench, flash_scaling, ior_pattern, kernel_bench, \
        overhead, streaming_flush, tool_comparison, trace_service

    # reader_scaling is intentionally NOT in this list: CI runs it as its
    # own `python -m benchmarks.reader_scaling --smoke` step (and the full
    # sweep is a standalone run), so including it here would time the same
    # sweep twice per CI run.  streaming_flush IS here (it asserts the
    # O(delta) per-flush invariant, cheap either way) and also gets its own
    # CI --smoke step so a regression is attributable at a glance.
    print("experiment,summary")
    for name, mod in (("ior_pattern", ior_pattern),
                      ("flash_scaling", flash_scaling),
                      ("tool_comparison", tool_comparison),
                      ("overhead", overhead),
                      ("streaming_flush", streaming_flush),
                      ("trace_service", trace_service),
                      ("dfg_bench", dfg_bench),
                      ("kernel_bench", kernel_bench)):
        t0 = time.time()
        try:
            for line in mod.main(fast=fast):
                print(line, flush=True)
        except Exception as e:  # pragma: no cover
            print(f"{name},FAILED: {type(e).__name__}: {e}", flush=True)
            raise
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
