"""Paper Figs 4-5: impact of intra-/inter-process I/O pattern recognition,
plus the finalize-scaling experiment for the tree-reduction topology.

Fig 4 (blocksize): fixed nprocs, increasing call count per rank; with
intra-process recognition the trace size must be FLAT in call count.
Fig 5 (scaling): fixed call count, increasing nprocs; with inter-process
recognition the trace size must be FLAT in process count.

Finalize scaling: sweeps simulated rank counts x {flat, tree} topology x
{python, vectorized} fit mode over synthesized IOR-shaped rank states and
times the inter-process finalization.  For the tree topology the reported
wall time is the *critical path* a real deployment would see -- the slowest
leaf build (leaves are built concurrently, one per rank) plus the slowest
merge of each O(log N) reduction round plus the root materialization --
while ``cpu_s`` is the total sequential work.  Traces from every
combination are checked byte-identical against the flat reference.

Outputs CSV to artifacts/bench/ior_{blocksize,scaling}.csv and JSON to
artifacts/bench/finalize_scaling.json.
"""

from __future__ import annotations

import csv
import gc
import json
import os
import shutil
import tempfile
import time
from typing import Dict, List

from repro.core import trace_format
from repro.core.interprocess import (finalize_ranks, make_rank_state,
                                     materialize_state, merge_rank_states)
from repro.core.recorder import RecorderConfig
from repro.core.specs import REGISTRY

from .workloads import ior_rank, run_ranks, synth_rank_states

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")

CONFIGS = {
    "both": RecorderConfig(intra_patterns=True, inter_patterns=True,
                           timestamps=False),
    "intra_only": RecorderConfig(intra_patterns=True, inter_patterns=False,
                                 timestamps=False),
    "inter_only": RecorderConfig(intra_patterns=False, inter_patterns=True,
                                 timestamps=False),
    "none": RecorderConfig(intra_patterns=False, inter_patterns=False,
                           timestamps=False),
}


def blocksize(n_calls_list=(64, 256, 1024, 4096), nprocs: int = 64
              ) -> List[dict]:
    rows = []
    for n_calls in n_calls_list:
        for cname in ("both", "inter_only"):
            d = tempfile.mkdtemp()
            try:
                r = run_ranks(ior_rank, nprocs, CONFIGS[cname],
                              n_calls=n_calls, data_dir=d)
            finally:
                shutil.rmtree(d, ignore_errors=True)
            rows.append({"n_calls": n_calls, "nprocs": nprocs,
                         "config": cname,
                         "pattern_bytes": r["pattern_bytes"],
                         "n_records": r["n_records"]})
    return rows


def scaling(nprocs_list=(4, 16, 64, 256), n_calls: int = 256) -> List[dict]:
    rows = []
    for nprocs in nprocs_list:
        for cname in ("both", "intra_only", "none"):
            d = tempfile.mkdtemp()
            try:
                r = run_ranks(ior_rank, nprocs, CONFIGS[cname],
                              n_calls=n_calls, data_dir=d)
            finally:
                shutil.rmtree(d, ignore_errors=True)
            rows.append({"nprocs": nprocs, "n_calls": n_calls,
                         "config": cname,
                         "pattern_bytes": r["pattern_bytes"],
                         "n_records": r["n_records"]})
    return rows


def _write_trace_tmp(merge, cfgs, nprocs: int) -> str:
    d = tempfile.mkdtemp()
    trace_format.write_trace(
        d, registry=REGISTRY, merged_cst=merge.merged_entries,
        unique_cfgs=cfgs.unique_cfgs, cfg_index=cfgs.cfg_index,
        rank_timestamps=[b""] * nprocs, meta_extra={})
    return d


def _traces_identical(d1: str, d2: str) -> bool:
    for name in ("merged_cst.bin", "unique_cfgs.bin", "cfg_index.bin",
                 "timestamps.bin"):
        with open(os.path.join(d1, name), "rb") as f1, \
                open(os.path.join(d2, name), "rb") as f2:
            if f1.read() != f2.read():
                return False
    return True


def finalize_scaling(nprocs_list=(16, 64, 256, 1024, 4096),
                     n_groups: int = 32, n_calls: int = 64,
                     pattern: str = "linear") -> List[dict]:
    """Time flat vs tree finalization over synthesized rank states."""
    rows: List[dict] = []
    for nprocs in nprocs_list:
        csts, cfgs = synth_rank_states(nprocs, n_groups=n_groups,
                                       n_calls=n_calls, pattern=pattern)
        ref_dir = None
        gc.disable()  # GC pauses would dominate the per-round maxima
        try:
            for topology in ("flat", "tree"):
                for fit_mode in ("python", "vectorized"):
                    gc.collect()
                    if topology == "flat":
                        t0 = time.perf_counter()
                        merge, cfgres = finalize_ranks(
                            csts, cfgs, REGISTRY, fit_mode=fit_mode)
                        wall = cpu = time.perf_counter() - t0
                    else:
                        # leaves are per-rank parallel work on a real run:
                        # critical path counts the slowest one only
                        leaf_times = []
                        states = []
                        for r in range(nprocs):
                            t0 = time.perf_counter()
                            states.append(make_rank_state(
                                r, csts[r], cfgs[r], REGISTRY))
                            leaf_times.append(time.perf_counter() - t0)
                        cpu = sum(leaf_times)
                        wall = max(leaf_times)
                        while len(states) > 1:
                            nxt, round_times = [], []
                            for i in range(0, len(states), 2):
                                if i + 1 < len(states):
                                    t0 = time.perf_counter()
                                    nxt.append(merge_rank_states(
                                        states[i], states[i + 1]))
                                    round_times.append(
                                        time.perf_counter() - t0)
                                else:
                                    nxt.append(states[i])
                            states = nxt
                            cpu += sum(round_times)
                            wall += max(round_times)
                        t0 = time.perf_counter()
                        merge, cfgres = materialize_state(
                            states[0], fit_mode=fit_mode)
                        dt = time.perf_counter() - t0
                        cpu += dt
                        wall += dt
                    d = _write_trace_tmp(merge, cfgres, nprocs)
                    if ref_dir is None:
                        ref_dir, identical = d, True
                    else:
                        identical = _traces_identical(ref_dir, d)
                        shutil.rmtree(d, ignore_errors=True)
                    rows.append({
                        "nprocs": nprocs, "topology": topology,
                        "fit_mode": fit_mode, "pattern": pattern,
                        "n_groups": n_groups, "n_calls": n_calls,
                        "wall_s": round(wall, 6), "cpu_s": round(cpu, 6),
                        "cst_entries": len(merge.merged_entries),
                        "identical_to_flat": identical,
                    })
        finally:
            gc.enable()
            if ref_dir:
                shutil.rmtree(ref_dir, ignore_errors=True)
    return rows


def main(fast: bool = False) -> List[str]:
    os.makedirs(ART, exist_ok=True)
    out = []
    bs = blocksize((64, 256, 1024) if fast else (64, 256, 1024, 4096),
                   nprocs=16 if fast else 64)
    with open(os.path.join(ART, "ior_blocksize.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, bs[0].keys())
        w.writeheader()
        w.writerows(bs)
    flat = [r["pattern_bytes"] for r in bs if r["config"] == "both"]
    grow = [r["pattern_bytes"] for r in bs if r["config"] == "inter_only"]
    out.append(f"ior_blocksize,intra_flat={max(flat) - min(flat)},"
               f"nointra_growth={grow[-1] - grow[0]}")
    sc = scaling((4, 16, 64) if fast else (4, 16, 64, 256),
                 n_calls=64 if fast else 256)
    with open(os.path.join(ART, "ior_scaling.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, sc[0].keys())
        w.writeheader()
        w.writerows(sc)
    flat = [r["pattern_bytes"] for r in sc if r["config"] == "both"]
    lin = [r["pattern_bytes"] for r in sc if r["config"] == "none"]
    out.append(f"ior_scaling,inter_flat={max(flat) - min(flat)},"
               f"nopattern_growth={lin[-1] - lin[0]}")
    fs = finalize_scaling((16, 64, 256) if fast
                          else (16, 64, 256, 1024, 4096),
                          n_groups=8 if fast else 32,
                          n_calls=16 if fast else 64)
    with open(os.path.join(ART, "finalize_scaling.json"), "w") as f:
        json.dump(fs, f, indent=1)
    by: Dict[tuple, dict] = {(r["nprocs"], r["topology"], r["fit_mode"]): r
                             for r in fs}
    peak = max(r["nprocs"] for r in fs)
    seed_flat = by[(peak, "flat", "python")]["wall_s"]
    tree_vec = by[(peak, "tree", "vectorized")]["wall_s"]
    speedup = seed_flat / max(tree_vec, 1e-9)
    ident = all(r["identical_to_flat"] for r in fs)
    out.append(f"finalize_scaling,nprocs={peak},flat_python_s={seed_flat},"
               f"tree_vectorized_s={tree_vec},speedup={speedup:.1f}x,"
               f"identical={ident}")
    return out


if __name__ == "__main__":
    for line in main():
        print(line)
