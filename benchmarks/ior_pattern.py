"""Paper Figs 4-5: impact of intra-/inter-process I/O pattern recognition.

Fig 4 (blocksize): fixed nprocs, increasing call count per rank; with
intra-process recognition the trace size must be FLAT in call count.
Fig 5 (scaling): fixed call count, increasing nprocs; with inter-process
recognition the trace size must be FLAT in process count.

Outputs CSV to artifacts/bench/ior_{blocksize,scaling}.csv.
"""

from __future__ import annotations

import csv
import os
import shutil
import tempfile
from typing import List

from repro.core.recorder import RecorderConfig

from .workloads import ior_rank, run_ranks

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")

CONFIGS = {
    "both": RecorderConfig(intra_patterns=True, inter_patterns=True,
                           timestamps=False),
    "intra_only": RecorderConfig(intra_patterns=True, inter_patterns=False,
                                 timestamps=False),
    "inter_only": RecorderConfig(intra_patterns=False, inter_patterns=True,
                                 timestamps=False),
    "none": RecorderConfig(intra_patterns=False, inter_patterns=False,
                           timestamps=False),
}


def blocksize(n_calls_list=(64, 256, 1024, 4096), nprocs: int = 64
              ) -> List[dict]:
    rows = []
    for n_calls in n_calls_list:
        for cname in ("both", "inter_only"):
            d = tempfile.mkdtemp()
            try:
                r = run_ranks(ior_rank, nprocs, CONFIGS[cname],
                              n_calls=n_calls, data_dir=d)
            finally:
                shutil.rmtree(d, ignore_errors=True)
            rows.append({"n_calls": n_calls, "nprocs": nprocs,
                         "config": cname,
                         "pattern_bytes": r["pattern_bytes"],
                         "n_records": r["n_records"]})
    return rows


def scaling(nprocs_list=(4, 16, 64, 256), n_calls: int = 256) -> List[dict]:
    rows = []
    for nprocs in nprocs_list:
        for cname in ("both", "intra_only", "none"):
            d = tempfile.mkdtemp()
            try:
                r = run_ranks(ior_rank, nprocs, CONFIGS[cname],
                              n_calls=n_calls, data_dir=d)
            finally:
                shutil.rmtree(d, ignore_errors=True)
            rows.append({"nprocs": nprocs, "n_calls": n_calls,
                         "config": cname,
                         "pattern_bytes": r["pattern_bytes"],
                         "n_records": r["n_records"]})
    return rows


def main(fast: bool = False) -> List[str]:
    os.makedirs(ART, exist_ok=True)
    out = []
    bs = blocksize((64, 256, 1024) if fast else (64, 256, 1024, 4096),
                   nprocs=16 if fast else 64)
    with open(os.path.join(ART, "ior_blocksize.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, bs[0].keys())
        w.writeheader()
        w.writerows(bs)
    flat = [r["pattern_bytes"] for r in bs if r["config"] == "both"]
    grow = [r["pattern_bytes"] for r in bs if r["config"] == "inter_only"]
    out.append(f"ior_blocksize,intra_flat={max(flat) - min(flat)},"
               f"nointra_growth={grow[-1] - grow[0]}")
    sc = scaling((4, 16, 64) if fast else (4, 16, 64, 256),
                 n_calls=64 if fast else 256)
    with open(os.path.join(ART, "ior_scaling.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, sc[0].keys())
        w.writeheader()
        w.writerows(sc)
    flat = [r["pattern_bytes"] for r in sc if r["config"] == "both"]
    lin = [r["pattern_bytes"] for r in sc if r["config"] == "none"]
    out.append(f"ior_scaling,inter_flat={max(flat) - min(flat)},"
               f"nopattern_growth={lin[-1] - lin[0]}")
    return out


if __name__ == "__main__":
    for line in main():
        print(line)
