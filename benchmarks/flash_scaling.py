"""Paper Figs 6-7: FLASH-analogue checkpoint I/O at scale.

Fig 6 left : weak scaling in ranks (independent I/O) -> constant trace.
Fig 6 right: scaling in iterations -> stepwise growth at each new output
             file set; the 'rolling' mitigation flattens it.
Fig 7      : collective I/O -- trace size tracks the aggregator count,
             which saturates at the stripe count.

CSV to artifacts/bench/flash_{weak,iters,collective}.csv.
"""

from __future__ import annotations

import csv
import os
import shutil
import tempfile
from typing import List

from repro.core.recorder import RecorderConfig

from .workloads import flash_rank, run_ranks

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")
CFG = RecorderConfig(timestamps=False)


def _run(nprocs, **kw):
    d = tempfile.mkdtemp()
    try:
        return run_ranks(flash_rank, nprocs, CFG, data_dir=d, **kw)
    finally:
        shutil.rmtree(d, ignore_errors=True)


def weak_scaling(nprocs_list=(16, 64, 256, 1024), iterations=100) -> List[dict]:
    rows = []
    for np_ in nprocs_list:
        r = _run(np_, iterations=iterations, mode="independent")
        rows.append({"nprocs": np_, "iterations": iterations,
                     "pattern_bytes": r["pattern_bytes"],
                     "n_records": r["n_records"],
                     "n_unique_cfgs": r["n_unique_cfgs"]})
    return rows


def iteration_scaling(iters_list=(100, 200, 400, 800), nprocs=64,
                      rolling=False) -> List[dict]:
    rows = []
    for it in iters_list:
        r = _run(nprocs, iterations=it, ckpt_every=20, rolling=rolling)
        rows.append({"nprocs": nprocs, "iterations": it,
                     "rolling": rolling,
                     "pattern_bytes": r["pattern_bytes"],
                     "n_records": r["n_records"]})
    return rows


def collective(nprocs_list=(64, 128, 256, 512, 1024), stripe=8,
               iterations=40) -> List[dict]:
    rows = []
    for np_ in nprocs_list:
        r = _run(np_, iterations=iterations, mode="collective",
                 stripe=stripe)
        rows.append({"nprocs": np_, "stripe": stripe,
                     "aggregators": min(stripe, max(1, np_ // 64)),
                     "pattern_bytes": r["pattern_bytes"],
                     "n_unique_cfgs": r["n_unique_cfgs"]})
    return rows


def main(fast: bool = False) -> List[str]:
    os.makedirs(ART, exist_ok=True)
    out = []
    wk = weak_scaling((16, 64, 256) if fast else (16, 64, 256, 1024),
                      iterations=40 if fast else 100)
    with open(os.path.join(ART, "flash_weak.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, wk[0].keys())
        w.writeheader()
        w.writerows(wk)
    out.append(f"flash_weak,first={wk[0]['pattern_bytes']},"
               f"last={wk[-1]['pattern_bytes']},"
               f"records_first={wk[0]['n_records']},"
               f"records_last={wk[-1]['n_records']}")
    its = iteration_scaling((40, 80, 160) if fast else (100, 200, 400, 800),
                            nprocs=16 if fast else 64)
    its += iteration_scaling((40, 80, 160) if fast else (100, 200, 400, 800),
                             nprocs=16 if fast else 64, rolling=True)
    with open(os.path.join(ART, "flash_iters.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, its[0].keys())
        w.writeheader()
        w.writerows(its)
    half = len(its) // 2
    out.append(f"flash_iters,growing={its[half-1]['pattern_bytes']},"
               f"rolling={its[-1]['pattern_bytes']}")
    co = collective((64, 128, 256) if fast else (64, 128, 256, 512, 1024),
                    stripe=8, iterations=20 if fast else 40)
    co += collective((64, 128, 256) if fast else (64, 128, 256, 512, 1024),
                     stripe=32, iterations=20 if fast else 40)
    with open(os.path.join(ART, "flash_collective.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, co[0].keys())
        w.writeheader()
        w.writerows(co)
    out.append(f"flash_collective,stripe8_last={co[len(co)//2-1]['pattern_bytes']},"
               f"stripe32_last={co[-1]['pattern_bytes']}")
    return out


if __name__ == "__main__":
    for line in main():
        print(line)
