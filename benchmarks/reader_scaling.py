"""Read-side scaling: record iterator vs compressed-domain TraceView.

The write side keeps traces ~constant in scale (paper Section 5); this
experiment shows the READ side exploiting that: grammar-weighted aggregates
(``io_summary``, ``size_histogram``, ``n_records``) answer in
O(|grammar| + |CST|) from the compressed representation, while the seed
iterator pays O(total records) of per-record Python work.

Sweeps records-per-rank x ranks x {iterator, view} over synthesized traces
(``workloads.synth_rank_states`` -> tree finalize -> on-disk trace).  The
iterator path is timed on a bounded rank sample (``iter_budget`` expanded
records per query) and extrapolated linearly to the full rank count when
the sample is partial -- every row records ``iterator_ranks_timed`` /
``iterator_extrapolated`` alongside the raw measurement, and rows whose
iterator pass covered ALL ranks also record ``value_match`` (query results
compared for exact equality).  The ``mixed_all`` points exercise the
nested IterPattern-of-RankPattern and multi-offset (lseek) shapes.

Writes artifacts/bench/reader_scaling.json:
  {"config": ..., "rows": [...]}, one row per
  (records_per_rank, nranks, pattern, query) with iterator_s, view_s
  (= build + query) and speedup = iterator_s / view_s.

    PYTHONPATH=src python -m benchmarks.reader_scaling [--smoke]
"""

from __future__ import annotations

import json
import os
import shutil
import sys
import tempfile
import time
from typing import Any, Dict, List, Sequence, Tuple

from repro.core import trace_format
from repro.core.interprocess import tree_finalize_ranks
from repro.core.reader import TraceReader
from repro.core.sequitur import expand_grammar
from repro.core.specs import REGISTRY
from repro.core.traceview import _DATA_FUNCS, TraceView

from .workloads import synth_rank_states

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")

_EDGES = (512, 4096, 65536, 1 << 20)


# ---------------------------------------------------------------------------
# the seed per-record reference path (restricted to a rank subset so large
# sweep points stay measurable; extrapolation is recorded, never hidden)
# ---------------------------------------------------------------------------


def _size_of(rec) -> int:
    for v, role in zip(rec.args, rec.roles):
        if role in ("buf", "size") and isinstance(v, int):
            return v
    return rec.ret if isinstance(rec.ret, int) else 0


def iter_io_summary(reader: TraceReader, ranks: Sequence[int]
                    ) -> Dict[str, Any]:
    """The seed iterator io_summary over the given ranks."""
    from collections import defaultdict
    per_file: Dict[Any, Dict[str, int]] = defaultdict(
        lambda: {"bytes": 0, "calls": 0})
    handles: Dict[Tuple[int, int], str] = {}
    n_meta = n_data = 0
    t_lo, t_hi = float("inf"), 0
    total_bytes = 0
    for r in ranks:
        for rec in reader.iter_records(r):
            if rec.func in ("open", "shard_open"):
                h = rec.ret
                if hasattr(h, "id"):
                    handles[(r, h.id)] = str(rec.args[0])
            if rec.func in _DATA_FUNCS:
                n_data += 1
                sz = _size_of(rec)
                total_bytes += sz
                key = next((handles.get((r, v.id)) for v, role in
                            zip(rec.args, rec.roles)
                            if role == "handle" and hasattr(v, "id")), "?")
                per_file[key]["bytes"] += sz
                per_file[key]["calls"] += 1
            elif rec.layer in ("posix", "shardio"):
                n_meta += 1
            if rec.t_entry is not None:
                t_lo = min(t_lo, rec.t_entry)
                t_hi = max(t_hi, rec.t_exit or rec.t_entry)
    wall_us = max(t_hi - t_lo, 1)
    return {
        "files": dict(per_file),
        "n_data_calls": n_data,
        "n_metadata_calls": n_meta,
        "metadata_ratio": n_meta / max(n_data + n_meta, 1),
        "total_bytes": total_bytes,
        "aggregate_MBps": total_bytes / wall_us,
    }


def iter_size_histogram(reader: TraceReader, ranks: Sequence[int],
                        edges=_EDGES) -> Dict[str, int]:
    """The seed iterator size_histogram over the given ranks."""
    buckets = {f"<{e}": 0 for e in edges}
    buckets[f">={edges[-1]}"] = 0
    for r in ranks:
        for rec in reader.iter_records(r, timestamps=False):
            if rec.func not in _DATA_FUNCS:
                continue
            sz = _size_of(rec)
            for e in edges:
                if sz < e:
                    buckets[f"<{e}"] += 1
                    break
            else:
                buckets[f">={edges[-1]}"] += 1
    return buckets


def iter_n_records(reader: TraceReader, ranks: Sequence[int]) -> int:
    """The seed expand-and-count n_records over the given ranks."""
    total = 0
    for r in ranks:
        g = reader.unique_cfgs[reader.cfg_index[r]]
        for _ in expand_grammar(g):
            total += 1
    return total


# ---------------------------------------------------------------------------
# sweep driver
# ---------------------------------------------------------------------------


def _build_trace(records_per_rank: int, nranks: int, pattern: str,
                 n_groups: int, tmp: str) -> str:
    n_calls = max(1, records_per_rank // n_groups)
    csts, cfgs = synth_rank_states(nranks, n_groups=n_groups,
                                   n_calls=n_calls, pattern=pattern)
    merge, cfgres = tree_finalize_ranks(csts, cfgs, REGISTRY)
    d = os.path.join(tmp, f"trace_{records_per_rank}_{nranks}_{pattern}")
    trace_format.write_trace(d, registry=REGISTRY,
                             merged_cst=merge.merged_entries,
                             unique_cfgs=cfgres.unique_cfgs,
                             cfg_index=cfgres.cfg_index,
                             rank_timestamps=[b""] * nranks, meta_extra={})
    return d


def _timed(fn) -> Tuple[float, Any]:
    t0 = time.perf_counter()
    res = fn()
    return time.perf_counter() - t0, res


def sweep(records_per_rank_list: Sequence[int], nranks_list: Sequence[int],
          patterns: Sequence[str] = ("linear",), n_groups: int = 16,
          iter_budget: int = 1_000_000) -> List[dict]:
    rows: List[dict] = []
    tmp = tempfile.mkdtemp(prefix="reader_scaling_")
    try:
        for pattern in patterns:
            for rpr in records_per_rank_list:
                for nranks in nranks_list:
                    d = _build_trace(rpr, nranks, pattern, n_groups, tmp)
                    reader = TraceReader(d)
                    # pre-build the reader's memoized view so the iterator
                    # timings (reader.iter_records delegates to it) don't
                    # pay the columnar decode; the view path is timed on
                    # fresh TraceView instances (cold build_s + query)
                    reader.view()
                    build_s, _ = _timed(lambda: TraceView(reader))
                    n_sample = max(1, min(nranks, iter_budget // max(rpr, 1)))
                    sample = list(range(n_sample))
                    full = n_sample == nranks
                    queries = [
                        ("io_summary",
                         lambda v: v.io_summary(),
                         lambda: iter_io_summary(reader, sample)),
                        ("size_histogram",
                         lambda v: v.size_histogram(_EDGES),
                         lambda: iter_size_histogram(reader, sample)),
                        ("n_records",
                         lambda v: sum(v.n_records(r)
                                       for r in range(nranks)),
                         lambda: iter_n_records(reader, sample)),
                    ]
                    for qname, vq, iq in queries:
                        view = TraceView(reader)  # fresh memos per query
                        view_q_s, vres = _timed(lambda: vq(view))
                        it_meas_s, ires = _timed(iq)
                        it_s = it_meas_s * (nranks / n_sample)
                        view_s = build_s + view_q_s
                        row = {
                            "records_per_rank": rpr, "nranks": nranks,
                            "pattern": pattern, "query": qname,
                            "n_records_total": rpr * nranks,
                            "iterator_s": it_s,
                            "iterator_s_measured": it_meas_s,
                            "iterator_ranks_timed": n_sample,
                            "iterator_extrapolated": not full,
                            "view_build_s": build_s,
                            "view_query_s": view_q_s,
                            "view_s": view_s,
                            "speedup": it_s / max(view_s, 1e-9),
                        }
                        if full:
                            row["value_match"] = bool(vres == ires)
                        rows.append(row)
                    shutil.rmtree(d, ignore_errors=True)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return rows


def main(fast: bool = False) -> List[str]:
    os.makedirs(ART, exist_ok=True)
    if fast:
        rows = sweep((2_000, 8_000), (4, 16),
                     patterns=("linear", "mixed_all"), iter_budget=200_000)
    else:
        rows = sweep((10_000, 100_000, 1_000_000), (16, 256),
                     patterns=("linear",))
        rows += sweep((10_000,), (16,), patterns=("mixed_all",))
    out = {"config": {"fast": fast, "edges": list(_EDGES)}, "rows": rows}
    with open(os.path.join(ART, "reader_scaling.json"), "w") as f:
        json.dump(out, f, indent=1)
    peak = max(rows, key=lambda r: r["n_records_total"])
    lines = []
    for q in ("io_summary", "size_histogram", "n_records"):
        r = next(r for r in rows
                 if r["query"] == q
                 and r["n_records_total"] == peak["n_records_total"]
                 and r["pattern"] == peak["pattern"])
        lines.append(
            f"reader_scaling,{q},records={r['n_records_total']},"
            f"iterator_s={r['iterator_s']:.3f},view_s={r['view_s']:.6f},"
            f"speedup={r['speedup']:.0f}x")
    mism = [r for r in rows if r.get("value_match") is False]
    lines.append(f"reader_scaling,value_mismatches={len(mism)}")
    return lines


if __name__ == "__main__":
    for line in main(fast="--smoke" in sys.argv or "--fast" in sys.argv):
        print(line)
