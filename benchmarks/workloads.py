"""Simulated-rank I/O workloads for the paper's experiments.

``ior_rank``    -- the paper's Listing 3: strided lseek+write to a shared
                   file (IOR, Section 5.1).
``flash_rank``  -- the FLASH checkpoint/plot-file pattern (Section 5.2):
                   every k-th iteration writes a plot + checkpoint file
                   through the shardio facade (HDF5 -> MPI-IO -> POSIX
                   analogue, call depths included), with independent or
                   collective (aggregator) I/O.

``synth_rank_states`` -- a direct CST/CFG synthesizer for the
                   finalize-scaling experiments: builds thousands of
                   simulated rank states without running a Recorder per
                   call (the per-rank grammar is structurally identical
                   across ranks, so it is built once; only the
                   rank-dependent offset signatures are re-encoded).

Each driver runs ONE rank's call stream against a fresh Recorder (or a
baseline adapter) and returns the tool's local state; the caller loops
ranks and feeds ``finalize_ranks`` (or ``tree_finalize_ranks``) --
bit-identical to what rank 0 of a real MPI run computes after the gather
(core/comm.py notes).
"""

from __future__ import annotations

import os
import random
from typing import Any, Dict, List, Optional, Tuple

from repro.core.apis import framework as frame
from repro.core.apis import posix, shardio
from repro.core.encoding import Handle, encode_signature
from repro.core.interprocess import finalize_ranks, tree_finalize_ranks
from repro.core.patterns import IntraPatternTracker
from repro.core.recorder import Recorder, RecorderConfig, attach, detach
from repro.core.sequitur import Sequitur
from repro.core.specs import REGISTRY


def ior_rank(tool, rank: int, nprocs: int, n_calls: int,
             chunk: int = 4096, data_dir: str = "/tmp/repro_ior") -> None:
    """Strided shared-file writes (paper Listing 3) through the facade."""
    os.makedirs(data_dir, exist_ok=True)
    path = os.path.join(data_dir, "shared.bin")
    attach(tool)
    try:
        fd = posix.open(path, os.O_RDWR | os.O_CREAT, 0o644)
        base = rank * chunk
        stride = nprocs * chunk
        buf = b"\0" * min(chunk, 256)   # byte count is what is recorded
        for i in range(n_calls):
            posix.lseek(fd, base + stride * i, 0)
            posix.write(fd, buf)
        posix.fsync(fd)
        posix.close(fd)
    finally:
        detach()


def _write_shared_file(path: str, rank: int, nprocs: int, *,
                       n_vars: int, block: int) -> None:
    """One FLASH output file, independent I/O: every rank writes its block
    of every variable at offset var_base + rank*block (rank-linear)."""
    fh = shardio.shard_open(path, 1)
    buf = b"\0" * 64
    for v in range(n_vars):
        var_base = v * nprocs * block
        shardio.shard_write_at(fh, buf, var_base + rank * block)
    shardio.shard_sync(fh)
    shardio.shard_close(fh)


def flash_rank(tool, rank: int, nprocs: int, *, iterations: int = 100,
               ckpt_every: int = 20, n_vars: int = 24, block: int = 16384,
               mode: str = "independent", stripe: int = 8, ppn: int = 64,
               rolling: bool = False,
               data_dir: str = "/tmp/repro_flash") -> None:
    """The FLASH weak-scaling I/O pattern for one rank."""
    os.makedirs(data_dir, exist_ok=True)
    nodes = max(1, nprocs // ppn)
    aggregators = min(stripe, nodes) if mode == "collective" else 0
    attach(tool)
    try:
        n_out = 0
        for it in range(iterations):
            frame.step(it)
            if it % ckpt_every == 0:
                idx = 0 if rolling else n_out
                for kind in ("plt", "chk"):
                    path = os.path.join(data_dir, f"{kind}_{idx:04d}.h5")
                    if mode == "independent":
                        _write_shared_file(path, rank, nprocs,
                                           n_vars=n_vars, block=block)
                    else:
                        _write_collective_file(path, rank, nprocs,
                                               n_vars=n_vars, block=block,
                                               aggregators=aggregators)
                n_out += 1
    finally:
        detach()


def _write_collective_file(path: str, rank: int, nprocs: int, *,
                           n_vars: int, block: int, aggregators: int
                           ) -> None:
    fh = shardio.shard_open(path, 1)
    buf = b"\0" * 64
    agg = max(1, aggregators)
    per_agg = max(1, nprocs // agg)
    for v in range(n_vars):
        var_base = v * nprocs * block
        # the MPI-level collective: every rank participates, rank-linear
        shardio.shard_write_at(fh, buf, var_base + rank * block)
        # aggregator POSIX writes: aggregator-linear offsets, bigger chunks
        if rank < agg:
            shardio.shard_write_at(fh, buf, var_base + rank * per_agg * block)
    shardio.shard_sync(fh)
    shardio.shard_close(fh)


# ---------------------------------------------------------------------------
# synthetic rank states (finalize-scaling experiments)
# ---------------------------------------------------------------------------


def synth_rank_states(nprocs: int, *, n_groups: int = 32, n_calls: int = 64,
                      pattern: str = "linear", chunk: int = 4096,
                      seed: int = 0) -> Tuple[List[List[bytes]], List[bytes]]:
    """Build (rank_csts, rank_cfgs) for ``nprocs`` simulated ranks directly.

    Each rank performs, per group g (a distinct shared file), one pwrite at
    ``base_g(rank)`` followed by ``n_calls - 1`` strided pwrites -- the IOR
    shape.  ``pattern`` controls the inter-process structure of the bases:

      linear     base = rank*chunk + g*BIG   (merges to one RankPattern)
      constant   base = g*BIG                (identical on every rank)
      irregular  base = random per (rank, g) (defeats the rank fit)
      nested     rank-linear base AND rank-linear stride: the group merges
                 to ``IterPattern(RankPattern, RankPattern)`` -- the
                 doubly-nested shape of paper Fig 3(c)
      multi      lseek groups whose OFFSET-role argument and OFFSET-role
                 return are tracked as one joint two-component run
      mixed      per-group random choice of linear/constant/irregular
                 (the original set, kept bit-stable for old seeds)
      mixed_all  per-group random choice across all five kinds

    The per-rank grammar (CFG) is structurally identical across ranks, so
    it is built once with run-length pushes; per rank only the distinct
    offset-bearing signatures are re-encoded.  Offset encoding goes through
    ``IntraPatternTracker.encode_many`` (the vectorized intra-process hot
    loop): the O(calls) per-(rank, group) work is a NumPy pass, with only
    O(groups) Python-level signature encodes per rank.
    """
    pw = REGISTRY.id_of("pwrite")
    lk = REGISTRY.id_of("lseek")
    rng = random.Random(seed)
    big = 1 << 24
    stride = nprocs * chunk
    plans = []  # per group: (kind, irregular per-rank bases or None)
    for g in range(n_groups):
        kind = pattern
        if pattern == "mixed":
            kind = rng.choice(["linear", "constant", "irregular"])
        elif pattern == "mixed_all":
            kind = rng.choice(["linear", "constant", "irregular",
                               "nested", "multi"])
        bases = ([rng.randrange(1 << 30) for _ in range(nprocs)]
                 if kind == "irregular" else None)
        plans.append((kind, bases))

    # grammar: per group, [pwrite-head, pwrite-pattern^(n_calls-1)]; terminal
    # ids are the same on every rank because the structure is
    grammar = Sequitur()
    t = 0
    for g in range(n_groups):
        grammar.push(t)          # head signature
        t += 1
        if n_calls > 1:
            grammar.push(t, n_calls - 1)  # shared IterPattern signature
            t += 1
    cfg = grammar.serialize()

    rank_csts: List[List[bytes]] = []
    for r in range(nprocs):
        tracker = IntraPatternTracker()
        cst: List[bytes] = []
        for g, (kind, bases) in enumerate(plans):
            if kind == "constant":
                base = g * big
            elif kind == "irregular":
                base = bases[r]
            else:  # linear / nested / multi: rank-linear base
                base = r * chunk + g * big
            # nested: the stride itself is rank-linear (paper Fig 3c)
            step = (nprocs + r) * chunk if kind == "nested" else stride
            if kind == "multi":
                # lseek: OFFSET-role arg and OFFSET-role return form one
                # joint two-component run (tracked and decoded together)
                offs = [(base + i * step, base + i * step)
                        for i in range(n_calls)]
                enc = tracker.encode_many(("lseek", g), offs)
                cst.append(encode_signature(lk, 0, 0,
                                            (Handle(g), enc[0][0], 0),
                                            enc[0][1]))
                if n_calls > 1:
                    cst.append(encode_signature(lk, 0, 0,
                                                (Handle(g), enc[1][0], 0),
                                                enc[1][1]))
                continue
            offs = [(base + i * step,) for i in range(n_calls)]
            enc = tracker.encode_many(("pwrite", g), offs)
            # head + (single) pattern signature, matching the grammar above
            cst.append(encode_signature(pw, 0, 0,
                                        (Handle(g), 64, enc[0][0]), 64))
            if n_calls > 1:
                cst.append(encode_signature(pw, 0, 0,
                                            (Handle(g), 64, enc[1][0]), 64))
        rank_csts.append(cst)
    return rank_csts, [cfg] * nprocs


# ---------------------------------------------------------------------------
# multi-rank simulation + size accounting
# ---------------------------------------------------------------------------


def run_ranks(workload, nprocs: int, recorder_config: RecorderConfig,
              finalize_topology: Optional[str] = None,
              fit_mode: str = "vectorized", **kw) -> Dict[str, Any]:
    """Run ``workload(tool, rank, nprocs, **kw)`` for every simulated rank
    with a fresh Recorder, then the inter-process stage; returns sizes.

    ``finalize_topology`` (default: honor
    ``recorder_config.finalize_topology``) and ``fit_mode`` select the
    finalize implementation (flat gather vs tree reduction, scalar vs
    vectorized fitting); all combinations produce identical sizes."""
    if finalize_topology is None:
        finalize_topology = recorder_config.finalize_topology
    states = []
    n_records = 0
    for r in range(nprocs):
        rec = Recorder(rank=r, config=recorder_config)
        workload(rec, r, nprocs, **kw)
        states.append(rec.local_state())
        n_records += rec.n_records
    csts = [s[0] for s in states]
    cfgs = [s[1] for s in states]
    ts = [s[2] for s in states]
    fin = (tree_finalize_ranks if finalize_topology == "tree"
           else finalize_ranks)
    merge, cfgres = fin(
        csts, cfgs, REGISTRY,
        inter_patterns=recorder_config.inter_patterns, fit_mode=fit_mode)
    cst_bytes = sum(len(e) + 2 for e in merge.merged_entries)
    cfg_bytes = sum(len(c) + 2 for c in cfgres.unique_cfgs)
    index_bytes = 2 * len(cfgres.cfg_index)
    ts_bytes = sum(len(t) for t in ts)
    return {
        "nprocs": nprocs,
        "n_records": n_records,
        "cst_entries": len(merge.merged_entries),
        "n_unique_cfgs": len(cfgres.unique_cfgs),
        "pattern_bytes": cst_bytes + cfg_bytes,   # Fig 4-7 metric
        "cst_bytes": cst_bytes,
        "cfg_bytes": cfg_bytes,
        "total_bytes": cst_bytes + cfg_bytes + index_bytes + ts_bytes,
        "ts_bytes": ts_bytes,
        "n_rank_patterns": merge.n_rank_patterns,
    }
