"""Per-kernel micro-benchmarks.

Pallas-interpret timings on CPU measure the Python emulator, not TPU perf;
the portable numbers are (a) the XLA-path wall times on this host and
(b) the analytic FLOP/byte counts that feed the Section Roofline analysis.

``encode_sweep`` measures the trace-encode hot path (delta+zigzag, varint
packing, rank-linear column fitting) across batch sizes under every
``encode_backend`` and writes ``artifacts/bench/encode_kernels.json`` with
the per-backend crossover points (smallest batch where the batched backend
beats the scalar Python encoder) and the speedup at the 64k-record batch
the streaming flusher typically hands the encoder.
"""

from __future__ import annotations

import csv
import json
import os
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encode_backend as eb
from repro.core.encoding import pack_uvarints
from repro.core.interprocess import batch_fit_columns
from repro.core.timestamps import delta_zigzag_encode
from repro.kernels.delta_encode.ops import delta_zigzag
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.models.layers import flash_attention_xla

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


def _timeit(fn, *args, reps=5) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def _wall(fn, reps: int) -> float:
    fn()  # warm (jit compile / allocator)
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


_ENCODE_OPS = ("delta_zigzag", "pack_uvarints", "fit_columns")


def encode_sweep(smoke: bool = False) -> Dict[str, Any]:
    """Batch-size sweep of the encode hot path under every backend."""
    rng = np.random.RandomState(3)
    sizes = [256, 4096, 1 << 16] if smoke else \
        [64, 256, 1024, 4096, 16384, 1 << 16, 1 << 18]
    backends = ["python", "numpy", "pallas"]
    # pallas on a CPU-only host runs the interpreter: cap its sizes so the
    # sweep stays CI-sized (the crossover there is numpy's anyway)
    pallas_cap = (1 << 16) if eb.has_accelerator() else \
        (4096 if smoke else (1 << 14))

    timings: Dict[str, Dict[str, Dict[str, float]]] = {
        op: {b: {} for b in backends} for op in _ENCODE_OPS}

    for n in sizes:
        ticks = np.cumsum(rng.randint(0, 1000, size=n)).astype(np.int64)
        vals = [int(v) for v in rng.randint(0, 1 << 48, size=n,
                                            dtype=np.uint64)]
        ranks = 16
        ncols = max(1, n // ranks)
        cols = [[b + r * a for r in range(ranks)]
                for a, b in zip(rng.randint(1, 9, size=ncols),
                                rng.randint(0, 10**6, size=ncols))]
        reps = 1 if n >= (1 << 16) else 3
        for b in backends:
            if b == "pallas" and n > pallas_cap:
                continue
            timings["delta_zigzag"][b][str(n)] = _wall(
                lambda b=b, t=ticks: eb.delta_zigzag(t, b), reps)
            timings["pack_uvarints"][b][str(n)] = _wall(
                lambda b=b, v=vals: pack_uvarints(v, backend=b), reps)
            timings["fit_columns"][b][str(n)] = _wall(
                lambda b=b, c=cols: batch_fit_columns(c, backend=b), reps)

    crossover: Dict[str, Dict[str, Optional[int]]] = {}
    speedup_64k: Dict[str, Dict[str, Optional[float]]] = {}
    for op in _ENCODE_OPS:
        crossover[op] = {}
        speedup_64k[op] = {}
        py = timings[op]["python"]
        for b in ("numpy", "pallas"):
            xs = [n for n in sizes
                  if str(n) in timings[op][b]
                  and timings[op][b][str(n)] < py[str(n)]]
            crossover[op][b] = min(xs) if xs else None
            k = str(1 << 16)
            speedup_64k[op][b] = (round(py[k] / timings[op][b][k], 2)
                                  if k in timings[op][b] else None)

    report = {
        "host_accelerator": eb.has_accelerator(),
        "interpret_mode": eb.interpret_mode(),
        "sizes": sizes,
        "timings_s": timings,
        "crossover_records": crossover,
        "speedup_at_64k": speedup_64k,
        "thresholds": {"numpy_min_batch": eb.NUMPY_MIN_BATCH,
                       "pallas_min_batch": eb.PALLAS_MIN_BATCH},
    }
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "encode_kernels.json"), "w") as f:
        json.dump(report, f, indent=2)
    return report


def encode_summary_lines(report: Dict[str, Any]) -> List[str]:
    lines = []
    for op in _ENCODE_OPS:
        for b in ("numpy", "pallas"):
            co = report["crossover_records"][op][b]
            sp = report["speedup_at_64k"][op][b]
            lines.append(
                f"encode,{op},{b},crossover="
                f"{co if co is not None else '-'}"
                f",speedup@64k={sp if sp is not None else '-'}x")
    return lines


def main(fast: bool = False, smoke: bool = False) -> List[str]:
    os.makedirs(ART, exist_ok=True)
    if smoke:
        # CI path: the encode sweep IS the artifact; skip the model kernels
        return encode_summary_lines(encode_sweep(smoke=True))
    rng = np.random.RandomState(0)
    rows = []

    B, S, H, D = (1, 512, 4, 64) if fast else (2, 1024, 8, 64)
    q = jnp.asarray(rng.randn(B, S, H, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, H, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, H, D), jnp.float32)
    flops = 4 * B * H * S * S * D  # qk^T + pv
    t_chunked = _timeit(jax.jit(lambda a, b, c: flash_attention_xla(
        a, b, c, causal=True)), q, k, v)
    t_naive = _timeit(jax.jit(lambda a, b, c: jnp.swapaxes(attention_ref(
        jnp.swapaxes(a, 1, 2), jnp.swapaxes(b, 1, 2), jnp.swapaxes(c, 1, 2),
        causal=True), 1, 2)), q, k, v)
    rows.append({"kernel": "flash_attention_xla", "us": t_chunked * 1e6,
                 "derived": f"gflops={flops/t_chunked/1e9:.1f}"})
    rows.append({"kernel": "attention_naive", "us": t_naive * 1e6,
                 "derived": f"gflops={flops/t_naive/1e9:.1f}"})

    Bs, nc, Q, nh, hd, ns = (1, 4, 64, 4, 32, 16) if fast else \
        (2, 8, 128, 8, 64, 32)
    x = jnp.asarray(rng.randn(Bs, nc, Q, nh, hd), jnp.float32)
    b = jnp.asarray(rng.randn(Bs, nc, Q, ns), jnp.float32)
    c = jnp.asarray(rng.randn(Bs, nc, Q, ns), jnp.float32)
    dt = jnp.asarray(rng.rand(Bs, nc, Q, nh), jnp.float32) * 0.1
    da = -jnp.asarray(rng.rand(Bs, nc, Q, nh), jnp.float32) * 0.5
    t_ref = _timeit(jax.jit(ssd_scan_ref), x, b, c, dt, da)
    rows.append({"kernel": "ssd_recurrence_ref", "us": t_ref * 1e6,
                 "derived": f"tokens={Bs*nc*Q}"})

    xx = jnp.asarray(rng.randn(4096, 1024), jnp.float32)
    w = jnp.asarray(rng.rand(1024), jnp.float32)
    t_norm = _timeit(jax.jit(rmsnorm_ref), xx, w)
    rows.append({"kernel": "rmsnorm_ref", "us": t_norm * 1e6,
                 "derived": f"GBps={(xx.nbytes*2)/t_norm/1e9:.1f}"})

    t = np.cumsum(rng.randint(0, 1000, size=1 << 16)).astype(np.uint32)
    tj = jnp.asarray(t)
    t_np = _timeit(lambda a: delta_zigzag_encode(np.asarray(a).reshape(-1, 2)), t)
    rows.append({"kernel": "delta_zigzag_numpy", "us": t_np * 1e6,
                 "derived": f"MBps={t.nbytes/t_np/1e6:.0f}"})

    with open(os.path.join(ART, "kernels.csv"), "w", newline="") as f:
        wcsv = csv.DictWriter(f, rows[0].keys())
        wcsv.writeheader()
        wcsv.writerows(rows)
    lines = [f"kernel,{r['kernel']},{r['us']:.1f}us,{r['derived']}"
             for r in rows]
    lines += encode_summary_lines(encode_sweep(smoke=fast))
    return lines


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="small shapes + reduced encode sweep")
    ap.add_argument("--smoke", action="store_true",
                    help="encode sweep only (CI): writes "
                         "artifacts/bench/encode_kernels.json")
    ns = ap.parse_args()
    for line in main(fast=ns.fast, smoke=ns.smoke):
        print(line)
