"""Per-kernel micro-benchmarks.

Pallas-interpret timings on CPU measure the Python emulator, not TPU perf;
the portable numbers are (a) the XLA-path wall times on this host and
(b) the analytic FLOP/byte counts that feed the Section Roofline analysis.
"""

from __future__ import annotations

import csv
import os
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.timestamps import delta_zigzag_encode
from repro.kernels.delta_encode.ops import delta_zigzag
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.models.layers import flash_attention_xla

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


def _timeit(fn, *args, reps=5) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def main(fast: bool = False) -> List[str]:
    os.makedirs(ART, exist_ok=True)
    rng = np.random.RandomState(0)
    rows = []

    B, S, H, D = (1, 512, 4, 64) if fast else (2, 1024, 8, 64)
    q = jnp.asarray(rng.randn(B, S, H, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, H, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, H, D), jnp.float32)
    flops = 4 * B * H * S * S * D  # qk^T + pv
    t_chunked = _timeit(jax.jit(lambda a, b, c: flash_attention_xla(
        a, b, c, causal=True)), q, k, v)
    t_naive = _timeit(jax.jit(lambda a, b, c: jnp.swapaxes(attention_ref(
        jnp.swapaxes(a, 1, 2), jnp.swapaxes(b, 1, 2), jnp.swapaxes(c, 1, 2),
        causal=True), 1, 2)), q, k, v)
    rows.append({"kernel": "flash_attention_xla", "us": t_chunked * 1e6,
                 "derived": f"gflops={flops/t_chunked/1e9:.1f}"})
    rows.append({"kernel": "attention_naive", "us": t_naive * 1e6,
                 "derived": f"gflops={flops/t_naive/1e9:.1f}"})

    Bs, nc, Q, nh, hd, ns = (1, 4, 64, 4, 32, 16) if fast else \
        (2, 8, 128, 8, 64, 32)
    x = jnp.asarray(rng.randn(Bs, nc, Q, nh, hd), jnp.float32)
    b = jnp.asarray(rng.randn(Bs, nc, Q, ns), jnp.float32)
    c = jnp.asarray(rng.randn(Bs, nc, Q, ns), jnp.float32)
    dt = jnp.asarray(rng.rand(Bs, nc, Q, nh), jnp.float32) * 0.1
    da = -jnp.asarray(rng.rand(Bs, nc, Q, nh), jnp.float32) * 0.5
    t_ref = _timeit(jax.jit(ssd_scan_ref), x, b, c, dt, da)
    rows.append({"kernel": "ssd_recurrence_ref", "us": t_ref * 1e6,
                 "derived": f"tokens={Bs*nc*Q}"})

    xx = jnp.asarray(rng.randn(4096, 1024), jnp.float32)
    w = jnp.asarray(rng.rand(1024), jnp.float32)
    t_norm = _timeit(jax.jit(rmsnorm_ref), xx, w)
    rows.append({"kernel": "rmsnorm_ref", "us": t_norm * 1e6,
                 "derived": f"GBps={(xx.nbytes*2)/t_norm/1e9:.1f}"})

    t = np.cumsum(rng.randint(0, 1000, size=1 << 16)).astype(np.uint32)
    tj = jnp.asarray(t)
    t_np = _timeit(lambda a: delta_zigzag_encode(np.asarray(a).reshape(-1, 2)), t)
    rows.append({"kernel": "delta_zigzag_numpy", "us": t_np * 1e6,
                 "derived": f"MBps={t.nbytes/t_np/1e6:.0f}"})

    with open(os.path.join(ART, "kernels.csv"), "w", newline="") as f:
        wcsv = csv.DictWriter(f, rows[0].keys())
        wcsv.writeheader()
        wcsv.writerows(rows)
    return [f"kernel,{r['kernel']},{r['us']:.1f}us,{r['derived']}"
            for r in rows]


if __name__ == "__main__":
    for line in main():
        print(line)
