"""Paper Table 4: trace sizes -- Recorder vs Recorder-old vs Darshan-like.

Same FLASH-analogue workload, three tools behind the same generated
wrappers.  Recorder reports all five files (CFG+CST+index+timestamps);
the baselines report their own on-disk formats.  The paper's headline:
Recorder ~12x smaller than Recorder-old while storing MORE information;
Darshan smaller still but lossy (counters + partial DXT).
"""

from __future__ import annotations

import csv
import os
import shutil
import tempfile
from typing import List

from repro.core.baselines import DarshanLike, RecorderOld, ToolAdapter
from repro.core.recorder import RecorderConfig

from .workloads import flash_rank, run_ranks

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


def _baseline_bytes(tool_cls, nprocs: int, **kw) -> dict:
    total = 0
    n_records = 0
    for r in range(nprocs):
        tool = tool_cls(r)
        adapter = ToolAdapter(tool, rank=r)
        d = kw.pop("data_dir")
        flash_rank(adapter, r, nprocs, data_dir=d, **kw)
        kw["data_dir"] = d
        total += len(tool.serialize()) if hasattr(tool, "serialize") \
            else tool.nbytes
        n_records += tool.n_records
    return {"bytes": total, "n_records": n_records}


def compare(nprocs_list=(16, 64, 256), iterations=100, mode="independent"
            ) -> List[dict]:
    rows = []
    for np_ in nprocs_list:
        d = tempfile.mkdtemp()
        try:
            rec = run_ranks(flash_rank, np_, RecorderConfig(), data_dir=d,
                            iterations=iterations, mode=mode)
            old = _baseline_bytes(RecorderOld, np_, iterations=iterations,
                                  mode=mode, data_dir=d)
            dar = _baseline_bytes(DarshanLike, np_, iterations=iterations,
                                  mode=mode, data_dir=d)
        finally:
            shutil.rmtree(d, ignore_errors=True)
        rows.append({
            "nprocs": np_, "mode": mode, "iterations": iterations,
            "recorder_bytes": rec["total_bytes"],
            "recorder_pattern_bytes": rec["pattern_bytes"],
            "recorder_old_bytes": old["bytes"],
            "darshan_bytes": dar["bytes"],
            "old_over_new": round(old["bytes"] / max(rec["total_bytes"], 1),
                                  2),
            "new_over_darshan": round(
                rec["total_bytes"] / max(dar["bytes"], 1), 2),
            "n_records": rec["n_records"],
        })
    return rows


def main(fast: bool = False) -> List[str]:
    os.makedirs(ART, exist_ok=True)
    rows = []
    plist = (16, 64) if fast else (16, 64, 256)
    iters = 40 if fast else 100
    for mode in ("independent", "collective"):
        rows += compare(plist, iterations=iters, mode=mode)
    with open(os.path.join(ART, "tool_comparison.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, rows[0].keys())
        w.writeheader()
        w.writerows(rows)
    last = rows[len(rows) // 2 - 1]
    return [f"tool_comparison,old_over_new={last['old_over_new']},"
            f"new_over_darshan={last['new_over_darshan']},"
            f"nprocs={last['nprocs']}"]


if __name__ == "__main__":
    for line in main():
        print(line)
