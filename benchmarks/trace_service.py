"""Trace query service under live load: latency, throughput, staleness.

Drives the always-on service (``repro.traceserve``) the way a cluster
monitoring dashboard would: J jobs each keep committing epoch segments
while C client threads hammer the service with a mixed query workload
(``io_summary``, ``size_histogram``, ``n_records``, ``call_chains``,
``digram_counts``, ``overlap_ratio``), every query demanding a fresh
snapshot (``max_staleness_s=0``, so each one pays the refresh check).

What must hold -- the incremental-service contract:

  * **fold accounting is exact**: serving E epochs costs exactly E - 1
    incremental segment folds per job after the initial build (one per
    committed epoch; never a rebuild, never a rescan of loaded epochs),
  * **query latency stays ~flat as epochs accumulate**: the per-epoch
    median over all concurrent clients may not grow past ``FLAT_FACTOR``
    x the early-epoch median plus an absolute slack -- a service that
    re-stitched history on refresh would grow linearly,
  * **staleness is bounded by the refresh path**: the observed
    commit-to-visible delay on a polled job stays under
    ``STALENESS_BUDGET_S`` (it is one manifest read + one segment fold,
    not a function of history length).

Writes artifacts/bench/trace_service.json:
  {"config": ..., "epochs": [{epoch, p50_s, p99_s, qps, staleness_s}...],
   "overall": {p50_s, p99_s, queries, folds, ...}}

    PYTHONPATH=src python -m benchmarks.trace_service [--smoke]
"""

from __future__ import annotations

import json
import os
import random
import shutil
import sys
import tempfile
import threading
import time
from typing import Dict, List

from repro.core.recorder import Recorder, RecorderConfig
from repro.core.specs import REGISTRY
from repro.traceserve import TraceService
import repro.core.apis  # noqa: F401  (populate registry)

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")

FLAT_FACTOR = 4.0     # late-epoch p50 may cost at most this x early p50
ABS_SLACK_S = 0.010   # plus this much absolute noise allowance
STALENESS_BUDGET_S = 2.0

_MIX = ("io_summary", "size_histogram", "n_records", "call_chains",
        "digram_counts", "overlap_ratio")


def _feed_epoch(rec: Recorder, rng: random.Random, epoch: int,
                calls: int) -> None:
    fids = {n: REGISTRY.id_of(n) for n in ("pwrite", "lseek", "write")}
    t = epoch * calls * 2
    fd = "fd-0"
    if epoch == 0:
        rec.record(REGISTRY.id_of("open"), ("/data/f.bin", 2, 438), fd,
                   0, t, t + 1)
        t += 2
    for i in range(calls):
        kind = rng.random()
        if kind < 0.6:
            off = (epoch * calls + i) * 4096
            rec.record(fids["pwrite"], (fd, b"x" * 4096, off), 4096,
                       0, t, t + 1)
        elif kind < 0.8:
            rec.record(fids["lseek"], (fd, i * 256, 0), i * 256, 0, t, t + 1)
        else:
            rec.record(fids["write"], (fd, b"z" * 128), 128, 0, t, t + 1)
        t += 2


def _pct(xs: List[float], q: float) -> float:
    s = sorted(xs)
    return s[min(len(s) - 1, int(q * len(s)))] if s else 0.0


def _burst(svc: TraceService, jobs: List[str], clients: int,
           per_client: int, seed: int) -> List[float]:
    """One concurrent query burst; returns every query's latency."""
    lat: List[float] = []
    lock = threading.Lock()

    def client(cid: int) -> None:
        rng = random.Random(seed * 1000 + cid)
        mine: List[float] = []
        for _ in range(per_client):
            job = rng.choice(jobs)
            fam = rng.choice(_MIX)
            t0 = time.perf_counter()
            svc.query(job, fam, max_staleness_s=0.0)
            mine.append(time.perf_counter() - t0)
        with lock:
            lat.extend(mine)

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(clients)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    return lat


def run(n_jobs: int, epochs: int, clients: int, per_client: int,
        calls_per_epoch: int) -> Dict:
    root = tempfile.mkdtemp(prefix="trace_service_bench_")
    try:
        recs = []
        for j in range(n_jobs):
            rec = Recorder(rank=0, config=RecorderConfig(
                trace_dir=os.path.join(root, f"job_{j:02d}")))
            _feed_epoch(rec, random.Random(j), 0, calls_per_epoch)
            rec.flush()
            recs.append(rec)
        jobs = [f"job_{j:02d}" for j in range(n_jobs)]

        svc = TraceService(root, max_staleness_s=0.0, workers=clients)
        for job in jobs:  # build every view on epoch 0: folds are pure delta
            svc.query(job, "n_records")
        rows = []
        all_lat: List[float] = []
        for e in range(1, epochs):
            for j, rec in enumerate(recs):
                _feed_epoch(rec, random.Random(100 * e + j), e,
                            calls_per_epoch)
                rec.flush()
            # observed staleness on one polled job: commit-to-visible
            want = (e + 1) * calls_per_epoch + 1  # +1: the epoch-0 open
            t_commit = time.perf_counter()
            while True:
                res = svc.query(jobs[0], "n_records", max_staleness_s=0.0)
                if res.value["total"] >= want:
                    break
            staleness = time.perf_counter() - t_commit
            t0 = time.perf_counter()
            lat = _burst(svc, jobs, clients, per_client, seed=e)
            wall = time.perf_counter() - t0
            all_lat.extend(lat)
            rows.append({
                "epoch": e, "n_queries": len(lat),
                "p50_s": _pct(lat, 0.50), "p99_s": _pct(lat, 0.99),
                "qps": len(lat) / max(wall, 1e-9),
                "staleness_s": staleness,
            })
        stats = svc.stats()
        # correctness spot check before teardown: full-history totals
        for j, job in enumerate(jobs):
            got = svc.query(job, "n_records").value["total"]
            assert got == epochs * calls_per_epoch + 1, (job, got)
        svc.close()
        p50s = [r["p50_s"] for r in rows]
        overall = {
            "queries": len(all_lat),
            "p50_s": _pct(all_lat, 0.50),
            "p99_s": _pct(all_lat, 0.99),
            "qps_mean": sum(r["qps"] for r in rows) / len(rows),
            "staleness_max_s": max(r["staleness_s"] for r in rows),
            "early_p50_s": min(p50s[:3]),
            "late_p50_s": min(p50s[-3:]),
            "folds": stats["cache"]["segment_folds"],
            "view_builds": stats["cache"]["view_builds"],
            "expected_folds": n_jobs * (epochs - 1),
        }
        overall["latency_flat"] = (
            overall["late_p50_s"]
            <= FLAT_FACTOR * overall["early_p50_s"] + ABS_SLACK_S)
        return {"rows": rows, "overall": overall}
    finally:
        shutil.rmtree(root, ignore_errors=True)


def main(fast: bool = False) -> List[str]:
    os.makedirs(ART, exist_ok=True)
    if fast:
        n_jobs, epochs, clients, per_client, calls = 3, 6, 3, 20, 60
    else:
        n_jobs, epochs, clients, per_client, calls = 6, 12, 4, 40, 150
    out = run(n_jobs, epochs, clients, per_client, calls)
    out["config"] = {
        "fast": fast, "n_jobs": n_jobs, "epochs": epochs,
        "clients": clients, "per_client": per_client,
        "calls_per_epoch": calls, "flat_factor": FLAT_FACTOR,
        "abs_slack_s": ABS_SLACK_S,
        "staleness_budget_s": STALENESS_BUDGET_S,
    }
    with open(os.path.join(ART, "trace_service.json"), "w") as f:
        json.dump(out, f, indent=1)
    ov = out["overall"]
    lines = [
        f"trace_service,jobs={n_jobs},epochs={epochs},clients={clients},"
        f"queries={ov['queries']},p50_s={ov['p50_s']:.5f},"
        f"p99_s={ov['p99_s']:.5f},qps={ov['qps_mean']:.0f}",
        f"trace_service,early_p50_s={ov['early_p50_s']:.5f},"
        f"late_p50_s={ov['late_p50_s']:.5f},flat={ov['latency_flat']},"
        f"staleness_max_s={ov['staleness_max_s']:.4f}",
        f"trace_service,folds={ov['folds']},"
        f"expected={ov['expected_folds']},builds={ov['view_builds']}",
    ]
    assert ov["folds"] == ov["expected_folds"], (
        f"incremental fold accounting broke: {ov['folds']} segment folds "
        f"for {ov['expected_folds']} committed epochs -- the service "
        f"re-read or re-built instead of folding per segment")
    assert ov["view_builds"] == n_jobs, (
        f"{ov['view_builds']} view builds for {n_jobs} jobs -- cached "
        f"views were rebuilt instead of refreshed")
    assert ov["latency_flat"], (
        f"query p50 grew {ov['late_p50_s'] / max(ov['early_p50_s'], 1e-9):.1f}x "
        f"from early to late epochs -- per-query cost is no longer "
        f"independent of accumulated history")
    assert ov["staleness_max_s"] <= STALENESS_BUDGET_S, (
        f"observed commit-to-visible staleness "
        f"{ov['staleness_max_s']:.3f}s exceeded the "
        f"{STALENESS_BUDGET_S}s budget")
    return lines


if __name__ == "__main__":
    for line in main(fast="--smoke" in sys.argv or "--fast" in sys.argv):
        print(line)
