"""Per-architecture smoke tests (reduced configs, CPU): forward + train +
serve steps, shape checks, no NaNs; plus model-math equivalences."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_names, get_config, get_smoke_config
from repro.models import get_model
from repro.models.layers import flash_attention_xla
from repro.kernels.flash_attention.ref import attention_ref

rng = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32, labels=True):
    tok = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tok}
    if labels:
        batch["labels"] = tok
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            rng, (B, cfg.n_patches, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(rng, (B, S, cfg.d_model),
                                            jnp.float32)
    return batch


@pytest.mark.parametrize("name", all_arch_names())
def test_arch_smoke(name):
    cfg = get_smoke_config(name)
    model = get_model(cfg)
    params = model.init_params(rng)
    B, S = 2, 32
    batch = _batch(cfg, B, S)

    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert np.isfinite(float(loss)), name
    assert float(metrics["ntok"]) == B * S

    pf = dict(batch)
    pf.pop("labels")
    logits, cache = jax.jit(model.prefill)(params, pf)
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    c0 = model.init_cache(B, 64)
    nxt = jnp.argmax(logits[:, :cfg.vocab_size], -1).astype(jnp.int32)[:, None]
    tok1, c1 = jax.jit(model.decode_step)(params, c0, nxt)
    assert tok1.shape == (B, 1)
    assert int(tok1.min()) >= 0 and int(tok1.max()) < cfg.vocab_size
    assert int(c1["pos"][0]) == 1


@pytest.mark.parametrize("name", all_arch_names())
def test_full_config_matches_assignment(name):
    cfg = get_config(name)
    spec = {
        "deepseek-moe-16b": (28, 2048, 16, 16, 102400),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 102400),
        "chatglm3-6b": (28, 4096, 32, 2, 65024),
        "stablelm-1.6b": (24, 2048, 32, 32, 100352),
        "qwen3-32b": (64, 5120, 64, 8, 151936),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 151936),
        "hymba-1.5b": (32, 1600, 25, 5, 32001),
        "llava-next-34b": (60, 7168, 56, 8, 64000),
        "mamba2-370m": (48, 1024, 0, 0, 50280),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 256206),
    }[name]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.vocab_size) == spec
    assert cfg.padded_vocab % 256 == 0 and cfg.padded_vocab >= cfg.vocab_size


def test_flash_xla_matches_naive():
    r = np.random.RandomState(3)
    q = jnp.asarray(r.randn(2, 64, 4, 16), jnp.float32)
    k = jnp.asarray(r.randn(2, 64, 2, 16), jnp.float32)
    v = jnp.asarray(r.randn(2, 64, 2, 16), jnp.float32)
    for causal, win in [(True, 0), (True, 20), (False, 0)]:
        out = flash_attention_xla(q, k, v, causal=causal, window=win,
                                  q_chunk=16, kv_chunk=16)
        ref = attention_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                            jnp.swapaxes(v, 1, 2), causal=causal, window=win)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(jnp.swapaxes(ref, 1, 2)),
                                   atol=2e-5, rtol=2e-5)


def test_decode_matches_prefill_logits():
    """Greedy decode after prefill(prompt[:-1]) must reproduce the full
    forward's next-token argmax at the last position."""
    cfg = get_smoke_config("qwen1.5-0.5b")
    model = get_model(cfg)
    params = model.init_params(rng)
    tok = jax.random.randint(jax.random.PRNGKey(5), (2, 16), 0,
                             cfg.vocab_size)
    logits_full, _ = model.train_forward(params, {"tokens": tok})
    want = jnp.argmax(logits_full[:, -1, :cfg.vocab_size], -1)
    # prefill on prompt, decode one step with the last token
    logits_pf, cache = model.prefill(params, {"tokens": tok[:, :-1]})
    c0 = model.init_cache(2, 32)
    from repro.serve.engine import _seat
    cache_seated = _seat(cfg, c0, cache, 15)
    nxt, _ = model.decode_step(params, cache_seated, tok[:, -1:])
    np.testing.assert_array_equal(np.asarray(nxt[:, 0]), np.asarray(want))


def test_ssm_decode_matches_full_forward():
    """Mamba2: sequential decode == chunked train forward (state passing)."""
    cfg = get_smoke_config("mamba2-370m")
    model = get_model(cfg)
    params = model.init_params(rng)
    tok = jax.random.randint(jax.random.PRNGKey(6), (1, 12), 0,
                             cfg.vocab_size)
    logits_full, _ = model.train_forward(params, {"tokens": tok})
    want = jnp.argmax(logits_full[:, -1, :cfg.vocab_size], -1)
    logits_pf, cache = model.prefill(params, {"tokens": tok[:, :-1]})
    nxt, _ = model.decode_step(params, {"layers": cache["layers"],
                                        "first": cache["first"],
                                        "pos": cache["pos"]}, tok[:, -1:])
    np.testing.assert_array_equal(np.asarray(nxt[:, 0]), np.asarray(want))


def test_chunked_ce_equals_unchunked():
    cfg = get_smoke_config("qwen1.5-0.5b").replace(loss_chunk=8)
    cfg0 = cfg.replace(loss_chunk=0)
    m, m0 = get_model(cfg), get_model(cfg0)
    params = m.init_params(rng)
    batch = _batch(cfg)
    l1, _ = m.loss_fn(params, batch)
    l0, _ = m0.loss_fn(params, batch)
    assert abs(float(l1) - float(l0)) < 2e-4
    g1 = jax.grad(lambda p: m.loss_fn(p, batch)[0])(params)
    g0 = jax.grad(lambda p: m0.loss_fn(p, batch)[0])(params)
    diff = max(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g0)))
    assert diff < 1e-4


def test_param_counts_sane():
    cfg = get_config("qwen1.5-0.5b")
    n = cfg.param_counts()["total"]
    assert 0.4e9 < n < 0.8e9   # ~0.5B class
    moe = get_config("deepseek-moe-16b").param_counts()
    assert 14e9 < moe["total"] < 20e9
    assert moe["active"] < 0.35 * moe["total"]
