"""Compressed-domain TraceView: value-identity with the record-iterator
path over randomized multi-rank traces, grammar-weight helpers, batched
signature decoding, and the exactness fallbacks."""

import random
import shutil
import tempfile
from collections import Counter, defaultdict

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fallback: seeded-random example generation
    from _hypothesis_compat import given, settings, strategies as st

from benchmarks.reader_scaling import (_size_of, iter_io_summary,
                                       iter_size_histogram)
from repro.core import analysis, trace_format
from repro.core.encoding import (Handle, IterPattern, RankPattern,
                                 decode_signature, decode_signatures_batch,
                                 encode_signature)
from repro.core.interprocess import finalize_ranks
from repro.core.reader import TraceReader
from repro.core.recorder import Recorder, RecorderConfig
from repro.core.sequitur import (Sequitur, expand_grammar,
                                 expand_grammar_reversed, expansion_length,
                                 parse_grammar, rule_weights,
                                 terminal_counts, terminal_positions)
from repro.core.specs import REGISTRY
from repro.core.traceview import _DATA_FUNCS, TraceView, sweep_conflicts
import repro.core.apis  # noqa: F401  (populate registry)


# ---------------------------------------------------------------------------
# the seed per-record reference implementations (the iterator path the view
# must be value-identical to); io_summary / size_histogram live in
# benchmarks.reader_scaling (single source, shared with its value_match)
# ---------------------------------------------------------------------------


def ref_io_summary(reader):
    return iter_io_summary(reader, range(reader.nranks))


def ref_size_histogram(reader, edges=(512, 4096, 65536, 1 << 20)):
    return iter_size_histogram(reader, range(reader.nranks), edges)


def ref_call_chains(reader, rank, targets=_DATA_FUNCS):
    chains = defaultdict(int)
    stack = []
    for rec in reversed(list(reader.iter_records(rank, timestamps=False))):
        del stack[rec.depth:]
        stack.append(rec.func)
        if rec.func in targets:
            chains["->".join(stack)] += 1
    return dict(chains)


def ref_overlap_ratio(reader, rank):
    events = []
    for rec in reader.iter_records(rank):
        if rec.t_entry is None or rec.t_exit is None:
            continue
        events.append((rec.t_entry, 1))
        events.append((rec.t_exit, -1))
    if not events:
        return 0.0
    events.sort()
    busy = overlap = 0
    depth = 0
    last = events[0][0]
    for t, d in events:
        if depth >= 1:
            busy += t - last
        if depth >= 2:
            overlap += t - last
        depth += d
        last = t
    return overlap / busy if busy else 0.0


def ref_consistency_writes(reader, targets=("pwrite", "shard_write_at")):
    """The seed per-record span collection (rank-major, stream order)."""
    writes = defaultdict(list)
    for r, rec in reader.all_records(timestamps=False):
        if rec.func not in targets:
            continue
        off = next((v for v, role in zip(rec.args, rec.roles)
                    if role == "offset" and isinstance(v, int)), None)
        if off is None:
            continue
        hid = next((v.id for v, role in zip(rec.args, rec.roles)
                    if role == "handle" and hasattr(v, "id")), -1)
        writes[hid].append((r, off, off + _size_of(rec)))
    return dict(writes)


# ---------------------------------------------------------------------------
# randomized multi-rank trace generation (direct record feeding: SPMD plan
# with rank-dependent offsets, plus rank-conditional ops so several unique
# CFGs and partially-present groups appear)
# ---------------------------------------------------------------------------

_PATHS = ["/data/a.bin", "/data/b.bin", "/data/c.bin"]


def _gen_plan(rng, nprocs):
    ops = []
    n_slots = rng.randint(1, 3)
    for _ in range(rng.randint(3, 10)):
        cond = rng.choice(["all"] * 4 + ["even", "first"])
        kind = rng.choice(["open", "pwrite_run", "lseek_run", "write",
                           "stat", "close", "pread_run"])
        slot = rng.randrange(n_slots)
        if kind == "open":
            ops.append((cond, kind, slot, rng.randrange(len(_PATHS))))
        elif kind == "close":
            ops.append((cond, kind, slot))
        elif kind == "stat":
            ops.append((cond, kind, rng.randrange(len(_PATHS))))
        elif kind == "write":
            ops.append((cond, kind, slot, rng.choice([17, 600, 5000])))
        else:
            ops.append((cond, kind, slot, rng.randint(1, 6),
                        rng.choice(["linear", "constant", "irregular",
                                    "nested"]),
                        rng.randrange(1 << 20),              # base
                        rng.randrange(4096),                 # rank coef
                        rng.randrange(512),                  # stride
                        rng.choice([0, 0, 8]),               # stride coef
                        [rng.randrange(1 << 20) for _ in range(nprocs)],
                        rng.choice([64, 600, 70000]),        # size
                        rng.randint(0, 2)))                  # depth
    return ops


def _run_plan(rec, ops, rank, nprocs, ts_rng):
    fid = REGISTRY.id_of
    fds = {}

    def t01():
        t0 = ts_rng.randrange(5000)
        return t0, t0 + ts_rng.randrange(100)

    for op in ops:
        cond, kind = op[0], op[1]
        if cond == "even" and rank % 2:
            continue
        if cond == "first" and rank != 0:
            continue
        t0, t1 = t01()
        if kind == "open":
            obj = object()
            fds[op[2]] = obj
            rec.record(fid("open"), (_PATHS[op[3]], 0, 438), obj, 0, t0, t1)
        elif kind == "close":
            obj = fds.pop(op[2], None)
            if obj is not None:
                rec.record(fid("close"), (obj,), 0, 0, t0, t1)
                rec.forget_handle(obj)
        elif kind == "stat":
            rec.record(fid("stat"), (_PATHS[op[2]],), 4096, 0, t0, t1)
        elif kind == "write":
            # a slot never opened exercises the late-registered-handle path
            obj = fds.setdefault(op[2], object())
            rec.record(fid("write"), (obj, b"w" * op[3]), op[3], 0, t0, t1)
        else:
            (_, _, slot, n, bk, base0, coef, stride, scoef, irr, size,
             depth) = op
            obj = fds.setdefault(slot, object())
            if bk == "constant":
                base = base0
            elif bk == "irregular":
                base = irr[rank]
            else:  # linear / nested
                base = base0 + rank * coef
            step = stride + rank * scoef if bk == "nested" else stride
            for i in range(n):
                off = base + i * step
                t0, t1 = t01()
                if kind == "pwrite_run":
                    rec.record(fid("pwrite"), (obj, b"p" * size, off), size,
                               depth, t0, t1)
                elif kind == "pread_run":
                    rec.record(fid("pread"), (obj, size, off), b"r" * 8,
                               depth, t0, t1)
                else:
                    rec.record(fid("lseek"), (obj, off, 0), off, depth,
                               t0, t1)


def _build_random_trace(tmp, seed):
    rng = random.Random(seed)
    nprocs = rng.randint(1, 6)
    ops = _gen_plan(rng, nprocs)
    states = []
    for r in range(nprocs):
        rec = Recorder(rank=r, config=RecorderConfig())
        _run_plan(rec, ops, r, nprocs, random.Random(seed * 1009 + r))
        states.append(rec.local_state())
    merge, cfgs = finalize_ranks([s[0] for s in states],
                                 [s[1] for s in states], REGISTRY)
    d = f"{tmp}/trace"
    trace_format.write_trace(d, registry=REGISTRY,
                             merged_cst=merge.merged_entries,
                             unique_cfgs=cfgs.unique_cfgs,
                             cfg_index=cfgs.cfg_index,
                             rank_timestamps=[s[2] for s in states],
                             meta_extra={})
    return d, nprocs


# ---------------------------------------------------------------------------
# the tentpole property: every analysis is value-identical on both paths
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 20))
def test_view_value_identical_to_iterator(seed):
    tmp = tempfile.mkdtemp(prefix="traceview_")
    try:
        d, nprocs = _build_random_trace(tmp, seed)
        reader = TraceReader(d)
        view = reader.view()
        assert view.io_summary() == ref_io_summary(reader)
        assert analysis.io_summary(reader) == ref_io_summary(reader)
        assert view.size_histogram() == ref_size_histogram(reader)
        assert (analysis.size_histogram(reader, edges=(128, 1024))
                == ref_size_histogram(reader, (128, 1024)))
        for r in range(nprocs):
            assert view.call_chains(rank=r) == ref_call_chains(reader, r)
            assert (view.call_chains(("lseek",), rank=r)
                    == ref_call_chains(reader, r, ("lseek",)))
            assert view.overlap_ratio(r) == ref_overlap_ratio(reader, r)
            assert reader.n_records(r) == sum(
                1 for _ in reader.iter_records(r, timestamps=False))
        assert (view.consistency_pairs()
                == sweep_conflicts(ref_consistency_writes(reader)))
        assert view.total_records() == sum(
            reader.n_records(r) for r in range(nprocs))
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def test_view_from_analysis_module(tmp_path):
    """analysis.* accepts both TraceReader and TraceView."""
    d, _ = _build_random_trace(str(tmp_path), 7)
    reader = TraceReader(d)
    assert analysis.io_summary(reader.view()) == analysis.io_summary(reader)
    assert (analysis.consistency_pairs(reader.view())
            == analysis.consistency_pairs(reader))


# ---------------------------------------------------------------------------
# exactness fallbacks
# ---------------------------------------------------------------------------


def test_per_file_fallback_on_handle_reuse(tmp_path):
    """close + reopen under a different path reuses the unified handle id:
    per-file attribution must walk the stream, not trust the weights."""
    states = []
    fid = REGISTRY.id_of
    for rank in range(2):
        rec = Recorder(rank=rank, config=RecorderConfig())
        f1, f2 = object(), object()
        rec.record(fid("open"), ("/data/a.bin", 0, 438), f1, 0, 0, 1)
        rec.record(fid("pwrite"), (f1, b"x" * 100, rank * 100), 100, 0, 1, 2)
        rec.record(fid("close"), (f1,), 0, 0, 2, 3)
        rec.forget_handle(f1)
        rec.record(fid("open"), ("/data/b.bin", 0, 438), f2, 0, 3, 4)
        rec.record(fid("pwrite"), (f2, b"x" * 100, rank * 100), 100, 0, 4, 5)
        rec.record(fid("close"), (f2,), 0, 0, 5, 6)
        rec.forget_handle(f2)
        states.append(rec.local_state())
    merge, cfgs = finalize_ranks([s[0] for s in states],
                                 [s[1] for s in states], REGISTRY)
    d = str(tmp_path / "t")
    trace_format.write_trace(d, registry=REGISTRY,
                             merged_cst=merge.merged_entries,
                             unique_cfgs=cfgs.unique_cfgs,
                             cfg_index=cfgs.cfg_index,
                             rank_timestamps=[s[2] for s in states],
                             meta_extra={})
    reader = TraceReader(d)
    s = analysis.io_summary(reader)
    assert s == ref_io_summary(reader)
    assert s["files"]["/data/a.bin"]["calls"] == 2
    assert s["files"]["/data/b.bin"]["calls"] == 2


def test_span_cols_rank_dependent_guard(tmp_path):
    """Two adjacent pattern signatures with RankPattern components under one
    run key cannot be resolved rank-symbolically: the view must detect the
    case and fall back to the exact per-rank path."""
    pw = REGISTRY.id_of("pwrite")
    sig_a = encode_signature(pw, 0, 0,
                             (Handle(0), 100,
                              IterPattern(4, RankPattern(2, 10))), 100)
    sig_b = encode_signature(pw, 0, 0,
                             (Handle(0), 100,
                              IterPattern(8, RankPattern(2, 10))), 100)
    g = Sequitur()
    g.push(0)
    g.push(1)
    d = str(tmp_path / "t")
    trace_format.write_trace(d, registry=REGISTRY,
                             merged_cst=[sig_a, sig_b],
                             unique_cfgs=[g.serialize()], cfg_index=[0, 0],
                             rank_timestamps=[b"", b""], meta_extra={})
    reader = TraceReader(d)
    view = reader.view()
    assert view._span_cols(0, ("pwrite", "shard_write_at")) is None
    assert (view.consistency_pairs()
            == sweep_conflicts(ref_consistency_writes(reader)))


# ---------------------------------------------------------------------------
# grammar-weight helpers
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 5), st.integers(1, 7)),
                min_size=0, max_size=40))
def test_grammar_weight_helpers_match_expansion(runs):
    g = Sequitur()
    stream = []
    for t, k in runs:
        g.push(t, k)
        stream += [t] * k
    rules = parse_grammar(g.serialize())
    assert list(expand_grammar(rules)) == stream
    assert list(expand_grammar_reversed(rules)) == stream[::-1]
    assert terminal_counts(rules) == dict(Counter(stream))
    assert expansion_length(rules) == len(stream)
    assert rule_weights(rules)[0] == 1
    first, last = terminal_positions(rules)
    assert set(first) == set(last) == set(stream)
    for t in set(stream):
        assert first[t] == stream.index(t)
        assert last[t] == len(stream) - 1 - stream[::-1].index(t)


# ---------------------------------------------------------------------------
# batched signature decoding
# ---------------------------------------------------------------------------


def _rand_value(rng, depth=0):
    kinds = ["int", "big", "str", "bytes", "none", "bool", "float",
             "handle", "rankpat"]
    if depth < 2:
        kinds += ["iterpat", "tuple"]
    k = rng.choice(kinds)
    if k == "int":
        return rng.randrange(-(1 << 20), 1 << 20)
    if k == "big":
        return rng.randrange(-(1 << 70), 1 << 70)
    if k == "str":
        return "".join(rng.choice("abc/xyz.0") for _ in range(rng.randrange(8)))
    if k == "bytes":
        return bytes(rng.randrange(256) for _ in range(rng.randrange(6)))
    if k == "none":
        return None
    if k == "bool":
        return rng.choice([True, False])
    if k == "float":
        return rng.uniform(-1e9, 1e9)
    if k == "handle":
        return Handle(rng.randrange(1 << 16))
    if k == "rankpat":
        return RankPattern(rng.randrange(-(1 << 30), 1 << 30),
                           rng.randrange(-(1 << 30), 1 << 30))
    if k == "iterpat":
        return IterPattern(_rand_value(rng, 2), _rand_value(rng, 2))
    return tuple(_rand_value(rng, depth + 1)
                 for _ in range(rng.randrange(3)))


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2 ** 20))
def test_decode_signatures_batch_matches_scalar(seed):
    rng = random.Random(seed)
    sigs = []
    for _ in range(rng.randrange(1, 20)):
        args = tuple(_rand_value(rng) for _ in range(rng.randrange(5)))
        sigs.append(encode_signature(rng.randrange(1 << 20),
                                     rng.randrange(1 << 14),
                                     rng.randrange(1 << 7),
                                     args, _rand_value(rng)))
    batch = decode_signatures_batch(sigs)
    assert len(batch) == len(sigs)
    for i, s in enumerate(sigs):
        fid, tid, dep, args, ret = decode_signature(s)
        assert (int(batch.func_id[i]), int(batch.thread[i]),
                int(batch.depth[i])) == (fid, tid, dep)
        assert batch.args[i] == args
        assert batch.ret[i] == ret


def test_decode_signatures_batch_empty():
    batch = decode_signatures_batch([])
    assert len(batch) == 0 and batch.args == [] and batch.ret == []
