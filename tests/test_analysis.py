"""Paper Section-4 analyses over real traces."""

import os
import sys
import threading

import pytest

sys.path.insert(0, "/root/repo")
from benchmarks.workloads import ior_rank  # noqa: E402
from repro.core import trace_format
from repro.core.analysis import (call_chains, consistency_pairs, io_summary,
                                 overlap_ratio, size_histogram)
from repro.core.apis import posix, shardio
from repro.core.interprocess import finalize_ranks
from repro.core.reader import TraceReader
from repro.core.recorder import Recorder, RecorderConfig, session
from repro.core.specs import REGISTRY


@pytest.fixture
def traced_workload(tmp_path):
    datadir = tmp_path / "data"
    datadir.mkdir()
    tracedir = str(tmp_path / "trace")
    with session(RecorderConfig(trace_dir=tracedir)):
        fh = shardio.shard_open(str(datadir / "big.bin"), 1)
        for i in range(20):
            shardio.shard_write_at(fh, b"x" * 8192, i * 8192)
        shardio.shard_sync(fh)
        shardio.shard_close(fh)
        fd = posix.open(str(datadir / "small.bin"),
                        os.O_RDWR | os.O_CREAT, 0o644)
        for i in range(10):
            posix.pwrite(fd, b"y" * 100, i * 100)
        posix.close(fd)
        posix.stat(str(datadir / "big.bin"))
    return tracedir


def test_io_summary(traced_workload):
    s = io_summary(TraceReader(traced_workload))
    # shardio writes recurse into posix pwrites: both layers counted
    assert s["total_bytes"] == 2 * (20 * 8192) + 10 * 100
    assert s["n_metadata_calls"] > 0
    assert 0 < s["metadata_ratio"] < 0.5
    assert s["aggregate_MBps"] > 0


def test_size_histogram(traced_workload):
    h = size_histogram(TraceReader(traced_workload))
    assert h["<512"] == 10                # the small pwrites
    assert h["<65536"] >= 40              # 8 KiB writes at both layers


def test_call_chains(traced_workload):
    c = call_chains(TraceReader(traced_workload))
    assert c.get("shard_write_at->pwrite") == 20
    assert c.get("pwrite") == 10          # direct application-level writes


def test_overlap_ratio_multithreaded(tmp_path):
    datadir = tmp_path / "d"
    datadir.mkdir()
    tracedir = str(tmp_path / "t")
    with session(RecorderConfig(trace_dir=tracedir)):
        def worker(i):
            fd = posix.open(str(datadir / f"{i}.bin"),
                            os.O_RDWR | os.O_CREAT, 0o644)
            for j in range(200):
                posix.pwrite(fd, b"z" * 1024, j * 1024)
            posix.close(fd)
        ts = [threading.Thread(target=worker, args=(i,)) for i in range(3)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
    r = overlap_ratio(TraceReader(tracedir))
    assert 0.0 <= r <= 1.0


def _write_span_trace(tmp_path, spans):
    """One pwrite per rank: ``spans[rank] = (offset, size)``."""
    states = []
    fid = REGISTRY.id_of("pwrite")
    for rank, (off, size) in enumerate(spans):
        rec = Recorder(rank=rank, config=RecorderConfig())
        fdobj = object()
        rec.record(fid, (fdobj, b"a" * size, off), size, 0, 0, 1)
        states.append(rec.local_state())
    merge, cfgs = finalize_ranks([s[0] for s in states],
                                 [s[1] for s in states], REGISTRY)
    tdir = str(tmp_path / "trace")
    trace_format.write_trace(tdir, registry=REGISTRY,
                             merged_cst=merge.merged_entries,
                             unique_cfgs=cfgs.unique_cfgs,
                             cfg_index=cfgs.cfg_index,
                             rank_timestamps=[s[2] for s in states])
    return tdir


def test_consistency_pairs(tmp_path):
    """Cross-rank overlapping writes (the [27,28] consistency study)."""
    # both ranks write [0, 100): a genuine conflict
    tdir = _write_span_trace(tmp_path, [(0, 100), (0, 100)])
    conflicts = consistency_pairs(TraceReader(tdir))
    assert len(conflicts) == 1
    assert conflicts[0]["extent"] == (0, 100)


def test_consistency_pairs_non_adjacent_overlap(tmp_path):
    """Regression: a long extent must conflict with every later overlapping
    span, not only the start-adjacent one.  Rank 0 writes [0, 100); rank 1
    writes [10, 20); rank 2 writes [30, 40) -- the seed adjacent-pair scan
    dropped the 0<->2 conflict."""
    tdir = _write_span_trace(tmp_path, [(0, 100), (10, 10), (30, 10)])
    conflicts = consistency_pairs(TraceReader(tdir))
    got = {(c["ranks"], c["extent"]) for c in conflicts}
    assert got == {((0, 1), (10, 20)), ((0, 2), (30, 40))}
