"""Accelerated encode layer vs the pure-Python reference encoders.

Every batched backend ("numpy" vectorized host, "pallas" kernels run in
interpret mode so the suite executes on CPU-only CI) must be BYTE-identical
to the scalar Python path: the backend knob may never change what lands in
a trace file.  Properties cover randomized tick streams (wraps, zero
deltas, max-u32), ragged varint length classes, empty blocks, the u64
batch guard, the rank-linear fit/segmentation dispatchers, the
grammar-stats kernels, and full Recorder round-trips through TraceReader
under every backend.
"""

import hashlib
import os
import shutil
import time
import zlib

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                      # pragma: no cover
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import encode_backend as eb
from repro.core import trace_format
from repro.core.apis import posix
from repro.core.encoding import (VarintRangeError, decode_value,
                                 encode_value, pack_uvarints, read_uvarint,
                                 write_uvarint)
from repro.core.interprocess import (arith_segments, batch_fit_columns,
                                     finalize_ranks)
from repro.core.patterns import IntraPatternTracker
from repro.core.reader import TraceReader
from repro.core.recorder import Recorder, RecorderConfig, attach, detach
from repro.core.sequitur import Sequitur, expand_grammar, parse_grammar
from repro.core.specs import REGISTRY
from repro.core.timestamps import (compress_timestamps,
                                   compress_timestamps_blocked,
                                   decompress_timestamps,
                                   delta_zigzag_encode)
from repro.core.traceview import TraceView, _DATA_FUNCS, _WRITE_FUNCS

rng = np.random.RandomState(11)

BATCH = ["numpy", "pallas"]          # backends that must match "python"


# ---------------------------------------------------------------------------
# tick streams: delta+zigzag and the fused varint emit
# ---------------------------------------------------------------------------

def _tick_stream(n, style):
    """(n, 2) uint32 tick pairs exercising the encoder's edge geometry."""
    if style == "wrap":
        # counters near the 32-bit wrap point: deltas straddle the wrap
        base = (1 << 32) - n - 5
        flat = base + np.sort(rng.randint(0, 2 * n + 9, size=2 * n))
    elif style == "zero":
        # heavy runs of identical ticks (zero deltas)
        flat = np.repeat(rng.randint(0, 1000, size=max(1, n // 4)), 8)[:2 * n]
        flat = np.sort(flat)
    elif style == "extreme":
        # arbitrary u32 values incl. 0 and max-u32: worst-case deltas
        flat = rng.randint(0, 1 << 32, size=2 * n, dtype=np.uint64)
        if n:
            flat[rng.randint(0, 2 * n)] = (1 << 32) - 1
            flat[rng.randint(0, 2 * n)] = 0
    else:
        flat = np.cumsum(rng.randint(0, 100000, size=2 * n))
    return (flat.astype(np.uint64) & 0xFFFFFFFF).astype(
        np.uint32).reshape(-1, 2)


@pytest.mark.parametrize("style", ["mono", "wrap", "zero", "extreme"])
@pytest.mark.parametrize("n", [0, 1, 5, 257, 5000])
def test_delta_zigzag_backends_identical(style, n):
    ticks = _tick_stream(n, style)
    ref = eb.delta_zigzag(ticks.reshape(-1).astype(np.uint32), "python")
    assert ref.dtype == np.uint32
    for b in BATCH:
        out = eb.delta_zigzag(ticks.reshape(-1).astype(np.uint32), b)
        np.testing.assert_array_equal(out, ref, err_msg=b)
    # and the decoder inverts every backend's output (they're equal, but
    # pin the round-trip too so the reference itself can't silently drift)
    blob = compress_timestamps(ticks, backend="numpy")
    np.testing.assert_array_equal(decompress_timestamps(blob), ticks)


@pytest.mark.parametrize("style", ["mono", "wrap", "zero", "extreme"])
def test_compress_timestamps_byte_identical(style):
    ticks = _tick_stream(1000, style)
    ref = compress_timestamps(ticks, backend="python")
    for b in BATCH + ["auto"]:
        assert compress_timestamps(ticks, backend=b) == ref, b


def test_compress_timestamps_blocked_byte_identical():
    ticks = _tick_stream(3000, "mono")
    ref = compress_timestamps_blocked(ticks, block_records=256,
                                      backend="python")
    for b in BATCH + ["auto"]:
        out = compress_timestamps_blocked(ticks, block_records=256,
                                          backend=b)
        assert out == ref, b


@pytest.mark.parametrize("style", ["mono", "wrap", "zero", "extreme"])
@pytest.mark.parametrize("n", [0, 1, 7, 1024])
def test_fused_ticks_varint_matches_python(style, n):
    ticks = _tick_stream(n, style)
    ref = eb.encode_ticks_varint(ticks, "python")
    for b in BATCH:
        assert eb.encode_ticks_varint(ticks, b) == ref, b
    # the stream really is the uvarint coding of the zigzag deltas
    zz = eb.delta_zigzag(ticks.reshape(-1).astype(np.uint32), "python")
    assert ref == pack_uvarints([int(v) for v in zz], backend="python")


# ---------------------------------------------------------------------------
# uvarint batch packing: ragged length classes, u64 edges, range guard
# ---------------------------------------------------------------------------

def _ragged_u64(rng, n):
    """Values spanning every varint length class 1..10 bytes."""
    bits = rng.randint(0, 65, size=n)
    return [int(rng.randint(0, 1 << 32, dtype=np.uint64)
               | (np.uint64(1) << np.uint64(max(0, b - 1))))
            & ((1 << 64) - 1) if b else 0 for b in bits]


@pytest.mark.parametrize("n", [0, 1, 3, 100, 2048])
def test_pack_uvarints_backends_identical(n):
    vals = _ragged_u64(rng, n)
    ref = pack_uvarints(vals, backend="python")
    for b in BATCH + ["auto"]:
        assert pack_uvarints(vals, backend=b) == ref, b
    # decodes back exactly
    pos, out = 0, []
    while pos < len(ref):
        v, pos = read_uvarint(ref, pos)
        out.append(v)
    assert out == vals


def test_pack_uvarints_u64_edges():
    edges = [0, 1, 127, 128, (1 << 14) - 1, 1 << 14, (1 << 21) - 1,
             (1 << 28), (1 << 32) - 1, 1 << 32, (1 << 35) + 7,
             (1 << 56) - 1, 1 << 56, (1 << 63), (1 << 64) - 1]
    ref = pack_uvarints(edges, backend="python")
    for b in BATCH:
        assert pack_uvarints(edges, backend=b) == ref, b


@pytest.mark.parametrize("backend", ["python", "numpy", "pallas"])
@pytest.mark.parametrize("bad", [1 << 64, (1 << 64) + 3, -1, -(1 << 70)])
def test_pack_uvarints_range_guard(backend, bad):
    with pytest.raises(VarintRangeError):
        pack_uvarints([0, 5, bad, 7], backend=backend)


def test_scalar_writers_stay_arbitrary_precision():
    # the u64 guard is a property of the BATCHED packers only: the scalar
    # signature encoder must keep accepting arbitrarily large ints
    buf = bytearray()
    write_uvarint(buf, 1 << 70)
    v, _ = read_uvarint(bytes(buf), 0)
    assert v == 1 << 70
    buf = bytearray()
    encode_value(buf, -(1 << 70))
    v, _ = decode_value(bytes(buf), 0)
    assert v == -(1 << 70)


# ---------------------------------------------------------------------------
# rank-linear fitting + run segmentation dispatchers
# ---------------------------------------------------------------------------

def _columns(n_cols, n_ranks):
    cols = []
    for _ in range(n_cols):
        kind = rng.randint(0, 3)
        if kind == 0:
            cols.append([int(rng.randint(-50, 50))] * n_ranks)
        elif kind == 1:
            a, b = int(rng.randint(-9, 9)) or 3, int(rng.randint(-99, 99))
            cols.append([b + r * a for r in range(n_ranks)])
        else:
            cols.append([int(v) for v in rng.randint(-1000, 1000,
                                                     size=n_ranks)])
    return cols


@pytest.mark.parametrize("n_cols,n_ranks", [(1, 2), (40, 8), (300, 16)])
def test_batch_fit_columns_backends_identical(n_cols, n_ranks):
    cols = _columns(n_cols, n_ranks)
    ref = batch_fit_columns(cols, backend="python")
    for b in BATCH:
        assert batch_fit_columns(cols, backend=b) == ref, b


@pytest.mark.parametrize("k", [1, 2, 3])
def test_arith_segments_backends_identical(k):
    V = np.concatenate([
        np.arange(50)[:, None] * rng.randint(1, 5, size=k)[None, :] + 7,
        rng.randint(-100, 100, size=(17, k)),
        np.full((31, k), 42),
    ]).astype(np.int64)
    ref = arith_segments(V, backend="python")
    for b in BATCH:
        assert arith_segments(V, backend=b) == ref, b


def test_encode_many_backend_matches_scalar_protocol():
    rows = ([(i * 8, 0) for i in range(60)]
            + [(5, 1), (9, 1), (13, 1)]               # new run, stride 4
            + [(int(v), 2) for v in rng.randint(0, 99, size=20)])
    ref_tr, out_tr = IntraPatternTracker(), {}
    ref = [ref_tr.encode("k", r) for r in rows]
    for b in ["python"] + BATCH:
        tr = IntraPatternTracker()
        got = tr.encode_many("k", rows, backend=b)
        assert got == ref, b
        assert tr._runs.keys() == ref_tr._runs.keys()
        assert all(vars(tr._runs[k]) == vars(ref_tr._runs[k])
                   for k in tr._runs), b


# ---------------------------------------------------------------------------
# grammar_stats kernels vs refs, and their users
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 2, 129, 4096])
@pytest.mark.parametrize("k", [1, 3])
def test_run_boundaries_backends_identical(n, k):
    V = rng.randint(0, 4, size=(n, k)).astype(np.int64)
    ref = eb.run_boundaries(V, "python")
    assert ref[0]
    for b in BATCH:
        np.testing.assert_array_equal(eb.run_boundaries(V, b), ref,
                                      err_msg=b)


@pytest.mark.parametrize("n,n_bins", [(1, 4), (1000, 7), (5000, 64)])
def test_terminal_histogram_backends_identical(n, n_bins):
    stream = rng.randint(0, n_bins, size=n).astype(np.int64)
    ref = eb.terminal_histogram(stream, n_bins, "python")
    np.testing.assert_array_equal(
        ref, np.bincount(stream, minlength=n_bins))
    for b in BATCH:
        np.testing.assert_array_equal(
            eb.terminal_histogram(stream, n_bins, b), ref, err_msg=b)


@pytest.mark.parametrize("n,T", [(0, 3), (1, 3), (2000, 5), (4097, 40)])
def test_digram_histogram_backends_identical(n, T):
    stream = rng.randint(0, T, size=n).astype(np.int64)
    ref = eb.digram_histogram(stream, T, "python")
    assert sum(ref.values()) == max(0, n - 1)
    for b in BATCH:
        assert eb.digram_histogram(stream, T, b) == ref, b


def test_push_stream_matches_per_terminal_push():
    stream = [int(v) for v in
              np.repeat(rng.randint(0, 6, size=200),
                        rng.randint(1, 9, size=200))]
    # grammar reference: one push(term, run_len) per maximal run (the batch
    # semantics push_stream promises); expansion must also equal the
    # original per-terminal stream
    ref = Sequitur()
    i = 0
    while i < len(stream):
        j = i
        while j < len(stream) and stream[j] == stream[i]:
            j += 1
        ref.push(stream[i], j - i)
        i = j
    for b in ["python"] + BATCH:
        s = Sequitur()
        s.push_stream(stream, backend=b)
        assert s.serialize() == ref.serialize(), b
        assert (list(expand_grammar(parse_grammar(s.serialize())))
                == stream), b


# ---------------------------------------------------------------------------
# full Recorder round-trip: traces byte-identical under every backend
# ---------------------------------------------------------------------------

class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1e-6
        return self.t


def _trace_digest(cfg_backend, base, datadir):
    """Run one deterministic workload under a backend; digest the files.

    ``datadir`` must be IDENTICAL across the runs being compared: the
    open() path string is recorded in the merged CST, so differing data
    directories would (correctly) change the trace bytes."""
    shutil.rmtree(base, ignore_errors=True)
    os.makedirs(base)
    os.makedirs(datadir, exist_ok=True)
    tdir = os.path.join(base, "trace")
    real = time.perf_counter
    time.perf_counter = _FakeClock()
    try:
        rec = Recorder(rank=0, config=RecorderConfig(
            trace_dir=tdir, encode_backend=cfg_backend))
        attach(rec)
        try:
            fd = posix.open(os.path.join(datadir, "f.bin"),
                            os.O_RDWR | os.O_CREAT, 0o644)
            for i in range(300):
                posix.pwrite(fd, b"x" * 512, 512 * i)
            posix.fsync(fd)
            posix.close(fd)
        finally:
            detach()
        rec.finalize()
    finally:
        time.perf_counter = real
    h = hashlib.sha256()
    for name in sorted(os.listdir(tdir)):
        if name.endswith(".json"):
            continue                      # metadata carries no trace bytes
        with open(os.path.join(tdir, name), "rb") as f:
            h.update(name.encode() + b"\0" + f.read())
    return h.hexdigest(), tdir


def test_trace_byte_identical_across_backends(tmp_path):
    datadir = str(tmp_path / "data")
    ref, tdir = _trace_digest("python", str(tmp_path / "python"), datadir)
    r = TraceReader(tdir)
    offs = [rc.arg("offset") for rc in r.iter_records(0)
            if rc.func == "pwrite"]
    assert offs == [512 * i for i in range(300)]
    for b in ["numpy", "pallas", "auto"]:
        got, tdir_b = _trace_digest(b, str(tmp_path / b), datadir)
        assert got == ref, b
        rb = TraceReader(tdir_b)
        assert [rc.arg("offset") for rc in rb.iter_records(0)
                if rc.func == "pwrite"] == offs, b


def test_config_rejects_unknown_backend():
    with pytest.raises(ValueError):
        RecorderConfig(encode_backend="cuda")


def test_resolve_crossover():
    assert eb.resolve("python", 10 ** 9) == "python"     # explicit wins
    assert eb.resolve("auto", 1) == "python"             # tiny -> scalar
    big = eb.resolve("auto", eb.PALLAS_MIN_BATCH)
    assert big == ("pallas" if eb.has_accelerator() else "numpy")
    assert eb.resolve(None, eb.NUMPY_MIN_BATCH) in ("numpy", "pallas")


# ---------------------------------------------------------------------------
# TraceView: memoized walks vs linear references
# ---------------------------------------------------------------------------

def _spmd_trace(base, nranks=3, n=120):
    shutil.rmtree(base, ignore_errors=True)
    os.makedirs(base)
    states = []
    for r in range(nranks):
        rec = Recorder(rank=r, config=RecorderConfig())
        attach(rec)
        try:
            fd = posix.open(os.path.join(base, "a.bin"),
                            os.O_RDWR | os.O_CREAT, 0o644)
            for i in range(n):
                posix.pwrite(fd, b"x" * 64, 64 * (nranks * i + r))
            posix.close(fd)
            fd2 = posix.open(os.path.join(base, "b.bin"),
                             os.O_RDWR | os.O_CREAT, 0o644)
            for j in range(6):
                for i in range(20):
                    posix.pread(fd2, 128, 128 * (j * 20 + i))
                    # every rank writes the SAME extent: cross-rank overlap
                    posix.pwrite(fd2, b"y" * 128, 128 * (j * 20 + i))
                posix.fsync(fd2)
            posix.close(fd2)
        finally:
            detach()
        states.append(rec.local_state())
    merge, cfgs = finalize_ranks([s[0] for s in states],
                                 [s[1] for s in states], REGISTRY)
    tdir = os.path.join(base, "trace")
    trace_format.write_trace(
        tdir, registry=REGISTRY, merged_cst=merge.merged_entries,
        unique_cfgs=cfgs.unique_cfgs, cfg_index=cfgs.cfg_index,
        rank_timestamps=[s[2] for s in states], meta_extra={})
    return tdir


@pytest.fixture(scope="module")
def spmd_view(tmp_path_factory):
    tdir = _spmd_trace(str(tmp_path_factory.mktemp("spmd") / "w"))
    return TraceView(TraceReader(tdir))


def test_per_file_walk_memo_matches_linear(spmd_view):
    tv = spmd_view
    for u in range(len(tv.grammars)):
        assert tv._per_file_walk_memo(u) == tv._per_file_walk_linear(u), u


def _norm_spans(res):
    if res is None:
        return None
    return [(h, list(map(int, cf)), list(map(int, ct)), list(map(int, sz)),
             npc is not None) for h, cf, ct, sz, npc in res]


def test_span_cols_walk_matches_linear(spmd_view):
    tv = spmd_view
    from repro.core.traceview import _SpanBail
    for targets in (_WRITE_FUNCS, _DATA_FUNCS, ("pread",), ("nosuch",)):
        tgt = tuple(targets)
        for u in range(len(tv.grammars)):
            lin = tv._span_cols_linear(u, tgt)
            try:
                walk = tv._span_cols_walk(u, tgt)
            except _SpanBail:
                assert lin is None, (u, tgt)
                continue
            assert _norm_spans(walk) == _norm_spans(lin), (u, tgt)


def test_span_cols_wrapper_caches(spmd_view):
    tv = spmd_view
    tgt = tuple(_WRITE_FUNCS)
    first = tv._span_cols(0, tgt)
    assert (0, tgt) in tv._spancols
    assert tv._span_cols(0, tgt) is first


def test_consistency_pairs_still_overlap(spmd_view):
    pairs = spmd_view.consistency_pairs()
    assert pairs                       # strided writes do interleave
    assert all(p["handle"] is not None for p in pairs)


def test_digram_counts_backends_identical(spmd_view):
    tv = spmd_view
    ref = tv.digram_counts(0, backend="python")
    assert sum(ref.values()) == tv.n_records(0) - 1
    for b in BATCH + ["auto"]:
        assert tv.digram_counts(0, backend=b) == ref, b


# ---------------------------------------------------------------------------
# randomized property sweeps (hypothesis or the seeded fallback)
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=(1 << 64) - 1),
                max_size=300))
def test_prop_pack_uvarints(vals):
    ref = pack_uvarints(vals, backend="python")
    for b in BATCH:
        assert pack_uvarints(vals, backend=b) == ref, b


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=(1 << 32) - 1),
                max_size=200))
def test_prop_tick_encode(flat):
    flat = flat + [0] * (len(flat) % 2)     # even count -> (n, 2)
    ticks = np.asarray(flat, np.uint32).reshape(-1, 2)
    ref = compress_timestamps(ticks, backend="python")
    for b in BATCH:
        assert compress_timestamps(ticks, backend=b) == ref, b
    np.testing.assert_array_equal(
        decompress_timestamps(ref), ticks)
