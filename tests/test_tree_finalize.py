"""Tree-reduction finalize: byte-identity with the flat pass, state
serialization, the ThreadComm collective path, reader round-trips, and the
vectorized fitting / batched intra-pattern encoding equivalences."""

import os
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fallback: seeded-random example generation
    from _hypothesis_compat import given, settings, strategies as st

from benchmarks.workloads import synth_rank_states
from repro.core import trace_format
from repro.core.comm import Comm, SoloComm, run_thread_world
from repro.core.interprocess import (batch_fit_columns, deserialize_rank_state,
                                     finalize_ranks, make_rank_state,
                                     materialize_state, merge_rank_states,
                                     merge_serialized_states,
                                     serialize_rank_state,
                                     tree_finalize_ranks, tree_reduce_states,
                                     _fit_component)
from repro.core.patterns import IntraPatternTracker
from repro.core.reader import TraceReader
from repro.core.recorder import Recorder, RecorderConfig
from repro.core.specs import REGISTRY
import repro.core.apis  # noqa: F401  (populate registry)


def _assert_same_finalize(r1, r2):
    m1, c1 = r1
    m2, c2 = r2
    assert m1.merged_entries == m2.merged_entries
    assert m1.remaps == m2.remaps
    assert m1.n_rank_patterns == m2.n_rank_patterns
    assert c1.unique_cfgs == c2.unique_cfgs
    assert c1.cfg_index == c2.cfg_index


# ---------------------------------------------------------------------------
# flat <-> tree byte-identity (the tentpole property)
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 64),
       st.sampled_from(["linear", "constant", "irregular", "mixed"]),
       st.integers(1, 6), st.integers(1, 8), st.integers(0, 2 ** 20))
def test_tree_matches_flat_bytes(nranks, pattern, n_groups, n_calls, seed):
    """tree_finalize_ranks output is identical to flat finalize_ranks for
    randomized rank counts (incl. non-powers-of-two) and offset patterns."""
    csts, cfgs = synth_rank_states(nranks, n_groups=n_groups,
                                   n_calls=n_calls, pattern=pattern,
                                   seed=seed)
    for inter in (True, False):
        flat = finalize_ranks(csts, cfgs, REGISTRY, inter_patterns=inter,
                              fit_mode="python")
        _assert_same_finalize(
            flat, finalize_ranks(csts, cfgs, REGISTRY, inter_patterns=inter,
                                 fit_mode="vectorized"))
        _assert_same_finalize(
            flat, tree_finalize_ranks(csts, cfgs, REGISTRY,
                                      inter_patterns=inter))


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 48), st.integers(1, 5), st.integers(0, 2 ** 20))
def test_tree_matches_flat_with_partial_groups(nranks, n_groups, seed):
    """Ranks with missing / extra entries (collective-I/O shape) still
    merge identically."""
    rng = random.Random(seed)
    csts, cfgs = synth_rank_states(nranks, n_groups=n_groups, n_calls=4,
                                   pattern="mixed", seed=seed)
    csts = [list(c) for c in csts]
    cfgs = list(cfgs)
    # drop a suffix of terminals on a few ranks (and shrink their grammar)
    from repro.core.sequitur import Sequitur
    for r in rng.sample(range(nranks), max(1, nranks // 4)):
        keep = rng.randrange(0, len(csts[r]))
        csts[r] = csts[r][:keep]
        g = Sequitur()
        for t in range(keep):
            g.push(t, rng.randrange(1, 4))
        cfgs[r] = g.serialize()
    _assert_same_finalize(
        finalize_ranks(csts, cfgs, REGISTRY),
        tree_finalize_ranks(csts, cfgs, REGISTRY))


def test_tree_reduction_order_invariance():
    """Sequential left-fold and pairwise-tree association produce identical
    states (serialized bytes compared)."""
    csts, cfgs = synth_rank_states(7, n_groups=3, n_calls=5, pattern="mixed",
                                   seed=3)
    leaves = [make_rank_state(r, csts[r], cfgs[r], REGISTRY)
              for r in range(7)]
    tree = tree_reduce_states([make_rank_state(r, csts[r], cfgs[r], REGISTRY)
                               for r in range(7)])
    fold = leaves[0]
    for s in leaves[1:]:
        fold = merge_rank_states(fold, s)
    assert serialize_rank_state(tree) == serialize_rank_state(fold)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 32),
       st.sampled_from(["nested", "multi", "mixed_all"]),
       st.integers(1, 4), st.integers(1, 6), st.integers(0, 2 ** 20))
def test_tree_matches_flat_nested_and_multi_offset(nranks, pattern, n_groups,
                                                   n_calls, seed):
    """The extended synth shapes -- nested IterPattern-of-RankPattern
    offsets (paper Fig 3c) and joint multi-offset lseek runs -- finalize
    identically on both topologies."""
    csts, cfgs = synth_rank_states(nranks, n_groups=n_groups,
                                   n_calls=n_calls, pattern=pattern,
                                   seed=seed)
    _assert_same_finalize(
        finalize_ranks(csts, cfgs, REGISTRY, fit_mode="python"),
        tree_finalize_ranks(csts, cfgs, REGISTRY))


def test_synth_nested_roundtrips_through_reader(tmp_path):
    """Nested offsets (rank-linear base AND stride) and joint lseek
    offset/return runs come back exactly from the merged trace."""
    nprocs, n_groups, n_calls, chunk = 5, 2, 6, 512
    big = 1 << 24
    for pattern in ("nested", "multi"):
        csts, cfgs = synth_rank_states(nprocs, n_groups=n_groups,
                                       n_calls=n_calls, pattern=pattern,
                                       chunk=chunk)
        merge, cfgres = tree_finalize_ranks(csts, cfgs, REGISTRY)
        d = str(tmp_path / pattern)
        trace_format.write_trace(d, registry=REGISTRY,
                                 merged_cst=merge.merged_entries,
                                 unique_cfgs=cfgres.unique_cfgs,
                                 cfg_index=cfgres.cfg_index,
                                 rank_timestamps=[b""] * nprocs,
                                 meta_extra={})
        reader = TraceReader(d)
        for r in range(nprocs):
            base = lambda g: r * chunk + g * big  # noqa: E731
            step = ((nprocs + r) * chunk if pattern == "nested"
                    else nprocs * chunk)
            want = [base(g) + i * step
                    for g in range(n_groups) for i in range(n_calls)]
            recs = list(reader.iter_records(r, timestamps=False))
            assert [rec.arg("offset") for rec in recs] == want, (pattern, r)
            if pattern == "multi":
                assert [rec.ret for rec in recs] == want  # joint OFFSET ret


def test_merge_requires_adjacent_blocks():
    csts, cfgs = synth_rank_states(3, n_groups=1, n_calls=2)
    s0, _, s2 = (make_rank_state(r, csts[r], cfgs[r], REGISTRY)
                 for r in range(3))
    with pytest.raises(ValueError):
        merge_rank_states(s0, s2)


# ---------------------------------------------------------------------------
# state serialization (tree hops)
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 16),
       st.sampled_from(["linear", "constant", "irregular", "mixed"]),
       st.integers(0, 2 ** 20))
def test_state_serialization_roundtrip(nranks, pattern, seed):
    csts, cfgs = synth_rank_states(nranks, n_groups=3, n_calls=6,
                                   pattern=pattern, seed=seed)
    root = tree_reduce_states([make_rank_state(r, csts[r], cfgs[r], REGISTRY)
                               for r in range(nranks)])
    blob = serialize_rank_state(root)
    back = deserialize_rank_state(blob)
    assert serialize_rank_state(back) == blob           # stable bytes
    _assert_same_finalize(materialize_state(root), materialize_state(back))


def test_merge_serialized_states_matches_object_merge():
    csts, cfgs = synth_rank_states(4, n_groups=2, n_calls=5, seed=1)
    leaves = [make_rank_state(r, csts[r], cfgs[r], REGISTRY)
              for r in range(4)]
    blob = merge_serialized_states(
        merge_serialized_states(serialize_rank_state(leaves[0]),
                                serialize_rank_state(leaves[1])),
        merge_serialized_states(serialize_rank_state(leaves[2]),
                                serialize_rank_state(leaves[3])))
    obj = tree_reduce_states(leaves)
    assert blob == serialize_rank_state(obj)


# ---------------------------------------------------------------------------
# Comm.reduce_tree + the SPMD finalize path (ThreadComm, multi-threaded)
# ---------------------------------------------------------------------------


def test_reduce_tree_solo_and_generic():
    assert SoloComm().reduce_tree(b"x", lambda a, b: a + b) == b"x"

    class ListComm(Comm):
        rank, size = 0, 5

        def gather(self, obj, root=0):
            return [obj * (i + 1) for i in range(5)]

    # fold of ["x","xx","xxx","xxxx","xxxxx"]: association-independent here
    assert ListComm().reduce_tree("x", lambda a, b: a + b) == "x" * 15


def _run_threaded(tmp_path, topology, nprocs=5, n_calls=24, chunk=512):
    """N ranks on N threads; records are fed directly (the wrapper slot is
    a process-global, shared across threads) and finalize runs through the
    real ThreadComm collectives with the requested topology."""
    trace_dir = str(tmp_path / f"trace_{topology}")
    fid_seek = REGISTRY.id_of("lseek")
    fid_write = REGISTRY.id_of("write")

    def worker(comm, rank):
        rec = Recorder(rank=rank, config=RecorderConfig(
            finalize_topology=topology))
        fd = object()
        for i in range(n_calls):
            off = rank * chunk + i * nprocs * chunk
            rec.record(fid_seek, (fd, off, 0), off, 0, 2 * i, 2 * i + 1)
            rec.record(fid_write, (fd, b"x" * 64), 64, 0, 2 * i + 1,
                       2 * i + 2)
        return rec.finalize(comm, trace_dir=trace_dir)

    stats = run_thread_world(nprocs, worker)
    assert stats[0] is not None
    assert all(s is None for s in stats[1:])
    return trace_dir


def test_threadcomm_tree_trace_matches_flat(tmp_path):
    """Concurrent tree finalize over ThreadComm writes byte-identical trace
    files to the flat gather path."""
    d_tree = _run_threaded(tmp_path, "tree")
    d_flat = _run_threaded(tmp_path, "flat")
    for name in ("merged_cst.bin", "unique_cfgs.bin", "cfg_index.bin"):
        with open(os.path.join(d_tree, name), "rb") as f1, \
                open(os.path.join(d_flat, name), "rb") as f2:
            assert f1.read() == f2.read(), name


def test_threadcomm_tree_nonpow2(tmp_path):
    for nprocs in (3, 6, 7):
        d = _run_threaded(tmp_path / str(nprocs), "tree", nprocs=nprocs)
        r = TraceReader(d)
        assert r.nranks == nprocs


def test_reader_roundtrip_tree_finalized(tmp_path):
    """TraceReader reconstructs every rank's exact offsets from a trace
    finalized through the tree topology."""
    nprocs, n_calls, chunk = 6, 30, 512
    d = _run_threaded(tmp_path, "tree", nprocs=nprocs, n_calls=n_calls,
                      chunk=chunk)
    reader = TraceReader(d)
    assert reader.nranks == nprocs
    assert len(reader.unique_cfgs) == 1    # identical SPMD ranks deduped
    for r in range(nprocs):
        offs = [rec.arg("offset") for rec in reader.iter_records(r)
                if rec.func == "lseek"]
        assert offs == [r * chunk + i * nprocs * chunk
                        for i in range(n_calls)]


def test_recorder_env_topology(monkeypatch):
    monkeypatch.setenv("RECORDER_FINALIZE_TOPOLOGY", "flat")
    assert RecorderConfig.from_env().finalize_topology == "flat"
    monkeypatch.delenv("RECORDER_FINALIZE_TOPOLOGY")
    assert RecorderConfig.from_env().finalize_topology == "tree"


# ---------------------------------------------------------------------------
# vectorized fitting / batched intra-pattern encoding equivalences
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(st.lists(st.lists(st.integers(-2 ** 40, 2 ** 40), min_size=3,
                         max_size=6), max_size=8))
def test_batch_fit_matches_scalar(cols):
    cols = [c for c in cols if len(c) == len(cols[0])] if cols else []
    assert batch_fit_columns(cols) == [_fit_component(c) for c in cols]


def test_batch_fit_bigint_fallback():
    cols = [[1 << 70, (1 << 70) + 5, (1 << 70) + 10], [7, 7, 7]]
    assert batch_fit_columns(cols) == [_fit_component(c) for c in cols]


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(0, 2 ** 20), max_size=50), st.integers(1, 3),
       st.integers(0, 2 ** 20))
def test_encode_many_matches_sequential(vals, arity, seed):
    rng = random.Random(seed)
    rows = []
    i = 0
    while i < len(vals):
        if rng.random() < 0.5:    # splice in an arithmetic run
            a, n = rng.randrange(0, 4096), rng.randrange(1, 8)
            rows.extend(tuple(vals[i] + j * a + s for s in range(arity))
                        for j in range(n))
        else:
            rows.append(tuple(vals[i] + s for s in range(arity)))
        i += 1
    seq, bat = IntraPatternTracker(), IntraPatternTracker()
    out_seq = [seq.encode("k", r) for r in rows]
    out_bat = bat.encode_many("k", rows)
    assert out_seq == out_bat
    rs, rb = seq._runs.get("k"), bat._runs.get("k")
    assert (rs is None) == (rb is None)
    if rs is not None:
        assert (rs.index, rs.base, rs.stride) == (rb.index, rb.base, rb.stride)


def test_encode_many_continues_existing_run():
    seq, bat = IntraPatternTracker(), IntraPatternTracker()
    head = [(0,), (8,)]
    tail = [(16,), (24,), (99,), (100,)]
    for r in head:
        assert seq.encode("k", r) == bat.encode("k", r)
    assert [seq.encode("k", r) for r in tail] == bat.encode_many("k", tail)


# ---------------------------------------------------------------------------
# scaling sanity: merged state stays O(groups) for SPMD rank blocks
# ---------------------------------------------------------------------------


def test_tree_state_constant_in_ranks():
    small = tree_reduce_states(
        [make_rank_state(r, *rc, REGISTRY) for r, rc in
         enumerate(zip(*synth_rank_states(8, n_groups=4, n_calls=8)))])
    big = tree_reduce_states(
        [make_rank_state(r, *rc, REGISTRY) for r, rc in
         enumerate(zip(*synth_rank_states(128, n_groups=4, n_calls=8)))])
    assert len(big.streams) == len(small.streams) == 1
    assert len(big.groups) == len(small.groups)
    # serialized state grows only by the per-rank stream index varints
    assert len(serialize_rank_state(big)) <= \
        len(serialize_rank_state(small)) + 2 * (128 - 8) + 16


# ---------------------------------------------------------------------------
# near-uniform remap stream cache (materialize_state fast path)
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 24),
       st.sampled_from(["linear", "constant", "irregular", "mixed",
                        "mixed_all"]),
       st.integers(1, 5), st.integers(1, 6), st.integers(0, 2 ** 20))
def test_materialize_stream_cache_matches_uncached(nranks, pattern, n_groups,
                                                   n_calls, seed):
    """Near-uniform remap-stream reuse (uniform prefix shared, only the
    irregular rows re-interned per rank) is byte-identical to the
    cache-disabled reference walk AND to the flat finalize."""
    csts, cfgs = synth_rank_states(nranks, n_groups=n_groups,
                                   n_calls=n_calls, pattern=pattern,
                                   seed=seed)
    state = tree_reduce_states([make_rank_state(r, csts[r], cfgs[r], REGISTRY)
                                for r in range(nranks)])
    for inter in (True, False):
        cached = materialize_state(state, inter_patterns=inter,
                                   cache_streams=True)
        _assert_same_finalize(
            cached,
            materialize_state(state, inter_patterns=inter,
                              cache_streams=False))
        _assert_same_finalize(
            cached,
            finalize_ranks(csts, cfgs, REGISTRY, inter_patterns=inter))
