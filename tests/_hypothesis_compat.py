"""Seeded-random fallback for the ``hypothesis`` property-testing API.

The property suites import ``given`` / ``settings`` / ``strategies`` from
``hypothesis`` when it is installed (see ``requirements-dev.txt``); in bare
environments they fall back to this module, which implements the small
strategy subset the tests use with deterministic seeded-random example
generation.  No shrinking and no database -- just reproducible examples
(the RNG is seeded from the test function's name) so the properties still
execute everywhere.
"""

from __future__ import annotations

import functools
import inspect
import math
import random
import string
import zlib
from typing import Any, Callable, List, Optional

_DEFAULT_MAX_EXAMPLES = 100
_TEXT_ALPHABET = (string.ascii_letters + string.digits + " _-/."
                  + "éß中文☃")


class SearchStrategy:
    """Base strategy: ``example(rng)`` draws one value."""

    def example(self, rng: random.Random) -> Any:
        raise NotImplementedError

    def __or__(self, other: "SearchStrategy") -> "SearchStrategy":
        mine = self._variants() if isinstance(self, _OneOf) else [self]
        theirs = other._variants() if isinstance(other, _OneOf) else [other]
        return _OneOf(mine + theirs)

    def _variants(self) -> List["SearchStrategy"]:
        return [self]


class _OneOf(SearchStrategy):
    def __init__(self, subs: List[SearchStrategy]):
        self.subs = subs

    def _variants(self) -> List[SearchStrategy]:
        return list(self.subs)

    def example(self, rng):
        return rng.choice(self.subs).example(rng)


class _Build(SearchStrategy):
    def __init__(self, fn: Callable, args: tuple, kwargs: dict):
        self.fn, self.args, self.kwargs = fn, args, kwargs

    def example(self, rng):
        return self.fn(*(a.example(rng) for a in self.args),
                       **{k: v.example(rng) for k, v in self.kwargs.items()})


class _Integers(SearchStrategy):
    def __init__(self, lo: Optional[int], hi: Optional[int]):
        self.lo = -(1 << 40) if lo is None else lo
        self.hi = (1 << 40) if hi is None else hi

    def example(self, rng):
        # bias towards boundaries and small magnitudes (bug-rich corners)
        r = rng.random()
        if r < 0.1:
            return rng.choice([self.lo, self.hi])
        if r < 0.3:
            v = rng.randint(-16, 16)
            if self.lo <= v <= self.hi:
                return v
        if r < 0.5:
            # log-uniform magnitude sweep
            span = self.hi - self.lo
            if span > 0:
                bits = max(1, span.bit_length() - 1)
                m = rng.randint(0, (1 << rng.randint(1, bits)) - 1)
                v = self.lo + (m % (span + 1))
                return v
        return rng.randint(self.lo, self.hi)


class _Floats(SearchStrategy):
    def __init__(self, allow_nan: bool = True, allow_infinity: bool = True):
        self.allow_nan = allow_nan
        self.allow_infinity = allow_infinity

    def example(self, rng):
        r = rng.random()
        if r < 0.05 and self.allow_nan:
            return math.nan
        if r < 0.1 and self.allow_infinity:
            return rng.choice([math.inf, -math.inf])
        if r < 0.3:
            return rng.choice([0.0, -0.0, 1.0, -1.0, 0.5, 1e-9, 1e300,
                               -1e300, 2.2250738585072014e-308])
        if r < 0.6:
            return rng.uniform(-1e6, 1e6)
        # wide exponent sweep, always finite
        m = rng.uniform(-1, 1)
        e = rng.randint(-300, 300)
        v = m * (10.0 ** e)
        return v if math.isfinite(v) else m


class _Booleans(SearchStrategy):
    def example(self, rng):
        return rng.random() < 0.5


class _Just(SearchStrategy):
    def __init__(self, value):
        self.value = value

    def example(self, rng):
        return self.value


class _Text(SearchStrategy):
    def __init__(self, max_size: Optional[int]):
        self.max_size = 20 if max_size is None else max_size

    def example(self, rng):
        n = rng.randint(0, self.max_size)
        return "".join(rng.choice(_TEXT_ALPHABET) for _ in range(n))


class _Binary(SearchStrategy):
    def __init__(self, max_size: Optional[int]):
        self.max_size = 20 if max_size is None else max_size

    def example(self, rng):
        return bytes(rng.randrange(256)
                     for _ in range(rng.randint(0, self.max_size)))


class _Tuples(SearchStrategy):
    def __init__(self, subs: tuple):
        self.subs = subs

    def example(self, rng):
        return tuple(s.example(rng) for s in self.subs)


class _Lists(SearchStrategy):
    def __init__(self, elem: SearchStrategy, min_size: int,
                 max_size: Optional[int]):
        self.elem = elem
        self.min_size = min_size
        self.max_size = min_size + 20 if max_size is None else max_size

    def example(self, rng):
        n = rng.randint(self.min_size, self.max_size)
        return [self.elem.example(rng) for _ in range(n)]


class _SampledFrom(SearchStrategy):
    def __init__(self, options):
        self.options = list(options)

    def example(self, rng):
        return rng.choice(self.options)


class _Recursive(SearchStrategy):
    def __init__(self, base: SearchStrategy, extend: Callable, max_leaves: int):
        self.base = base
        self.extend = extend
        self.max_depth = max(1, min(4, max_leaves.bit_length() - 1))

    def example(self, rng):
        s = self.base
        for _ in range(rng.randint(0, self.max_depth)):
            s = self.extend(s)
        return s.example(rng)


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` (subset)."""

    @staticmethod
    def none():
        return _Just(None)

    @staticmethod
    def just(value):
        return _Just(value)

    @staticmethod
    def booleans():
        return _Booleans()

    @staticmethod
    def integers(min_value=None, max_value=None):
        return _Integers(min_value, max_value)

    @staticmethod
    def floats(allow_nan=True, allow_infinity=True):
        return _Floats(allow_nan, allow_infinity)

    @staticmethod
    def text(max_size=None):
        return _Text(max_size)

    @staticmethod
    def binary(max_size=None):
        return _Binary(max_size)

    @staticmethod
    def builds(fn, *args, **kwargs):
        return _Build(fn, args, kwargs)

    @staticmethod
    def tuples(*subs):
        return _Tuples(subs)

    @staticmethod
    def lists(elem, min_size=0, max_size=None):
        return _Lists(elem, min_size, max_size)

    @staticmethod
    def sampled_from(options):
        return _SampledFrom(options)

    @staticmethod
    def recursive(base, extend, max_leaves=16):
        return _Recursive(base, extend, max_leaves)

    @staticmethod
    def one_of(*subs):
        return _OneOf(list(subs))


def given(*strats: SearchStrategy):
    """Run the test once per generated example (seeded by test name)."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for i in range(n):
                example = tuple(s.example(rng) for s in strats)
                try:
                    fn(*args, *example, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (run {i}): {example!r}") from e

        # hide the strategy-supplied (rightmost) parameters from pytest's
        # fixture resolution; remaining leading params stay fixtures
        params = list(inspect.signature(fn).parameters.values())
        wrapper.__signature__ = inspect.Signature(
            params[:max(0, len(params) - len(strats))])
        del wrapper.__wrapped__
        wrapper._max_examples = _DEFAULT_MAX_EXAMPLES
        return wrapper

    return deco


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    """Applied above ``given``: caps the number of generated examples."""

    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco
