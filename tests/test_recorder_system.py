"""End-to-end Recorder behaviour: tracing sessions, lossless read-back,
filtering, layers, threads, converters, baselines."""

import json
import os
import threading

import numpy as np
import pytest

from repro.core import trace_format
from repro.core.apis import framework as frame
from repro.core.apis import posix, shardio
from repro.core.baselines import DarshanLike, RecorderOld, ToolAdapter
from repro.core.converters import read_columnar, to_chrome_timeline, \
    to_columnar
from repro.core.reader import TraceReader
from repro.core.recorder import Recorder, RecorderConfig, attach, detach, \
    session


@pytest.fixture
def dirs(tmp_path):
    d = tmp_path / "data"
    d.mkdir()
    return str(tmp_path / "trace"), str(d)


def _workload(datadir, n=50):
    fd = posix.open(os.path.join(datadir, "f.bin"), os.O_RDWR | os.O_CREAT,
                    0o644)
    for i in range(n):
        posix.pwrite(fd, b"x" * 64, i * 64)
    posix.fsync(fd)
    posix.close(fd)


def test_session_roundtrip(dirs):
    tracedir, datadir = dirs
    with session(RecorderConfig(trace_dir=tracedir)) as rec:
        _workload(datadir)
        for s in range(20):
            frame.step(s)
    r = TraceReader(tracedir)
    recs = list(r.iter_records(0))
    assert len(recs) == rec.n_records
    offs = [rc.arg("offset") for rc in recs if rc.func == "pwrite"]
    assert offs == [i * 64 for i in range(50)]
    assert [rc.arg("step_idx") for rc in recs if rc.func == "step"] \
        == list(range(20))
    # timestamps are monotone non-decreasing entry times
    ts = [rc.t_entry for rc in recs]
    assert all(a <= b for a, b in zip(ts, ts[1:]))


def test_call_depth_chain(dirs):
    tracedir, datadir = dirs
    with session(RecorderConfig(trace_dir=tracedir)):
        fh = shardio.shard_open(os.path.join(datadir, "s.bin"), 1)
        shardio.shard_write_at(fh, b"y" * 8, 0)
        shardio.shard_close(fh)
    r = TraceReader(tracedir)
    depth = {(rc.func): rc.depth for rc in r.iter_records(0)}
    assert depth["shard_open"] == 0 and depth["open"] == 1
    assert depth["shard_write_at"] == 0 and depth["pwrite"] == 1


def test_path_prefix_filtering(dirs, tmp_path):
    tracedir, datadir = dirs
    other = tmp_path / "other"
    other.mkdir()
    cfg = RecorderConfig(trace_dir=tracedir, path_prefixes=[datadir])
    with session(cfg) as rec:
        _workload(datadir, n=5)
        fd = posix.open(str(other / "x.bin"), os.O_RDWR | os.O_CREAT, 0o644)
        posix.pwrite(fd, b"z", 0)     # must be skipped (untracked handle)
        posix.close(fd)
    assert rec.n_skipped == 3
    r = TraceReader(tracedir)
    paths = [rc.args[0] for rc in r.iter_records(0) if rc.func == "open"]
    assert all(p.startswith(datadir) for p in paths)


def test_layer_toggle(dirs):
    tracedir, datadir = dirs
    with session(RecorderConfig(trace_dir=tracedir,
                                layers={"shardio"})) as rec:
        fh = shardio.shard_open(os.path.join(datadir, "s.bin"), 1)
        shardio.shard_write_at(fh, b"y" * 8, 0)
        shardio.shard_close(fh)
    r = TraceReader(tracedir)
    layers = {rc.layer for rc in r.iter_records(0)}
    assert layers == {"shardio"}


def test_multithreaded_tracing(dirs):
    tracedir, datadir = dirs
    with session(RecorderConfig(trace_dir=tracedir)) as rec:
        def worker(i):
            fd = posix.open(os.path.join(datadir, f"t{i}.bin"),
                            os.O_RDWR | os.O_CREAT, 0o644)
            for j in range(10):
                posix.pwrite(fd, b"t", j)
            posix.close(fd)
        ts = [threading.Thread(target=worker, args=(i,)) for i in range(3)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
    r = TraceReader(tracedir)
    threads = {rc.thread for rc in r.iter_records(0)}
    assert len(threads) == 3
    assert r.n_records(0) == 3 * 12


def test_handle_reuse_constant_signatures(dirs):
    """Re-opening files (rolling checkpoints) must not mint new handle ids."""
    tracedir, datadir = dirs
    with session(RecorderConfig(trace_dir=tracedir)) as rec:
        for cycle in range(5):
            fh = shardio.shard_open(os.path.join(datadir, "roll.bin"), 1)
            shardio.shard_write_at(fh, b"x" * 16, 0)
            shardio.shard_close(fh)
    assert len(rec.cst) == len(set(rec.cst.entries))
    # cycles 2..5 add no new signatures -> small constant CST
    assert len(rec.cst) <= 8


def test_mkdir_posix_semantics(dirs):
    """posix.mkdir must behave like os.mkdir: re-creating an existing
    directory fails with EEXIST (recorded as an err return) instead of the
    old silent exist_ok success; posix.makedirs keeps the idempotent
    recursive behaviour for the checkpoint engine."""
    tracedir, datadir = dirs
    target = os.path.join(datadir, "sub")
    nested = os.path.join(datadir, "a", "b", "c")
    with session(RecorderConfig(trace_dir=tracedir)):
        posix.mkdir(target, 0o755)
        with pytest.raises(FileExistsError):
            posix.mkdir(target, 0o755)
        posix.makedirs(nested, 0o755)
        posix.makedirs(nested, 0o755)  # idempotent, records two successes
    assert os.path.isdir(nested)
    r = TraceReader(tracedir)
    recs = [(rc.func, rc.ret) for rc in r.iter_records(0)]
    assert recs[0] == ("mkdir", None)
    assert recs[1] == ("mkdir", ("err", "FileExistsError"))
    assert recs[2:] == [("makedirs", None), ("makedirs", None)]


def test_error_capture(dirs):
    tracedir, datadir = dirs
    with session(RecorderConfig(trace_dir=tracedir)):
        with pytest.raises(FileNotFoundError):
            posix.open(os.path.join(datadir, "missing", "x"), os.O_RDONLY,
                       0o644)
    r = TraceReader(tracedir)
    recs = list(r.iter_records(0))
    assert recs[0].ret == ("err", "FileNotFoundError")


def test_chrome_and_columnar_converters(dirs):
    tracedir, datadir = dirs
    with session(RecorderConfig(trace_dir=tracedir)) as rec:
        _workload(datadir, n=30)
    out = os.path.join(tracedir, "chrome.json")
    n = to_chrome_timeline(tracedir, out)
    events = json.load(open(out))["traceEvents"]
    assert n == len(events) == rec.n_records
    cols_dir = os.path.join(tracedir, "cols")
    to_columnar(tracedir, cols_dir)
    cols = read_columnar(cols_dir)
    assert len(cols["offset"]) == rec.n_records
    got = [o for o in cols["offset"] if o >= 0]
    assert got == [i * 64 for i in range(30)]


def test_baseline_adapters(dirs, tmp_path):
    _, datadir = dirs
    old = RecorderOld(0)
    attach(ToolAdapter(old))
    try:
        _workload(datadir, n=40)
    finally:
        detach()
    assert old.n_records == 43
    assert old.nbytes > 0
    dar = DarshanLike(0)
    attach(ToolAdapter(dar))
    try:
        _workload(datadir, n=40)
    finally:
        detach()
    assert dar.n_records == 43
    blob = dar.serialize()
    assert 0 < len(blob) < old.nbytes  # counters < per-record trace


def test_peephole_compresses_regular_writes(dirs):
    _, datadir = dirs
    old = RecorderOld(0)
    attach(ToolAdapter(old))
    try:
        _workload(datadir, n=500)
    finally:
        detach()
    # repeat tokens: ~10 bytes per repeated call, full record for the rest
    assert old.nbytes < 500 * 12 + 1000
