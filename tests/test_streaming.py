"""Streaming trace subsystem: N-epoch flushes value-identical to a one-shot
finalize, crash recovery over committed segments, incremental cross-epoch
state accumulation, block-indexed timestamp windows, and flush knobs."""

import json
import os
import random
import shutil
import threading

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fallback: seeded-random example generation
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import streaming, trace_format
from repro.core.comm import Comm, SoloComm, run_thread_world
from repro.core.interprocess import (append_epoch_state, make_rank_state,
                                     serialize_rank_state,
                                     tree_reduce_states)
from repro.core.reader import TraceReader
from repro.core.recorder import Recorder, RecorderConfig
from repro.core.specs import REGISTRY
from repro.core.timestamps import (BlockedTimestampStore,
                                   compress_timestamps_blocked,
                                   pack_ts_blocks, unpack_ts_blocks)
from repro.core.trace_format import TraceFormatError
import repro.core.apis  # noqa: F401  (populate registry)


# ---------------------------------------------------------------------------
# deterministic randomized workloads (explicit ticks, so records -- including
# timestamps -- are reproducible across separate recorder runs)
# ---------------------------------------------------------------------------


def _gen_calls(rng: random.Random, n_calls: int, rank: int, nranks: int):
    """A reproducible mixed call list: strided pwrites (rank-linear offsets),
    irregular pwrites, lseeks with OFFSET returns, opens/closes, stats."""
    fids = {name: REGISTRY.id_of(name)
            for name in ("open", "close", "pwrite", "lseek", "write", "stat")}
    calls = []
    fd = f"fd-{rank}"
    calls.append((fids["open"], ("/data/f.bin", 2, 438), fd))
    stride_base = rank * 4096
    for i in range(n_calls):
        kind = rng.random()
        if kind < 0.5:
            off = stride_base + i * nranks * 4096
            calls.append((fids["pwrite"], (fd, b"x" * 4096, off), 4096))
        elif kind < 0.7:
            off = rng.randrange(1 << 20)
            calls.append((fids["pwrite"], (fd, b"y" * 512, off), 512))
        elif kind < 0.85:
            off = rank * 256 + i * 256
            calls.append((fids["lseek"], (fd, off, 0), off))
        elif kind < 0.95:
            calls.append((fids["write"], (fd, b"z" * 128), 128))
        else:
            calls.append((fids["stat"], ("/data/f.bin",), 4096))
    calls.append((fids["close"], (fd,), 0))
    return calls


def _feed(rec: Recorder, calls, tick_start: int = 0) -> int:
    t = tick_start
    for fid, args, ret in calls:
        rec.record(fid, args, ret, 0, t, t + 1)
        t += 2
    return t


def _split(calls, boundaries):
    """Split a call list at the given record-count boundaries (epochs)."""
    out, prev = [], 0
    for b in boundaries:
        out.append(calls[prev:b])
        prev = b
    out.append(calls[prev:])
    return out


def _drive_streaming(trace_dir, rank_calls, boundaries, comm_factory=None,
                     **cfg_kw):
    """Run every rank's calls with a flush at each boundary; returns the
    root stats.  Single-rank uses SoloComm; multi-rank runs a ThreadComm
    world (flush is a collective)."""
    nranks = len(rank_calls)
    if nranks == 1:
        rec = Recorder(rank=0, config=RecorderConfig(trace_dir=trace_dir,
                                                     **cfg_kw))
        parts = _split(rank_calls[0], boundaries)
        t = 0
        for i, part in enumerate(parts):
            t = _feed(rec, part, t)
            if i < len(parts) - 1:
                rec.flush()
        return rec.finalize()

    def worker(comm: Comm, rank: int):
        rec = Recorder(rank=rank,
                       config=RecorderConfig(trace_dir=trace_dir, **cfg_kw))
        parts = _split(rank_calls[rank], boundaries)
        t = 0
        for i, part in enumerate(parts):
            t = _feed(rec, part, t)
            if i < len(parts) - 1:
                rec.flush(comm)
        return rec.finalize(comm)

    return run_thread_world(nranks, worker)[0]


def _drive_oneshot(trace_dir, rank_calls, **cfg_kw):
    nranks = len(rank_calls)
    if nranks == 1:
        rec = Recorder(rank=0, config=RecorderConfig(trace_dir=trace_dir,
                                                     **cfg_kw))
        _feed(rec, rank_calls[0])
        return rec.finalize()

    def worker(comm: Comm, rank: int):
        rec = Recorder(rank=rank,
                       config=RecorderConfig(trace_dir=trace_dir, **cfg_kw))
        _feed(rec, rank_calls[rank])
        return rec.finalize(comm)

    return run_thread_world(nranks, worker)[0]


def _assert_value_identical(got: TraceReader, want: TraceReader):
    """All five analyses + the lossless record stream must match."""
    gv, wv = got.view(), want.view()
    assert got.nranks == want.nranks
    assert list(got.all_records()) == list(want.all_records())
    assert gv.io_summary() == wv.io_summary()
    assert gv.size_histogram() == wv.size_histogram()
    for r in range(want.nranks):
        assert gv.call_chains(rank=r) == wv.call_chains(rank=r)
        assert gv.overlap_ratio(r) == wv.overlap_ratio(r)
    assert gv.consistency_pairs() == wv.consistency_pairs()


# ---------------------------------------------------------------------------
# the core property: N-epoch streaming == one-shot finalize
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 32 - 1),
       st.integers(min_value=1, max_value=4),
       st.integers(min_value=2, max_value=5),
       st.integers(min_value=8, max_value=60))
def test_streaming_equals_oneshot(seed, nranks, n_epochs, n_calls):
    import tempfile
    rng = random.Random(seed)
    rank_calls = [_gen_calls(random.Random(seed * 1000 + r), n_calls,
                             r, nranks) for r in range(nranks)]
    total = len(rank_calls[0])
    boundaries = sorted(rng.sample(range(1, total), min(n_epochs - 1,
                                                        total - 1)))
    base = tempfile.mkdtemp(prefix="stream_prop_")
    try:
        sd, od = os.path.join(base, "stream"), os.path.join(base, "oneshot")
        _drive_streaming(sd, rank_calls, boundaries)
        _drive_oneshot(od, rank_calls)
        want = TraceReader(od)
        assert trace_format.is_stream_dir(sd)
        for mode in ("stitched", "merged", "auto"):
            got = TraceReader(sd, mode=mode)
            assert got.skipped == []
            _assert_value_identical(got, want)
    finally:
        shutil.rmtree(base, ignore_errors=True)


def test_streaming_matches_oneshot_multirank_explicit(tmp_path):
    """A fixed 4-rank SPMD case through the real ThreadComm collectives."""
    nranks, n_calls = 4, 40
    rank_calls = [_gen_calls(random.Random(7 + r), n_calls, r, nranks)
                  for r in range(nranks)]
    sd, od = str(tmp_path / "s"), str(tmp_path / "o")
    stats = _drive_streaming(sd, rank_calls, [10, 20, 30])
    assert stats is not None and stats.epochs == 4
    _drive_oneshot(od, rank_calls)
    _assert_value_identical(TraceReader(sd, mode="stitched"), TraceReader(od))
    _assert_value_identical(TraceReader(sd, mode="merged"), TraceReader(od))


def test_tail_mode_serves_latest_epoch(tmp_path):
    sd = str(tmp_path / "s")
    calls = _gen_calls(random.Random(1), 30, 0, 1)
    _drive_streaming(sd, [calls], [10, 25])
    tail = TraceReader(sd, mode="tail")
    # the final flush (during finalize) covers records 25..end
    assert tail.view().n_records(0) == len(calls) - 25
    full = TraceReader(sd, mode="stitched")
    assert full.view().n_records(0) == len(calls)
    tail_recs = [rec.func for _, rec in tail.all_records()]
    full_recs = [rec.func for _, rec in full.all_records()]
    assert full_recs[25:] == tail_recs


# ---------------------------------------------------------------------------
# crash recovery: committed-but-corrupted and never-committed segments
# ---------------------------------------------------------------------------


def test_truncated_segment_is_skipped_and_reported(tmp_path):
    sd = str(tmp_path / "s")
    calls = _gen_calls(random.Random(2), 30, 0, 1)
    _drive_streaming(sd, [calls], [10, 20])
    # corrupt the middle committed epoch AFTER commit (truncation)
    victim = os.path.join(sd, trace_format.segment_name(1), "merged_cst.bin")
    with open(victim, "r+b") as f:
        f.truncate(max(os.path.getsize(victim) // 2, 1))
    reader = TraceReader(sd, mode="stitched")
    assert len(reader.skipped) == 1
    assert "epoch_00001" in reader.skipped[0]["segment"]
    assert "truncated or corrupt" in reader.skipped[0]["reason"]
    # every intact committed epoch is still served, in order
    funcs = [rec.func for _, rec in reader.all_records()]
    want = [REGISTRY.spec(fid).name for fid, _, _ in calls[:10] + calls[20:]]
    assert funcs == want
    # auto mode falls back to the (intact) merged trace written at finalize
    assert TraceReader(sd, mode="auto").view().n_records(0) == len(calls)


def test_tail_mode_skips_corrupt_newest_segment(tmp_path):
    """tail serves the newest INTACT epoch: a truncated newest segment is
    skipped (and reported) and the previous one served, without ever
    decoding older epochs."""
    sd = str(tmp_path / "s")
    calls = _gen_calls(random.Random(11), 30, 0, 1)
    _drive_streaming(sd, [calls], [10, 20])  # epochs 0,1 + tail epoch 2
    newest = os.path.join(sd, trace_format.segment_name(2), "unique_cfgs.bin")
    with open(newest, "ab") as f:
        f.write(b"junk")  # size mismatch vs manifest
    tail = TraceReader(sd, mode="tail")
    assert [s["segment"] for s in tail.skipped] == ["epoch_00002"]
    funcs = [rec.func for _, rec in tail.all_records()]
    assert funcs == [REGISTRY.spec(fid).name for fid, _, _ in calls[10:20]]


def test_missing_segment_directory_is_reported(tmp_path):
    sd = str(tmp_path / "s")
    _drive_streaming(sd, [_gen_calls(random.Random(3), 20, 0, 1)], [10])
    shutil.rmtree(os.path.join(sd, trace_format.segment_name(0)))
    reader = TraceReader(sd, mode="stitched")
    assert reader.skipped and "missing" in reader.skipped[0]["reason"]


def test_uncommitted_tmp_segment_is_invisible(tmp_path):
    sd = str(tmp_path / "s")
    _drive_streaming(sd, [_gen_calls(random.Random(4), 20, 0, 1)], [10])
    # a crashed mid-write segment: directory exists, never renamed in
    debris = os.path.join(sd, trace_format.segment_name(7) + ".tmp")
    os.makedirs(debris)
    with open(os.path.join(debris, "merged_cst.bin"), "wb") as f:
        f.write(b"partial")
    reader = TraceReader(sd, mode="stitched")
    assert reader.skipped == []
    assert reader.n_segments == 2  # epoch 0 + the finalize tail


def test_all_segments_corrupt_is_a_format_error(tmp_path):
    sd = str(tmp_path / "s")
    _drive_streaming(sd, [_gen_calls(random.Random(5), 8, 0, 1)], [4])
    shutil.rmtree(os.path.join(sd, "merged"))
    shutil.rmtree(os.path.join(sd, trace_format.segment_name(0)))
    shutil.rmtree(os.path.join(sd, trace_format.segment_name(1)))
    with pytest.raises(TraceFormatError, match="no intact epoch segments"):
        TraceReader(sd, mode="stitched")


def test_mixed_format_version_rejected(tmp_path):
    sd = str(tmp_path / "s")
    _drive_streaming(sd, [_gen_calls(random.Random(6), 20, 0, 1)], [10])
    meta_path = os.path.join(sd, trace_format.segment_name(1),
                             "metadata.json")
    meta = json.load(open(meta_path))
    meta["format_version"] = trace_format.FORMAT_VERSION + 1
    blob = json.dumps(meta)
    # keep the byte size identical so only the version check can fire
    blob += " " * (os.path.getsize(meta_path) - len(blob))
    with open(meta_path, "w") as f:
        f.write(blob)
    with pytest.raises(TraceFormatError, match="mixed format_version"):
        trace_format.read_stream_trace(sd)
    with pytest.raises(TraceFormatError, match="mixed format_version"):
        TraceReader(sd, mode="stitched")


def test_retention_ring_keeps_newest_epochs(tmp_path):
    sd = str(tmp_path / "s")
    calls = _gen_calls(random.Random(8), 40, 0, 1)
    _drive_streaming(sd, [calls], [10, 20, 30], max_epochs_retained=2)
    manifest = trace_format.read_manifest(sd)
    names = [e["name"] for e in manifest["segments"]]
    assert names == [trace_format.segment_name(2),
                     trace_format.segment_name(3)]
    on_disk = sorted(d for d in os.listdir(sd)
                     if d.startswith(trace_format.SEGMENT_PREFIX))
    assert on_disk == names
    # no merged trace under retention (incomplete history), and the
    # stitched view serves exactly the retained window
    assert "merged" not in manifest
    reader = TraceReader(sd)  # auto -> stitched
    funcs = [rec.func for _, rec in reader.all_records()]
    assert funcs == [REGISTRY.spec(fid).name for fid, _, _ in calls[20:]]


def test_restarted_run_appends_to_existing_trace_dir(tmp_path):
    """A restarted job reusing a preempted run's trace_dir must append new
    epochs after the old ones (no name collision), resume the cumulative
    state from the committed segments, and finalize a merged trace that
    covers BOTH runs' combined history (crash-resume)."""
    sd = str(tmp_path / "s")
    calls_a = _gen_calls(random.Random(20), 12, 0, 1)
    _drive_streaming(sd, [calls_a], [6])       # run A: epochs 0,1 + merged
    assert "merged" in trace_format.read_manifest(sd)
    calls_b = _gen_calls(random.Random(21), 8, 0, 1)
    _drive_streaming(sd, [calls_b], [4])       # run B: resumes, appends
    manifest = trace_format.read_manifest(sd)
    epochs = [e["epoch"] for e in manifest["segments"]]
    assert epochs == sorted(epochs) == [0, 1, 2, 3]
    # run B folded run A's committed state.bin deltas at startup, so its
    # finalize merged trace covers the full four-epoch history
    assert "merged" in manifest
    want = [REGISTRY.spec(fid).name for fid, _, _ in calls_a + calls_b]
    for mode in ("stitched", "merged"):
        funcs = [r.func for _, r in TraceReader(sd, mode=mode).all_records()]
        assert funcs == want


def test_restart_without_resume_keeps_append_only_behavior(tmp_path):
    """``resume=False``: run B appends after run A's epochs but cannot
    write a merged trace covering the combined history -- its finalize
    must WARN (not silently skip), run A's stale merged directory must be
    reclaimed, and the stitched reader still serves both runs in order."""
    sd = str(tmp_path / "s")
    calls_a = _gen_calls(random.Random(20), 12, 0, 1)
    _drive_streaming(sd, [calls_a], [6])
    assert "merged" in trace_format.read_manifest(sd)
    calls_b = _gen_calls(random.Random(21), 8, 0, 1)
    with pytest.warns(RuntimeWarning, match="no merged trace"):
        _drive_streaming(sd, [calls_b], [4], resume=False)
    manifest = trace_format.read_manifest(sd)
    epochs = [e["epoch"] for e in manifest["segments"]]
    assert epochs == sorted(epochs) == [0, 1, 2, 3]
    # run B's merged would cover only run B's epochs, so it must NOT be
    # listed, and run A's stale merged directory must be reclaimed
    assert "merged" not in manifest
    assert not os.path.exists(os.path.join(sd, "merged"))
    reader = TraceReader(sd)  # auto -> stitched
    funcs = [rec.func for _, rec in reader.all_records()]
    want = [REGISTRY.spec(fid).name for fid, _, _ in calls_a + calls_b]
    assert funcs == want


def test_failed_segment_write_keeps_state_consistent(tmp_path, monkeypatch):
    """A failed segment commit must surface the error WITHOUT desyncing the
    cumulative state from the directory -- and without losing the epoch:
    the snapshot is restored into the recorder, so the next flush covers
    the failed epoch's records exactly once."""
    sd = str(tmp_path / "s")
    calls = _gen_calls(random.Random(22), 30, 0, 1)
    rec = Recorder(rank=0, config=RecorderConfig(trace_dir=sd))
    t = _feed(rec, calls[:10])
    rec.flush()
    t = _feed(rec, calls[10:20], t)
    real = streaming.trace_format.write_trace
    monkeypatch.setattr(streaming.trace_format, "write_trace",
                        lambda *a, **k: (_ for _ in ()).throw(OSError("disk full")))
    with pytest.raises(OSError, match="disk full"):
        rec.flush()
    monkeypatch.setattr(streaming.trace_format, "write_trace", real)
    assert rec._cum.n_epochs == 1  # the failed epoch was never folded in
    assert rec.epochs_restored == 1
    _feed(rec, calls[20:], t)
    rec.finalize()
    manifest = trace_format.read_manifest(sd)
    assert "merged" in manifest  # cum matches the committed segments
    # records 10..20 were retained by the restore and rode the tail flush:
    # every record exactly once, in order
    for mode in ("stitched", "merged"):
        funcs = [r.func for _, r in TraceReader(sd, mode=mode).all_records()]
        assert funcs == [REGISTRY.spec(fid).name for fid, _, _ in calls]


def test_merged_mode_preserves_multi_wrap_epoch_gaps(tmp_path):
    """Regression: epochs separated by >= 2 whole uint32 wrap periods of
    silence (undetectable from tick values alone) must unwrap exactly in
    merged mode.  Each epoch's blocks carry their own wrap base
    (``tick_wrap_spans``), so the merged store matches the stitched
    per-segment stores instead of collapsing the gap."""
    sd = str(tmp_path / "s")
    rec = Recorder(rank=0, config=RecorderConfig(trace_dir=sd))
    calls_a = _gen_calls(random.Random(30), 6, 0, 1)
    t = _feed(rec, calls_a)
    rec.flush()
    # 5 whole wrap periods (~6 hours) of silence before the next epoch
    gap = 5 * (2 ** 32)
    calls_b = _gen_calls(random.Random(31), 6, 0, 1)
    _feed(rec, calls_b, t + gap)
    rec.flush()
    rec.finalize()
    stitched = TraceReader(sd, mode="stitched")
    merged = TraceReader(sd, mode="merged")
    ts_s = stitched.ts_store.load_unwrapped(0)
    ts_m = merged.ts_store.load_unwrapped(0)
    np.testing.assert_array_equal(ts_m, ts_s)
    n_a = len(calls_a)
    assert int(ts_m[n_a, 0]) - int(ts_m[n_a - 1, 0]) >= 2 * (2 ** 32)
    assert int(ts_m[n_a, 0]) == t + gap  # exact, not just monotonic
    assert bool(np.all(np.diff(ts_m[:, 0]) >= 0))


# ---------------------------------------------------------------------------
# incremental cross-epoch state: O(delta) accumulator == pure reference fold
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 32 - 1),
       st.integers(min_value=1, max_value=6),
       st.integers(min_value=1, max_value=4))
def test_cumulative_state_matches_reference_fold(seed, nranks, n_epochs):
    rng = random.Random(seed)
    cum = streaming.CumulativeState()
    ref, occ = None, None
    for _ in range(n_epochs):
        n_calls = rng.randrange(3, 25)
        epoch_seed = rng.randrange(1 << 30)
        states = []
        for r in range(nranks):
            rec = Recorder(rank=r, config=RecorderConfig())
            _feed(rec, _gen_calls(random.Random(epoch_seed + r), n_calls,
                                  r, nranks))
            entries, cfg, _, _ = rec.take_epoch()
            states.append(make_rank_state(r, entries, cfg, REGISTRY))
        delta = tree_reduce_states(states)
        ref, occ = append_epoch_state(ref, occ, delta)
        cum.append(delta)
    assert serialize_rank_state(cum.to_rank_state()) == \
        serialize_rank_state(ref)


def test_gather_tree_orders_by_rank():
    def worker(comm: Comm, rank: int):
        return comm.gather_tree(f"payload-{rank}")

    results = run_thread_world(5, worker)
    assert results[0] == [f"payload-{r}" for r in range(5)]
    assert all(r is None for r in results[1:])
    assert SoloComm().gather_tree(b"x") == [b"x"]


# ---------------------------------------------------------------------------
# block-indexed timestamps: only intersecting blocks are decompressed
# ---------------------------------------------------------------------------


def test_blocked_store_roundtrip_and_window():
    ticks = np.arange(1, 2 * 100 + 1, dtype=np.uint32).reshape(100, 2)
    blocks = compress_timestamps_blocked(ticks, block_records=16)
    assert [n for _, n, _, _, _ in blocks] == [16] * 6 + [4]
    assert unpack_ts_blocks(pack_ts_blocks(blocks)) == blocks
    raw = bytearray()
    index = [[]]
    for blob, n, t_min, t_max, n_bytes in blocks:
        assert n_bytes is None  # (n, 2) input carries no byte column
        index[0].append([len(raw), len(blob), n, t_min, t_max])
        raw.extend(blob)
    store = BlockedTimestampStore(bytes(raw), index)
    assert np.array_equal(store.load(0), ticks)
    assert store.blocks_touched == 7
    # a window inside block 2 touches exactly one block
    before = store.blocks_touched
    w = store.window(0, int(ticks[40, 0]), int(ticks[41, 0]))
    assert store.blocks_touched - before == 1
    assert np.array_equal(w, ticks[40:41])  # only row 40 intersects [81, 83)
    # out-of-range window: zero rows, zero decompression
    before = store.blocks_touched
    w = store.window(0, 10 ** 9, 10 ** 9 + 5)
    assert len(w) == 0 and store.blocks_touched == before
    assert store.window(1, 0, 10) is None  # no such rank


def test_windowed_view_queries_touch_only_intersecting_blocks(tmp_path):
    sd = str(tmp_path / "s")
    calls = _gen_calls(random.Random(9), 200, 0, 1)
    _drive_streaming(sd, [calls], [64, 128], ts_block_records=16)
    view = TraceReader(sd, mode="stitched").view()
    store = view.ts_store
    total = store.n_blocks(0)
    assert total > 8
    before = store.blocks_touched
    bounds = view.bandwidth_bounds(10, 40)
    touched = store.blocks_touched - before
    assert 1 <= touched < total
    assert bounds["n_calls"] > 0
    assert bounds["hi_MBps"] >= bounds["lo_MBps"] >= 0.0
    before = store.blocks_touched
    view.overlap_ratio(0, 10, 40)
    assert 1 <= store.blocks_touched - before < total
    # the full (unwindowed) analyses still see every record
    assert view.n_records(0) == len(calls)


# ---------------------------------------------------------------------------
# flush knobs: auto-flush cadence and from_env validation
# ---------------------------------------------------------------------------


def test_autoflush_every_n_records(tmp_path):
    sd = str(tmp_path / "s")
    calls = _gen_calls(random.Random(10), 50, 0, 1)
    rec = Recorder(rank=0, config=RecorderConfig(
        trace_dir=sd, flush_every_n_records=20))
    _feed(rec, calls)
    assert rec.epoch == len(calls) // 20
    rec.finalize()
    manifest = trace_format.read_manifest(sd)
    assert len(manifest["segments"]) == rec.epoch
    assert sum(e["n_records"] for e in manifest["segments"]) == len(calls)
    reader = TraceReader(sd, mode="stitched")
    assert [rec.func for _, rec in reader.all_records()] == \
        [REGISTRY.spec(fid).name for fid, _, _ in calls]


def test_autoflush_interval(tmp_path, monkeypatch):
    import repro.core.recorder as recorder_mod
    fake = [0.0]
    monkeypatch.setattr(recorder_mod.time, "perf_counter",
                        lambda: fake[0])
    sd = str(tmp_path / "s")
    rec = Recorder(rank=0, config=RecorderConfig(trace_dir=sd,
                                                 flush_interval_s=5.0))
    fid = REGISTRY.id_of("stat")
    rec.record(fid, ("/a",), 1, 0, 0, 1)
    assert rec.epoch == 0
    fake[0] = 6.0
    rec.record(fid, ("/a",), 1, 0, 2, 3)
    assert rec.epoch == 1


def test_autoflush_failure_never_breaks_app_calls(tmp_path, monkeypatch):
    """A trace-volume failure during AUTO-flush must not surface inside the
    application's unrelated I/O call: it warns once, disables auto-flush,
    and recording continues; explicit flush/finalize still raise."""
    sd = str(tmp_path / "s")
    rec = Recorder(rank=0, config=RecorderConfig(
        trace_dir=sd, flush_every_n_records=5))
    fid = REGISTRY.id_of("stat")
    boom = lambda *a, **k: (_ for _ in ()).throw(OSError("disk full"))
    monkeypatch.setattr(streaming.trace_format, "write_trace", boom)
    with pytest.warns(RuntimeWarning, match="auto-flush failed"):
        for i in range(6):  # crosses the cadence -> auto-flush fails inside
            rec.record(fid, ("/a",), 1, 0, 2 * i, 2 * i + 1)
    assert rec._autoflush_broken
    for i in range(10):  # no further flush attempts, no warnings, no raise
        rec.record(fid, ("/a",), 1, 0, 100 + 2 * i, 101 + 2 * i)
    with pytest.raises(OSError, match="disk full"):
        rec.flush()  # the explicit path still surfaces the error


def test_flush_requires_trace_dir():
    rec = Recorder(rank=0, config=RecorderConfig())
    with pytest.raises(ValueError, match="trace_dir"):
        rec.flush()


def test_from_env_parses_flush_knobs(monkeypatch):
    monkeypatch.setenv("RECORDER_FLUSH_EVERY_N_RECORDS", "5000")
    monkeypatch.setenv("RECORDER_FLUSH_INTERVAL_S", "2.5")
    monkeypatch.setenv("RECORDER_MAX_EPOCHS_RETAINED", "8")
    monkeypatch.setenv("RECORDER_TS_BLOCK_RECORDS", "1024")
    cfg = RecorderConfig.from_env()
    assert cfg.flush_every_n_records == 5000
    assert cfg.flush_interval_s == 2.5
    assert cfg.max_epochs_retained == 8
    assert cfg.ts_block_records == 1024


@pytest.mark.parametrize("kw", [
    {"flush_every_n_records": 0},
    {"flush_every_n_records": -5},
    {"flush_interval_s": 0.0},
    {"flush_interval_s": -1.0},
    {"max_epochs_retained": 0},
    {"ts_block_records": 0},
])
def test_constructor_rejects_malformed_knobs(kw):
    """Directly-constructed configs (the README path) enforce the same
    bounds as from_env: flush_every_n_records=0 would otherwise silently
    flush on EVERY record."""
    with pytest.raises(ValueError, match=next(iter(kw))):
        RecorderConfig(**kw)


@pytest.mark.parametrize("var,val", [
    ("RECORDER_FLUSH_EVERY_N_RECORDS", "soon"),
    ("RECORDER_FLUSH_EVERY_N_RECORDS", "0"),
    ("RECORDER_FLUSH_INTERVAL_S", "fast"),
    ("RECORDER_FLUSH_INTERVAL_S", "-1"),
    ("RECORDER_MAX_EPOCHS_RETAINED", "-3"),
    ("RECORDER_TS_BLOCK_RECORDS", "zero"),
])
def test_from_env_rejects_malformed_knobs(monkeypatch, var, val):
    monkeypatch.setenv(var, val)
    with pytest.raises(ValueError, match=var):
        RecorderConfig.from_env()


# ---------------------------------------------------------------------------
# uint32 tick wrap (the ~71.6-minute boundary) and windowed byte exactness
# ---------------------------------------------------------------------------


def test_tick_wrap_unwrapped_monotonic(tmp_path):
    """Ticks are uint32 microseconds on the wire and wrap every ~71.6
    minutes.  Epochs that cross the boundary mid-epoch, start after it,
    or skip WHOLE wrap periods (undetectable from the masked ticks alone
    -- only the per-epoch ``tick_wraps`` metadata recovers them) must all
    come back as the true monotonic int64 ticks."""
    td = str(tmp_path / "t")
    fid = REGISTRY.id_of("write")
    rec = Recorder(config=RecorderConfig(trace_dir=td, ts_block_records=8))
    wrap = 1 << 32
    true_ticks = []

    def feed(t_start, n):
        t = t_start
        for _ in range(n):
            rec.record(fid, ("fd", b"x" * 8), 8, 0, t, t + 1)
            true_ticks.append((t, t + 1))
            t += 3

    feed(wrap - 30, 20)    # epoch 0 crosses the boundary mid-epoch
    rec.flush()
    feed(wrap + 100, 10)   # epoch 1 starts one period in
    rec.flush()
    feed(3 * wrap + 7, 10)  # epoch 2 skips two whole periods
    rec.finalize()

    view = TraceReader(td, mode="stitched").view()
    got = view.timestamps_unwrapped(0)
    want = np.asarray(true_ticks, dtype=np.int64)
    assert np.array_equal(got, want)
    assert (np.diff(got[:, 0]) > 0).all()
    # per-record iteration keeps the raw masked u32 ticks (wrap recovery
    # is the unwrapped view's job); count and masked values line up
    entries = [r.t_entry for r in TraceReader(td, mode="stitched")
               .iter_records(0)]
    assert entries == [t & (wrap - 1) for t, _ in true_ticks]


def test_tick_wrap_survives_merged_trace(tmp_path):
    """The merged (finalized) trace carries the first segment's wrap base
    and re-detects intra-stream wraps, so single-period gaps stay exact."""
    td = str(tmp_path / "t")
    fid = REGISTRY.id_of("write")
    rec = Recorder(config=RecorderConfig(trace_dir=td, ts_block_records=8))
    wrap = 1 << 32
    base = 5 * wrap + 11  # non-zero wrap base at the FIRST epoch
    for i in range(12):
        rec.record(fid, ("fd", b"x" * 8), 8, 0, base + 3 * i, base + 3 * i + 1)
    rec.flush()
    start2 = 6 * wrap - 5  # epoch 1 crosses into the next period
    for i in range(8):
        rec.record(fid, ("fd", b"x" * 8), 8, 0, start2 + 3 * i,
                   start2 + 3 * i + 1)
    rec.finalize()
    got = TraceReader(td, mode="merged").view().timestamps_unwrapped(0)
    want = [base + 3 * i for i in range(12)] + \
           [start2 + 3 * i for i in range(8)]
    assert got[:, 0].tolist() == want
    assert (got[:, 1] - got[:, 0] == 1).all()


def test_windowed_bandwidth_exact_vs_record_iterator(tmp_path):
    """Per-block byte counters make windowed ``bandwidth_bounds`` EXACT:
    the reported byte total must equal a per-record walk over the same
    window, for windows cutting blocks at arbitrary points."""
    from repro.core.specs import DATA_FUNCS, Role

    sd = str(tmp_path / "s")
    calls = _gen_calls(random.Random(11), 150, 0, 1)
    _drive_streaming(sd, [calls], [50, 100], ts_block_records=16)
    reader = TraceReader(sd, mode="stitched")
    view = reader.view()
    recs = list(reader.iter_records(0))

    def rec_bytes(rc):
        if rc.func not in DATA_FUNCS:
            return 0
        spec = REGISTRY.spec(REGISTRY.id_of(rc.func))
        for a, v in zip(spec.args, rc.args):
            if a.role in (Role.BUF, Role.SIZE) and isinstance(v, int):
                return v
        return rc.ret if isinstance(rc.ret, int) else 0

    for t0, t1 in ((10, 40), (0, 10 ** 6), (95, 215), (240, 260), (33, 34)):
        want_rows = [rc for rc in recs
                     if rc.t_entry < t1 and (rc.t_exit or rc.t_entry) >= t0]
        b = view.bandwidth_bounds(t0, t1)
        assert b["exact"] is True
        assert b["n_calls"] == len(want_rows)
        want_bytes = sum(rec_bytes(rc) for rc in want_rows)
        assert b["bytes"] == want_bytes
        assert b["lo_MBps"] == b["hi_MBps"]


# ---------------------------------------------------------------------------
# incremental refresh: fold newly committed epochs without reconstruction
# ---------------------------------------------------------------------------


def test_refresh_folds_each_new_epoch_value_identically(tmp_path):
    """Commit epochs one at a time against a live stitched reader:
    ``refresh()`` reports exactly one fold per epoch and the folded
    reader stays value-identical to a from-scratch stitched read --
    including forwarded view memos (the queries warmed before the fold)."""
    sd = str(tmp_path / "s")
    calls = _gen_calls(random.Random(90), 70, 0, 1)
    bounds = [0, 18, 35, 52, len(calls)]
    rec = Recorder(rank=0, config=RecorderConfig(trace_dir=sd))
    t = _feed(rec, calls[bounds[0]:bounds[1]])
    rec.flush()

    reader = TraceReader(sd, mode="stitched")
    for i in range(1, len(bounds) - 1):
        # warm every memo path so the fold must carry them all forward
        view = reader.view()
        view.io_summary()
        view.call_chains()
        view.consistency_pairs()
        old_view, old_total = view, view.total_records()
        t = _feed(rec, calls[bounds[i]:bounds[i + 1]], t)
        rec.flush()
        assert reader.refresh() == 1
        assert reader.refresh() == 0  # idempotent until the next commit
        _assert_value_identical(reader, TraceReader(sd, mode="stitched"))
        # the pre-fold view keeps serving its snapshot
        assert old_view.total_records() == old_total
    assert reader.n_segments == len(bounds) - 1


def test_refresh_multirank_under_live_world(tmp_path):
    """A 4-rank world commits an epoch, pauses while the main thread
    opens a reader and warms its view, then commits another: one
    ``refresh()`` folds it and matches a fresh stitched read."""
    sd = str(tmp_path / "s")
    nranks = 4
    rank_calls = [_gen_calls(random.Random(100 + r), 20, r, nranks)
                  for r in range(nranks)]
    split = [len(c) // 2 for c in rank_calls]
    b_open = threading.Barrier(nranks + 1)
    b_go = threading.Barrier(nranks + 1)

    def worker(comm: Comm, rank: int):
        rec = Recorder(rank=rank,
                       config=RecorderConfig(trace_dir=sd))
        t = _feed(rec, rank_calls[rank][:split[rank]])
        rec.flush(comm)
        b_open.wait()
        b_go.wait()
        _feed(rec, rank_calls[rank][split[rank]:], t)
        rec.flush(comm)
        return None

    world = threading.Thread(target=run_thread_world, args=(nranks, worker),
                             daemon=True)
    world.start()
    b_open.wait()
    reader = TraceReader(sd, mode="stitched")
    view = reader.view()
    view.io_summary()
    for r in range(nranks):
        view.n_records(r)
    b_go.wait()
    world.join(timeout=30)
    assert not world.is_alive()
    assert reader.refresh() == 1
    _assert_value_identical(reader, TraceReader(sd, mode="stitched"))


def test_refresh_tail_advances_to_newest_segment(tmp_path):
    sd = str(tmp_path / "s")
    calls = _gen_calls(random.Random(91), 40, 0, 1)
    rec = Recorder(rank=0, config=RecorderConfig(trace_dir=sd))
    t = _feed(rec, calls[:14])
    rec.flush()
    tail = TraceReader(sd, mode="tail")
    n0 = tail.view().total_records()
    assert tail.refresh() == 0  # nothing new
    t = _feed(rec, calls[14:27], t)
    rec.flush()
    assert tail.refresh() == 1  # newest segment changed
    assert tail._tail_name == trace_format.segment_name(1)
    want = TraceReader(sd, mode="tail")
    assert tail.view().total_records() == want.view().total_records() != n0
    assert list(tail.all_records()) == list(want.all_records())


def test_refresh_single_and_merged_are_noops(tmp_path):
    # plain single-segment trace: immutable once written
    td = str(tmp_path / "plain")
    rec = Recorder(rank=0, config=RecorderConfig(trace_dir=td))
    _feed(rec, _gen_calls(random.Random(92), 10, 0, 1))
    rec.finalize()
    reader = TraceReader(td)
    assert reader.refresh() == 0

    # finalized stream served via the merged trace: refresh stays put
    sd = str(tmp_path / "s")
    calls = _gen_calls(random.Random(93), 20, 0, 1)
    _drive_streaming(sd, [calls], [10])
    auto = TraceReader(sd, mode="auto")
    assert auto._serving == "merged"
    total = auto.view().total_records()
    assert auto.refresh() == 0
    assert auto.view().total_records() == total

    # a stitched reader over the same finalized stream: the merged entry
    # is not a new epoch, so nothing folds
    stitched = TraceReader(sd, mode="stitched")
    assert stitched.refresh() == 0


# ---------------------------------------------------------------------------
# writer/reader race at the commit crash points (satellite: concurrent
# readers must never observe a half-committed segment or torn manifest)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("crash_point",
                         ["pre-rename", "pre-manifest", "post-commit"])
def test_reader_never_observes_partial_commit_across_crash(
        tmp_path, crash_point):
    """A writer crashes mid-commit at each commit point while a reader
    loop concurrently opens/refreshes the directory.  Readers may only
    ever see exact manifest prefixes -- a half-written ``.tmp`` segment,
    an orphan directory (renamed but unlisted), or a torn manifest must
    be invisible.  The run then resumes (new recorder, same directory)
    and the readers converge on the final committed history."""
    from repro.core import faults
    from repro.core.faults import FaultPlan, SimulatedCrash

    sd = str(tmp_path / "s")
    calls = _gen_calls(random.Random(94), 60, 0, 1)
    bounds = [0, 16, 31, 47, len(calls)]
    parts = [calls[bounds[i]:bounds[i + 1]] for i in range(len(bounds) - 1)]

    stop = threading.Event()
    observed, errors = [], []

    def reader_loop():
        rdr = None
        while not stop.is_set():
            try:
                if rdr is None:
                    rdr = TraceReader(sd, mode="stitched")
                else:
                    rdr.refresh()
                observed.append(rdr.view().total_records())
                rdr._view = None  # re-derive from the folded state
            except TraceFormatError:
                rdr = None  # not readable yet / superseded: retry fresh
            except Exception as e:  # noqa: BLE001
                errors.append(repr(e))
                return

    rec = Recorder(rank=0, config=RecorderConfig(trace_dir=sd))
    t = _feed(rec, parts[0])
    rec.flush()
    th = threading.Thread(target=reader_loop, daemon=True)
    th.start()
    t = _feed(rec, parts[1], t)
    with faults.injected(FaultPlan(crash_point=crash_point)):
        with pytest.raises(SimulatedCrash):
            rec.flush()
    # the "process" died mid-commit; what a reader sees RIGHT NOW must be
    # an exact committed prefix (epoch 1 only made it in post-commit)
    mid = TraceReader(sd, mode="stitched")
    committed = 2 if crash_point == "post-commit" else 1
    assert mid.n_segments == committed
    assert mid.skipped == []
    del rec

    # restart: a new recorder resumes the committed epochs and carries on
    rec2 = Recorder(rank=0, config=RecorderConfig(trace_dir=sd))
    t = _feed(rec2, parts[2], t)
    rec2.flush()
    assert rec2.epochs_resumed == committed
    _feed(rec2, parts[3], t)
    rec2.flush()
    stop.set()
    th.join(timeout=30)
    assert not th.is_alive()
    assert errors == []

    # every concurrently observed total is an exact epoch-boundary cumsum
    # of the final manifest -- never a torn intermediate
    entries = trace_format.read_manifest(sd)["segments"]
    valid, acc = set(), 0
    for e in entries:
        acc += e["n_records"]
        valid.add(acc)
    assert set(observed) <= valid
    final = TraceReader(sd, mode="stitched")
    assert final.n_segments == committed + 2
    _assert_value_identical(final, final)
    # post-commit: nothing lost; pre-*: exactly the crashed epoch's
    # records are gone (the process died holding them)
    lost = 0 if crash_point == "post-commit" else len(parts[1])
    assert final.view().total_records() == len(calls) - lost
