"""Trace query service: live compressed-domain queries over many jobs.

The properties under test --

  * the watcher classifies jobs (stream / plain / degraded / quarantined
    / unreadable) from manifests alone,
  * per-segment incrementality: one newly committed epoch costs exactly
    ONE segment fold and never re-reads already-loaded segments,
  * every query family served from the cache is value-identical to a
    fresh direct ``TraceReader(mode="stitched")`` read, asserted while
    epochs commit underneath, including a degraded ``ranks_present``
    epoch whose coverage mask propagates into service responses,
  * generation-stamped snapshots: concurrent clients hammering the
    service while a writer commits never observe a torn view (every
    observed total is an exact epoch-boundary cumsum),
  * LRU eviction by resident size keeps generations monotonic,
  * stragglers carry per-rank reasons (lagging / partial_coverage /
    dfg_divergent), not just a flat union,
  * the CLI answers --list/--query/--league/--stragglers/--phases/
    --anomalies with JSON.
"""

import json
import random
import threading
import time
import warnings

import pytest

from repro.core import faults, trace_format
from repro.core.comm import run_thread_world
from repro.core.faults import FaultPlan
from repro.core.reader import TraceReader
from repro.core.recorder import Recorder, RecorderConfig
from repro.core.specs import REGISTRY
from repro.launch import traceserve as cli
from repro.traceserve import (IncrementalViewCache, JobWatcher, TraceService,
                              ViewSnapshot, run_query)
import repro.core.apis  # noqa: F401  (populate registry)


@pytest.fixture(autouse=True)
def _no_leaked_plan():
    yield
    faults.uninstall()


def _gen_calls(rng: random.Random, n_calls: int, rank: int, nranks: int):
    fids = {name: REGISTRY.id_of(name)
            for name in ("open", "close", "pwrite", "lseek", "write")}
    fd = f"fd-{rank}"
    calls = [(fids["open"], ("/data/f.bin", 2, 438), fd)]
    for i in range(n_calls):
        kind = rng.random()
        if kind < 0.6:
            off = rank * 4096 + i * nranks * 4096
            calls.append((fids["pwrite"], (fd, b"x" * 4096, off), 4096))
        elif kind < 0.8:
            calls.append((fids["lseek"], (fd, rank * 256 + i * 256, 0),
                          rank * 256 + i * 256))
        else:
            calls.append((fids["write"], (fd, b"z" * 128), 128))
    calls.append((fids["close"], (fd,), 0))
    return calls


def _feed(rec: Recorder, calls, tick_start: int = 0) -> int:
    t = tick_start
    for fid, args, ret in calls:
        rec.record(fid, args, ret, 0, t, t + 1)
        t += 2
    return t


def _fresh_snapshot(path: str) -> ViewSnapshot:
    """A direct, from-scratch stitched read wrapped as a snapshot, so the
    same ``run_query`` dispatch answers both sides of an identity check."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        reader = TraceReader(path, mode="stitched")
        view = reader.view()
    return ViewSnapshot(path=path, view=view, generation=0,
                        n_segments=reader.n_segments,
                        coverage=reader.coverage(), refreshed_at=0.0)


_FAMILIES_NO_PARAMS = ("io_summary", "size_histogram", "call_chains",
                       "overlap_ratio", "consistency_pairs",
                       "digram_counts", "n_records",
                       "dfg", "phases", "anomalies")


# ---------------------------------------------------------------------------
# watcher
# ---------------------------------------------------------------------------


def test_watcher_classifies_jobs(tmp_path):
    root = tmp_path / "runs"
    root.mkdir()
    (root / "not_a_trace").mkdir()          # ignored
    (root / "loose_file.txt").write_text("x")

    rec = Recorder(rank=0, config=RecorderConfig(
        trace_dir=str(root / "stream_job")))
    calls = _gen_calls(random.Random(0), 20, 0, 1)
    t = _feed(rec, calls[:10])
    rec.flush()
    _feed(rec, calls[10:], t)
    rec.flush()

    plain = Recorder(rank=0, config=RecorderConfig(
        trace_dir=str(root / "plain_job")))
    _feed(plain, _gen_calls(random.Random(1), 8, 0, 1))
    plain.finalize()

    jobs = JobWatcher(str(root)).scan()
    assert set(jobs) == {"stream_job", "plain_job"}
    sj = jobs["stream_job"]
    assert sj.is_stream and sj.n_segments == 2 and sj.newest_epoch == 1
    assert not sj.has_merged and sj.complete
    assert sj.n_records == sum(
        e["n_records"]
        for e in trace_format.read_manifest(sj.path)["segments"])
    pj = jobs["plain_job"]
    assert not pj.is_stream and pj.n_segments == 1 and pj.complete


def test_watcher_reports_quarantined_and_caches_validation(tmp_path):
    root = tmp_path / "runs"
    sd = root / "job"
    rec = Recorder(rank=0, config=RecorderConfig(trace_dir=str(sd)))
    calls = _gen_calls(random.Random(2), 16, 0, 1)
    t = _feed(rec, calls[:8])
    rec.flush()
    _feed(rec, calls[8:], t)
    rec.flush()
    seg = trace_format.segment_name(1)
    faults.corrupt_file(str(sd / seg / "unique_cfgs.bin"), seed=4)

    w = JobWatcher(str(root))
    info = w.scan()["job"]
    assert [q["segment"] for q in info.quarantined] == [seg]
    assert not info.complete
    # committed segments are immutable: the second scan must answer from
    # the validation cache, not re-checksum every blob
    calls_before = len(w._val_cache)
    w.scan()
    assert len(w._val_cache) == calls_before


# ---------------------------------------------------------------------------
# per-segment incrementality (the acceptance criterion)
# ---------------------------------------------------------------------------


def test_one_new_epoch_costs_exactly_one_segment_fold(tmp_path, monkeypatch):
    root = tmp_path / "runs"
    sd = root / "job"
    rec = Recorder(rank=0, config=RecorderConfig(trace_dir=str(sd)))
    calls = _gen_calls(random.Random(3), 45, 0, 1)
    t = _feed(rec, calls[:15])
    rec.flush()
    t = _feed(rec, calls[15:30], t)
    rec.flush()

    svc = TraceService(str(root), max_staleness_s=0.0)
    r0 = svc.query("job", "io_summary")
    s0 = svc.stats()["cache"]
    assert s0["view_builds"] == 1 and s0["segments_loaded"] == 2
    assert s0["segment_folds"] == 0

    # every segment read from here on is observed
    loads = []
    real_load = trace_format.load_segment

    def counting_load(trace_dir, entry):
        loads.append(entry["name"])
        return real_load(trace_dir, entry)

    monkeypatch.setattr(trace_format, "load_segment", counting_load)

    _feed(rec, calls[30:], t)
    rec.flush()
    r1 = svc.query("job", "io_summary")
    s1 = svc.stats()["cache"]
    # exactly one fold, exactly the new segment touched: prior segments
    # are never re-read, re-validated or re-decoded
    assert s1["segment_folds"] - s0["segment_folds"] == 1
    assert loads == [trace_format.segment_name(2)]
    assert s1["view_builds"] == 1
    assert r1.generation == r0.generation + 1
    # and the folded aggregate is the full-history answer
    assert r1.value == run_query(_fresh_snapshot(str(sd)), "io_summary")
    assert r1.value["total_bytes"] > r0.value["total_bytes"]
    svc.close()


def test_fresh_hit_is_pure_lookup_and_memo_invalidates_per_generation(
        tmp_path):
    root = tmp_path / "runs"
    sd = root / "job"
    rec = Recorder(rank=0, config=RecorderConfig(trace_dir=str(sd)))
    calls = _gen_calls(random.Random(4), 30, 0, 1)
    t = _feed(rec, calls[:15])
    rec.flush()

    svc = TraceService(str(root), max_staleness_s=0.0)
    a = svc.query("job", "size_histogram")
    assert not a.cached
    b = svc.query("job", "size_histogram")
    assert b.cached and b.value == a.value and b.generation == a.generation
    # a new epoch bumps the generation; the memo entry must miss
    _feed(rec, calls[15:], t)
    rec.flush()
    c = svc.query("job", "size_histogram")
    assert not c.cached and c.generation == b.generation + 1
    assert c.value != b.value
    svc.close()


def test_staleness_bound_pins_or_refreshes(tmp_path):
    root = tmp_path / "runs"
    sd = root / "job"
    rec = Recorder(rank=0, config=RecorderConfig(trace_dir=str(sd)))
    calls = _gen_calls(random.Random(5), 30, 0, 1)
    t = _feed(rec, calls[:15])
    rec.flush()

    svc = TraceService(str(root), max_staleness_s=0.0)
    r0 = svc.query("job", "n_records")
    _feed(rec, calls[15:], t)
    rec.flush()
    # an infinite bound serves the pinned snapshot: stale but consistent
    stale = svc.query("job", "n_records", max_staleness_s=float("inf"))
    assert stale.generation == r0.generation
    assert stale.value == r0.value
    # a zero bound forces the refresh
    live = svc.query("job", "n_records", max_staleness_s=0.0)
    assert live.generation == r0.generation + 1
    assert live.value["total"] > r0.value["total"]
    svc.close()


# ---------------------------------------------------------------------------
# value identity while epochs commit underneath
# ---------------------------------------------------------------------------


def test_every_family_value_identical_while_committing(tmp_path):
    root = tmp_path / "runs"
    sd = root / "job"
    rec = Recorder(rank=0, config=RecorderConfig(trace_dir=str(sd)))
    calls = _gen_calls(random.Random(6), 60, 0, 1)
    bounds = [0, 16, 31, 47, len(calls)]

    svc = TraceService(str(root), max_staleness_s=0.0)
    t = 0
    for i in range(len(bounds) - 1):
        t = _feed(rec, calls[bounds[i]:bounds[i + 1]], t)
        rec.flush()
        fresh = _fresh_snapshot(str(sd))
        for fam in _FAMILIES_NO_PARAMS:
            got = svc.query("job", fam)
            assert got.value == run_query(fresh, fam), (i, fam)
        got = svc.query("job", "bandwidth_bounds", {"t0": 0, "t1": t})
        assert got.value == run_query(fresh, "bandwidth_bounds",
                                      {"t0": 0, "t1": t})
        got = svc.query("job", "overlap_ratio",
                        {"rank": 0, "t0": 0, "t1": t})
        assert got.value == run_query(fresh, "overlap_ratio",
                                      {"rank": 0, "t0": 0, "t1": t})
    stats = svc.stats()["cache"]
    assert stats["view_builds"] == 1
    assert stats["segment_folds"] == len(bounds) - 2
    svc.close()


@pytest.mark.filterwarnings("ignore::RuntimeWarning")
def test_degraded_epoch_coverage_propagates_into_responses(tmp_path):
    """A rank dies mid-run: the survivors commit a ``ranks_present``
    epoch.  The service folds it incrementally and every response carries
    the coverage mask; the straggler report flags the gapped rank."""
    root = tmp_path / "runs"
    sd = str(root / "job")
    # dead=1 is a leaf of the reduce tree: exactly one rank goes missing
    # (an interior rank's silence would absorb its subtree's ranks too)
    nranks, dead = 4, 1
    first = [_gen_calls(random.Random(70 + r), 8, r, nranks)
             for r in range(nranks)]
    extra = [_gen_calls(random.Random(80 + r), 5, r, nranks)
             for r in range(nranks)]
    b_built = threading.Barrier(nranks + 1)
    b_go = threading.Barrier(nranks + 1)

    def worker(comm, rank):
        rec = Recorder(rank=rank, config=RecorderConfig(
            trace_dir=sd, flush_timeout_s=2.0))
        t = _feed(rec, first[rank])
        rec.flush(comm)
        b_built.wait()   # main: build the service on the healthy epoch
        b_go.wait()      # main: install the dead-rank fault
        _feed(rec, extra[rank], t)
        rec.flush(comm)  # degraded commit (no finalize: job still "live")
        return None

    world = threading.Thread(
        target=run_thread_world, args=(nranks, worker), daemon=True)
    world.start()
    b_built.wait()
    svc = TraceService(str(root), mode="stitched", max_staleness_s=0.0)
    r0 = svc.query("job", "n_records")
    assert r0.coverage["complete"] and r0.coverage["ranks_partial"] == []
    faults.install(FaultPlan(dead_ranks=(dead,)))
    b_go.wait()
    world.join(timeout=30)
    assert not world.is_alive()
    faults.uninstall()

    r1 = svc.query("job", "n_records")
    assert r1.generation == r0.generation + 1
    assert not r1.coverage["complete"]
    assert r1.coverage["ranks_partial"] == [dead]
    assert len(r1.coverage["degraded_epochs"]) == 1
    assert svc.query("job", "coverage").value == r1.coverage
    # the dead rank's epoch-2 records are absent; count + coverage match
    # a fresh direct stitched read of the same directory
    fresh = _fresh_snapshot(sd)
    assert r1.value == run_query(fresh, "n_records")
    assert r1.coverage["degraded_epochs"] == \
        fresh.coverage["degraded_epochs"]
    rep = svc.stragglers("job")
    assert dead in rep["stragglers"]
    # the report carries the REASON, not just the union membership
    assert "partial_coverage" in rep["reasons"][dead]
    assert dead in rep["ranks_partial"]
    assert svc.query("job", "io_summary").value == \
        run_query(fresh, "io_summary")
    svc.close()


# ---------------------------------------------------------------------------
# snapshot consistency under concurrent commit + query load
# ---------------------------------------------------------------------------


def test_concurrent_clients_never_observe_torn_views(tmp_path):
    root = tmp_path / "runs"
    sd = root / "job"
    rec = Recorder(rank=0, config=RecorderConfig(trace_dir=str(sd)))
    calls = _gen_calls(random.Random(7), 120, 0, 1)
    bounds = list(range(0, len(calls), 12)) + [len(calls)]
    t = _feed(rec, calls[bounds[0]:bounds[1]])
    rec.flush()

    svc = TraceService(str(root), max_staleness_s=0.0, workers=4)
    stop = threading.Event()
    observed = []   # (generation, total) per successful client read
    errors = []

    def client():
        while not stop.is_set():
            try:
                res = svc.query("job", "n_records")
                observed.append((res.generation, res.value["total"]))
            except Exception as e:  # noqa: BLE001
                errors.append(repr(e))

    threads = [threading.Thread(target=client) for _ in range(3)]
    for th in threads:
        th.start()
    for i in range(1, len(bounds) - 1):
        t = _feed(rec, calls[bounds[i]:bounds[i + 1]], t)
        rec.flush()
        time.sleep(0.01)
    stop.set()
    for th in threads:
        th.join()
    svc.close()

    assert errors == []
    # every observed total is an exact epoch-boundary cumsum: no client
    # ever saw a half-folded view
    entries = trace_format.read_manifest(str(sd))["segments"]
    valid, acc = set(), 0
    for e in entries:
        acc += e["n_records"]
        valid.add(acc)
    totals = {tot for _, tot in observed}
    assert totals <= valid
    assert acc in totals  # the final state was eventually observed
    # totals grow monotonically with the generation stamp
    by_gen = {}
    for gen, tot in observed:
        by_gen.setdefault(gen, set()).add(tot)
    for gen, tots in by_gen.items():
        assert len(tots) == 1, f"generation {gen} served two totals"
    gens = sorted(by_gen)
    ordered = [next(iter(by_gen[g])) for g in gens]
    assert ordered == sorted(ordered)


# ---------------------------------------------------------------------------
# eviction
# ---------------------------------------------------------------------------


def test_lru_eviction_by_resident_size_keeps_generations_monotonic(tmp_path):
    root = tmp_path / "runs"
    for name, seed in (("a", 10), ("b", 11)):
        rec = Recorder(rank=0, config=RecorderConfig(
            trace_dir=str(root / name)))
        calls = _gen_calls(random.Random(seed), 20, 0, 1)
        t = _feed(rec, calls[:10])
        rec.flush()
        _feed(rec, calls[10:], t)
        rec.flush()

    cache = IncrementalViewCache(max_resident_bytes=1)  # one job at most
    pa, pb = str(root / "a"), str(root / "b")
    s_a = cache.get(pa)
    assert cache.resident_paths() == [pa]
    cache.get(pb)
    assert cache.resident_paths() == [pb]       # a evicted (LRU)
    assert cache.stats["evictions"] == 1
    s_a2 = cache.get(pa)
    # rebuilt from scratch, but the generation never goes backwards
    assert s_a2.generation > s_a.generation
    assert cache.stats["view_builds"] == 3
    # in-flight snapshots of the evicted entry still answer queries
    assert run_query(s_a, "n_records") == run_query(s_a2, "n_records")


# ---------------------------------------------------------------------------
# cross-job comparisons + CLI
# ---------------------------------------------------------------------------


def _two_job_root(tmp_path):
    root = tmp_path / "runs"
    for name, seed, n in (("heavy", 20, 40), ("light", 21, 10)):
        rec = Recorder(rank=0, config=RecorderConfig(
            trace_dir=str(root / name)))
        calls = _gen_calls(random.Random(seed), n, 0, 1)
        t = _feed(rec, calls[: len(calls) // 2])
        rec.flush()
        _feed(rec, calls[len(calls) // 2:], t)
        rec.flush()
    return root


def test_league_table_ranks_jobs(tmp_path):
    root = _two_job_root(tmp_path)
    with TraceService(str(root), max_staleness_s=0.0) as svc:
        rows = svc.league_table()
        assert [r["rank"] for r in rows] == [0, 1]
        assert rows[0]["aggregate_MBps"] >= rows[1]["aggregate_MBps"]
        assert {r["path"].rsplit("/", 1)[-1] for r in rows} == \
            {"heavy", "light"}
        # per-job isolation: a bogus path ranks last with an error
        rows = svc.engine.league_table(
            [str(root / "heavy"), str(root / "nope")])
        assert rows[-1]["path"].endswith("nope") and "error" in rows[-1]


def test_cli_list_query_league_stragglers(tmp_path, capsys):
    root = _two_job_root(tmp_path)
    assert cli.main(["--root", str(root), "--list"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert set(doc["jobs"]) == {"heavy", "light"}
    assert doc["jobs"]["heavy"]["n_segments"] == 2

    assert cli.main(["--root", str(root), "--job", "heavy",
                     "--query", "size_histogram"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["family"] == "size_histogram" and doc["generation"] == 1
    assert doc["value"] == run_query(
        _fresh_snapshot(str(root / "heavy")), "size_histogram")

    assert cli.main(["--root", str(root), "--job", "heavy",
                     "--query", "call_chains", "--rank", "0"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["params"] == {"rank": 0}

    assert cli.main(["--root", str(root), "--league"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert len(doc["league"]) == 2
    assert doc["stats"]["cache"]["view_builds"] == 2

    assert cli.main(["--root", str(root), "--job", "light",
                     "--stragglers"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["stragglers"] == []
    assert doc["reasons"] == {} and doc["dfg_divergent"] == []

    assert cli.main(["--root", str(root), "--job", "heavy",
                     "--phases"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["family"] == "phases"
    ph = doc["value"]["phases"]
    assert ph and ph[0]["start_record"] == 0
    assert all(set(p) >= {"start_record", "end_record", "dominant_funcs",
                          "label"} for p in ph)

    assert cli.main(["--root", str(root), "--job", "heavy",
                     "--anomalies", "--divergence", "0.1"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["family"] == "anomalies"
    assert doc["value"]["threshold"] == 0.1
    assert len(doc["value"]["per_rank"]) == doc["value"]["nranks"]

    assert cli.main(["--root", str(root), "--job", "heavy",
                     "--query", "dfg", "--top", "3"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["family"] == "dfg" and len(doc["value"]["edges"]) <= 3
    assert doc["value"]["n_records"] > 0

    # actions needing --job fail cleanly
    assert cli.main(["--root", str(root), "--query", "io_summary"]) == 2
    assert cli.main(["--root", str(root), "--phases"]) == 2
    assert cli.main(["--root", str(root), "--anomalies"]) == 2
    capsys.readouterr()


def test_watch_thread_keeps_resident_jobs_fresh(tmp_path):
    root = tmp_path / "runs"
    sd = root / "job"
    rec = Recorder(rank=0, config=RecorderConfig(trace_dir=str(sd)))
    calls = _gen_calls(random.Random(30), 30, 0, 1)
    t = _feed(rec, calls[:15])
    rec.flush()
    svc = TraceService(str(root), max_staleness_s=float("inf"),
                       watch_interval_s=0.05)
    r0 = svc.query("job", "n_records")
    _feed(rec, calls[15:], t)
    rec.flush()
    # the watch thread refreshes the resident job in the background, so
    # even an infinitely-stale-tolerant query sees the new epoch soon
    deadline = time.monotonic() + 5.0
    while time.monotonic() < deadline:
        r1 = svc.query("job", "n_records",
                       max_staleness_s=float("inf"))
        if r1.generation > r0.generation:
            break
        time.sleep(0.02)
    assert r1.generation > r0.generation
    assert r1.value["total"] > r0.value["total"]
    svc.close()
