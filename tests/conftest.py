"""Test bootstrap: make ``src`` (the package) and the repo root (the
``benchmarks`` package) importable regardless of how pytest is invoked."""

import os
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (os.path.join(_REPO, "src"), _REPO):
    if p not in sys.path:
        sys.path.insert(0, p)
