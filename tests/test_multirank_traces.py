"""Multi-rank trace lifecycle: simulated-rank states -> inter-process
compression -> on-disk trace -> per-rank lossless reconstruction; plus the
concurrent (ThreadComm) finalize path used on real multi-host runs."""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, "/root/repo")
from benchmarks.workloads import ior_rank  # noqa: E402
from repro.core import trace_format
from repro.core.comm import run_thread_world
from repro.core.interprocess import finalize_ranks
from repro.core.reader import TraceReader
from repro.core.recorder import Recorder, RecorderConfig
from repro.core.specs import REGISTRY


def _write_multirank_trace(tmp_path, nprocs, n_calls, chunk=512):
    data_dir = str(tmp_path / "data")
    states = []
    for r in range(nprocs):
        rec = Recorder(rank=r, config=RecorderConfig())
        ior_rank(rec, r, nprocs, n_calls, chunk=chunk, data_dir=data_dir)
        states.append(rec.local_state())
    merge, cfgs = finalize_ranks([s[0] for s in states],
                                 [s[1] for s in states], REGISTRY)
    trace_dir = str(tmp_path / "trace")
    trace_format.write_trace(
        trace_dir, registry=REGISTRY, merged_cst=merge.merged_entries,
        unique_cfgs=cfgs.unique_cfgs, cfg_index=cfgs.cfg_index,
        rank_timestamps=[s[2] for s in states], meta_extra={})
    return trace_dir


def test_multirank_reader_reconstructs_per_rank_offsets(tmp_path):
    """Every rank's strided offsets come back EXACTLY from the single
    merged CST + one shared CFG (RankPattern + IterPattern resolution)."""
    nprocs, n_calls, chunk = 8, 40, 512
    trace_dir = _write_multirank_trace(tmp_path, nprocs, n_calls, chunk)
    reader = TraceReader(trace_dir)
    assert reader.nranks == nprocs
    assert len(reader.unique_cfgs) == 1        # identical CFGs deduped
    for r in range(nprocs):
        offs = [rec.arg("offset") for rec in reader.iter_records(r)
                if rec.func == "lseek"]
        want = [r * chunk + i * nprocs * chunk for i in range(n_calls)]
        assert offs == want, f"rank {r}"


def test_multirank_trace_constant_on_disk(tmp_path):
    d1 = _write_multirank_trace(tmp_path / "a", 4, 64)
    d2 = _write_multirank_trace(tmp_path / "b", 32, 64)
    s1 = trace_format.trace_size_report(d1)
    s2 = trace_format.trace_size_report(d2)
    # pattern files flat in rank count; index/timestamps grow linearly
    assert abs(s2["pattern_files"] - s1["pattern_files"]) <= 8
    assert s2["cfg_index.bin"] >= s1["cfg_index.bin"]


def test_threadcomm_concurrent_finalize(tmp_path):
    """The SPMD finalize path: N ranks on N threads, gather -> merge ->
    rank 0 writes, all barriers met; result equals the sequential path."""
    nprocs = 4
    data_dir = str(tmp_path / "data")
    trace_dir = str(tmp_path / "trace")

    def worker(comm, rank):
        rec = Recorder(rank=rank, config=RecorderConfig())
        # build the rank's stream WITHOUT attaching (wrappers use a global
        # slot shared across threads; feed records directly)
        fid_seek = REGISTRY.id_of("lseek")
        fid_write = REGISTRY.id_of("write")
        fd = object()
        for i in range(20):
            off = rank * 256 + i * nprocs * 256
            rec.record(fid_seek, (fd, off, 0), off, 0, 2 * i, 2 * i + 1)
            rec.record(fid_write, (fd, b"x" * 64), 64, 0, 2 * i + 1,
                       2 * i + 2)
        stats = rec.finalize(comm, trace_dir=trace_dir)
        return stats

    results = run_thread_world(nprocs, worker)
    assert results[0] is not None          # root got stats
    assert all(r is None for r in results[1:])
    reader = TraceReader(trace_dir)
    assert reader.nranks == nprocs
    for r in range(nprocs):
        offs = [rec.arg("offset") for rec in reader.iter_records(r)
                if rec.func == "lseek"]
        assert offs == [r * 256 + i * nprocs * 256 for i in range(20)]
    # constant-size structure: one unique CFG, few CST entries
    assert len(reader.unique_cfgs) == 1
    assert len(reader.merged_cst) <= 4
