"""The paper's scaling figures as assertions (Figs 4-6, Section 5.1-5.2)."""

import shutil
import sys
import tempfile

import pytest

sys.path.insert(0, "/root/repo")  # benchmarks package lives at repo root
from benchmarks.workloads import flash_rank, ior_rank, run_ranks  # noqa: E402
from repro.core.recorder import RecorderConfig


def _ior(nprocs, n_calls, **cfg_kw):
    d = tempfile.mkdtemp()
    try:
        return run_ranks(ior_rank, nprocs, RecorderConfig(timestamps=False,
                                                          **cfg_kw),
                         n_calls=n_calls, data_dir=d)
    finally:
        shutil.rmtree(d, ignore_errors=True)


def test_fig4_intra_flat_in_calls():
    a = _ior(8, 32)["pattern_bytes"]
    b = _ior(8, 1024)["pattern_bytes"]
    assert abs(b - a) <= 4          # varint exponent growth only


def test_fig4_no_intra_grows():
    a = _ior(8, 32, intra_patterns=False)["pattern_bytes"]
    b = _ior(8, 1024, intra_patterns=False)["pattern_bytes"]
    assert b > 8 * a


def test_fig5_inter_flat_in_ranks():
    a = _ior(4, 128)["pattern_bytes"]
    b = _ior(64, 128)["pattern_bytes"]
    assert abs(b - a) <= 8


def test_fig5_no_inter_linear_in_ranks():
    a = _ior(4, 128, inter_patterns=False)["pattern_bytes"]
    b = _ior(64, 128, inter_patterns=False)["pattern_bytes"]
    assert b > 10 * a


def test_fig5_intra_off_inter_on_constant_but_larger():
    base = _ior(16, 128)["pattern_bytes"]
    a = _ior(4, 128, intra_patterns=False)["pattern_bytes"]
    b = _ior(64, 128, intra_patterns=False)["pattern_bytes"]
    # structurally constant in ranks (only varint widths of the larger
    # offsets grow -- log factor, the paper's "slightly larger" curve)
    assert abs(b - a) <= 0.05 * a
    assert a > base                  # ...and larger than with intra


def _flash(nprocs, iterations, **kw):
    d = tempfile.mkdtemp()
    try:
        return run_ranks(flash_rank, nprocs, RecorderConfig(timestamps=False),
                         data_dir=d, iterations=iterations, **kw)
    finally:
        shutil.rmtree(d, ignore_errors=True)


def test_fig6_weak_scaling_constant():
    a = _flash(8, 60)["pattern_bytes"]
    b = _flash(128, 60)["pattern_bytes"]
    assert abs(b - a) <= 16


def test_fig6_iterations_growth_and_rolling_mitigation():
    grow_small = _flash(8, 80)["pattern_bytes"]
    grow_big = _flash(8, 320)["pattern_bytes"]
    roll_small = _flash(8, 80, rolling=True)["pattern_bytes"]
    roll_big = _flash(8, 320, rolling=True)["pattern_bytes"]
    assert grow_big > grow_small + 100   # new filenames -> new signatures
    assert abs(roll_big - roll_small) <= 8


def test_fig7_collective_tracks_aggregators():
    # more aggregators (more nodes) -> more unique grammars, until stripe cap
    small = _flash(64, 40, mode="collective", stripe=8)
    big = _flash(1024, 40, mode="collective", stripe=8)
    assert big["n_unique_cfgs"] >= small["n_unique_cfgs"]


def test_table4_recorder_much_smaller_than_old():
    import os
    from repro.core.baselines import RecorderOld, ToolAdapter
    d = tempfile.mkdtemp()
    try:
        rec = run_ranks(flash_rank, 8, RecorderConfig(), data_dir=d,
                        iterations=60)
        old_total = 0
        for r in range(8):
            tool = RecorderOld(r)
            flash_rank(ToolAdapter(tool, rank=r), r, 8, data_dir=d,
                       iterations=60)
            old_total += tool.nbytes
    finally:
        shutil.rmtree(d, ignore_errors=True)
    assert old_total > 5 * rec["total_bytes"]
