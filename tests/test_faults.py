"""Fault-tolerance suite: deterministic fault injection into the comm
layer and the segment writer, and the properties it must uphold --

  * a failed or crashed epoch commit retains the delta; the next flush
    covers the failed epoch's records exactly once (sync AND async),
  * a dead/unresponsive rank degrades the epoch (survivors commit with a
    ``ranks_present`` mask) instead of deadlocking the world,
  * in-flight torn writes and post-commit bit rot are caught by the
    manifest CRC32s, quarantined and reported,
  * a killed-and-restarted run resumes its cumulative state and
    finalizes a merged trace value-identical to an uninterrupted run,
  * every surviving trace directory is fully readable or reports
    degraded coverage -- never silently wrong.
"""

import os
import random
import time

import pytest

from repro.core import faults, streaming, trace_format
from repro.core.comm import run_thread_world
from repro.core.faults import FaultPlan, SimulatedCrash
from repro.core.reader import TraceReader
from repro.core.recorder import Recorder, RecorderConfig
from repro.core.specs import REGISTRY
from repro.core.trace_format import SegmentWriteError, TraceFormatError
import repro.core.apis  # noqa: F401  (populate registry)


@pytest.fixture(autouse=True)
def _no_leaked_plan():
    yield
    faults.uninstall()


def _gen_calls(rng: random.Random, n_calls: int, rank: int, nranks: int):
    fids = {name: REGISTRY.id_of(name)
            for name in ("open", "close", "pwrite", "lseek", "write")}
    calls = [(fids["open"], ("/data/f.bin", 2, 438), f"fd-{rank}")]
    fd = f"fd-{rank}"
    for i in range(n_calls):
        kind = rng.random()
        if kind < 0.6:
            off = rank * 4096 + i * nranks * 4096
            calls.append((fids["pwrite"], (fd, b"x" * 4096, off), 4096))
        elif kind < 0.8:
            calls.append((fids["lseek"], (fd, rank * 256 + i * 256, 0),
                          rank * 256 + i * 256))
        else:
            calls.append((fids["write"], (fd, b"z" * 128), 128))
    calls.append((fids["close"], (fd,), 0))
    return calls


def _feed(rec: Recorder, calls, tick_start: int = 0) -> int:
    t = tick_start
    for fid, args, ret in calls:
        rec.record(fid, args, ret, 0, t, t + 1)
        t += 2
    return t


def _funcs(reader: TraceReader):
    return [r.func for _, r in reader.all_records()]


def _names(calls):
    return [REGISTRY.spec(fid).name for fid, _, _ in calls]


# ---------------------------------------------------------------------------
# the plan itself: seeded, replayable, counted
# ---------------------------------------------------------------------------


def test_fault_plan_is_deterministic():
    decisions = []
    for _ in range(2):
        plan = FaultPlan(seed=123, drop_prob=0.3, delay_prob=0.3,
                         delay_s=0.01)
        decisions.append([plan.on_send(0, 1) for _ in range(200)])
    assert decisions[0] == decisions[1]
    assert "drop" in decisions[0] and 0.01 in decisions[0]


def test_torn_write_mangles_only_the_named_file(tmp_path):
    plan = FaultPlan(torn_file="b.bin")
    out = plan.on_write(str(tmp_path / "a.bin"), b"\xff" * 64)
    assert out == b"\xff" * 64
    out = plan.on_write(str(tmp_path / "b.bin"), b"\xff" * 64)
    assert len(out) == 64 and out != b"\xff" * 64  # same size, wrong bytes
    assert plan.counters["files_torn"] == 1


# ---------------------------------------------------------------------------
# failed commit -> delta retained -> exactly-once on retry (satellite c)
# ---------------------------------------------------------------------------


def test_enospc_flush_retains_delta_sync(tmp_path):
    sd = str(tmp_path / "s")
    calls = _gen_calls(random.Random(1), 28, 0, 1)
    rec = Recorder(rank=0, config=RecorderConfig(trace_dir=sd))
    t = _feed(rec, calls[:10])
    rec.flush()
    t = _feed(rec, calls[10:20], t)
    with faults.injected(FaultPlan(fail_write_at=1)) as plan:
        with pytest.raises(OSError, match="disk full") as ei:
            rec.flush()
    assert isinstance(ei.value, SegmentWriteError)
    assert plan.counters["writes_failed"] == 1
    # clean failure: no .tmp debris, nothing committed, delta restored
    assert not [d for d in os.listdir(sd) if d.endswith(".tmp")]
    assert len(trace_format.read_manifest(sd)["segments"]) == 1
    assert rec.epochs_restored == 1
    _feed(rec, calls[20:], t)
    rec.finalize()
    for mode in ("stitched", "merged"):
        assert _funcs(TraceReader(sd, mode=mode)) == _names(calls)


def test_enospc_async_flush_retains_delta(tmp_path):
    sd = str(tmp_path / "s")
    calls = _gen_calls(random.Random(2), 28, 0, 1)
    rec = Recorder(rank=0,
                   config=RecorderConfig(trace_dir=sd, async_flush=True))
    t = _feed(rec, calls[:10])
    rec.flush()
    rec.drain()
    t = _feed(rec, calls[10:20], t)
    with faults.injected(FaultPlan(fail_write_at=1)):
        rec.flush()
        with pytest.raises(RuntimeError, match="records were retained"):
            rec.drain()
    assert rec.epochs_restored == 1
    _feed(rec, calls[20:], t)
    rec.finalize()
    for mode in ("stitched", "merged"):
        assert _funcs(TraceReader(sd, mode=mode)) == _names(calls)


def test_crash_pre_rename_leaves_tmp_debris_and_retains_delta(tmp_path):
    sd = str(tmp_path / "s")
    calls = _gen_calls(random.Random(3), 28, 0, 1)
    rec = Recorder(rank=0, config=RecorderConfig(trace_dir=sd))
    t = _feed(rec, calls[:10])
    rec.flush()
    t = _feed(rec, calls[10:20], t)
    with faults.injected(FaultPlan(crash_point="pre-rename")):
        with pytest.raises(SimulatedCrash):
            rec.flush()
    # a kill mid-write leaves .tmp debris -- invisible to readers, swept
    # by the next attempt
    assert [d for d in os.listdir(sd) if d.endswith(".tmp")]
    reader = TraceReader(sd, mode="stitched")
    assert reader.skipped == []
    assert _funcs(reader) == _names(calls[:10])
    assert rec.epochs_restored == 1
    _feed(rec, calls[20:], t)
    rec.finalize()
    assert not [d for d in os.listdir(sd) if d.endswith(".tmp")]
    for mode in ("stitched", "merged"):
        assert _funcs(TraceReader(sd, mode=mode)) == _names(calls)


def test_crash_pre_manifest_orphan_segment_is_replaced(tmp_path):
    sd = str(tmp_path / "s")
    calls = _gen_calls(random.Random(4), 28, 0, 1)
    rec = Recorder(rank=0, config=RecorderConfig(trace_dir=sd))
    t = _feed(rec, calls[:10])
    rec.flush()
    t = _feed(rec, calls[10:20], t)
    with faults.injected(FaultPlan(crash_point="pre-manifest")):
        with pytest.raises(SimulatedCrash):
            rec.flush()
    # the segment directory was renamed in but never listed: an orphan no
    # reader serves, so the restored delta cannot be double-counted
    orphan = os.path.join(sd, trace_format.segment_name(1))
    assert os.path.isdir(orphan)
    assert len(trace_format.read_manifest(sd)["segments"]) == 1
    assert _funcs(TraceReader(sd, mode="stitched")) == _names(calls[:10])
    _feed(rec, calls[20:], t)
    rec.finalize()  # the retry overwrites the orphan
    for mode in ("stitched", "merged"):
        assert _funcs(TraceReader(sd, mode=mode)) == _names(calls)


# ---------------------------------------------------------------------------
# integrity: checksummed segments catch torn writes and bit rot
# ---------------------------------------------------------------------------


def test_in_flight_torn_write_caught_by_checksum(tmp_path):
    sd = str(tmp_path / "s")
    calls = _gen_calls(random.Random(5), 20, 0, 1)
    rec = Recorder(rank=0, config=RecorderConfig(trace_dir=sd))
    t = _feed(rec, calls[:10])
    rec.flush()
    t = _feed(rec, calls[10:], t)
    with faults.injected(FaultPlan(torn_file="merged_cst.bin")) as plan:
        rec.flush()  # the writer believes the write succeeded
    assert plan.counters["files_torn"] == 1
    manifest = trace_format.read_manifest(sd)
    entry = manifest["segments"][1]
    reason = trace_format.validate_segment(sd, entry)
    assert reason is not None and "checksum" in reason
    # size checks alone cannot see it: the torn file has the right length
    path = os.path.join(sd, entry["name"], "merged_cst.bin")
    assert os.path.getsize(path) == entry["files"]["merged_cst.bin"]
    # stitched: quarantined + reported; tail: falls back to the intact one
    reader = TraceReader(sd, mode="stitched")
    assert [s["segment"] for s in reader.skipped] == [entry["name"]]
    assert reader.degraded
    assert _funcs(reader) == _names(calls[:10])
    tail = TraceReader(sd, mode="tail")
    assert [s["segment"] for s in tail.skipped] == [entry["name"]]
    assert _funcs(tail) == _names(calls[:10])


def test_post_commit_bit_rot_caught_by_checksum(tmp_path):
    sd = str(tmp_path / "s")
    calls = _gen_calls(random.Random(6), 20, 0, 1)
    rec = Recorder(rank=0, config=RecorderConfig(trace_dir=sd))
    t = _feed(rec, calls[:10])
    rec.flush()
    _feed(rec, calls[10:], t)
    rec.flush()
    rec.finalize()
    seg = trace_format.segment_name(0)
    faults.corrupt_file(os.path.join(sd, seg, "unique_cfgs.bin"), seed=9)
    reason = trace_format.validate_segment(
        sd, trace_format.read_manifest(sd)["segments"][0])
    assert reason is not None and "checksum" in reason
    reader = TraceReader(sd, mode="stitched")
    assert [s["segment"] for s in reader.skipped] == [seg]
    assert _funcs(reader) == _names(calls[10:])
    # the merged trace was written from in-memory state before the rot:
    # auto mode still serves the complete history
    assert _funcs(TraceReader(sd, mode="auto")) == _names(calls)


def test_torn_tail_caught_by_size_check(tmp_path):
    sd = str(tmp_path / "s")
    calls = _gen_calls(random.Random(7), 20, 0, 1)
    rec = Recorder(rank=0, config=RecorderConfig(trace_dir=sd))
    t = _feed(rec, calls[:10])
    rec.flush()
    _feed(rec, calls[10:], t)
    rec.flush()
    seg = trace_format.segment_name(1)
    faults.tear_file(os.path.join(sd, seg, "timestamps.bin"))
    reader = TraceReader(sd, mode="stitched")
    assert [s["segment"] for s in reader.skipped] == [seg]
    assert _funcs(reader) == _names(calls[:10])


# ---------------------------------------------------------------------------
# degraded collectives: survivor votes and partial commits
# ---------------------------------------------------------------------------


def test_agree_without_timeout_is_vote_any_with_full_presence():
    def worker(comm, rank):
        return comm.agree(rank == 1)

    for verdict, present in run_thread_world(3, worker):
        assert (verdict, present) == (True, frozenset({0, 1, 2}))


def test_agree_survivor_vote_excludes_unresponsive_subtree():
    faults.install(FaultPlan(dead_ranks=(2,)))

    def worker(comm, rank):
        return comm.agree(rank == 1, timeout=0.5)

    res = run_thread_world(4, worker)
    # rank 2 owns the [2, 4) subtree hop: its silence absorbs rank 3's
    # vote too, but every rank still hears the survivors' verdict
    for verdict, present in res:
        assert (verdict, present) == (True, frozenset({0, 1}))


def test_agree_verdictless_rank_falls_back_to_its_own_flag():
    faults.install(FaultPlan(dead_ranks=(0,)))

    def worker(comm, rank):
        return comm.agree(rank == 1, timeout=0.4)

    res = run_thread_world(2, worker)
    # rank 0 hears everyone (its inbound links are fine) but its verdict
    # fan-out is dropped; rank 1 times out and self-reports
    assert res[0] == (True, frozenset({0, 1}))
    assert res[1] == (True, frozenset({1}))


@pytest.mark.filterwarnings("ignore::RuntimeWarning")
@pytest.mark.parametrize("dead,mask", [(1, [0, 2, 3]), (2, [0, 1])])
def test_degraded_flush_survives_unresponsive_rank(tmp_path, dead, mask):
    """One rank goes mute mid-run: the survivors commit a degraded epoch
    with a ``ranks_present`` mask (never deadlock), the lost ranks retain
    their deltas, and after the rank recovers the next flush covers every
    record exactly once.  ``dead=2`` is the interior-node case: rank 3's
    subtree is absorbed by the silence, so BOTH 2 and 3 retry."""
    sd = str(tmp_path / "s")
    nranks = 4
    first = [_gen_calls(random.Random(40 + r), 8, r, nranks)
             for r in range(nranks)]
    extra = [_gen_calls(random.Random(50 + r), 5, r, nranks)
             for r in range(nranks)]
    faults.install(FaultPlan(dead_ranks=(dead,)))

    def worker(comm, rank):
        rec = Recorder(rank=rank, config=RecorderConfig(
            trace_dir=sd, flush_timeout_s=2.0))
        t = _feed(rec, first[rank])
        rec.flush(comm)
        comm.barrier()
        if rank == 0:
            faults.uninstall()  # the mute rank recovers
        comm.barrier()
        t = _feed(rec, extra[rank], t)
        rec.flush(comm)
        rec.finalize(comm)
        return (rec.epochs_restored, rec.epochs_degraded,
                rec.last_flush_outcome.lost_local)

    res = run_thread_world(nranks, worker)
    lost = sorted(set(range(nranks)) - set(mask))
    for r in range(nranks):
        assert res[r][0] == (1 if r in lost else 0)
    assert res[0][1] == 1  # rank 0 counted one degraded epoch
    assert not any(r[2] for r in res)  # final flush included everyone
    entry0 = trace_format.read_manifest(sd)["segments"][0]
    assert entry0["ranks_present"] == mask
    reader = TraceReader(sd, mode="stitched")
    assert reader.degraded
    assert reader.degraded_epochs == {entry0["name"]: mask}
    assert reader.ranks_partial == lost
    cov = reader.coverage()
    assert cov["complete"] is False and cov["ranks_partial"] == lost
    # exactly-once per rank: lost ranks' first-batch records rode epoch 1
    for r in range(nranks):
        got = [rec.func for rec in reader.iter_records(r)]
        assert got == _names(first[r] + extra[r])
    # the merged trace (written from the cumulative state) agrees, and
    # carries the degraded map in its metadata
    merged = TraceReader(sd, mode="merged")
    assert merged.degraded_epochs == {entry0["name"]: mask}
    for r in range(nranks):
        got = [rec.func for rec in merged.iter_records(r)]
        assert got == _names(first[r] + extra[r])
    with pytest.warns(RuntimeWarning, match="PARTIAL coverage"):
        TraceReader(sd, mode="stitched").view()


def test_degraded_protocol_matches_sync_flush_byte_for_byte(tmp_path):
    """A fault-free degraded flush must commit byte-identical segments to
    the plain barrier-based flush (same tree, same association order) --
    the CRC columns of the manifests are a byte-level witness."""
    def drive(sd, timeout):
        calls = [_gen_calls(random.Random(60 + r), 10, r, 2)
                 for r in range(2)]

        def worker(comm, rank):
            rec = Recorder(rank=rank, config=RecorderConfig(
                trace_dir=sd, flush_timeout_s=timeout))
            t = _feed(rec, calls[rank][:6])
            rec.flush(comm)
            _feed(rec, calls[rank][6:], t)
            rec.flush(comm)
            return rec.finalize(comm)

        run_thread_world(2, worker)

    sd_sync = str(tmp_path / "sync")
    sd_deg = str(tmp_path / "deg")
    drive(sd_sync, None)
    drive(sd_deg, 5.0)
    m_sync = trace_format.read_manifest(sd_sync)
    m_deg = trace_format.read_manifest(sd_deg)
    assert [e["crcs"] for e in m_sync["segments"]] == \
        [e["crcs"] for e in m_deg["segments"]]
    assert "ranks_present" not in m_deg["segments"][0]
    assert m_sync["merged"]["crcs"] == m_deg["merged"]["crcs"]


@pytest.mark.filterwarnings("ignore::RuntimeWarning")
def test_delayed_messages_within_timeout_do_not_degrade(tmp_path):
    sd = str(tmp_path / "s")
    calls = [_gen_calls(random.Random(70 + r), 10, r, 2) for r in range(2)]
    faults.install(FaultPlan(delay_prob=1.0, delay_s=0.05))

    def worker(comm, rank):
        rec = Recorder(rank=rank, config=RecorderConfig(
            trace_dir=sd, flush_timeout_s=5.0))
        t = _feed(rec, calls[rank][:6])
        rec.flush(comm)
        _feed(rec, calls[rank][6:], t)
        rec.flush(comm)
        rec.finalize(comm)
        return rec.epochs_degraded + rec.epochs_restored

    res = run_thread_world(2, worker)
    faults.uninstall()
    assert res == [0, 0]
    reader = TraceReader(sd, mode="stitched")
    assert not reader.degraded
    for r in range(2):
        assert [rec.func for rec in reader.iter_records(r)] == \
            _names(calls[r])


@pytest.mark.filterwarnings("ignore::RuntimeWarning")
def test_stale_stragglers_from_degraded_epoch_are_discarded(tmp_path):
    """Messages that arrive AFTER their collective timed out must not be
    mistaken for the next collective's traffic: the epoch they belonged
    to is committed degraded, the stragglers are discarded by tag, and
    the next flush is clean and complete."""
    sd = str(tmp_path / "s")
    calls = [_gen_calls(random.Random(80 + r), 8, r, 2) for r in range(2)]
    extra = [_gen_calls(random.Random(90 + r), 5, r, 2) for r in range(2)]
    # every message delivered 1s late, but the protocol only waits 0.25s:
    # epoch 0 degrades to rank 0 alone and the late messages become
    # queued stragglers for epoch 1 to step over
    faults.install(FaultPlan(delay_prob=1.0, delay_s=1.0))

    def worker(comm, rank):
        rec = Recorder(rank=rank, config=RecorderConfig(
            trace_dir=sd, flush_timeout_s=0.25))
        t = _feed(rec, calls[rank])
        rec.flush(comm)
        comm.barrier()
        if rank == 0:
            faults.uninstall()
        comm.barrier()
        time.sleep(1.2)  # let the stragglers land in the queues
        t = _feed(rec, extra[rank], t)
        rec.flush(comm)
        rec.finalize(comm)
        return rec.epochs_restored

    res = run_thread_world(2, worker)
    assert res == [0, 1]
    entry0 = trace_format.read_manifest(sd)["segments"][0]
    assert entry0["ranks_present"] == [0]
    reader = TraceReader(sd, mode="stitched")
    assert reader.ranks_partial == [1]
    for r in range(2):
        assert [rec.func for rec in reader.iter_records(r)] == \
            _names(calls[r] + extra[r])


# ---------------------------------------------------------------------------
# crash-resume (tentpole part 1 + satellite d)
# ---------------------------------------------------------------------------


def test_resume_cumulative_state_folds_committed_segments(tmp_path):
    sd = str(tmp_path / "s")
    calls = _gen_calls(random.Random(10), 20, 0, 1)
    rec = Recorder(rank=0, config=RecorderConfig(trace_dir=sd))
    t = _feed(rec, calls[:10])
    rec.flush()
    _feed(rec, calls[10:], t)
    rec.flush()
    cum = streaming.resume_cumulative_state(sd)
    assert cum.n_epochs == 2
    from repro.core.interprocess import serialize_rank_state
    assert serialize_rank_state(cum.to_rank_state()) == \
        serialize_rank_state(rec._cum.to_rank_state())
    # any unusable segment is a hard error: a merged trace must cover
    # every epoch exactly, so resume refuses rather than under-covers
    faults.corrupt_file(
        os.path.join(sd, trace_format.segment_name(0), "state.bin"), seed=3)
    with pytest.raises(TraceFormatError, match="cannot resume"):
        streaming.resume_cumulative_state(sd)


def test_resumed_run_merged_identical_to_uninterrupted(tmp_path):
    """Run A is killed after 2 committed epochs (no finalize); run B
    reuses the directory, records the remaining calls and finalizes.
    The merged trace must be value-identical to one uninterrupted run
    flushing at the same boundaries."""
    calls = _gen_calls(random.Random(11), 28, 0, 1)

    sd_clean = str(tmp_path / "clean")
    rec = Recorder(rank=0, config=RecorderConfig(trace_dir=sd_clean))
    t = _feed(rec, calls[:10])
    rec.flush()
    t = _feed(rec, calls[10:20], t)
    rec.flush()
    t = _feed(rec, calls[20:], t)
    rec.flush()
    rec.finalize()

    sd_res = str(tmp_path / "resumed")
    rec_a = Recorder(rank=0, config=RecorderConfig(trace_dir=sd_res))
    t = _feed(rec_a, calls[:10])
    rec_a.flush()
    t = _feed(rec_a, calls[10:20], t)
    rec_a.flush()
    del rec_a  # killed: no finalize, no merged trace
    assert "merged" not in trace_format.read_manifest(sd_res)
    rec_b = Recorder(rank=0, config=RecorderConfig(trace_dir=sd_res))
    t = _feed(rec_b, calls[20:], t)
    rec_b.flush()
    assert rec_b.epochs_resumed == 2
    rec_b.finalize()

    assert "merged" in trace_format.read_manifest(sd_res)
    ra = TraceReader(sd_clean, mode="merged")
    rb = TraceReader(sd_res, mode="merged")
    rows_a = [(r.func, r.args, r.ret, r.t_entry, r.t_exit)
              for _, r in ra.all_records()]
    rows_b = [(r.func, r.args, r.ret, r.t_entry, r.t_exit)
              for _, r in rb.all_records()]
    assert rows_a == rows_b


# ---------------------------------------------------------------------------
# the umbrella invariant: readable or reported -- never silently wrong
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("plan_kw", [
    dict(fail_write_at=1),
    dict(fail_write_at=4),
    dict(crash_point="pre-rename"),
    dict(crash_point="pre-manifest"),
    dict(torn_file="merged_cst.bin"),
    dict(torn_file="timestamps.bin"),
    dict(torn_file="state.bin"),
])
def test_surviving_trace_readable_or_reported(tmp_path, plan_kw):
    sd = str(tmp_path / "s")
    calls = _gen_calls(random.Random(77), 14, 0, 1)
    rec = Recorder(rank=0, config=RecorderConfig(trace_dir=sd))
    t = _feed(rec, calls[:8])
    rec.flush()
    _feed(rec, calls[8:], t)
    with faults.injected(FaultPlan(seed=5, **plan_kw)):
        try:
            rec.flush()
        except (OSError, SimulatedCrash):
            pass
    report = faults.check_trace_invariants(sd)
    assert report["readable"]
    committed = len(trace_format.read_manifest(sd)["segments"])
    served = committed - len(report["skipped"])
    # every served segment decodes to exactly its 8-record epoch: damage
    # either never committed, or is quarantined and listed in `skipped`
    assert report["n_records"] == 8 * served >= 8
