"""Sequitur (exponent-carrying) property + unit tests."""

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fallback: seeded-random example generation
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.sequitur import (Sequitur, expand_grammar, parse_grammar,
                                 remap_grammar, serialize_grammar)


def build(stream):
    g = Sequitur()
    for t in stream:
        g.push(t)
    return g


@settings(max_examples=200, deadline=None)
@given(st.lists(st.integers(0, 7), max_size=200))
def test_roundtrip_identity(stream):
    g = build(stream)
    assert g.expand() == stream


@settings(max_examples=100, deadline=None)
@given(st.lists(st.integers(0, 3), max_size=120))
def test_serialized_expansion_matches(stream):
    g = build(stream)
    rules = parse_grammar(g.serialize())
    assert list(expand_grammar(rules)) == stream


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 4), st.integers(1, 9)),
                max_size=60))
def test_push_with_counts(runs):
    g = Sequitur()
    want = []
    for t, n in runs:
        g.push(t, n)
        want.extend([t] * n)
    assert g.expand() == want


def test_nested_loop_constant_grammar():
    """Paper Listing 2: m x (n writes + fsync) -> grammar size independent
    of m and n (exponents absorb the counts)."""
    def size(m, n):
        g = Sequitur()
        for _ in range(m):
            for _ in range(n):
                g.push(0)
            g.push(1)
        return len(g.serialize())

    s = size(4, 6)
    assert size(40, 6) <= s + 2       # exponent varint may add a byte
    assert size(40, 600) <= s + 4
    assert size(400, 600) <= s + 4


def test_digram_uniqueness_and_utility():
    import itertools
    for stream in itertools.product(range(3), repeat=7):
        g = build(list(stream))
        assert g.expand() == list(stream), stream
        # rule utility: every non-start rule used >= 2 times (or exp >= 2)
        rules = g.rules()
        for r in rules[1:]:
            uses = sum(s.exp for s in _all_refs(g, r))
            assert uses >= 2, (stream, repr(r))


def _all_refs(g, rule):
    out = []
    for r in g.rules():
        for s in r.body():
            if s.rule is rule:
                out.append(s)
    return out


def test_remap_grammar():
    g = build([0, 1, 0, 1, 2])
    remapped = remap_grammar(g.serialize(), {0: 5, 1: 7, 2: 9})
    assert list(expand_grammar(parse_grammar(remapped))) == [5, 7, 5, 7, 9]


def test_serialize_grammar_roundtrip():
    g = build([0, 1, 2, 0, 1, 2, 0, 1, 2])
    rules = parse_grammar(g.serialize())
    assert parse_grammar(serialize_grammar(rules)) == rules
