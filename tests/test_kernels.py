"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.timestamps import delta_zigzag_encode
from repro.kernels.delta_encode.ops import delta_zigzag
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref

rng = np.random.RandomState(7)

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("B,Sq,Skv,H,KVH,D", [
    (1, 32, 32, 2, 2, 16),
    (2, 64, 64, 4, 2, 32),
    (1, 128, 128, 8, 1, 64),
    (2, 96, 96, 6, 3, 32),      # non-power-of-two seq
])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 24)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, Sq, Skv, H, KVH, D, causal, window, dtype):
    q = jnp.asarray(rng.randn(B, Sq, H, D), dtype)
    k = jnp.asarray(rng.randn(B, Skv, KVH, D), dtype)
    v = jnp.asarray(rng.randn(B, Skv, KVH, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_block=32, kv_block=32, interpret=True)
    ref = attention_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                        jnp.swapaxes(v, 1, 2), causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(jnp.swapaxes(ref, 1, 2), np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("B,nc,Q,nh,hd,ns", [
    (1, 2, 8, 2, 8, 4),
    (2, 4, 16, 3, 8, 4),
    (1, 3, 32, 4, 16, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(B, nc, Q, nh, hd, ns, dtype):
    x = jnp.asarray(rng.randn(B, nc, Q, nh, hd), dtype)
    b = jnp.asarray(rng.randn(B, nc, Q, ns), dtype)
    c = jnp.asarray(rng.randn(B, nc, Q, ns), dtype)
    dt = jnp.asarray(rng.rand(B, nc, Q, nh) * 0.1, jnp.float32)
    da = jnp.asarray(-rng.rand(B, nc, Q, nh) * 0.5, jnp.float32)
    out = ssd_scan(x, b, c, dt, da, interpret=True)
    ref = ssd_scan_ref(x, b, c, dt, da)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype] * 5, rtol=TOL[dtype] * 5)


@pytest.mark.parametrize("shape", [(8, 64), (3, 5, 128), (1, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x = jnp.asarray(rng.randn(*shape), dtype)
    w = jnp.asarray(rng.rand(shape[-1]), jnp.float32)
    out = rmsnorm(x, w, block_rows=4, interpret=True)
    ref = rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("n,block", [(64, 16), (1000, 100), (4096, 512)])
def test_delta_zigzag_sweep(n, block):
    t = np.cumsum(rng.randint(0, 100000, size=n)).astype(np.uint32)
    out = np.asarray(delta_zigzag(jnp.asarray(t), block=block,
                                  interpret=True))
    ref = delta_zigzag_encode(t.reshape(-1, 2)) if n % 2 == 0 else None
    if ref is not None:
        np.testing.assert_array_equal(out, ref)
    # decode roundtrip always holds
    dec = np.cumsum((out.astype(np.int64) >> 1) ^ -(out.astype(np.int64) & 1))
    np.testing.assert_array_equal(dec.astype(np.uint32), t)


def test_model_uses_pallas_attention_path():
    """attn_impl='pallas_interpret' must agree with the XLA path."""
    from repro.configs import get_smoke_config
    from repro.models import get_model
    cfg = get_smoke_config("qwen1.5-0.5b")
    m_x = get_model(cfg)
    m_p = get_model(cfg.replace(attn_impl="pallas_interpret"))
    params = m_x.init_params(jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                             cfg.vocab_size)
    batch = {"tokens": tok, "labels": tok}
    l1, _ = m_x.loss_fn(params, batch)
    l2, _ = m_p.loss_fn(params, batch)
    assert abs(float(l1) - float(l2)) < 1e-4
